//! Planner anatomy on multi-head attention: shows, vertex by vertex, what
//! EinDecomp chooses versus the Megatron / sequence / attention-head
//! heuristics on the paper's own Section-3 example — and why ("surprising
//! finding": sequence decomposition is strong for prefill).
//!
//! ```sh
//! cargo run --release --example attention_planner
//! ```

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::einsum::graph::EinGraph;
use eindecomp::einsum::macros::multihead_attention;
use eindecomp::sim::{Cluster, NetworkProfile};

fn main() -> eindecomp::Result<()> {
    // Paper Section 3 shapes: s=seq, a=model, h=heads, d=head dim.
    let (s, a, h, d) = (512, 256, 8, 32);
    let mut g = EinGraph::new();
    let q = g.input("Q", vec![s, a]);
    let k = g.input("K", vec![s, a]);
    let v = g.input("V", vec![s, a]);
    let wq = g.input("WQ", vec![a, h, d]);
    let wk = g.input("WK", vec![a, h, d]);
    let wv = g.input("WV", vec![a, h, d]);
    let wo = g.input("WO", vec![a, h, d]);
    multihead_attention(&mut g, "mha", q, k, v, wq, wk, wv, wo, false)?;
    println!(
        "multi-head attention EinGraph: {} vertices (s={s} a={a} h={h} d={d})",
        g.len()
    );

    let p = 8;
    let roles = LabelRoles::by_convention();
    let strategies = [
        Strategy::EinDecomp,
        Strategy::Megatron,
        Strategy::Sequence,
        Strategy::AttentionHead,
    ];
    let cluster = Cluster::new(p, NetworkProfile::gpu_server_v100());

    // header
    println!("\npredicted communication + modeled time (V100-class profile):");
    println!("{:<12} {:>16} {:>12} {:>10}", "strategy", "pred floats", "moved MiB", "sim ms");
    let mut plans = Vec::new();
    for strat in &strategies {
        let plan = assign(&g, strat, p, &roles)?;
        let rep = cluster.dry_run(&g, &plan)?;
        println!(
            "{:<12} {:>16.0} {:>12.2} {:>10.3}",
            strat.name(),
            plan.predicted_cost,
            rep.bytes_moved as f64 / (1 << 20) as f64,
            rep.sim_makespan_s * 1e3
        );
        plans.push((strat.name(), plan));
    }

    // per-vertex comparison for the interesting vertices
    println!("\nper-vertex partitioning vectors (d over unique labels):");
    print!("{:<16}", "vertex");
    for (name, _) in &plans {
        print!(" {name:>14}");
    }
    println!();
    for vert in g.vertices() {
        if plans[0].1.parts.contains_key(&vert.id) {
            let uniq = vert.op.unique_labels();
            print!("{:<16}", vert.name);
            for (_, plan) in &plans {
                print!(" {:>14}", format!("{:?}", plan.parts[&vert.id]));
            }
            println!("   labels {uniq:?}");
        }
    }
    Ok(())
}

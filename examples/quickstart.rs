//! Quickstart: declare a computation with the lazy expression frontend,
//! compile it **once** (EinDecomp plan → task graph → placement), run it
//! **many** times on the simulated cluster, and verify the numbers — the
//! whole compile-once / run-many pipeline in ~60 lines of user code.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use eindecomp::prelude::*;
use eindecomp::runtime::native::eval_graph;
use std::collections::HashMap;

fn main() -> eindecomp::Result<()> {
    // 1. A session owns the kernel engine, the simulated 8-worker
    //    cluster, and the plan cache. Backend::Auto uses AOT-compiled
    //    PJRT kernels (make artifacts) where available, native elsewhere.
    let session = Session::new(DriverConfig {
        workers: 8,
        p: 8,
        backend: Backend::Auto,
        ..Default::default()
    })?;

    // 2. Declare the computation lazily — a matrix chain with a relu and
    //    a row reduction, chained off the session's input expressions.
    let a = session.input("A", &[256, 256]);
    let b = session.input("B", &[256, 256]);
    let c = session.input("C", &[256, 256]);
    let s = a
        .einsum("ij,jk->ik", &b)?
        .einsum("ik,km->im", &c)?
        .map(UnaryOp::Relu)?
        .reduce("im->i", AggOp::Sum)?;

    // 3. Compile once: EinDecomp picks a partitioning vector per vertex
    //    minimizing the communication upper bound at p=8 kernel calls,
    //    lowering and placement are frozen into the Executable.
    let exe = session.compile_expr(&s)?;
    let g = exe.graph();
    println!("EinGraph: {} vertices, {:.2} Mflop", g.len(), g.total_flops() / 1e6);
    println!("\nEinDecomp plan (d over each vertex's unique labels):");
    for vert in g.vertices() {
        if let Some(d) = exe.plan().parts.get(&vert.id) {
            println!("  {:<20} d = {:?}", vert.name, d);
        }
    }
    let (plan_s, lower_s) = exe.compile_times();
    println!(
        "predicted communication bound: {:.0} floats (planned in {:.2} ms, lowered in {:.2} ms)",
        exe.plan().predicted_cost,
        plan_s * 1e3,
        lower_s * 1e3
    );

    // 4. Run many: three "requests" — zero planner and zero lowering
    //    work per call, buffer pools warm across calls.
    let mut inputs = HashMap::new();
    for (i, v) in [&a, &b, &c].into_iter().enumerate() {
        inputs.insert(v.id(), Tensor::random(&[256, 256], 42 + i as u64));
    }
    let mut last = None;
    for req in 0..3 {
        let (outs, report) = exe.run(&inputs)?;
        println!("\nrequest {req}: {}", report.exec.summary());
        last = Some(outs);
    }
    let outs = last.unwrap();
    let (pjrt_hits, native_hits) = session.engine().hit_counts();
    println!("kernel dispatch: {pjrt_hits} PJRT (AOT XLA), {native_hits} native");

    // 5. A canonically-equivalent program — different tensor and label
    //    names, same shapes — is a plan-cache hit: no second compile.
    let x = session.input("X", &[256, 256]);
    let y = session.input("Y", &[256, 256]);
    let z = session.input("Z", &[256, 256]);
    let s2 = x
        .einsum("pq,qr->pr", &y)?
        .einsum("pr,rt->pt", &z)?
        .map(UnaryOp::Relu)?
        .reduce("pt->p", AggOp::Sum)?;
    let exe2 = session.compile_expr(&s2)?;
    println!(
        "\nrecompile of a renamed twin: provenance = {}, cache {:?}",
        exe2.provenance(),
        session.stats()
    );
    assert_eq!(exe2.provenance(), PlanProvenance::CacheHit);

    // 6. Verify against direct dense evaluation of the same EinGraph.
    let want = eval_graph(g, &inputs)?;
    let got = &outs[&s.id()];
    println!(
        "\nverification: max |dense - decomposed| = {:.2e}",
        got.max_abs_diff(&want[&s.id()])?
    );
    assert!(got.allclose(&want[&s.id()], 1e-3, 1e-3));
    println!("quickstart OK");
    Ok(())
}

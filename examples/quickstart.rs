//! Quickstart: declare a computation in EinSum, let EinDecomp choose the
//! decomposition, execute it on the simulated cluster, and verify the
//! numbers — the whole pipeline in ~60 lines of user code.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use eindecomp::decomp::{plan_graph, PlannerConfig};
use eindecomp::einsum::parser::parse_program;
use eindecomp::runtime::{Backend, DispatchEngine, KernelEngine};
use eindecomp::sim::{Cluster, NetworkProfile};
use eindecomp::tensor::Tensor;
use std::collections::HashMap;

fn main() -> eindecomp::Result<()> {
    // 1. Declare the computation — a matrix chain with a reduction, in
    //    the textual EinSum program format.
    let g = parse_program(
        r#"
        input A [256, 256]
        input B [256, 256]
        input C [256, 256]
        AB   = einsum ij,jk->ik A B
        ABC  = einsum ik,km->im AB C
        R    = map relu ABC
        S    = reduce sum im->i R
        "#,
    )?;
    println!("EinGraph: {} vertices, {:.2} Mflop", g.len(), g.total_flops() / 1e6);

    // 2. Plan: EinDecomp picks a partitioning vector per vertex that
    //    minimizes the communication upper bound at p=8 kernel calls.
    let plan = plan_graph(&g, &PlannerConfig { p: 8, ..Default::default() })?;
    println!("\nEinDecomp plan (d over each vertex's unique labels):");
    for vert in g.vertices() {
        if let Some(d) = plan.parts.get(&vert.id) {
            println!("  {:<8} d = {:?}", vert.name, d);
        }
    }
    println!("predicted communication bound: {:.0} floats", plan.predicted_cost);

    // 3. Execute on a simulated 8-worker cluster. Backend::Auto uses the
    //    AOT-compiled PJRT kernels (make artifacts) where tile shapes
    //    match, falling back to native kernels elsewhere.
    let engine = DispatchEngine::new(Backend::Auto, "artifacts")
        .unwrap_or_else(|_| DispatchEngine::native());
    let cluster = Cluster::new(8, NetworkProfile::cpu_cluster());
    let mut inputs = HashMap::new();
    for (i, v) in g.inputs().into_iter().enumerate() {
        inputs.insert(v, Tensor::random(&g.vertex(v).bound, 42 + i as u64));
    }
    let (outs, report) = cluster.execute(&g, &plan, &engine, &inputs)?;
    println!("\nexecution: {}", report.summary());
    let (pjrt_hits, native_hits) = engine.hit_counts();
    println!("kernel dispatch: {pjrt_hits} PJRT (AOT XLA), {native_hits} native");

    // 4. Verify against direct dense evaluation.
    let s = g.by_name("S").unwrap();
    let native = eindecomp::runtime::NativeEngine::new();
    let ab = native.eval(&g.vertex(g.by_name("AB").unwrap()).op, &[
        &inputs[&g.by_name("A").unwrap()],
        &inputs[&g.by_name("B").unwrap()],
    ])?;
    let abc = native.eval(&g.vertex(g.by_name("ABC").unwrap()).op, &[
        &ab,
        &inputs[&g.by_name("C").unwrap()],
    ])?;
    let r = native.eval(&g.vertex(g.by_name("R").unwrap()).op, &[&abc])?;
    let want = native.eval(&g.vertex(s).op, &[&r])?;
    let got = &outs[&s];
    println!(
        "\nverification: max |dense - decomposed| = {:.2e}",
        got.max_abs_diff(&want)?
    );
    assert!(got.allclose(&want, 1e-3, 1e-3));
    println!("quickstart OK");
    Ok(())
}

//! END-TO-END VALIDATION DRIVER (see DESIGN.md §5): train a feed-forward
//! classifier through the *full* stack for a few hundred steps on
//! synthetic AmazonCat-like data, logging the loss curve.
//!
//! Every step goes: EinGraph (fwd+bwd as EinSums) -> EinDecomp plan ->
//! TaskGraph -> simulated p-worker cluster -> kernels (AOT PJRT where the
//! tile shapes match, native otherwise). Gradients come back as graph
//! outputs; SGD updates happen host-side, exactly like a parameter-server
//! step in the paper's Experiment 2.
//!
//! ```sh
//! cargo run --release --example train_ffnn [steps] [features]
//! ```

use eindecomp::coordinator::driver::{Driver, DriverConfig};
use eindecomp::data::classifier_batch;
use eindecomp::decomp::baselines::Strategy;
use eindecomp::models::ffnn::{ffnn_step, step_inputs, FfnnState};
use eindecomp::runtime::Backend;
use eindecomp::sim::NetworkProfile;

fn main() -> eindecomp::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let features: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);
    let (batch, hidden, classes) = (128, 256, 64);
    let p = 8;

    println!(
        "FFNN training: batch={batch} features={features} hidden={hidden} classes={classes} \
         ({:.1}M params), {steps} steps, p={p} workers"
    , (features * hidden + hidden * classes) as f64 / 1e6);

    let step = ffnn_step(batch, features, hidden, classes)?;
    println!(
        "training-step EinGraph: {} vertices, {:.1} Mflop/step",
        step.graph.len(),
        step.graph.total_flops() / 1e6
    );

    let driver = Driver::new(DriverConfig {
        workers: p,
        p,
        strategy: Strategy::EinDecomp,
        backend: Backend::Auto,
        network: NetworkProfile::cpu_cluster(),
        ..Default::default()
    })?;
    // plan once; the step graph is static
    let (plan, plan_s) = driver.plan(&step.graph)?;
    println!(
        "plan: strategy={} cost={:.0} floats ({:.1} ms to plan)\n",
        plan.strategy,
        plan.predicted_cost,
        plan_s * 1e3
    );

    let mut state = FfnnState::init(features, hidden, classes, 1234);
    let lr = 0.3f32;
    let t0 = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    let mut moved_total = 0u64;
    for s in 0..steps {
        let (x, t) = classifier_batch(batch, features, classes, 0.05, 5000 + s as u64);
        let inputs = step_inputs(&step, &state, x, t);
        let (outs, rep) = driver.run_with_plan(&step.graph, &plan, &inputs)?;
        let loss = outs[&step.loss].at(&[]);
        state.apply(&outs[&step.dw1], &outs[&step.dw2], lr)?;
        moved_total += rep.exec.bytes_moved;
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
        if s % 25 == 0 || s + 1 == steps {
            println!(
                "step {s:>4}  loss {loss:>10.6}  wall {:>6.1} ms  moved {:>7.2} MiB",
                rep.exec.wall_s * 1e3,
                rep.exec.bytes_moved as f64 / (1 << 20) as f64
            );
        }
    }
    let total_s = t0.elapsed().as_secs_f64();
    let first = first_loss.unwrap();
    println!(
        "\ntrained {steps} steps in {total_s:.1}s ({:.1} steps/s); loss {first:.4} -> {last_loss:.4} ({:.1}x reduction)",
        steps as f64 / total_s,
        first / last_loss.max(1e-9)
    );
    println!(
        "total data moved across workers: {:.1} MiB",
        moved_total as f64 / (1 << 20) as f64
    );
    let (pjrt_hits, native_hits) = driver.engine().hit_counts();
    println!("kernel dispatch: {pjrt_hits} PJRT / {native_hits} native");
    assert!(
        last_loss < first * 0.7,
        "loss did not fall enough: {first} -> {last_loss}"
    );
    println!("train_ffnn OK");
    Ok(())
}

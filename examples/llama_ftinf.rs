//! Serving-style driver (paper Experiments 3–4): first-token inference on
//! a LLaMA-shaped decoder stack under each decomposition strategy.
//!
//! Part 1 executes a container-scale model for real (batched requests,
//! per-request latency and throughput, results cross-checked between
//! strategies). Part 2 dry-runs the *actual* LLaMA-7B shapes on the
//! modeled V100 server, reproducing Experiment 3's comparison at paper
//! scale.
//!
//! ```sh
//! cargo run --release --example llama_ftinf
//! ```

use eindecomp::coordinator::driver::{Driver, DriverConfig};
use eindecomp::decomp::baselines::Strategy;
use eindecomp::models::llama::{llama_graph, llama_inputs, LlamaConfig};
use eindecomp::runtime::{Backend, MemoryBudget};
use eindecomp::sim::NetworkProfile;

fn main() -> eindecomp::Result<()> {
    let p = 8;
    let strategies = [
        Strategy::EinDecomp,
        Strategy::Megatron,
        Strategy::Sequence,
        Strategy::AttentionHead,
    ];

    // ---------- Part 1: real execution at container scale ----------
    let cfg = LlamaConfig {
        layers: 4,
        batch: 4,
        seq: 64,
        model_dim: 128,
        heads: 4,
        head_dim: 32,
        ffn_dim: 256,
    };
    let model = llama_graph(&cfg)?;
    println!(
        "LLaMA-style stack (real run): {} layers, {:.2}M params, batch={} seq={}, {} EinGraph vertices",
        cfg.layers,
        cfg.params() as f64 / 1e6,
        cfg.batch,
        cfg.seq,
        model.graph.len()
    );
    let inputs = llama_inputs(&model, 99);
    println!(
        "\n{:<12} {:>10} {:>12} {:>12} {:>14}",
        "strategy", "wall ms", "ms/request", "req/s", "moved MiB"
    );
    let mut reference: Option<eindecomp::tensor::Tensor> = None;
    for strat in &strategies {
        let driver = Driver::new(DriverConfig {
            workers: p,
            p,
            strategy: strat.clone(),
            backend: Backend::Auto,
            network: NetworkProfile::gpu_server_v100(),
            ..Default::default()
        })?;
        let (outs, rep) = driver.run(&model.graph, &inputs)?;
        let out = &outs[&model.out];
        match &reference {
            None => reference = Some(out.clone()),
            Some(r) => assert!(
                out.allclose(r, 1e-2, 1e-2),
                "{} diverged from reference decomposition",
                strat.name()
            ),
        }
        let per_req = rep.exec.wall_s / cfg.batch as f64;
        println!(
            "{:<12} {:>10.1} {:>12.2} {:>12.1} {:>14.2}",
            strat.name(),
            rep.exec.wall_s * 1e3,
            per_req * 1e3,
            1.0 / per_req,
            rep.exec.bytes_moved as f64 / (1 << 20) as f64
        );
    }
    println!("(all strategies produced numerically identical first-token activations)");

    // ---------- Part 1b: out-of-core execution under a memory budget ----------
    // Rerun the EinDecomp arm with a per-worker tile budget that the
    // weights alone overflow — the `--mem-budget-mb` regime. Cold tiles
    // spill to disk and fault back on demand (Experiment 4's offload
    // setting, executed for real rather than modeled), and the first-token
    // activations must still match the unbudgeted run bit for bit.
    let mk_cfg = |budget: Option<MemoryBudget>| DriverConfig {
        workers: p,
        p,
        strategy: Strategy::EinDecomp,
        backend: Backend::Auto,
        network: NetworkProfile::gpu_server_v100(),
        mem_budget: budget,
        ..Default::default()
    };
    let (full_outs, full_rep) = Driver::new(mk_cfg(None))?.run(&model.graph, &inputs)?;
    let peak = full_rep.exec.peak_resident_bytes.iter().copied().max().unwrap_or(0);
    let weight_bytes = cfg.params() as u64 * 4;
    let budget = (peak / 2).min(3 * weight_bytes / 4).max(1);
    assert!(weight_bytes > budget, "weights must overflow the per-worker budget");
    let (oo_outs, oo_rep) =
        Driver::new(mk_cfg(Some(MemoryBudget::per_worker_bytes(budget))))?
            .run(&model.graph, &inputs)?;
    let (got, want) = (&oo_outs[&model.out], &full_outs[&model.out]);
    assert_eq!(got.shape(), want.shape());
    assert!(
        got.data().iter().zip(want.data()).all(|(a, b)| a.to_bits() == b.to_bits()),
        "out-of-core run must be bitwise-identical to the unbudgeted run"
    );
    let oo = &oo_rep.exec;
    let oo_peak = oo.peak_resident_bytes.iter().copied().max().unwrap_or(0);
    assert!(oo.spill_bytes > 0, "an over-budget run must spill");
    assert!(oo_peak <= budget, "peak resident {oo_peak} B exceeds budget {budget} B");
    println!(
        "\nout-of-core: budget {:.2} MiB/worker (weights alone are {:.2} MiB) -> \
         spilled {:.2} MiB, {} faults, stall {:.1} ms, peak resident {:.2} MiB; \
         outputs bitwise-identical to the unbudgeted run",
        budget as f64 / (1 << 20) as f64,
        weight_bytes as f64 / (1 << 20) as f64,
        oo.spill_bytes as f64 / (1 << 20) as f64,
        oo.spill_faults,
        oo.spill_stall_s * 1e3,
        oo_peak as f64 / (1 << 20) as f64,
    );

    // ---------- Part 2: paper-scale dry run (LLaMA-7B, V100 x8) ----------
    println!("\nLLaMA-7B shapes, batch=8 seq=1024, modeled V100x8 (Experiment 3, middle panel):");
    let cfg7b = LlamaConfig::llama7b(8, 1024);
    // one representative layer keeps planning fast; costs scale linearly
    // in depth (every layer is identical)
    let one = LlamaConfig { layers: 1, ..cfg7b.clone() };
    let model7b = llama_graph(&one)?;
    println!(
        "{:<12} {:>16} {:>14} {:>12}",
        "strategy", "pred floats/layer", "moved GiB(32L)", "sim ms(32L)"
    );
    for strat in &strategies {
        let driver = Driver::new(DriverConfig {
            workers: p,
            p,
            strategy: strat.clone(),
            backend: Backend::Native,
            network: NetworkProfile::gpu_server_v100(),
            ..Default::default()
        })?;
        let rep = driver.dry_run(&model7b.graph)?;
        println!(
            "{:<12} {:>16.2e} {:>14.2} {:>12.1}",
            strat.name(),
            rep.plan_cost,
            rep.exec.bytes_moved as f64 * 32.0 / (1 << 30) as f64,
            rep.exec.sim_makespan_s * 32.0 * 1e3
        );
    }
    println!("\nllama_ftinf OK");
    Ok(())
}

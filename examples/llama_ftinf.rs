//! Serving-style driver (paper Experiments 3–4): first-token inference on
//! a LLaMA-shaped decoder stack under each decomposition strategy.
//!
//! Part 1 executes a container-scale model for real (batched requests,
//! per-request latency and throughput, results cross-checked between
//! strategies). Part 2 dry-runs the *actual* LLaMA-7B shapes on the
//! modeled V100 server, reproducing Experiment 3's comparison at paper
//! scale.
//!
//! ```sh
//! cargo run --release --example llama_ftinf
//! ```

use eindecomp::coordinator::driver::{Driver, DriverConfig};
use eindecomp::decomp::baselines::Strategy;
use eindecomp::models::llama::{llama_graph, llama_inputs, LlamaConfig};
use eindecomp::runtime::Backend;
use eindecomp::sim::NetworkProfile;

fn main() -> eindecomp::Result<()> {
    let p = 8;
    let strategies = [
        Strategy::EinDecomp,
        Strategy::Megatron,
        Strategy::Sequence,
        Strategy::AttentionHead,
    ];

    // ---------- Part 1: real execution at container scale ----------
    let cfg = LlamaConfig {
        layers: 4,
        batch: 4,
        seq: 64,
        model_dim: 128,
        heads: 4,
        head_dim: 32,
        ffn_dim: 256,
    };
    let model = llama_graph(&cfg)?;
    println!(
        "LLaMA-style stack (real run): {} layers, {:.2}M params, batch={} seq={}, {} EinGraph vertices",
        cfg.layers,
        cfg.params() as f64 / 1e6,
        cfg.batch,
        cfg.seq,
        model.graph.len()
    );
    let inputs = llama_inputs(&model, 99);
    println!(
        "\n{:<12} {:>10} {:>12} {:>12} {:>14}",
        "strategy", "wall ms", "ms/request", "req/s", "moved MiB"
    );
    let mut reference: Option<eindecomp::tensor::Tensor> = None;
    for strat in &strategies {
        let driver = Driver::new(DriverConfig {
            workers: p,
            p,
            strategy: strat.clone(),
            backend: Backend::Auto,
            network: NetworkProfile::gpu_server_v100(),
            ..Default::default()
        })?;
        let (outs, rep) = driver.run(&model.graph, &inputs)?;
        let out = &outs[&model.out];
        match &reference {
            None => reference = Some(out.clone()),
            Some(r) => assert!(
                out.allclose(r, 1e-2, 1e-2),
                "{} diverged from reference decomposition",
                strat.name()
            ),
        }
        let per_req = rep.exec.wall_s / cfg.batch as f64;
        println!(
            "{:<12} {:>10.1} {:>12.2} {:>12.1} {:>14.2}",
            strat.name(),
            rep.exec.wall_s * 1e3,
            per_req * 1e3,
            1.0 / per_req,
            rep.exec.bytes_moved as f64 / (1 << 20) as f64
        );
    }
    println!("(all strategies produced numerically identical first-token activations)");

    // ---------- Part 2: paper-scale dry run (LLaMA-7B, V100 x8) ----------
    println!("\nLLaMA-7B shapes, batch=8 seq=1024, modeled V100x8 (Experiment 3, middle panel):");
    let cfg7b = LlamaConfig::llama7b(8, 1024);
    // one representative layer keeps planning fast; costs scale linearly
    // in depth (every layer is identical)
    let one = LlamaConfig { layers: 1, ..cfg7b.clone() };
    let model7b = llama_graph(&one)?;
    println!(
        "{:<12} {:>16} {:>14} {:>12}",
        "strategy", "pred floats/layer", "moved GiB(32L)", "sim ms(32L)"
    );
    for strat in &strategies {
        let driver = Driver::new(DriverConfig {
            workers: p,
            p,
            strategy: strat.clone(),
            backend: Backend::Native,
            network: NetworkProfile::gpu_server_v100(),
            ..Default::default()
        })?;
        let rep = driver.dry_run(&model7b.graph)?;
        println!(
            "{:<12} {:>16.2e} {:>14.2} {:>12.1}",
            strat.name(),
            rep.plan_cost,
            rep.exec.bytes_moved as f64 * 32.0 / (1 << 30) as f64,
            rep.exec.sim_makespan_s * 32.0 * 1e3
        );
    }
    println!("\nllama_ftinf OK");
    Ok(())
}

//! Experiment-1-style demo: the matrix chain `(A x B) + (C x (D x E))`
//! under every decomposition strategy, uniform and skewed, at a runnable
//! scale — built with the lazy expression frontend, compiled once per
//! strategy through a `Session`, and executed for real (wall-clock plus
//! the modeled cluster timeline). Ends with a compile-once / run-many
//! serving loop showing the amortized throughput the plan cache buys.
//! The full sweep that regenerates Figs. 7–8 lives in `cargo bench`
//! (fig7/fig8).
//!
//! ```sh
//! cargo run --release --example matrix_chain [scale]
//! ```

use eindecomp::prelude::*;
use eindecomp::runtime::native::eval_graph;
use std::collections::HashMap;

/// Build the chain lazily; returns (graph, input ids, output id).
fn build_chain(
    session: &Session,
    scale: usize,
    skewed: bool,
) -> eindecomp::Result<(EinGraph, Vec<VertexId>, VertexId)> {
    let t = (scale / 10).max(1);
    let (da, db, dc, dd, de) = if skewed {
        ([scale, t], [t, scale], [scale, t], [t, 10 * scale], [10 * scale, scale])
    } else {
        ([scale; 2], [scale; 2], [scale; 2], [scale; 2], [scale; 2])
    };
    let a = session.input("A", &da);
    let b = session.input("B", &db);
    let c = session.input("C", &dc);
    let d = session.input("D", &dd);
    let e = session.input("E", &de);
    let ab = a.einsum("ij,jk->ik", &b)?;
    let de = d.einsum("jm,mk->jk", &e)?;
    let cde = c.einsum("ij,jk->ik", &de)?;
    let z = ab.ew(JoinOp::Add, &cde)?;
    let ids = vec![a.id(), b.id(), c.id(), d.id(), e.id()];
    Ok((z.graph(), ids, z.id()))
}

fn main() -> eindecomp::Result<()> {
    let scale: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(320);
    let p = 8;
    for skewed in [false, true] {
        // one throwaway session stages the lazy program; the per-strategy
        // sessions below compile the resulting EinGraph
        let builder = Session::new(DriverConfig::default())?;
        let (graph, input_ids, z) = build_chain(&builder, scale, skewed)?;
        let mut inputs = HashMap::new();
        for (i, &v) in input_ids.iter().enumerate() {
            inputs.insert(v, Tensor::random(&graph.vertex(v).bound, 7 + i as u64));
        }
        let want = eval_graph(&graph, &inputs)?;
        println!(
            "\n=== chain s={scale} {} | p={p} ===",
            if skewed { "skewed (paper variant 2)" } else { "uniform" }
        );
        println!(
            "{:<14} {:>14} {:>12} {:>12} {:>10}",
            "strategy", "pred floats", "moved MiB", "sim ms", "wall ms"
        );
        for strategy in [
            Strategy::EinDecomp,
            Strategy::Greedy,
            Strategy::Sqrt,
            Strategy::DaskLike { chunk: scale / 4 },
        ] {
            let session = Session::new(DriverConfig {
                workers: p,
                p,
                strategy: strategy.clone(),
                backend: Backend::Auto,
                network: NetworkProfile::cpu_cluster(),
                ..Default::default()
            })?;
            let exe = session.compile(&graph)?;
            let (outs, rep) = exe.run(&inputs)?;
            assert!(
                outs[&z].allclose(&want[&z], 1e-2, 1e-2),
                "{}: wrong result",
                strategy.name()
            );
            println!(
                "{:<14} {:>14.0} {:>12.2} {:>12.3} {:>10.1}",
                strategy.name(),
                rep.plan_cost,
                rep.exec.bytes_moved as f64 / (1 << 20) as f64,
                rep.exec.sim_makespan_s * 1e3,
                rep.exec.wall_s * 1e3,
            );
        }
        // compile once, run many: the serving loop (uniform chain only)
        if !skewed {
            let session = Session::new(DriverConfig {
                workers: p,
                p,
                network: NetworkProfile::cpu_cluster(),
                ..Default::default()
            })?;
            let t0 = std::time::Instant::now();
            let exe = session.compile(&graph)?;
            let compile_s = t0.elapsed().as_secs_f64();
            let reqs = 10;
            let t1 = std::time::Instant::now();
            for _ in 0..reqs {
                exe.run(&inputs)?;
            }
            let run_s = t1.elapsed().as_secs_f64();
            // an equivalent graph compiled again is a cache hit
            assert_eq!(session.compile(&graph)?.provenance(), PlanProvenance::CacheHit);
            println!(
                "serving loop   : compile {:.1} ms once + {reqs} runs x {:.1} ms -> {:.1} req/s \
                 amortized (cache {:?})",
                compile_s * 1e3,
                run_s * 1e3 / reqs as f64,
                reqs as f64 / (compile_s + run_s),
                session.stats()
            );
        }
    }
    println!("\nmatrix_chain OK (all strategies produced identical results)");
    Ok(())
}

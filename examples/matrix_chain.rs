//! Experiment-1-style demo: the matrix chain `(A x B) + (C x (D x E))`
//! under every decomposition strategy, uniform and skewed, at a runnable
//! scale — real execution with wall-clock, plus the modeled cluster
//! timeline. The full sweep that regenerates Figs. 7–8 lives in
//! `cargo bench` (fig7/fig8).
//!
//! ```sh
//! cargo run --release --example matrix_chain [scale]
//! ```

use eindecomp::coordinator::driver::{Driver, DriverConfig};
use eindecomp::decomp::baselines::Strategy;
use eindecomp::models::matchain::{chain_graph, chain_inputs, chain_reference};
use eindecomp::runtime::Backend;
use eindecomp::sim::NetworkProfile;

fn main() -> eindecomp::Result<()> {
    let scale: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(320);
    let p = 8;
    for skewed in [false, true] {
        let chain = chain_graph(scale, skewed)?;
        let inputs = chain_inputs(&chain, 7);
        let want = chain_reference(&chain, &inputs)?;
        println!(
            "\n=== chain s={scale} {} | p={p} ===",
            if skewed { "skewed (paper variant 2)" } else { "uniform" }
        );
        println!(
            "{:<14} {:>14} {:>12} {:>12} {:>10}",
            "strategy", "pred floats", "moved MiB", "sim ms", "wall ms"
        );
        for strategy in [
            Strategy::EinDecomp,
            Strategy::Greedy,
            Strategy::Sqrt,
            Strategy::DaskLike { chunk: scale / 4 },
        ] {
            let driver = Driver::new(DriverConfig {
                workers: p,
                p,
                strategy: strategy.clone(),
                backend: Backend::Auto,
                network: NetworkProfile::cpu_cluster(),
                ..Default::default()
            })?;
            let (outs, rep) = driver.run(&chain.graph, &inputs)?;
            assert!(
                outs[&chain.z].allclose(&want, 1e-2, 1e-2),
                "{}: wrong result",
                strategy.name()
            );
            println!(
                "{:<14} {:>14.0} {:>12.2} {:>12.3} {:>10.1}",
                strategy.name(),
                rep.plan_cost,
                rep.exec.bytes_moved as f64 / (1 << 20) as f64,
                rep.exec.sim_makespan_s * 1e3,
                rep.exec.wall_s * 1e3,
            );
        }
    }
    println!("\nmatrix_chain OK (all strategies produced identical results)");
    Ok(())
}

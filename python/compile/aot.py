"""AOT compile path: lower every (kind, shape) kernel of model.py to HLO
*text* and emit the artifact manifest the rust runtime loads.

Interchange is HLO text, NOT ``lowered.compile()`` or a serialized
``HloModuleProto``: jax >= 0.5 emits protos with 64-bit instruction ids
that the `xla` crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out ../artifacts [--quick]

Emits ``<name>.hlo.txt`` per kernel plus ``manifest.txt`` (tab-separated:
name, kind, dims, file — parsed by rust) and ``manifest.json`` (for
humans). This runs ONCE at build time; the rust binary is self-contained
afterwards.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def kernel_table(quick: bool):
    """(name, kind, dims, fn, specs) for every artifact.

    The BMM family covers the canonical tile shapes the default example
    and bench configurations produce; anything else falls back to the
    rust-native kernel (runtime::engine handles dispatch).
    """
    table = []
    bmm_shapes = [
        (1, 16, 16, 16),
        (1, 32, 32, 32),
        (1, 64, 64, 64),
        (1, 128, 128, 128),
        (1, 64, 16, 64),
        (1, 128, 32, 128),
        (1, 32, 128, 32),
        (1, 256, 64, 256),
    ]
    if not quick:
        bmm_shapes += [
            (1, 256, 256, 256),
            (2, 64, 64, 64),
            (4, 32, 32, 32),
            (1, 512, 128, 512),
        ]
    for (b, m, k, n) in bmm_shapes:
        table.append(
            (
                f"bmm_b{b}_m{m}_k{k}_n{n}",
                "bmm",
                [b, m, k, n],
                model.bmm,
                [f32(b, m, k), f32(b, k, n)],
            )
        )
    flat_ns = [1024, 4096, 16384] + ([65536] if not quick else [])
    for n in flat_ns:
        for op in ["add", "mul", "sub", "div"]:
            table.append(
                (f"ew_{op}_n{n}", f"ew_{op}", [n], model.ew(op), [f32(n), f32(n)])
            )
        for op in ["exp", "relu", "silu", "square"]:
            table.append(
                (f"map_{op}_n{n}", f"map_{op}", [n], model.unary_map(op), [f32(n)])
            )
    for (rows, cols) in [(64, 64), (128, 128), (256, 128)]:
        for op in ["sum", "max"]:
            table.append(
                (
                    f"reduce_{op}_r{rows}_c{cols}",
                    f"reduce_{op}_last",
                    [rows, cols],
                    model.reduce_last(op),
                    [f32(rows, cols)],
                )
            )
        table.append(
            (
                f"softmax_r{rows}_c{cols}",
                "softmax",
                [rows, cols],
                model.softmax,
                [f32(rows, cols)],
            )
        )
    for (s, d) in [(64, 32), (128, 64)]:
        table.append(
            (
                f"attention_s{s}_d{d}",
                "attention_tile",
                [s, d],
                model.attention_tile,
                [f32(s, d), f32(s, d), f32(s, d)],
            )
        )
    # fused L2 FFNN tile step (batch, feat, hidden, classes)
    (bt, ft, hd, cl) = (32, 64, 32, 16)
    table.append(
        (
            f"ffnn_step_b{bt}_f{ft}_h{hd}_c{cl}",
            "ffnn_step",
            [bt, ft, hd, cl],
            model.ffnn_tile_step,
            [f32(bt, ft), f32(ft, hd), f32(hd, cl), f32(bt, cl)],
        )
    )
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--quick", action="store_true", help="smaller artifact set for CI"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest_lines = []
    manifest_json = []
    table = kernel_table(args.quick)
    for i, (name, kind, dims, fn, specs) in enumerate(table):
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        text = to_hlo_text(fn, *specs)
        with open(path, "w") as f:
            f.write(text)
        dims_s = ",".join(str(d) for d in dims)
        manifest_lines.append(f"{name}\t{kind}\t{dims_s}\t{fname}")
        manifest_json.append(
            {"name": name, "kind": kind, "dims": dims, "file": fname}
        )
        print(f"[{i + 1}/{len(table)}] {name} -> {fname} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("# name\tkind\tdims\tfile\n")
        f.write("\n".join(manifest_lines) + "\n")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"kernels": manifest_json}, f, indent=2)
    print(f"wrote {len(table)} artifacts to {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()

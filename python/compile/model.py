"""Layer-2: the jax compute-graph functions that get AOT-lowered to HLO.

Each public function here is a *kernel entry point* the rust runtime can
load (`artifacts/manifest.txt` maps (kind, dims) -> HLO file): the TRA
join's kernel function K in its canonical layouts. Every function calls
the Layer-1 Pallas kernels, so the Pallas code lowers into the same HLO
module and runs on the PJRT CPU client with no Python anywhere near the
request path.

`ffnn_tile_step` additionally demonstrates a *fused* Layer-2 graph — a
whole FFNN forward+backward tile-step lowered as one module (XLA fuses
the elementwise chain between the Pallas matmuls).
"""

import jax
import jax.numpy as jnp

from .kernels import elementwise as ew_k
from .kernels import matmul as mm_k
from .kernels import softmax as sm_k


def bmm(x, y):
    """[b,m,k] @ [b,k,n] -> [b,m,n] (Pallas blocked BMM)."""
    return (mm_k.bmm(x, y),)


def ew(op):
    def f(x, y):
        return (ew_k.ew(op, x, y),)

    return f


def unary_map(op):
    def f(x):
        return (ew_k.unary_map(op, x),)

    return f


def reduce_last(op):
    def f(x):
        return (ew_k.reduce_last(op, x),)

    return f


def softmax(x):
    return (sm_k.softmax(x),)


def attention_tile(q, k, v):
    return (sm_k.attention_tile(q, k, v),)


def ffnn_tile_step(x, w1, w2, t):
    """Fused forward+backward of a 2-layer FFNN on one data tile:
    returns (loss, dW1, dW2). Pallas matmuls + XLA-fused elementwise.

    Mirrors `models::ffnn` in the rust layer so the L2 fusion can be
    compared against the per-vertex TRA execution of the same math.
    """
    batch = x.shape[0]
    p1 = mm_k.matmul(x, w1)
    h1 = jnp.maximum(p1, 0.0)
    y = mm_k.matmul(h1, w2)
    diff = y - t
    loss = 0.5 / batch * jnp.sum(diff * diff)
    g2 = diff / batch
    dw2 = mm_k.matmul(h1.T, g2)
    gh = mm_k.matmul(g2, w2.T)
    g1 = gh * (p1 > 0.0)
    dw1 = mm_k.matmul(x.T, g1)
    return (loss, dw1, dw2)

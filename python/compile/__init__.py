"""Build-time compile path (Layer 1 + Layer 2). Never imported at run time."""

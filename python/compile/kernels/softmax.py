"""Layer-1 Pallas kernel: fused, numerically-stable row softmax.

The paper expresses softmax as four EinSum vertices (max, sub-exp, sum,
divide); when the planner keeps a softmax's row dimension unsplit within a
tile, the runtime can use this fused kernel instead, saving three
intermediate materializations. Rows are processed in VMEM-resident row
blocks with the full column extent in-block (one pass: max, exp, sum,
normalize — the online-softmax trick is unnecessary when the whole row
fits VMEM).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rows_block(rows: int, cols: int, budget: int = 1 << 17) -> int:
    rb = max(1, min(rows, budget // max(cols, 1)))
    while rb > 1 and rows % rb != 0:
        rb -= 1
    return rb


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = e / s


def softmax(x):
    """Row softmax over [rows, cols]."""
    rows, cols = x.shape
    rb = _rows_block(rows, cols)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(e / s, v, preferred_element_type=jnp.float32)


def attention_tile(q, k, v):
    """Fused single-tile attention ``softmax(Q K^T / sqrt(d)) V`` for
    [s, d] tiles (whole tile in VMEM) — the fusion Experiment 3's planner
    exploits when a head-tile stays local."""
    s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_attention_kernel, scale=scale),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((s, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        interpret=True,
    )(q, k, v)

"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
reference (pytest compares kernel vs. ref under shape/seed sweeps)."""

import jax
import jax.numpy as jnp


def bmm(x, y):
    return jnp.einsum("bmk,bkn->bmn", x, y)


def matmul(x, y):
    return x @ y


def ew(op, x, y):
    return {
        "add": x + y,
        "mul": x * y,
        "sub": x - y,
        "div": x / y,
    }[op]


def unary_map(op, x):
    return {
        "exp": jnp.exp(x),
        "relu": jnp.maximum(x, 0.0),
        "silu": x * jax.nn.sigmoid(x),
        "square": x * x,
    }[op]


def reduce_last(op, x):
    return {"sum": jnp.sum(x, axis=-1), "max": jnp.max(x, axis=-1)}[op]


def softmax(x):
    return jax.nn.softmax(x, axis=-1)


def attention_tile(q, k, v):
    scale = 1.0 / (q.shape[-1] ** 0.5)
    return jax.nn.softmax(q @ k.T * scale, axis=-1) @ v

"""Layer-1 Pallas kernels (build-time only; never imported at run time)."""

from . import elementwise, matmul, ref, softmax  # noqa: F401

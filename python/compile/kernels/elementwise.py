"""Layer-1 Pallas kernels: elementwise binary ops, unary maps, and
last-axis reductions — the remaining TRA kernel functions.

All operate on flat or [rows, cols] layouts; the rust runtime reshapes
tiles into these canonical forms before dispatch (mirroring the paper's
"unpack, kernel, re-pack" CPU pipeline).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BINOPS = {
    "add": lambda a, b: a + b,
    "mul": lambda a, b: a * b,
    "sub": lambda a, b: a - b,
    "div": lambda a, b: a / b,
}

_MAPS = {
    "exp": jnp.exp,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "silu": lambda x: x * jax.nn.sigmoid(x),
    "square": lambda x: x * x,
}


def _chunk(n: int, target: int = 4096) -> int:
    c = min(n, target)
    while c > 1 and n % c != 0:
        c //= 2
    return max(c, 1)


def _ew_kernel(x_ref, y_ref, o_ref, *, op):
    o_ref[...] = _BINOPS[op](x_ref[...], y_ref[...])


def ew(op: str, x, y):
    """Elementwise binary op over flat [n] arrays."""
    (n,) = x.shape
    c = _chunk(n)
    return pl.pallas_call(
        functools.partial(_ew_kernel, op=op),
        grid=(n // c,),
        in_specs=[
            pl.BlockSpec((c,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, y)


def _map_kernel(x_ref, o_ref, *, op):
    o_ref[...] = _MAPS[op](x_ref[...])


def unary_map(op: str, x):
    """Unary map over flat [n] arrays."""
    (n,) = x.shape
    c = _chunk(n)
    return pl.pallas_call(
        functools.partial(_map_kernel, op=op),
        grid=(n // c,),
        in_specs=[pl.BlockSpec((c,), lambda i: (i,))],
        out_specs=pl.BlockSpec((c,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)


def _reduce_kernel(x_ref, o_ref, *, op):
    if op == "sum":
        o_ref[...] = jnp.sum(x_ref[...], axis=-1)
    else:
        o_ref[...] = jnp.max(x_ref[...], axis=-1)


def reduce_last(op: str, x):
    """Reduce the last axis of [rows, cols] -> [rows]; whole rows stay in
    one VMEM block (row-blocked grid)."""
    rows, cols = x.shape
    rb = _chunk(rows, 256)
    return pl.pallas_call(
        functools.partial(_reduce_kernel, op=op),
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(x)

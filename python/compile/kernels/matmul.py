"""Layer-1 Pallas kernel: blocked batched matrix multiply (the TRA kernel
function K for Mul/Sum contractions).

TPU-shaped even though we execute with ``interpret=True`` on CPU (the CPU
PJRT plugin cannot run Mosaic custom-calls — see DESIGN.md
§Hardware-Adaptation): operands stream HBM->VMEM in MXU-friendly blocks
(128x128 where the shape allows), a float32 VMEM scratch accumulator runs
across the K grid dimension (marked "arbitrary" so only the K loop is
sequential), and the epilogue stores the accumulator once on the final K
step. VMEM footprint per step: bm*bk + bk*bn + 2*bm*bn floats — at the
default 128 blocks that is 256 KiB, an 8x double-buffering margin inside
a 16 MiB VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def block_of(dim: int, target: int = 128) -> int:
    """Largest power-of-two block <= target that divides dim (>=1)."""
    b = min(dim, target)
    while b > 1 and dim % b != 0:
        b //= 2
    return max(b, 1)


def _bmm_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], y_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _store():
        o_ref[0] = acc_ref[...]


def bmm(x, y, *, bm: int = 0, bk: int = 0, bn: int = 0):
    """Batched matmul ``[b, m, k] @ [b, k, n] -> [b, m, n]``.

    Block sizes default to the largest power-of-two divisor of each dim,
    capped at 128 (one MXU tile edge).
    """
    b, m, k = x.shape
    b2, k2, n = y.shape
    assert b == b2 and k == k2, (x.shape, y.shape)
    bm = bm or block_of(m)
    bk = bk or block_of(k)
    bn = bn or block_of(n)
    k_steps = k // bk
    grid = (b, m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_bmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda bi, i, j, kk: (bi, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda bi, i, j, kk: (bi, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda bi, i, j, kk: (bi, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, y)


def matmul(x, y, **kw):
    """Plain 2-D matmul through the same kernel."""
    return bmm(x[None], y[None], **kw)[0]


def vmem_floats(bm: int, bk: int, bn: int) -> int:
    """VMEM working-set estimate (floats) for a block configuration:
    one x block + one y block + output block + accumulator."""
    return bm * bk + bk * bn + 2 * bm * bn

"""AOT path: HLO-text lowering works, the manifest round-trips, and the
emitted HLO parses as a module (smoke-level — the real load+execute check
happens on the rust side in rust/tests/pjrt_roundtrip.rs)."""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_smoke():
    text = aot.to_hlo_text(
        model.bmm,
        jax.ShapeDtypeStruct((1, 8, 8), jnp.float32),
        jax.ShapeDtypeStruct((1, 8, 8), jnp.float32),
    )
    assert "HloModule" in text
    assert "f32[1,8,8]" in text


def test_kernel_table_well_formed():
    table = aot.kernel_table(quick=True)
    names = [t[0] for t in table]
    assert len(names) == len(set(names)), "duplicate artifact names"
    kinds = {t[1] for t in table}
    for expect in ["bmm", "ew_add", "map_relu", "reduce_sum_last", "softmax"]:
        assert expect in kinds


def test_quick_emit_and_manifest(tmp_path):
    # emit just two artifacts by monkeypatching the table
    orig = aot.kernel_table
    try:
        aot.kernel_table = lambda quick: orig(quick)[:2]
        argv = sys.argv
        sys.argv = ["aot", "--out", str(tmp_path), "--quick"]
        aot.main()
        sys.argv = argv
    finally:
        aot.kernel_table = orig
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    # header + 2 entries
    entries = [l for l in manifest if not l.startswith("#")]
    assert len(entries) == 2
    for line in entries:
        name, kind, dims, fname = line.split("\t")
        assert (tmp_path / fname).exists()
        assert all(d.isdigit() for d in dims.split(","))
    assert (tmp_path / "manifest.json").exists()

"""Layer-2 correctness: the fused model entry points vs jax autodiff and
shape checks on every kernel entry."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def test_bmm_entry_shape():
    (out,) = model.bmm(rand(0, 2, 16, 8), rand(1, 2, 8, 4))
    assert out.shape == (2, 16, 4)


def test_ffnn_tile_step_matches_autodiff():
    batch, feat, hid, cls = 8, 12, 10, 4
    x = rand(0, batch, feat)
    w1 = rand(1, feat, hid) * 0.5
    w2 = rand(2, hid, cls) * 0.5
    t = rand(3, batch, cls)

    loss, dw1, dw2 = model.ffnn_tile_step(x, w1, w2, t)

    def loss_fn(w1_, w2_):
        h1 = jnp.maximum(x @ w1_, 0.0)
        y = h1 @ w2_
        return 0.5 / batch * jnp.sum((y - t) ** 2)

    want_loss = loss_fn(w1, w2)
    gw1, gw2 = jax.grad(loss_fn, argnums=(0, 1))(w1, w2)
    np.testing.assert_allclose(float(loss), float(want_loss), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(dw1), np.asarray(gw1), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw2), np.asarray(gw2), rtol=1e-3, atol=1e-4)


def test_softmax_entry():
    (out,) = model.softmax(rand(0, 8, 16))
    np.testing.assert_allclose(np.asarray(out.sum(axis=-1)), 1.0, rtol=1e-5)


def test_unary_and_ew_factories():
    x = rand(0, 64)
    y = rand(1, 64)
    (s,) = model.ew("add")(x, y)
    np.testing.assert_allclose(np.asarray(s), np.asarray(x + y), rtol=1e-6)
    (r,) = model.unary_map("relu")(x)
    assert (np.asarray(r) >= 0).all()
    (m,) = model.reduce_last("max")(x.reshape(8, 8))
    assert m.shape == (8,)

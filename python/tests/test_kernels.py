"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (power-of-two and ragged-divisible) and seeds;
this is the CORE build-time correctness signal for the kernels the rust
runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise as ew_k
from compile.kernels import matmul as mm_k
from compile.kernels import ref
from compile.kernels import softmax as sm_k

POW2 = [1, 2, 4, 8, 16, 32, 64, 128]


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def assert_close(a, b, tol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# ---------- bmm ----------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3]),
    m=st.sampled_from(POW2[2:]),
    k=st.sampled_from(POW2[2:]),
    n=st.sampled_from(POW2[2:]),
    seed=st.integers(0, 1000),
)
def test_bmm_matches_ref(b, m, k, n, seed):
    x = rand(seed, b, m, k)
    y = rand(seed + 1, b, k, n)
    assert_close(mm_k.bmm(x, y), ref.bmm(x, y), tol=1e-4 * k)


def test_bmm_explicit_blocks():
    x = rand(0, 2, 64, 32)
    y = rand(1, 2, 32, 16)
    out = mm_k.bmm(x, y, bm=16, bk=8, bn=8)
    assert_close(out, ref.bmm(x, y), tol=1e-4)


def test_matmul_2d():
    x = rand(2, 48, 24)
    y = rand(3, 24, 12)
    assert_close(mm_k.matmul(x, y), ref.matmul(x, y), tol=1e-4)


def test_block_of_divides():
    for dim in [1, 2, 3, 6, 48, 100, 128, 384, 1000]:
        b = mm_k.block_of(dim)
        assert dim % b == 0
        assert b <= 128


def test_vmem_budget_default_blocks():
    # default 128-blocks: 4 buffers, 256 KiB — far inside 16 MiB VMEM
    floats = mm_k.vmem_floats(128, 128, 128)
    assert floats * 4 <= 16 * 2**20 / 8


# ---------- elementwise / map / reduce ----------

@settings(max_examples=20, deadline=None)
@given(
    op=st.sampled_from(["add", "mul", "sub", "div"]),
    n=st.sampled_from([16, 128, 1024, 4096, 5000]),
    seed=st.integers(0, 100),
)
def test_ew_matches_ref(op, n, seed):
    x = rand(seed, n)
    y = rand(seed + 7, n) + 3.0  # keep div well-conditioned
    assert_close(ew_k.ew(op, x, y), ref.ew(op, x, y), tol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    op=st.sampled_from(["exp", "relu", "silu", "square"]),
    n=st.sampled_from([16, 1024, 3000]),
    seed=st.integers(0, 100),
)
def test_map_matches_ref(op, n, seed):
    x = rand(seed, n)
    assert_close(ew_k.unary_map(op, x), ref.unary_map(op, x), tol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    op=st.sampled_from(["sum", "max"]),
    rows=st.sampled_from([1, 8, 64, 100]),
    cols=st.sampled_from([4, 64, 256]),
    seed=st.integers(0, 100),
)
def test_reduce_matches_ref(op, rows, cols, seed):
    x = rand(seed, rows, cols)
    assert_close(ew_k.reduce_last(op, x), ref.reduce_last(op, x), tol=1e-4)


# ---------- softmax / attention ----------

@settings(max_examples=15, deadline=None)
@given(
    rows=st.sampled_from([1, 8, 64]),
    cols=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 100),
)
def test_softmax_matches_ref(rows, cols, seed):
    x = rand(seed, rows, cols) * 5.0
    out = sm_k.softmax(x)
    assert_close(out, ref.softmax(x), tol=1e-5)
    # rows sum to 1
    np.testing.assert_allclose(np.asarray(jnp.sum(out, axis=-1)), 1.0, rtol=1e-5)


def test_softmax_extreme_values_stable():
    x = jnp.array([[1e4, 1e4 - 1.0, 0.0], [-1e4, 0.0, 1e4]], dtype=jnp.float32)
    out = np.asarray(sm_k.softmax(x))
    assert np.isfinite(out).all()


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([8, 32]),
    seed=st.integers(0, 100),
)
def test_attention_tile_matches_ref(s, d, seed):
    q, k, v = (rand(seed + i, s, d) for i in range(3))
    assert_close(sm_k.attention_tile(q, k, v), ref.attention_tile(q, k, v), tol=1e-4)


# ---------- dtype robustness ----------

def test_bmm_rejects_shape_mismatch():
    x = rand(0, 1, 8, 4)
    y = rand(1, 1, 8, 4)  # bad inner dim
    with pytest.raises(AssertionError):
        mm_k.bmm(x, y)

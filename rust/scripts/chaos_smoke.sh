#!/usr/bin/env bash
# Chaos smoke: a fixed-seed multi-fault CLI run diffed against a clean
# one. The CLI seeds its inputs deterministically and prints an FNV-1a
# fingerprint of every output tensor ("output checksum: ..."), so the
# recovery contract — a faulted run reproduces the fault-free outputs
# BITWISE — reduces to a string comparison. Used by CI and as a local
# quickstart for the fault-injection machinery.
#
#   rust/scripts/chaos_smoke.sh
#
# The fault plan mixes a seeded 20% transient sweep with an explicit
# permanent worker death, so both recovery paths (retry-in-place and
# lineage recompute after re-homing) run every time; the explicit clause
# guarantees the run is never vacuously fault-free.
set -euo pipefail
cd "$(dirname "$0")/.."

MODEL_ARGS=(--model chain --scale 24 --workers 4)
FAULTS="seed:7:0.2,task:5:permanent"

run() { cargo run --release --quiet -- run "${MODEL_ARGS[@]}" "$@"; }

echo "== clean run =="
clean_out=$(run)
echo "$clean_out"

echo
echo "== chaos run (--inject-faults $FAULTS) =="
chaos_out=$(run --inject-faults "$FAULTS" --max-retries 4)
echo "$chaos_out"

checksum() { grep '^output checksum' <<<"$1" | awk '{print $3}'; }

clean_sum=$(checksum "$clean_out")
chaos_sum=$(checksum "$chaos_out")
if [[ -z "$clean_sum" || -z "$chaos_sum" ]]; then
  echo "chaos_smoke: FAIL: missing output checksum line" >&2
  exit 1
fi
if [[ "$clean_sum" != "$chaos_sum" ]]; then
  echo "chaos_smoke: FAIL: faulted outputs diverged bitwise" \
       "(clean $clean_sum vs chaos $chaos_sum)" >&2
  exit 1
fi

# the clean run must report zero recovery overhead...
if grep -q 'faults=' <<<"$clean_out"; then
  echo "chaos_smoke: FAIL: clean run reports injected faults" >&2
  exit 1
fi
if ! grep -q '"faults_injected":0' <<<"$clean_out"; then
  echo "chaos_smoke: FAIL: clean run JSON lacks faults_injected:0" >&2
  exit 1
fi
# ...and the chaos run must actually have injected and recovered
if ! grep -q 'faults=' <<<"$chaos_out"; then
  echo "chaos_smoke: FAIL: chaos run summary lacks a faults= ledger" >&2
  exit 1
fi
if grep -q '"faults_injected":0' <<<"$chaos_out"; then
  echo "chaos_smoke: FAIL: chaos run injected nothing (vacuous)" >&2
  exit 1
fi

echo
echo "chaos_smoke: OK — checksum $clean_sum reproduced under faults ($FAULTS)"

#!/usr/bin/env bash
# Capped bench smoke: exercises the two real-execution benches end to end
# without the full figure sweeps. Used by CI and as a quick local sanity
# check that the scheduler A/B still runs and reports a speedup line.
#
#   rust/scripts/bench_smoke.sh
#
# EINDECOMP_SMOKE=1 makes micro_hotpath shrink its problem sizes (see the
# bench source); fig9_ffnn is dry-run-only modeling and already cheap at
# its smallest sweep points, so it runs as-is.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== micro_hotpath (EINDECOMP_SMOKE=1) =="
EINDECOMP_SMOKE=1 cargo bench --bench micro_hotpath

echo
echo "== serving (EINDECOMP_SMOKE=1): cold vs compile-once/run-many =="
EINDECOMP_SMOKE=1 cargo bench --bench serving

echo
echo "== lowering (EINDECOMP_SMOKE=1): direct vs TRA-IR, per-pass deltas =="
EINDECOMP_SMOKE=1 cargo bench --bench lowering

echo
echo "== faults (EINDECOMP_SMOKE=1): recovery overhead, clean vs faulted =="
EINDECOMP_SMOKE=1 cargo bench --bench faults

echo
echo "== fig11_offload (EINDECOMP_SMOKE=1): modeled sweep + real budget arms =="
EINDECOMP_SMOKE=1 cargo bench --bench fig11_offload

echo
echo "== fig9_ffnn (modeled, full sweep is cheap) =="
cargo bench --bench fig9_ffnn

#!/usr/bin/env python3
"""Validate BENCH_serving.json's latency/throughput schema.

CI gate for the multi-tenant serving bench: the legacy cold-vs-cached
section must carry well-formed throughput entries and a >= 1.3x
amortized speedup, and the `serving` section must report solo and
batched load arms for every serving pool size, each with nearest-rank
latency percentiles (p50 <= p95 <= p99), a non-negative request ledger
that adds up, zero rejections, and an output checksum equal to the
solo-reference XOR (bitwise parity). The batched-vs-solo speedup must
meet the 1.5x gate the bench itself asserts.

Usage: check_serving_json.py [BENCH_serving.json]
"""

import json
import sys

ARM_FIELDS = [
    "mode",
    "serve_workers",
    "max_batch",
    "requests",
    "completed",
    "rejected",
    "elapsed_s",
    "req_per_s",
    "latency",
    "max_batched_with",
    "mean_batched_with",
    "checksum",
]

LATENCY_FIELDS = ["p50_ms", "p95_ms", "p99_ms", "mean_ms"]


def fail(msg: str) -> None:
    print(f"check_serving_json: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def is_int_valued(v) -> bool:
    return is_num(v) and float(v) == int(v)


def load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        fail(f"{path} not found (did the serving bench run?)")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")


def check_legacy(doc) -> None:
    for key in ("driver_per_call", "session_cached"):
        entry = doc.get(key)
        if not isinstance(entry, dict):
            fail(f"missing or malformed {key!r} entry")
        for field in ("workload", "mode"):
            if not isinstance(entry.get(field), str):
                fail(f"{key}.{field} must be a string")
        for field in ("total_s", "ms_per_run", "runs_per_s"):
            if not is_num(entry.get(field)) or entry[field] < 0:
                fail(f"{key}.{field} must be a non-negative number")
    if not is_num(doc.get("speedup_amortized")):
        fail("speedup_amortized must be a number")
    if doc["speedup_amortized"] < 1.3:
        fail(
            "amortized cached-vs-cold speedup "
            f"{doc['speedup_amortized']:.2f}x below the 1.3x gate"
        )
    if doc.get("bitwise_identical") is not True:
        fail("bitwise_identical must be true")


def check_arm(arm, expected_checksum: str) -> str:
    for field in ARM_FIELDS:
        if field not in arm:
            fail(f"serving arm missing field {field!r}: {arm}")
    mode = arm["mode"]
    if mode not in ("solo", "batched"):
        fail(f"unknown serving arm mode {mode!r}")
    for field in ("serve_workers", "max_batch", "requests", "completed", "rejected"):
        if not is_int_valued(arm[field]) or arm[field] < 0:
            fail(f"arm {mode}: {field} must be a non-negative integer")
    if arm["serve_workers"] < 1:
        fail(f"arm {mode}: serve_workers must be >= 1")
    if arm["rejected"] != 0:
        fail(f"arm {mode} x{arm['serve_workers']}: {arm['rejected']} rejected requests")
    if arm["completed"] != arm["requests"]:
        fail(
            f"arm {mode} x{arm['serve_workers']}: completed {arm['completed']} "
            f"!= requests {arm['requests']}"
        )
    if not is_num(arm["req_per_s"]) or arm["req_per_s"] <= 0:
        fail(f"arm {mode} x{arm['serve_workers']}: req_per_s must be positive")
    lat = arm["latency"]
    if not isinstance(lat, dict):
        fail(f"arm {mode}: latency must be an object")
    for field in LATENCY_FIELDS:
        if not is_num(lat.get(field)) or lat[field] < 0:
            fail(f"arm {mode}: latency.{field} must be a non-negative number")
    if not lat["p50_ms"] <= lat["p95_ms"] <= lat["p99_ms"]:
        fail(
            f"arm {mode} x{arm['serve_workers']}: percentiles not monotone "
            f"(p50 {lat['p50_ms']}, p95 {lat['p95_ms']}, p99 {lat['p99_ms']})"
        )
    if not is_int_valued(arm["max_batched_with"]) or arm["max_batched_with"] < 1:
        fail(f"arm {mode}: max_batched_with must be >= 1")
    if mode == "solo" and arm["max_batched_with"] != 1:
        fail("solo arm reports coalesced requests")
    if not is_num(arm["mean_batched_with"]) or not (
        1.0 <= arm["mean_batched_with"] <= arm["max_batched_with"]
    ):
        fail(f"arm {mode}: mean_batched_with out of [1, max_batched_with]")
    if arm["checksum"] != expected_checksum:
        fail(
            f"arm {mode} x{arm['serve_workers']}: checksum {arm['checksum']} "
            f"!= solo reference {expected_checksum} (bitwise parity broken)"
        )
    return mode


def check_serving(doc) -> None:
    serving = doc.get("serving")
    if not isinstance(serving, dict):
        fail("missing 'serving' section")
    for field in ("workload", "expected_checksum"):
        if not isinstance(serving.get(field), str):
            fail(f"serving.{field} must be a string")
    for field in ("scale", "clients", "requests_per_client"):
        if not is_int_valued(serving.get(field)) or serving[field] < 1:
            fail(f"serving.{field} must be a positive integer")
    if not is_num(serving.get("batch_window_ms")) or serving["batch_window_ms"] < 0:
        fail("serving.batch_window_ms must be a non-negative number")
    arms = serving.get("arms")
    if not isinstance(arms, list) or not arms:
        fail("serving.arms must be a non-empty array")
    expected = serving["expected_checksum"]
    modes_by_workers = {}
    for arm in arms:
        if not isinstance(arm, dict):
            fail("serving.arms entries must be objects")
        mode = check_arm(arm, expected)
        modes_by_workers.setdefault(int(arm["serve_workers"]), set()).add(mode)
    for workers, modes in sorted(modes_by_workers.items()):
        if modes != {"solo", "batched"}:
            fail(f"serving pool size {workers} missing an arm: has {sorted(modes)}")
    for field in ("best_solo_req_per_s", "best_batched_req_per_s", "batched_speedup"):
        if not is_num(serving.get(field)) or serving[field] <= 0:
            fail(f"serving.{field} must be a positive number")
    best_solo = max(a["req_per_s"] for a in arms if a["mode"] == "solo")
    best_batched = max(a["req_per_s"] for a in arms if a["mode"] == "batched")
    ratio = best_batched / best_solo
    if abs(serving["batched_speedup"] - ratio) > 1e-6 * max(1.0, ratio):
        fail(
            f"serving.batched_speedup {serving['batched_speedup']} does not match "
            f"the arms ({ratio:.4f})"
        )
    if serving.get("parity_ok") is not True:
        fail("serving.parity_ok must be true")
    if serving.get("gate_1_5x") is not True:
        fail("serving.gate_1_5x must be true")
    if serving["batched_speedup"] < 1.5:
        fail(
            f"dynamic batching speedup {serving['batched_speedup']:.2f}x "
            "below the 1.5x gate"
        )
    print(
        "check_serving_json: OK "
        f"({len(arms)} arms over pool sizes {sorted(modes_by_workers)}, "
        f"batched speedup {serving['batched_speedup']:.2f}x, parity verified)"
    )


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_serving.json"
    doc = load(path)
    if not isinstance(doc, dict):
        fail("top-level JSON must be an object")
    check_legacy(doc)
    check_serving(doc)


if __name__ == "__main__":
    main()

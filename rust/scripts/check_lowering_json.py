#!/usr/bin/env python3
"""Validate BENCH_lowering.json's per-pass delta ledger.

CI gate for the TRA pass pipeline: every suite workload must carry a
`pass_log` in which each entry names a pass and reports well-formed
`changes` / `tasks_delta` / `repart_bytes_delta` fields, plus the
workload-level task and repartition-byte totals the deltas roll up to.
Fails (exit 1) if any field is missing or malformed, if the pass names
do not match the pipeline, or if no workload shows the strict
task+byte win the pipeline is supposed to deliver.

Usage: check_lowering_json.py [path/to/BENCH_lowering.json]
"""

import json
import sys

EXPECTED_PASSES = [
    "propagate-partitions",
    "elide-identity-repart",
    "cse",
    "alias-refinement-repart",
    "fuse-epilogue",
    "agg-tree",
    "dead-rel-elim",
]

WORKLOAD_COUNTERS = [
    "tasks_unoptimized",
    "tasks_optimized",
    "repart_tasks_unoptimized",
    "repart_tasks_optimized",
    "repart_bytes_unoptimized",
    "repart_bytes_optimized",
]


def fail(msg: str) -> None:
    print(f"check_lowering_json: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def is_int_valued(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) and float(v) == int(v)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_lowering.json"
    try:
        with open(path) as f:
            report = json.load(f)
    except FileNotFoundError:
        fail(f"{path} not found (did the lowering bench run?)")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    workloads = report.get("workloads")
    if not isinstance(workloads, list) or not workloads:
        fail("top-level 'workloads' missing or empty")

    strict_wins = 0
    for w in workloads:
        name = w.get("workload")
        if not isinstance(name, str) or not name:
            fail("workload entry without a 'workload' name")

        for k in WORKLOAD_COUNTERS:
            if not is_int_valued(w.get(k)):
                fail(f"{name}: counter '{k}' missing or not an integer count")

        log = w.get("pass_log")
        if not isinstance(log, list) or not log:
            fail(f"{name}: 'pass_log' missing or empty")
        names = []
        for entry in log:
            if not isinstance(entry, dict):
                fail(f"{name}: pass_log entry is not an object")
            pname = entry.get("pass")
            if not isinstance(pname, str) or not pname:
                fail(f"{name}: pass_log entry without a 'pass' name")
            names.append(pname)
            for k in ("changes", "tasks_delta", "repart_bytes_delta"):
                if not is_int_valued(entry.get(k)):
                    fail(f"{name}: pass '{pname}' field '{k}' missing or malformed")
            if int(entry["changes"]) < 0:
                fail(f"{name}: pass '{pname}' has negative change count")
        if names != EXPECTED_PASSES:
            fail(
                f"{name}: pass_log names {names} != expected pipeline "
                f"{EXPECTED_PASSES}"
            )

        # deltas must roll up to the workload totals
        dt = sum(int(e["tasks_delta"]) for e in log)
        if dt != int(w["tasks_optimized"]) - int(w["tasks_unoptimized"]):
            fail(f"{name}: sum of tasks_delta ({dt}) does not match task totals")
        db = sum(int(e["repart_bytes_delta"]) for e in log)
        if db != int(w["repart_bytes_optimized"]) - int(w["repart_bytes_unoptimized"]):
            fail(f"{name}: sum of repart_bytes_delta ({db}) does not match byte totals")

        if w.get("strict_win") is True:
            strict_wins += 1

    if strict_wins == 0:
        fail("no workload shows a strict task+byte win with the full pipeline")

    print(
        f"check_lowering_json: OK — {len(workloads)} workloads, "
        f"{len(EXPECTED_PASSES)} passes each, {strict_wins} strict win(s)"
    )


if __name__ == "__main__":
    main()

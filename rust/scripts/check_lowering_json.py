#!/usr/bin/env python3
"""Validate BENCH_lowering.json's per-pass delta ledger.

CI gate for the TRA pass pipeline: every suite workload must carry a
`pass_log` in which each entry names a pass and reports well-formed
`changes` / `tasks_delta` / `repart_bytes_delta` fields, plus the
workload-level task and repartition-byte totals the deltas roll up to.
Fails (exit 1) if any field is missing or malformed, if the pass names
do not match the pipeline, if no workload shows the strict task+byte
win the pipeline is supposed to deliver, if the topology sweep's
per-link-class byte ledgers do not roll up to the workload totals, or
if no three-level workload shows a strict cross-node byte reduction
from `lower-collectives`.

When a third path is given, also validates BENCH_faults.json from the
fault-recovery bench: each workload must carry the four arms
(clean / single_transient / single_permanent / seeded_10pct) with
integer counters and bitwise-match flags, the clean arm must report
zero recovery overhead, single faults must count exactly one injection
with the right worker-loss shape (transient: no loss, no recovery
bytes; permanent: one worker lost), and every faulted arm must cost
retries and modeled makespan.

When a fourth path is given, also validates BENCH_memory.json from the
offload bench's real-executor arm: every arm must carry well-formed
spill counters with the per-worker peak-residency ledger rolling up to
its max, the unlimited arm must report zero spill overhead, budgeted
arms must keep every worker's peak at or under the budget, the
tightest arm must actually spill, every arm must be bitwise-identical
to the unbudgeted run, and the modeled makespan must be monotone
non-decreasing as the budget shrinks.

Usage: check_lowering_json.py [BENCH_lowering.json] [BENCH_topology.json]
                              [BENCH_faults.json] [BENCH_memory.json]
"""

import json
import sys

EXPECTED_PASSES = [
    "propagate-partitions",
    "elide-identity-repart",
    "cse",
    "alias-refinement-repart",
    "fuse-epilogue",
    "agg-tree",
    "lower-collectives",
    "dead-rel-elim",
]

WORKLOAD_COUNTERS = [
    "tasks_unoptimized",
    "tasks_optimized",
    "repart_tasks_unoptimized",
    "repart_tasks_optimized",
    "repart_bytes_unoptimized",
    "repart_bytes_optimized",
]


def fail(msg: str) -> None:
    print(f"check_lowering_json: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def is_int_valued(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) and float(v) == int(v)


def load(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        fail(f"{path} not found (did the lowering bench run?)")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")


FAULT_ARMS = ["clean", "single_transient", "single_permanent", "seeded_10pct"]

FAULT_COUNTERS = [
    "faults_injected",
    "retries",
    "recomputed_tasks",
    "recovery_bytes",
    "workers_lost",
]


def check_faults(path: str) -> str:
    """Validate BENCH_faults.json; returns a summary fragment."""
    workloads = load(path).get("workloads")
    if not isinstance(workloads, list) or not workloads:
        fail(f"{path}: top-level 'workloads' missing or empty")
    for w in workloads:
        name = w.get("workload")
        if not isinstance(name, str) or not name:
            fail(f"{path}: fault workload entry without a 'workload' name")
        if not is_int_valued(w.get("tasks")) or int(w["tasks"]) <= 0:
            fail(f"{name}: 'tasks' missing or not a positive count")
        arms = {a.get("arm"): a for a in w.get("arms", []) if isinstance(a, dict)}
        if sorted(arms) != sorted(FAULT_ARMS):
            fail(f"{name}: arms {sorted(arms)} != expected {sorted(FAULT_ARMS)}")
        for arm_name, a in arms.items():
            tag = f"{name}/{arm_name}"
            for k in FAULT_COUNTERS:
                if not is_int_valued(a.get(k)) or int(a[k]) < 0:
                    fail(f"{tag}: counter '{k}' missing or malformed")
            for k in ("recovery_stall_s", "sim_makespan_s"):
                v = a.get(k)
                if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
                    fail(f"{tag}: '{k}' missing or malformed")
            if a.get("bitwise_match") is not True:
                fail(f"{tag}: not marked bitwise-identical to the clean run")
            if not isinstance(a.get("fault_plan"), str) or not a["fault_plan"]:
                fail(f"{tag}: 'fault_plan' missing")
        clean = arms["clean"]
        if any(int(clean[k]) != 0 for k in FAULT_COUNTERS) or clean["recovery_stall_s"] != 0:
            fail(f"{name}: clean arm reports nonzero recovery overhead")
        for arm_name in FAULT_ARMS[1:]:
            a = arms[arm_name]
            if int(a["faults_injected"]) < 1:
                fail(f"{name}/{arm_name}: no fault was injected (vacuous arm)")
            if int(a["retries"]) < int(a["faults_injected"]):
                fail(f"{name}/{arm_name}: fewer retries than injected faults")
            if a["sim_makespan_s"] <= clean["sim_makespan_s"]:
                fail(
                    f"{name}/{arm_name}: recovery stall missing from the "
                    f"modeled makespan"
                )
        for arm_name, lost in (("single_transient", 0), ("single_permanent", 1)):
            a = arms[arm_name]
            if int(a["faults_injected"]) != 1:
                fail(f"{name}/{arm_name}: expected exactly one injected fault")
            if int(a["workers_lost"]) != lost:
                fail(f"{name}/{arm_name}: expected workers_lost == {lost}")
        if int(arms["single_transient"]["recovery_bytes"]) != 0:
            fail(f"{name}: transient fault charged recovery bytes")
    return f", {len(workloads)} fault workloads x {len(FAULT_ARMS)} arms"


MEMORY_COUNTERS = ["budget_bytes", "spill_bytes", "spill_faults", "peak_resident_bytes_max"]


def check_memory(path: str) -> str:
    """Validate BENCH_memory.json; returns a summary fragment."""
    report = load(path)
    arms = report.get("arms")
    if not isinstance(arms, list) or not arms:
        fail(f"{path}: top-level 'arms' missing or empty")
    for k in ("floor_bytes", "unbudgeted_peak_bytes"):
        if not is_int_valued(report.get(k)) or int(report[k]) <= 0:
            fail(f"{path}: '{k}' missing or not a positive byte count")
    for a in arms:
        tag = f"{a.get('workload')}/budget={a.get('budget_bytes')}"
        for k in MEMORY_COUNTERS:
            if not is_int_valued(a.get(k)) or int(a[k]) < 0:
                fail(f"{tag}: counter '{k}' missing or malformed")
        for k in ("spill_stall_s", "sim_makespan_s", "wall_s"):
            v = a.get(k)
            if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
                fail(f"{tag}: '{k}' missing or malformed")
        per_worker = a.get("peak_resident_bytes")
        if not isinstance(per_worker, list) or not per_worker:
            fail(f"{tag}: 'peak_resident_bytes' missing or empty")
        if any(not is_int_valued(b) or b < 0 for b in per_worker):
            fail(f"{tag}: malformed per-worker peak residency")
        # the per-worker ledger must roll up to the reported max
        if max(int(b) for b in per_worker) != int(a["peak_resident_bytes_max"]):
            fail(f"{tag}: per-worker peaks do not roll up to peak_resident_bytes_max")
        if a.get("bitwise_match") is not True:
            fail(f"{tag}: not marked bitwise-identical to the unbudgeted run")
        budget = int(a["budget_bytes"])
        if budget == 0:
            # unlimited arm: the spill machinery must stay entirely cold
            if int(a["spill_bytes"]) or int(a["spill_faults"]) or a["spill_stall_s"]:
                fail(f"{tag}: unlimited arm reports spill overhead")
        else:
            if any(int(b) > budget for b in per_worker):
                fail(f"{tag}: a worker's peak residency exceeds the budget")
    if not any(int(a["budget_bytes"]) == 0 for a in arms):
        fail(f"{path}: no unlimited (budget 0) arm")
    budgeted = [a for a in arms if int(a["budget_bytes"]) > 0]
    if not budgeted:
        fail(f"{path}: no budgeted arm")
    tightest = min(budgeted, key=lambda a: int(a["budget_bytes"]))
    if int(tightest["spill_bytes"]) <= 0:
        fail(f"{path}: tightest arm never spilled (out-of-core path unexercised)")
    # shrinking the budget can only add spill traffic: makespan is
    # monotone non-decreasing as the budget shrinks (0 = unlimited)
    ordered = sorted(arms, key=lambda a: -(int(a["budget_bytes"]) or 1 << 62))
    for prev, nxt in zip(ordered, ordered[1:]):
        if nxt["sim_makespan_s"] < prev["sim_makespan_s"]:
            fail(
                f"{path}: makespan decreased when the budget shrank "
                f"({prev['budget_bytes']} -> {nxt['budget_bytes']})"
            )
    return f", {len(arms)} memory arms (tightest spilled {int(tightest['spill_bytes'])} B)"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_lowering.json"
    topo_path = sys.argv[2] if len(sys.argv) > 2 else "BENCH_topology.json"
    faults_path = sys.argv[3] if len(sys.argv) > 3 else None
    memory_path = sys.argv[4] if len(sys.argv) > 4 else None
    report = load(path)

    workloads = report.get("workloads")
    if not isinstance(workloads, list) or not workloads:
        fail("top-level 'workloads' missing or empty")

    strict_wins = 0
    for w in workloads:
        name = w.get("workload")
        if not isinstance(name, str) or not name:
            fail("workload entry without a 'workload' name")

        for k in WORKLOAD_COUNTERS:
            if not is_int_valued(w.get(k)):
                fail(f"{name}: counter '{k}' missing or not an integer count")

        log = w.get("pass_log")
        if not isinstance(log, list) or not log:
            fail(f"{name}: 'pass_log' missing or empty")
        names = []
        for entry in log:
            if not isinstance(entry, dict):
                fail(f"{name}: pass_log entry is not an object")
            pname = entry.get("pass")
            if not isinstance(pname, str) or not pname:
                fail(f"{name}: pass_log entry without a 'pass' name")
            names.append(pname)
            for k in ("changes", "tasks_delta", "repart_bytes_delta"):
                if not is_int_valued(entry.get(k)):
                    fail(f"{name}: pass '{pname}' field '{k}' missing or malformed")
            if int(entry["changes"]) < 0:
                fail(f"{name}: pass '{pname}' has negative change count")
        if names != EXPECTED_PASSES:
            fail(
                f"{name}: pass_log names {names} != expected pipeline "
                f"{EXPECTED_PASSES}"
            )

        # deltas must roll up to the workload totals
        dt = sum(int(e["tasks_delta"]) for e in log)
        if dt != int(w["tasks_optimized"]) - int(w["tasks_unoptimized"]):
            fail(f"{name}: sum of tasks_delta ({dt}) does not match task totals")
        db = sum(int(e["repart_bytes_delta"]) for e in log)
        if db != int(w["repart_bytes_optimized"]) - int(w["repart_bytes_unoptimized"]):
            fail(f"{name}: sum of repart_bytes_delta ({db}) does not match byte totals")

        if w.get("strict_win") is True:
            strict_wins += 1

    if strict_wins == 0:
        fail("no workload shows a strict task+byte win with the full pipeline")

    # topology sweep: per-link-class ledgers must roll up to the workload
    # byte totals, and the three-level topology must show at least one
    # strict cross-node byte reduction from lower-collectives.
    sweep = load(topo_path).get("topology_sweep")
    if not isinstance(sweep, list) or not sweep:
        fail("top-level 'topology_sweep' missing or empty")
    cross_node_wins = 0
    for e in sweep:
        name = f"{e.get('workload')}/{e.get('topology')}"
        for arm in ("safe", "collective"):
            by_link = e.get(f"bytes_by_link_{arm}")
            if not isinstance(by_link, dict) or not by_link:
                fail(f"{name}: 'bytes_by_link_{arm}' missing or empty")
            total = e.get(f"bytes_moved_{arm}")
            if not is_int_valued(total):
                fail(f"{name}: 'bytes_moved_{arm}' missing or malformed")
            classes = list(by_link.values())
            if any(not is_int_valued(b) or b < 0 for b in classes):
                fail(f"{name}: malformed per-class byte count in {arm} arm")
            if sum(int(b) for b in classes) != int(total):
                fail(
                    f"{name}: per-class bytes do not roll up to "
                    f"bytes_moved_{arm} ({classes} vs {total})"
                )
            cross = e.get(f"cross_node_bytes_{arm}")
            if not is_int_valued(cross):
                fail(f"{name}: 'cross_node_bytes_{arm}' missing or malformed")
            # cross-node = everything above the innermost link class
            if sum(int(b) for b in classes[1:]) != int(cross):
                fail(f"{name}: cross_node_bytes_{arm} inconsistent with ledger")
        if e.get("bitwise_identical_execution") is not True:
            fail(f"{name}: topology sweep entry not marked bitwise-identical")
        if int(e.get("levels", 0)) == 3 and int(
            e["cross_node_bytes_collective"]
        ) < int(e["cross_node_bytes_safe"]):
            cross_node_wins += 1
    if cross_node_wins == 0:
        fail(
            "no three-level workload shows a strict cross-node byte "
            "reduction from lower-collectives"
        )

    faults_note = check_faults(faults_path) if faults_path else ""
    memory_note = check_memory(memory_path) if memory_path else ""
    print(
        f"check_lowering_json: OK — {len(workloads)} workloads, "
        f"{len(EXPECTED_PASSES)} passes each, {strict_wins} strict win(s), "
        f"{len(sweep)} topology-sweep entries, {cross_node_wins} "
        f"cross-node win(s){faults_note}{memory_note}"
    )


if __name__ == "__main__":
    main()

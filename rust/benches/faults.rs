//! Fault-recovery benchmark: what failure costs, and that it costs
//! nothing when absent.
//!
//! For each bench workload (matrix chain, FFNN training step, one-layer
//! attention) at p = 4, runs four arms over the SAME frozen task graph
//! and precomputed model (compile-once / run-many):
//!
//! * **clean** — no faults; the recovery counters must all be zero and
//!   the modeled ledger identical to the precomputed model (the
//!   zero-overhead gate);
//! * **single_transient** — one mid-graph task fails twice and then
//!   succeeds: retries and backoff stall, no worker loss, no bytes;
//! * **single_permanent** — the final task's worker dies on first touch:
//!   pending work re-homes to survivors and reclaimed tiles are
//!   recomputed from task-graph lineage;
//! * **seeded_10pct** — a seeded 10 % transient sweep (first seed that
//!   actually arms a fault, recorded in the JSON for replay).
//!
//! Every faulted arm is executed in BOTH real-execution modes and must
//! reproduce the clean outputs bitwise; injected-fault counts are a pure
//! function of the plan, so both modes must agree on them. Counters in
//! the JSON come from the work-stealing run. Writes `BENCH_faults.json`
//! (validated by `scripts/check_lowering_json.py`, uploaded as a CI
//! artifact). Run with `EINDECOMP_SMOKE=1` for the smaller chain.
//!
//! ```sh
//! cargo bench --bench faults
//! ```

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::einsum::graph::{EinGraph, VertexId};
use eindecomp::models::ffnn::ffnn_step;
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::models::matchain::chain_graph;
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, ExecMode, FaultPlan, NetworkProfile, RunOptions};
use eindecomp::tensor::Tensor;
use eindecomp::util::Json;
use std::collections::HashMap;

const P: usize = 4;
const SEEDED_RATE: f64 = 0.1;

fn random_inputs(g: &EinGraph, seed: u64) -> HashMap<VertexId, Tensor> {
    g.inputs()
        .into_iter()
        .enumerate()
        .map(|(i, v)| (v, Tensor::random(&g.vertex(v).bound, seed + i as u64)))
        .collect()
}

fn arm_json(
    arm: &str,
    plan: &FaultPlan,
    rep: &eindecomp::sim::ExecReport,
    extra: Vec<(String, Json)>,
) -> Json {
    let mut kv = vec![
        ("arm".into(), Json::str(arm)),
        ("fault_plan".into(), Json::str(plan.to_string())),
        ("faults_injected".into(), Json::num(rep.faults_injected as f64)),
        ("retries".into(), Json::num(rep.retries as f64)),
        ("recomputed_tasks".into(), Json::num(rep.recomputed_tasks as f64)),
        ("recovery_bytes".into(), Json::num(rep.recovery_bytes as f64)),
        ("workers_lost".into(), Json::num(rep.workers_lost as f64)),
        ("recovery_stall_s".into(), Json::num(rep.recovery_stall_s)),
        ("sim_makespan_s".into(), Json::num(rep.sim_makespan_s)),
        ("bitwise_match".into(), Json::Bool(true)),
    ];
    kv.extend(extra);
    Json::Obj(kv)
}

fn main() {
    let smoke = std::env::var("EINDECOMP_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let tag = if smoke { " (smoke)" } else { "" };
    println!("=== faults: recovery overhead per workload at p={P}{tag} ===");

    let roles = LabelRoles::by_convention();
    let engine = NativeEngine::new();
    let opts = RunOptions::default();

    let workloads: Vec<(&str, EinGraph)> = vec![
        (
            "matchain",
            chain_graph(if smoke { 24 } else { 48 }, false).unwrap().graph,
        ),
        ("ffnn", ffnn_step(32, 48, 24, 8).unwrap().graph),
        (
            "attention",
            llama_graph(&LlamaConfig {
                layers: 1,
                batch: 2,
                seq: 16,
                model_dim: 32,
                heads: 2,
                head_dim: 16,
                ffn_dim: 64,
            })
            .unwrap()
            .graph,
        ),
    ];

    let mut entries: Vec<Json> = Vec::new();
    for (name, g) in &workloads {
        let plan = assign(g, &Strategy::EinDecomp, P, &roles).unwrap();
        let inputs = random_inputs(g, 4100);
        let base = Cluster::new(P, NetworkProfile::loopback());
        let tg = base.lower(g, &plan).unwrap();
        let model = base.model(&tg);
        let n = tg.tasks.len();

        // clean baseline: zero recovery overhead, ledger == model
        let (clean, clean_rep) = base
            .run_lowered_modeled_opts(g, &plan, &tg, &model, &engine, &inputs, &opts)
            .unwrap();
        assert_eq!(clean_rep.faults_injected, 0, "{name}");
        assert_eq!(clean_rep.retries, 0, "{name}");
        assert_eq!(clean_rep.recomputed_tasks, 0, "{name}");
        assert_eq!(clean_rep.recovery_bytes, 0, "{name}");
        assert_eq!(clean_rep.workers_lost, 0, "{name}");
        assert_eq!(clean_rep.recovery_stall_s, 0.0, "{name}");
        assert_eq!(
            clean_rep.sim_makespan_s, model.sim_makespan_s,
            "{name}: fault-free run must not perturb the modeled makespan"
        );

        // run one faulted arm in both modes, demand bitwise-clean outputs
        // and a schedule-independent injected count; report the
        // work-stealing counters
        let run_arm = |fp: &FaultPlan| -> eindecomp::sim::ExecReport {
            let mut ws_rep = None;
            for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
                let cluster = Cluster::new(P, NetworkProfile::loopback())
                    .with_exec_mode(mode)
                    .with_faults(fp.clone());
                let (outs, rep) = cluster
                    .run_lowered_modeled_opts(g, &plan, &tg, &model, &engine, &inputs, &opts)
                    .unwrap();
                for out in g.outputs() {
                    assert_eq!(
                        clean[&out], outs[&out],
                        "{name} [{fp}] {mode:?}: recovery diverged bitwise"
                    );
                }
                match &ws_rep {
                    None => ws_rep = Some(rep),
                    Some(first) => assert_eq!(
                        first.faults_injected, rep.faults_injected,
                        "{name} [{fp}]: injected count must be schedule-independent"
                    ),
                }
            }
            ws_rep.unwrap()
        };

        let transient_plan = FaultPlan::new().transient(n / 2, 2);
        let transient_rep = run_arm(&transient_plan);
        assert_eq!(transient_rep.faults_injected, 1, "{name}");
        assert!(transient_rep.retries >= 2, "{name}: two failures need two retries");
        assert_eq!(transient_rep.workers_lost, 0, "{name}");
        assert_eq!(
            transient_rep.recovery_bytes, 0,
            "{name}: transient faults move no bytes"
        );
        assert!(
            transient_rep.sim_makespan_s > clean_rep.sim_makespan_s,
            "{name}: retry stall must show up in the modeled makespan"
        );

        let permanent_plan = FaultPlan::new().permanent(n - 1);
        let permanent_rep = run_arm(&permanent_plan);
        assert_eq!(permanent_rep.faults_injected, 1, "{name}");
        assert_eq!(permanent_rep.workers_lost, 1, "{name}");
        assert!(permanent_rep.retries >= 1, "{name}");
        assert!(
            permanent_rep.sim_makespan_s > clean_rep.sim_makespan_s,
            "{name}: worker death must show up in the modeled makespan"
        );

        // seeded sweep: first seed that actually arms a fault (arming is
        // a pure function of (seed, rate, task count), so the recorded
        // seed replays identically — scripts/chaos_smoke.sh relies on it)
        let (seed, seeded_plan, seeded_rep) = (1u64..=64)
            .find_map(|seed| {
                let fp = FaultPlan::seeded(seed, SEEDED_RATE);
                let rep = run_arm(&fp);
                (rep.faults_injected > 0).then_some((seed, fp, rep))
            })
            .expect("no seed in 1..=64 armed a fault at rate 0.1");
        assert!(
            seeded_rep.retries >= seeded_rep.faults_injected,
            "{name}: every injected failure costs at least one retry"
        );

        println!(
            "{name:<10} tasks {n:>3} | clean {:>9.3}ms | transient {:>9.3}ms \
             | permanent {:>9.3}ms ({} recomputed, {} recovery B) \
             | seed {seed} x{}",
            clean_rep.sim_makespan_s * 1e3,
            transient_rep.sim_makespan_s * 1e3,
            permanent_rep.sim_makespan_s * 1e3,
            permanent_rep.recomputed_tasks,
            permanent_rep.recovery_bytes,
            seeded_rep.faults_injected,
        );

        entries.push(Json::Obj(vec![
            ("workload".into(), Json::str(*name)),
            ("tasks".into(), Json::num(n as f64)),
            (
                "arms".into(),
                Json::Arr(vec![
                    arm_json("clean", &FaultPlan::new(), &clean_rep, vec![]),
                    arm_json("single_transient", &transient_plan, &transient_rep, vec![]),
                    arm_json("single_permanent", &permanent_plan, &permanent_rep, vec![]),
                    arm_json(
                        "seeded_10pct",
                        &seeded_plan,
                        &seeded_rep,
                        vec![("seed".into(), Json::num(seed as f64))],
                    ),
                ]),
            ),
        ]));
    }

    let report = Json::Obj(vec![
        ("p".into(), Json::num(P as f64)),
        ("seeded_rate".into(), Json::num(SEEDED_RATE)),
        ("workloads".into(), Json::Arr(entries)),
    ]);
    std::fs::write("BENCH_faults.json", report.render()).expect("write BENCH_faults.json");
    println!("wrote BENCH_faults.json");
}

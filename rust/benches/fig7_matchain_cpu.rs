//! Figure 7: EinDecomp vs SQRT vs ScaLAPACK on the matrix chain
//! `(A x B) + (C x (D x E))`, CPU-cluster profile (16 workers, 100 Gb/s).
//!
//! Paper shape to reproduce: EinDecomp ≈ SQRT on uniform sizes (both find
//! the square decomposition), EinDecomp ~2x better on skewed sizes (SQRT
//! cannot adapt), ScaLAPACK far behind (and OOM at large scale).
//!
//! ScaLAPACK proxy: SQRT partitioning + master-distributed inputs (no
//! free pre-placement) + round-robin placement — the redistribution
//! behaviour of a driver-fed PBLAS run. Our substitute cannot reproduce
//! ScaLAPACK's internal constant factors, only its extra distribution
//! traffic; DESIGN.md §Deviations discusses this.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::models::matchain::{chain_graph, chain_inputs};
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, NetworkProfile};
use eindecomp::taskgraph::TaskKind;

fn main() {
    let p = 16;
    let roles = LabelRoles::by_convention();
    let net = NetworkProfile::cpu_cluster();
    let cluster = Cluster::new(p, net);

    for skewed in [false, true] {
        println!(
            "\n=== Fig 7 ({}) | p={p}, cpu-cluster ===",
            if skewed { "skewed" } else { "uniform" }
        );
        println!(
            "{:>7} {:>14} {:>14} {:>14} {:>22}   (modeled seconds; lower is better)",
            "s", "eindecomp", "sqrt", "scalapack*", "moved GiB (ein/sqrt)"
        );
        for s in [640usize, 1280, 2560, 5120, 10240] {
            let chain = chain_graph(s, skewed).unwrap();
            let mut row = format!("{s:>7}");
            let mut moved = Vec::new();
            // eindecomp + sqrt: standard modeled run
            for strat in [Strategy::EinDecomp, Strategy::Sqrt] {
                let plan = assign(&chain.graph, &strat, p, &roles).unwrap();
                let rep = cluster.dry_run(&chain.graph, &plan).unwrap();
                row += &format!(" {:>14.6}", rep.sim_makespan_s);
                moved.push(rep.bytes_moved as f64 / (1u64 << 30) as f64);
            }
            // scalapack proxy: sqrt plan, master-held inputs (no free
            // pre-placement) — its NIC serializes the distribution
            let plan = assign(&chain.graph, &Strategy::Sqrt, p, &roles).unwrap();
            let mut tg = cluster.lower(&chain.graph, &plan).unwrap();
            for t in tg.tasks.iter_mut() {
                if matches!(t.kind, TaskKind::InputTile { .. }) {
                    t.worker = Some(0); // master distributes everything
                }
            }
            let rep = cluster.model(&tg);
            row += &format!(" {:>14.6}", rep.sim_makespan_s);
            row += &format!("      {:>6.3} / {:>6.3}", moved[0], moved[1]);
            println!("{row}");
        }
    }

    // small-scale REAL execution sanity (wall-clock, native kernels)
    println!("\n--- real execution at s=320 (wall ms, median of 3) ---");
    let engine = NativeEngine::new();
    for skewed in [false, true] {
        let chain = chain_graph(320, skewed).unwrap();
        let inputs = chain_inputs(&chain, 3);
        print!("{:>8}:", if skewed { "skewed" } else { "uniform" });
        for strat in [Strategy::EinDecomp, Strategy::Sqrt] {
            let plan = assign(&chain.graph, &strat, p, &roles).unwrap();
            let mut times = Vec::new();
            for _ in 0..3 {
                let (_, rep) = cluster
                    .execute(&chain.graph, &plan, &engine, &inputs)
                    .unwrap();
                times.push(rep.wall_s);
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            print!("  {}={:.1}ms", strat.name(), times[1] * 1e3);
        }
        println!();
    }
}

//! Hot-path microbenchmarks used by the §Perf pass (EXPERIMENTS.md):
//! GEMM throughput, the GEMM intra-op A/B (serial vs row-sharded packed
//! kernel at 1/2/4/8 shards), permutation bandwidth, einsum dispatch,
//! lowering and planning rates, and the real-execution scheduler A/B
//! (work stealing vs the retained level-barrier reference). Run with
//! `cargo bench micro`
//! (harness=false). Set `EINDECOMP_SMOKE=1` for the capped configuration
//! used by `rust/scripts/bench_smoke.sh` / CI.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::einsum::expr::EinSum;
use eindecomp::einsum::label::labels;
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::runtime::gemm::{sgemm, sgemm_scoped};
use eindecomp::runtime::native::eval_einsum;
use eindecomp::runtime::{Backend, DispatchEngine, KernelEngine};
use eindecomp::sim::{Cluster, ExecMode, NetworkProfile};
use eindecomp::tensor::Tensor;
use eindecomp::util::with_intra_op_pool;

fn time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    // warmup
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let smoke = std::env::var("EINDECOMP_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    println!("=== L3 hot-path microbenchmarks{} ===", if smoke { " (smoke)" } else { "" });

    // 1. raw GEMM
    let gemm_sizes: &[usize] = if smoke { &[128, 256] } else { &[128, 256, 512, 1024] };
    for &n in gemm_sizes {
        let a = Tensor::random(&[n, n], 1);
        let b = Tensor::random(&[n, n], 2);
        let mut c = vec![0.0f32; n * n];
        let dt = time(
            || sgemm(n, n, n, 1.0, a.data(), b.data(), 0.0, &mut c),
            if n <= 256 { 20 } else { 5 },
        );
        let gflops = 2.0 * (n as f64).powi(3) / dt / 1e9;
        println!("sgemm {n:>5}^3: {:>8.2} ms  {gflops:>7.2} GFLOP/s", dt * 1e3);
    }

    // 1b. GEMM intra-op A/B: serial packed kernel vs row-sharded under a
    // standalone intra-op pool at 1/2/4/8 shards. The acceptance line the
    // docs quote (rust/README.md) is the 8-shard speedup; outputs are
    // asserted bitwise-identical to serial while we are at it.
    let n = if smoke { 256 } else { 512 };
    let a = Tensor::random(&[n, n], 11);
    let b = Tensor::random(&[n, n], 12);
    let (ad, bd) = (a.data(), b.data());
    let reps_ab = if smoke { 10 } else { 5 };
    let mut serial_c = vec![0.0f32; n * n];
    let serial_dt = time(|| sgemm(n, n, n, 1.0, ad, bd, 0.0, &mut serial_c), reps_ab);
    println!(
        "sgemm {n:>5}^3 serial:     {:>8.2} ms  {:>7.2} GFLOP/s",
        serial_dt * 1e3,
        2.0 * (n as f64).powi(3) / serial_dt / 1e9
    );
    for shards in [1usize, 2, 4, 8] {
        let mut c = vec![0.0f32; n * n];
        let dt = with_intra_op_pool(shards, |scope| {
            time(|| sgemm_scoped(n, n, n, 1.0, ad, bd, 0.0, &mut c, scope), reps_ab)
        });
        assert_eq!(c, serial_c, "sharded GEMM diverged at {shards} shards");
        println!(
            "sgemm {n:>5}^3 intra-op {shards}: {:>8.2} ms  {:>7.2} GFLOP/s  speedup {:>5.2}x",
            dt * 1e3,
            2.0 * (n as f64).powi(3) / dt / 1e9,
            serial_dt / dt
        );
    }

    // 2. permutation bandwidth (the "unpack" step)
    let perm_sizes: &[usize] = if smoke { &[256] } else { &[256, 1024] };
    for &n in perm_sizes {
        let t = Tensor::random(&[n, n], 3);
        let dt = time(|| { let _ = t.permute(&[1, 0]).unwrap(); }, 10);
        let gbps = (n * n * 4) as f64 / dt / 1e9;
        println!("permute {n:>4}x{n:<4}: {:>8.3} ms  {gbps:>7.2} GB/s", dt * 1e3);
    }

    // 3. einsum dispatch overhead: BMM path on small tiles
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    let x = Tensor::random(&[64, 64], 4);
    let y = Tensor::random(&[64, 64], 5);
    let dt = time(|| { let _ = eval_einsum(&op, &[&x, &y]).unwrap(); }, 200);
    println!("eval_einsum 64^3 (native): {:>8.1} us", dt * 1e6);
    if let Ok(engine) = DispatchEngine::new(Backend::Auto, "artifacts") {
        if engine.has_pjrt() {
            let dt = time(|| { let _ = engine.eval(&op, &[&x, &y]).unwrap(); }, 200);
            println!("eval_einsum 64^3 (pjrt):   {:>8.1} us", dt * 1e6);
        }
    }

    // 4. planning + lowering throughput on a 32-layer LLaMA graph
    let roles = LabelRoles::by_convention();
    if !smoke {
        let model = llama_graph(&LlamaConfig::llama7b(8, 1024)).unwrap();
        println!("LLaMA-7B full graph: {} vertices", model.graph.len());
        let t0 = std::time::Instant::now();
        let plan = assign(&model.graph, &Strategy::EinDecomp, 8, &roles).unwrap();
        println!("plan 32-layer graph (p=8): {:>8.1} ms", t0.elapsed().as_secs_f64() * 1e3);
        let cluster = Cluster::new(8, NetworkProfile::gpu_server_v100());
        let t0 = std::time::Instant::now();
        let tg = cluster.lower(&model.graph, &plan).unwrap();
        println!(
            "lower+place ({} tasks):    {:>8.1} ms",
            tg.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );
        let t0 = std::time::Instant::now();
        let _ = cluster.model(&tg);
        println!("model timeline:            {:>8.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    }

    // 5. end-to-end real execution: work-stealing vs level-barrier A/B.
    // The tiny-llama stack is deep (hundreds of levels, few tasks per
    // level) — exactly the shape where per-level barriers idle cores and
    // dependency-counted overlap pays off.
    let engine = eindecomp::runtime::NativeEngine::new();
    let reps = if smoke { 3 } else { 5 };

    let tiny = llama_graph(&LlamaConfig {
        layers: if smoke { 2 } else { 4 },
        batch: 2,
        seq: 32,
        model_dim: 64,
        heads: 2,
        head_dim: 32,
        ffn_dim: 128,
    })
    .unwrap();
    let inputs = eindecomp::models::llama::llama_inputs(&tiny, 6);
    let plan = assign(&tiny.graph, &Strategy::EinDecomp, 4, &roles).unwrap();
    scheduler_ab("tiny llama step", 4, &tiny.graph, &plan, &inputs, &engine, reps);

    // same A/B on a wide-and-shallow graph (many tasks per level): the
    // barrier is cheap here, so this bounds the scheduler's overhead.
    let chain_scale = if smoke { 160 } else { 320 };
    let chain = eindecomp::models::matchain::chain_graph(chain_scale, true).unwrap();
    let cinputs = eindecomp::models::matchain::chain_inputs(&chain, 7);
    let cplan = assign(&chain.graph, &Strategy::EinDecomp, 8, &roles).unwrap();
    scheduler_ab("skewed chain   ", 8, &chain.graph, &cplan, &cinputs, &engine, reps);
}

/// One barrier-vs-steal A/B measurement over a placed plan: times both
/// exec modes and prints the speedup line the acceptance criteria read.
fn scheduler_ab(
    label: &str,
    workers: usize,
    g: &eindecomp::einsum::graph::EinGraph,
    plan: &eindecomp::decomp::Plan,
    inputs: &std::collections::HashMap<eindecomp::einsum::graph::VertexId, Tensor>,
    engine: &eindecomp::runtime::NativeEngine,
    reps: usize,
) {
    let mut wall = Vec::new();
    for mode in [ExecMode::LevelBarrier, ExecMode::WorkStealing] {
        let cluster = Cluster::new(workers, NetworkProfile::loopback()).with_exec_mode(mode);
        let dt = time(
            || {
                let _ = cluster.execute(g, plan, engine, inputs).unwrap();
            },
            reps,
        );
        println!("{label} ({mode:?}): {:>8.1} ms", dt * 1e3);
        wall.push(dt);
    }
    println!(
        "scheduler speedup (barrier/steal): {:>5.2}x",
        wall[0] / wall[1]
    );
}

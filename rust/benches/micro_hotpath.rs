//! Hot-path microbenchmarks used by the §Perf pass (EXPERIMENTS.md):
//! GEMM throughput, the GEMM intra-op A/B (serial vs row-sharded packed
//! kernel at 1/2/4/8 shards), permutation bandwidth, einsum dispatch,
//! lowering and planning rates, the real-execution scheduler A/B
//! (work stealing vs the retained level-barrier reference), and the
//! zero-copy data-plane A/B (owned-tile copies vs strided views on
//! partition / assemble / repartition and the end-to-end `ij,jk->ik` TRA
//! path). Run with `cargo bench micro`
//! (harness=false). Set `EINDECOMP_SMOKE=1` for the capped configuration
//! used by `rust/scripts/bench_smoke.sh` / CI. Data-plane timings are
//! also written to `BENCH_micro.json` (`{op, shape, mode, ns_per_iter}`
//! entries) so the perf trajectory is tracked across PRs; CI uploads the
//! file as an artifact.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::einsum::expr::EinSum;
use eindecomp::einsum::label::{concat_dedup, labels, project};
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::runtime::gemm::{sgemm, sgemm_scoped};
use eindecomp::runtime::native::eval_einsum;
use eindecomp::runtime::{Backend, DispatchEngine, KernelEngine, NativeEngine};
use eindecomp::sim::{Cluster, ExecMode, NetworkProfile};
use eindecomp::tensor::{Tensor, TensorView};
use eindecomp::tra::ops::{aggregate, join, repartition};
use eindecomp::tra::relation::TensorRelation;
use eindecomp::util::{with_intra_op_pool, Json};

fn time<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    // warmup
    f();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let smoke = std::env::var("EINDECOMP_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    println!("=== L3 hot-path microbenchmarks{} ===", if smoke { " (smoke)" } else { "" });

    // 1. raw GEMM
    let gemm_sizes: &[usize] = if smoke { &[128, 256] } else { &[128, 256, 512, 1024] };
    for &n in gemm_sizes {
        let a = Tensor::random(&[n, n], 1);
        let b = Tensor::random(&[n, n], 2);
        let mut c = vec![0.0f32; n * n];
        let dt = time(
            || sgemm(n, n, n, 1.0, a.data(), b.data(), 0.0, &mut c),
            if n <= 256 { 20 } else { 5 },
        );
        let gflops = 2.0 * (n as f64).powi(3) / dt / 1e9;
        println!("sgemm {n:>5}^3: {:>8.2} ms  {gflops:>7.2} GFLOP/s", dt * 1e3);
    }

    // 1b. GEMM intra-op A/B: serial packed kernel vs row-sharded under a
    // standalone intra-op pool at 1/2/4/8 shards. The acceptance line the
    // docs quote (rust/README.md) is the 8-shard speedup; outputs are
    // asserted bitwise-identical to serial while we are at it.
    let n = if smoke { 256 } else { 512 };
    let a = Tensor::random(&[n, n], 11);
    let b = Tensor::random(&[n, n], 12);
    let (ad, bd) = (a.data(), b.data());
    let reps_ab = if smoke { 10 } else { 5 };
    let mut serial_c = vec![0.0f32; n * n];
    let serial_dt = time(|| sgemm(n, n, n, 1.0, ad, bd, 0.0, &mut serial_c), reps_ab);
    println!(
        "sgemm {n:>5}^3 serial:     {:>8.2} ms  {:>7.2} GFLOP/s",
        serial_dt * 1e3,
        2.0 * (n as f64).powi(3) / serial_dt / 1e9
    );
    for shards in [1usize, 2, 4, 8] {
        let mut c = vec![0.0f32; n * n];
        let dt = with_intra_op_pool(shards, |scope| {
            time(|| sgemm_scoped(n, n, n, 1.0, ad, bd, 0.0, &mut c, scope), reps_ab)
        });
        assert_eq!(c, serial_c, "sharded GEMM diverged at {shards} shards");
        println!(
            "sgemm {n:>5}^3 intra-op {shards}: {:>8.2} ms  {:>7.2} GFLOP/s  speedup {:>5.2}x",
            dt * 1e3,
            2.0 * (n as f64).powi(3) / dt / 1e9,
            serial_dt / dt
        );
    }

    // 2. permutation bandwidth (the "unpack" step)
    let perm_sizes: &[usize] = if smoke { &[256] } else { &[256, 1024] };
    for &n in perm_sizes {
        let t = Tensor::random(&[n, n], 3);
        let dt = time(|| { let _ = t.permute(&[1, 0]).unwrap(); }, 10);
        let gbps = (n * n * 4) as f64 / dt / 1e9;
        println!("permute {n:>4}x{n:<4}: {:>8.3} ms  {gbps:>7.2} GB/s", dt * 1e3);
    }

    // 3. einsum dispatch overhead: BMM path on small tiles
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    let x = Tensor::random(&[64, 64], 4);
    let y = Tensor::random(&[64, 64], 5);
    let dt = time(|| { let _ = eval_einsum(&op, &[&x, &y]).unwrap(); }, 200);
    println!("eval_einsum 64^3 (native): {:>8.1} us", dt * 1e6);
    if let Ok(engine) = DispatchEngine::new(Backend::Auto, "artifacts") {
        if engine.has_pjrt() {
            let dt = time(|| { let _ = engine.eval(&op, &[&x, &y]).unwrap(); }, 200);
            println!("eval_einsum 64^3 (pjrt):   {:>8.1} us", dt * 1e6);
        }
    }

    // 4. planning + lowering throughput on a 32-layer LLaMA graph
    let roles = LabelRoles::by_convention();
    if !smoke {
        let model = llama_graph(&LlamaConfig::llama7b(8, 1024)).unwrap();
        println!("LLaMA-7B full graph: {} vertices", model.graph.len());
        let t0 = std::time::Instant::now();
        let plan = assign(&model.graph, &Strategy::EinDecomp, 8, &roles).unwrap();
        println!("plan 32-layer graph (p=8): {:>8.1} ms", t0.elapsed().as_secs_f64() * 1e3);
        let cluster = Cluster::new(8, NetworkProfile::gpu_server_v100());
        let t0 = std::time::Instant::now();
        let tg = cluster.lower(&model.graph, &plan).unwrap();
        println!(
            "lower+place ({} tasks):    {:>8.1} ms",
            tg.len(),
            t0.elapsed().as_secs_f64() * 1e3
        );
        let t0 = std::time::Instant::now();
        let _ = cluster.model(&tg);
        println!("model timeline:            {:>8.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    }

    // 5. end-to-end real execution: work-stealing vs level-barrier A/B.
    // The tiny-llama stack is deep (hundreds of levels, few tasks per
    // level) — exactly the shape where per-level barriers idle cores and
    // dependency-counted overlap pays off.
    let engine = eindecomp::runtime::NativeEngine::new();
    let reps = if smoke { 3 } else { 5 };

    let tiny = llama_graph(&LlamaConfig {
        layers: if smoke { 2 } else { 4 },
        batch: 2,
        seq: 32,
        model_dim: 64,
        heads: 2,
        head_dim: 32,
        ffn_dim: 128,
    })
    .unwrap();
    let inputs = eindecomp::models::llama::llama_inputs(&tiny, 6);
    let plan = assign(&tiny.graph, &Strategy::EinDecomp, 4, &roles).unwrap();
    scheduler_ab("tiny llama step", 4, &tiny.graph, &plan, &inputs, &engine, reps);

    // same A/B on a wide-and-shallow graph (many tasks per level): the
    // barrier is cheap here, so this bounds the scheduler's overhead.
    let chain_scale = if smoke { 160 } else { 320 };
    let chain = eindecomp::models::matchain::chain_graph(chain_scale, true).unwrap();
    let cinputs = eindecomp::models::matchain::chain_inputs(&chain, 7);
    let cplan = assign(&chain.graph, &Strategy::EinDecomp, 8, &roles).unwrap();
    scheduler_ab("skewed chain   ", 8, &chain.graph, &cplan, &cinputs, &engine, reps);

    // 6. zero-copy data plane A/B: owned-tile copies vs strided views.
    // Timings are recorded into BENCH_micro.json for cross-PR tracking.
    let mut entries: Vec<Json> = Vec::new();
    let np = if smoke { 512 } else { 1024 };
    let dense = Tensor::random(&[np, np], 20);
    let reps_dp = if smoke { 20 } else { 10 };
    let shape2 = format!("{np}x{np}");
    let dt_pc = time(
        || {
            let _ = TensorRelation::partition_owned(&dense, &[4, 4]).unwrap();
        },
        reps_dp,
    );
    let dt_pv = time(
        || {
            let _ = TensorRelation::partition(&dense, &[4, 4]).unwrap();
        },
        reps_dp,
    );
    println!(
        "partition {shape2} d=[4,4]  copy: {:>9.1} us  view: {:>9.1} us  speedup {:>6.1}x",
        dt_pc * 1e6,
        dt_pv * 1e6,
        dt_pc / dt_pv
    );
    record(&mut entries, "partition", &shape2, "copy", dt_pc);
    record(&mut entries, "partition", &shape2, "view", dt_pv);
    let rel_owned = TensorRelation::partition_owned(&dense, &[4, 4]).unwrap();
    let rel_view = TensorRelation::partition(&dense, &[4, 4]).unwrap();
    let dt_ac = time(|| { let _ = rel_owned.assemble().unwrap(); }, reps_dp);
    let dt_av = time(|| { let _ = rel_view.assemble().unwrap(); }, reps_dp);
    assert_eq!(rel_owned.assemble().unwrap(), rel_view.assemble().unwrap());
    println!(
        "assemble  {shape2} d=[4,4]  copy: {:>9.1} us  view: {:>9.1} us",
        dt_ac * 1e6,
        dt_av * 1e6
    );
    record(&mut entries, "assemble", &shape2, "copy", dt_ac);
    record(&mut entries, "assemble", &shape2, "view", dt_av);
    // repartition [4,4] -> [8,2]: the old path assembled the full dense
    // tensor and re-sliced it; the new path moves only overlapping
    // sub-regions tile-to-tile (aliasing contained tiles).
    let dt_rc = time(
        || {
            let d = rel_owned.assemble().unwrap();
            let _ = TensorRelation::partition_owned(&d, &[8, 2]).unwrap();
        },
        reps_dp,
    );
    let dt_rv = time(|| { let _ = repartition(&rel_view, &[8, 2]).unwrap(); }, reps_dp);
    println!(
        "repart    {shape2} [4,4]->[8,2]  copy: {:>9.1} us  view: {:>9.1} us  speedup {:>6.1}x",
        dt_rc * 1e6,
        dt_rv * 1e6,
        dt_rc / dt_rv
    );
    record(&mut entries, "repartition", &shape2, "copy", dt_rc);
    record(&mut entries, "repartition", &shape2, "view", dt_rv);

    // End-to-end ij,jk->ik TRA path at d = [2,2,4] — the acceptance
    // gate reads this line: the view pipeline must be >= 1.5x the serial
    // copy-based baseline, bitwise-identical. A movement-bound shape
    // (skinny contracted dim) isolates the data plane the way the
    // post-decomposition tiles on real graphs do.
    let (mt, jt) = if smoke { (768, 8) } else { (1024, 8) };
    let tx = Tensor::random(&[mt, jt], 21);
    let ty = Tensor::random(&[jt, mt], 22);
    let d224 = [2usize, 2, 4];
    let shape_tra = format!("{mt}x{jt}x{mt}");
    let reps_tra = if smoke { 10 } else { 5 };
    let base = tra_matmul(&tx, &ty, &d224, true);
    let view = tra_matmul(&tx, &ty, &d224, false);
    assert_eq!(view, base, "TRA view path diverged from copy baseline");
    let dt_tc = time(|| { let _ = tra_matmul(&tx, &ty, &d224, true); }, reps_tra);
    let dt_tv = time(|| { let _ = tra_matmul(&tx, &ty, &d224, false); }, reps_tra);
    println!(
        "TRA ij,jk->ik {shape_tra} d=[2,2,4]  copy: {:>8.2} ms  view: {:>8.2} ms  speedup {:>5.2}x",
        dt_tc * 1e3,
        dt_tv * 1e3,
        dt_tc / dt_tv
    );
    record(&mut entries, "tra_matmul", &shape_tra, "copy", dt_tc);
    record(&mut entries, "tra_matmul", &shape_tra, "view", dt_tv);

    let report = Json::Obj(vec![
        ("schema".into(), Json::str("eindecomp-bench-micro/v1")),
        ("smoke".into(), Json::Bool(smoke)),
        ("entries".into(), Json::Arr(entries)),
    ]);
    std::fs::write("BENCH_micro.json", report.render()).expect("write BENCH_micro.json");
    println!("wrote BENCH_micro.json");
}

/// Append one `{op, shape, mode, ns_per_iter}` record.
fn record(entries: &mut Vec<Json>, op: &str, shape: &str, mode: &str, secs_per_iter: f64) {
    entries.push(Json::Obj(vec![
        ("op".into(), Json::str(op)),
        ("shape".into(), Json::str(shape)),
        ("mode".into(), Json::str(mode)),
        ("ns_per_iter".into(), Json::num(secs_per_iter * 1e9)),
    ]));
}

/// One serial `ij,jk->ik` evaluation through the TRA rewrite.
/// `copy_based = true` replays the pre-refactor data plane: owned-tile
/// partitioning, per-call operand materialization onto the canonical
/// layout, and a fresh (unpooled) output buffer per kernel call — the
/// three copy seams the zero-copy refactor deleted. Both modes return
/// bitwise-identical tensors.
fn tra_matmul(x: &Tensor, y: &Tensor, d: &[usize], copy_based: bool) -> Tensor {
    let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
    let (lx, ly, lz) = (labels("i j"), labels("j k"), labels("i k"));
    let uniq = op.unique_labels();
    let dx = project(d, &lx, &uniq);
    let dy = project(d, &ly, &uniq);
    let dz = project(d, &lz, &uniq);
    let bz = vec![x.shape()[0], y.shape()[1]];
    let engine = NativeEngine::new();
    let (rx, ry) = if copy_based {
        (
            TensorRelation::partition_owned(x, &dx).unwrap(),
            TensorRelation::partition_owned(y, &dy).unwrap(),
        )
    } else {
        (
            TensorRelation::partition(x, &dx).unwrap(),
            TensorRelation::partition(y, &dy).unwrap(),
        )
    };
    let mut kernel = |a: &TensorView, b: &TensorView| {
        if copy_based {
            // pre-refactor seams: permute-materialize both operands onto
            // the canonical layout, re-pack the result into a fresh Vec
            let ao = Tensor::new(a.shape().to_vec(), a.to_vec()).unwrap();
            let bo = Tensor::new(b.shape().to_vec(), b.to_vec()).unwrap();
            let z = engine.eval(&op, &[&ao, &bo]).unwrap();
            Tensor::new(z.shape().to_vec(), z.data().to_vec())
        } else {
            engine.eval_view(&op, &[a, b])
        }
    };
    let joined = join(&rx, &ry, &lx, &ly, &mut kernel).unwrap();
    let lj = concat_dedup(&lx, &ly);
    let grouped = aggregate(joined, &lj, &lz, eindecomp::einsum::expr::AggOp::Sum).unwrap();
    let tiles: Vec<Tensor> = grouped.into_iter().map(|(_, t)| t).collect();
    TensorRelation::from_tiles(bz, dz, tiles)
        .unwrap()
        .assemble()
        .unwrap()
}

/// One barrier-vs-steal A/B measurement over a placed plan: times both
/// exec modes and prints the speedup line the acceptance criteria read.
fn scheduler_ab(
    label: &str,
    workers: usize,
    g: &eindecomp::einsum::graph::EinGraph,
    plan: &eindecomp::decomp::Plan,
    inputs: &std::collections::HashMap<eindecomp::einsum::graph::VertexId, Tensor>,
    engine: &eindecomp::runtime::NativeEngine,
    reps: usize,
) {
    let mut wall = Vec::new();
    for mode in [ExecMode::LevelBarrier, ExecMode::WorkStealing] {
        let cluster = Cluster::new(workers, NetworkProfile::loopback()).with_exec_mode(mode);
        let dt = time(
            || {
                let _ = cluster.execute(g, plan, engine, inputs).unwrap();
            },
            reps,
        );
        println!("{label} ({mode:?}): {:>8.1} ms", dt * 1e3);
        wall.push(dt);
    }
    println!(
        "scheduler speedup (barrier/steal): {:>5.2}x",
        wall[0] / wall[1]
    );
}

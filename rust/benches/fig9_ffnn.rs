//! Figure 9: EinDecomp vs data-parallel PyTorch on the high-dimensional
//! FFNN classifier training step (AmazonCat-14K dimensions: 14,588
//! labels, 8,192 hidden, features swept up to 597,540; batch 128 & 512;
//! 4 P100-class devices).
//!
//! Paper shape to reproduce: data parallelism collapses (the whole model
//! must be broadcast every step while the batch is small) — PyTorch on
//! ONE GPU beats PyTorch-DP on four — while EinDecomp picks a far better
//! mixed decomposition. Baseline proxies: `data-parallel` (batch-sharded,
//! weights replicated = PyTorch-DDP's traffic pattern) and the same on a
//! single worker (no broadcast) for the 1-GPU line.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::models::ffnn::ffnn_step;
use eindecomp::sim::{Cluster, NetworkProfile};

fn main() {
    let roles = LabelRoles::by_convention();
    let net = NetworkProfile::gpu_server_p100();
    let p = 4;
    let cluster = Cluster::new(p, net.clone());
    let single = Cluster::new(1, net);
    let hidden = 8192;
    let classes = 14_588;

    for batch in [128usize, 512] {
        println!(
            "\n=== Fig 9 | batch={batch}, hidden={hidden}, classes={classes}, 4xP100 ==="
        );
        println!(
            "{:>9} {:>14} {:>16} {:>14} {:>18}",
            "features", "eindecomp", "data-parallel", "1-gpu", "dp bytes moved GiB"
        );
        for features in [8_192usize, 32_768, 131_072, 262_144, 597_540] {
            let step = ffnn_step(batch, features, hidden, classes).unwrap();
            // EinDecomp on 4 devices
            let ein = assign(&step.graph, &Strategy::EinDecomp, p, &roles).unwrap();
            let ein_rep = cluster.dry_run(&step.graph, &ein).unwrap();
            // data parallel on 4 devices: batch sharded; weights must be
            // re-broadcast each step (model as master-held weight inputs)
            let dp = assign(&step.graph, &Strategy::DataParallel, p, &roles).unwrap();
            let mut tg = cluster.lower(&step.graph, &dp).unwrap();
            for t in tg.tasks.iter_mut() {
                if let eindecomp::taskgraph::TaskKind::InputTile { vertex, .. } = &t.kind {
                    let name = &step.graph.vertex(*vertex).name;
                    if name.starts_with('W') {
                        t.worker = Some(0); // parameter holder broadcasts
                    }
                }
            }
            let dp_rep = cluster.model(&tg);
            // single device: no communication at all
            let one = assign(&step.graph, &Strategy::DataParallel, 1, &roles).unwrap();
            let one_rep = single.dry_run(&step.graph, &one).unwrap();
            println!(
                "{features:>9} {:>14.4} {:>16.4} {:>14.4} {:>18.2}",
                ein_rep.sim_makespan_s,
                dp_rep.sim_makespan_s,
                one_rep.sim_makespan_s,
                dp_rep.bytes_moved as f64 / (1u64 << 30) as f64
            );
        }
    }
}

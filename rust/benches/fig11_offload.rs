//! Figure 11: Einsummable (EinDecomp + TURNIP-style paging) vs
//! ZeRO-Inference vs FlexGen for memory-constrained LLaMA first-token
//! inference. A100 server profile (8 x 40 GB), batch 16, sweeping the
//! sequence length; 7B and 65B shapes, full-depth graphs.
//!
//! Policy mapping (DESIGN.md §Deviations):
//!  * einsummable — EinDecomp plan, weights resident (sharded by the
//!    plan), LRU paging to host under the 40 GB/device budget;
//!  * zero        — data-parallel plan, weights sharded and gathered over
//!    the interconnect on every use (ZeRO-Inference's layer broadcast);
//!  * flexgen     — data-parallel plan, weights streamed from host RAM on
//!    every use (FlexGen's offload schedule).
//!
//! Paper shape to reproduce: einsummable fastest, gap growing with the
//! sequence length; the 65B model runs at all (241 GiB of f32 weights)
//! because paging/sharding replaces OOM.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::models::llama::{llama_graph, llama_inputs, weight_bytes, weight_set, LlamaConfig};
use eindecomp::runtime::{MemoryBudget, NativeEngine};
use eindecomp::sim::memory::{model_with_memory, MemoryConfig, WeightPolicy};
use eindecomp::sim::{Cluster, ExecMode, NetworkProfile};
use eindecomp::util::Json;

fn main() {
    let p = 8;
    let cap = 40u64 << 30; // 40 GB per device
    let roles = LabelRoles::by_convention();
    let net = NetworkProfile::gpu_server_a100();
    let cluster = Cluster::new(p, net.clone());

    for (name, layers, mk, seqs) in [
        (
            "LLaMA-7B",
            32usize,
            (&|seq| LlamaConfig::llama7b(16, seq)) as &dyn Fn(usize) -> LlamaConfig,
            vec![512usize, 1024, 2048, 4096],
        ),
        (
            "LLaMA-65B",
            80,
            &|seq| LlamaConfig::llama65b(16, seq),
            vec![512usize, 1024, 2048],
        ),
    ] {
        println!("\n=== Fig 11 {name} | batch=16, A100x8, 40GB/device, {layers} layers ===");
        println!(
            "{:>6} {:>14} {:>12} {:>12} {:>14} {:>12}",
            "seq", "einsummable", "zero", "flexgen", "eins paged GiB", "ein speedup"
        );
        for &seq in &seqs {
            let cfg = mk(seq);
            let model = llama_graph(&cfg).unwrap();
            let weights = weight_set(&model);
            let mut cells = Vec::new();
            let mut paged = 0f64;
            for (strat, policy) in [
                (Strategy::EinDecomp, WeightPolicy::Resident),
                (Strategy::DataParallel, WeightPolicy::ZeroSharded),
                (Strategy::DataParallel, WeightPolicy::HostStreamed),
            ] {
                let plan = assign(&model.graph, &strat, p, &roles).unwrap();
                let tg = cluster.lower(&model.graph, &plan).unwrap();
                let mem = MemoryConfig {
                    capacity_bytes: cap,
                    weight_policy: policy,
                };
                let rep = model_with_memory(&tg, &net, p, &mem, &weights);
                cells.push(rep.sim_makespan_s);
                if policy == WeightPolicy::Resident {
                    paged = rep.bytes_paged as f64 / (1u64 << 30) as f64;
                }
            }
            println!(
                "{seq:>6} {:>14.3} {:>12.3} {:>12.3} {:>14.2} {:>11.2}x",
                cells[0],
                cells[1],
                cells[2],
                paged,
                cells[1].min(cells[2]) / cells[0]
            );
        }
        println!(
            "(weights: {:.1} GiB total at f32)",
            weight_bytes(&llama_graph(&mk(512)).unwrap()) as f64 / (1u64 << 30) as f64
        );
    }

    // ---------- real-executor arm: out-of-core budget sweep -------------
    // The tables above are modeled; this arm *runs* a container-scale
    // stack under shrinking `--mem-budget-mb` arms: cold tiles spill to
    // disk and fault back, outputs must stay bitwise-identical, and
    // per-worker peak residency must respect the budget. Makespan is
    // modeled as the unbudgeted makespan plus host-link time for the
    // spill traffic (every spilled byte crosses the host link twice —
    // out and back), mirroring `model_with_memory`'s paging charge.
    // Writes BENCH_memory.json (checked by check_lowering_json.py).
    let smoke = std::env::var("EINDECOMP_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let real_cfg = LlamaConfig {
        layers: if smoke { 1 } else { 2 },
        batch: 2,
        seq: if smoke { 16 } else { 32 },
        model_dim: if smoke { 32 } else { 64 },
        heads: 2,
        head_dim: if smoke { 16 } else { 32 },
        ffn_dim: if smoke { 64 } else { 128 },
    };
    let rp = 4;
    let engine = NativeEngine::new();
    let rnet = NetworkProfile::cpu_cluster();
    let model = llama_graph(&real_cfg).unwrap();
    let inputs = llama_inputs(&model, 41);
    let plan = assign(&model.graph, &Strategy::EinDecomp, rp, &roles).unwrap();
    let base = Cluster::new(rp, rnet.clone()).with_exec_mode(ExecMode::LevelBarrier);
    // largest single-task working set: output tile + every dep tile
    let tg = base.lower(&model.graph, &plan).unwrap();
    let floor: u64 = tg
        .tasks
        .iter()
        .map(|t| {
            t.out_bytes as u64
                + t.deps
                    .iter()
                    .map(|d| tg.tasks[d.0].out_bytes as u64)
                    .sum::<u64>()
        })
        .max()
        .unwrap_or(0);
    let (want, base_rep) = base.execute(&model.graph, &plan, &engine, &inputs).unwrap();
    let peak = base_rep.peak_resident_bytes.iter().copied().max().unwrap_or(0);
    println!(
        "\n=== real-executor budget sweep | p={rp}, {} layers, unbudgeted peak {:.1} KiB/worker ===",
        real_cfg.layers,
        peak as f64 / 1024.0
    );
    println!(
        "{:>14} {:>12} {:>8} {:>12} {:>12} {:>10}",
        "budget KiB", "spill KiB", "faults", "peak KiB", "sim ms", "bitwise"
    );
    // widely-separated arms so spill traffic (and hence modeled makespan)
    // grows as the budget shrinks; 0 = unlimited. The tightest arm must sit
    // strictly below the unbudgeted peak (else nothing ever evicts) while
    // staying at or above the working-set floor (else nothing can run) —
    // small smoke configs can push 2*floor past the peak, so fall back to
    // the bare floor there.
    let mut tight = (peak / 4).max(2 * floor);
    if tight >= peak {
        tight = (peak / 4).max(floor);
    }
    let arms: Vec<u64> = vec![0, (2 * peak / 3).max(2 * floor), tight];
    let mut rows: Vec<Json> = Vec::new();
    let mut tight_spill = 0u64;
    for &budget in &arms {
        let cluster = if budget == 0 {
            base.clone()
        } else {
            base.clone()
                .with_mem_budget(MemoryBudget::per_worker_bytes(budget))
        };
        let (got, rep) = cluster.execute(&model.graph, &plan, &engine, &inputs).unwrap();
        for out in model.graph.outputs() {
            let (a, b) = (&got[&out], &want[&out]);
            assert!(
                a.data().iter().zip(b.data()).all(|(x, y)| x.to_bits() == y.to_bits()),
                "budget {budget}: output {out} diverged bitwise from the unbudgeted run"
            );
        }
        let peak_max = rep.peak_resident_bytes.iter().copied().max().unwrap_or(0);
        if budget > 0 {
            for (w, &r) in rep.peak_resident_bytes.iter().enumerate() {
                assert!(r <= budget, "worker {w} peak {r} exceeds budget {budget}");
            }
            tight_spill = rep.spill_bytes; // last arm is the tightest
        }
        let sim_s = base_rep.sim_makespan_s + rnet.host_s(2 * rep.spill_bytes as usize);
        println!(
            "{:>14} {:>12.1} {:>8} {:>12.1} {:>12.3} {:>10}",
            if budget == 0 { "unlimited".to_string() } else { format!("{:.1}", budget as f64 / 1024.0) },
            rep.spill_bytes as f64 / 1024.0,
            rep.spill_faults,
            peak_max as f64 / 1024.0,
            sim_s * 1e3,
            "yes"
        );
        rows.push(Json::Obj(vec![
            ("workload".into(), Json::str("llama-real")),
            ("budget_bytes".into(), Json::num(budget as f64)),
            ("spill_bytes".into(), Json::num(rep.spill_bytes as f64)),
            ("spill_faults".into(), Json::num(rep.spill_faults as f64)),
            ("spill_stall_s".into(), Json::num(rep.spill_stall_s)),
            (
                "peak_resident_bytes".into(),
                Json::Arr(
                    rep.peak_resident_bytes
                        .iter()
                        .map(|&b| Json::num(b as f64))
                        .collect(),
                ),
            ),
            ("peak_resident_bytes_max".into(), Json::num(peak_max as f64)),
            ("bitwise_match".into(), Json::Bool(true)),
            ("sim_makespan_s".into(), Json::num(sim_s)),
            ("wall_s".into(), Json::num(rep.wall_s)),
        ]));
    }
    assert!(
        tight_spill > 0,
        "tightest budget arm never spilled — the out-of-core path was not exercised"
    );
    let report = Json::Obj(vec![
        ("p".into(), Json::num(rp as f64)),
        ("floor_bytes".into(), Json::num(floor as f64)),
        ("unbudgeted_peak_bytes".into(), Json::num(peak as f64)),
        ("base_sim_makespan_s".into(), Json::num(base_rep.sim_makespan_s)),
        ("arms".into(), Json::Arr(rows)),
    ]);
    std::fs::write("BENCH_memory.json", report.render()).expect("write BENCH_memory.json");
    println!("wrote BENCH_memory.json");
}

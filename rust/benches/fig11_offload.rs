//! Figure 11: Einsummable (EinDecomp + TURNIP-style paging) vs
//! ZeRO-Inference vs FlexGen for memory-constrained LLaMA first-token
//! inference. A100 server profile (8 x 40 GB), batch 16, sweeping the
//! sequence length; 7B and 65B shapes, full-depth graphs.
//!
//! Policy mapping (DESIGN.md §Deviations):
//!  * einsummable — EinDecomp plan, weights resident (sharded by the
//!    plan), LRU paging to host under the 40 GB/device budget;
//!  * zero        — data-parallel plan, weights sharded and gathered over
//!    the interconnect on every use (ZeRO-Inference's layer broadcast);
//!  * flexgen     — data-parallel plan, weights streamed from host RAM on
//!    every use (FlexGen's offload schedule).
//!
//! Paper shape to reproduce: einsummable fastest, gap growing with the
//! sequence length; the 65B model runs at all (241 GiB of f32 weights)
//! because paging/sharding replaces OOM.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::models::llama::{llama_graph, weight_bytes, weight_set, LlamaConfig};
use eindecomp::sim::memory::{model_with_memory, MemoryConfig, WeightPolicy};
use eindecomp::sim::{Cluster, NetworkProfile};

fn main() {
    let p = 8;
    let cap = 40u64 << 30; // 40 GB per device
    let roles = LabelRoles::by_convention();
    let net = NetworkProfile::gpu_server_a100();
    let cluster = Cluster::new(p, net.clone());

    for (name, layers, mk, seqs) in [
        (
            "LLaMA-7B",
            32usize,
            (&|seq| LlamaConfig::llama7b(16, seq)) as &dyn Fn(usize) -> LlamaConfig,
            vec![512usize, 1024, 2048, 4096],
        ),
        (
            "LLaMA-65B",
            80,
            &|seq| LlamaConfig::llama65b(16, seq),
            vec![512usize, 1024, 2048],
        ),
    ] {
        println!("\n=== Fig 11 {name} | batch=16, A100x8, 40GB/device, {layers} layers ===");
        println!(
            "{:>6} {:>14} {:>12} {:>12} {:>14} {:>12}",
            "seq", "einsummable", "zero", "flexgen", "eins paged GiB", "ein speedup"
        );
        for &seq in &seqs {
            let cfg = mk(seq);
            let model = llama_graph(&cfg).unwrap();
            let weights = weight_set(&model);
            let mut cells = Vec::new();
            let mut paged = 0f64;
            for (strat, policy) in [
                (Strategy::EinDecomp, WeightPolicy::Resident),
                (Strategy::DataParallel, WeightPolicy::ZeroSharded),
                (Strategy::DataParallel, WeightPolicy::HostStreamed),
            ] {
                let plan = assign(&model.graph, &strat, p, &roles).unwrap();
                let tg = cluster.lower(&model.graph, &plan).unwrap();
                let mem = MemoryConfig {
                    capacity_bytes: cap,
                    weight_policy: policy,
                };
                let rep = model_with_memory(&tg, &net, p, &mem, &weights);
                cells.push(rep.sim_makespan_s);
                if policy == WeightPolicy::Resident {
                    paged = rep.bytes_paged as f64 / (1u64 << 30) as f64;
                }
            }
            println!(
                "{seq:>6} {:>14.3} {:>12.3} {:>12.3} {:>14.2} {:>11.2}x",
                cells[0],
                cells[1],
                cells[2],
                paged,
                cells[1].min(cells[2]) / cells[0]
            );
        }
        println!(
            "(weights: {:.1} GiB total at f32)",
            weight_bytes(&llama_graph(&mk(512)).unwrap()) as f64 / (1u64 << 30) as f64
        );
    }
}

//! Figure 10: LLaMA-7B first-token inference under EinDecomp vs the
//! bespoke LLM decompositions (Megatron tensor-parallel, sequence split,
//! attention-head split), all on the same runtime — the paper's own
//! apples-to-apples methodology. V100-class 8-GPU profile, per-layer
//! dry-run costing at the真 7B shapes (costs are identical across the 32
//! layers, so one layer x 32 is exact for the block stack).
//!
//! Paper shape to reproduce: EinDecomp >= all baselines everywhere;
//! "sequence" surprisingly strong (beats Megatron); gaps narrow as GPUs
//! or batch decrease.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::sim::{Cluster, NetworkProfile};

const STRATS: [Strategy; 4] = [
    Strategy::EinDecomp,
    Strategy::Megatron,
    Strategy::Sequence,
    Strategy::AttentionHead,
];

fn run_panel(title: &str, configs: &[(String, LlamaConfig, usize)]) {
    println!("\n=== Fig 10 {title} (modeled ms per layer-stack, V100x{{p}}) ===");
    print!("{:>16}", "config");
    for s in &STRATS {
        print!(" {:>12}", s.name());
    }
    println!();
    let roles = LabelRoles::by_convention();
    for (label, cfg, p) in configs {
        let one_layer = LlamaConfig {
            layers: 1,
            ..cfg.clone()
        };
        let model = llama_graph(&one_layer).unwrap();
        let cluster = Cluster::new(*p, NetworkProfile::gpu_server_v100());
        print!("{label:>16}");
        for strat in &STRATS {
            let plan = assign(&model.graph, strat, *p, &roles).unwrap();
            let rep = cluster.dry_run(&model.graph, &plan).unwrap();
            print!(" {:>12.1}", rep.sim_makespan_s * cfg.layers as f64 * 1e3);
        }
        println!();
    }
}

fn main() {
    // Panel (a): 8 GPUs, seq 4096, vary batch
    let panel_a: Vec<(String, LlamaConfig, usize)> = [1usize, 2, 4, 8]
        .iter()
        .map(|&b| (format!("batch={b}"), LlamaConfig::llama7b(b, 4096), 8))
        .collect();
    run_panel("(a) seq=4096, 8 GPUs, varying batch", &panel_a);

    // Panel (b): seq 1024, batch 8, vary GPUs
    let panel_b: Vec<(String, LlamaConfig, usize)> = [1usize, 2, 4, 8]
        .iter()
        .map(|&p| (format!("gpus={p}"), LlamaConfig::llama7b(8, 1024), p))
        .collect();
    run_panel("(b) seq=1024, batch=8, varying GPUs", &panel_b);

    // Panel (c): seq 4096, batch 4, vary GPUs
    let panel_c: Vec<(String, LlamaConfig, usize)> = [2usize, 4, 8]
        .iter()
        .map(|&p| (format!("gpus={p}"), LlamaConfig::llama7b(4, 4096), p))
        .collect();
    run_panel("(c) seq=4096, batch=4, varying GPUs", &panel_c);

    // Predicted-communication table for panel (a), the planner's own
    // metric (floats moved per layer):
    println!("\n--- predicted floats/layer, panel (a) ---");
    let roles = LabelRoles::by_convention();
    print!("{:>16}", "config");
    for s in &STRATS {
        print!(" {:>12}", s.name());
    }
    println!();
    for &b in &[1usize, 2, 4, 8] {
        let cfg = LlamaConfig {
            layers: 1,
            ..LlamaConfig::llama7b(b, 4096)
        };
        let model = llama_graph(&cfg).unwrap();
        print!("{:>16}", format!("batch={b}"));
        for strat in &STRATS {
            let plan = assign(&model.graph, strat, 8, &roles).unwrap();
            print!(" {:>12.2e}", plan.predicted_cost);
        }
        println!();
    }
}

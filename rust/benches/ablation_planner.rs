//! Planner ablations (DESIGN.md design-choice studies):
//!
//!  1. exact tree DP vs linearized DP vs linearized+off-path-aware vs
//!     greedy — cost quality and planning time;
//!  2. the §8.1 power-of-two restriction: behaviour when the worker count
//!     is not a power of two (p rounded up, paper's recommendation);
//!  3. placement policy: locality-greedy vs round-robin.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::decomp::{plan_graph, PlanMode, PlannerConfig};
use eindecomp::einsum::graph::EinGraph;
use eindecomp::einsum::macros::multihead_attention;
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::models::matchain::chain_graph;
use eindecomp::sim::{Cluster, NetworkProfile};
use eindecomp::taskgraph::placement::Policy;

fn mha_graph() -> EinGraph {
    let (s, a, h, d) = (1024, 512, 8, 64);
    let mut g = EinGraph::new();
    let q = g.input("Q", vec![s, a]);
    let k = g.input("K", vec![s, a]);
    let v = g.input("V", vec![s, a]);
    let wq = g.input("WQ", vec![a, h, d]);
    let wk = g.input("WK", vec![a, h, d]);
    let wv = g.input("WV", vec![a, h, d]);
    let wo = g.input("WO", vec![a, h, d]);
    multihead_attention(&mut g, "mha", q, k, v, wq, wk, wv, wo, false).unwrap();
    g
}

fn ablate_modes(name: &str, g: &EinGraph, p: usize) {
    println!("\n--- planner modes on {name} (p={p}) ---");
    println!("{:<28} {:>16} {:>10}", "mode", "total cost", "plan ms");
    let modes: Vec<(&str, PlannerConfig)> = vec![
        (
            "exact-tree (if tree)",
            PlannerConfig {
                p,
                mode: PlanMode::ExactTree,
                off_path_cost: false,
                ..Default::default()
            },
        ),
        (
            "linearized (paper §8.4)",
            PlannerConfig {
                p,
                mode: PlanMode::Linearized,
                off_path_cost: false,
                ..Default::default()
            },
        ),
        (
            "linearized + off-path",
            PlannerConfig {
                p,
                mode: PlanMode::Linearized,
                off_path_cost: true,
                ..Default::default()
            },
        ),
        (
            "greedy",
            PlannerConfig {
                p,
                mode: PlanMode::Greedy,
                off_path_cost: false,
                ..Default::default()
            },
        ),
    ];
    for (label, cfg) in modes {
        let t0 = std::time::Instant::now();
        match plan_graph(g, &cfg) {
            Ok(plan) => println!(
                "{label:<28} {:>16.0} {:>10.2}",
                plan.predicted_cost,
                t0.elapsed().as_secs_f64() * 1e3
            ),
            Err(e) => println!("{label:<28} n/a ({e})"),
        }
    }
}

fn main() {
    // 1. modes on a tree (chain), a DAG (MHA), and a deep DAG (LLaMA 4L)
    let chain = chain_graph(2560, true).unwrap();
    ablate_modes("matrix chain (tree)", &chain.graph, 16);
    ablate_modes("multi-head attention (DAG)", &mha_graph(), 8);
    let llama = llama_graph(&LlamaConfig {
        layers: 4,
        ..LlamaConfig::llama7b(8, 1024)
    })
    .unwrap();
    ablate_modes("LLaMA 4-layer stack (DAG)", &llama.graph, 8);

    // 2. non-power-of-two worker counts: plan at p rounded up, run on the
    //    actual worker count (paper §8.1's recommendation)
    println!("\n--- non-pow2 workers: chain s=2560 skewed, 12 workers ---");
    let roles = LabelRoles::by_convention();
    let net = NetworkProfile::cpu_cluster();
    for plan_p in [8usize, 16] {
        let plan = assign(&chain.graph, &Strategy::EinDecomp, plan_p, &roles).unwrap();
        let cluster = Cluster::new(12, net.clone());
        let rep = cluster.dry_run(&chain.graph, &plan).unwrap();
        println!(
            "plan p={plan_p:<3} on 12 workers: makespan {:.6}s, eff {:.0}%",
            rep.sim_makespan_s,
            rep.efficiency() * 100.0
        );
    }

    // 3. placement policy
    println!("\n--- placement policy: LLaMA 4L, 8 workers ---");
    let plan = assign(&llama.graph, &Strategy::EinDecomp, 8, &roles).unwrap();
    for (name, pol) in [
        ("locality-greedy", Policy::LocalityGreedy),
        ("round-robin", Policy::RoundRobin),
    ] {
        let mut cluster = Cluster::new(8, net.clone());
        cluster.placement = pol;
        let rep = cluster.dry_run(&llama.graph, &plan).unwrap();
        println!(
            "{name:<16} moved {:>8.1} MiB, makespan {:.6}s",
            rep.bytes_moved as f64 / (1 << 20) as f64,
            rep.sim_makespan_s
        );
    }
}

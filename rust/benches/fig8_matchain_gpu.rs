//! Figure 8: EinDecomp vs SQRT vs Dask on the matrix chain, GPU-server
//! profile (4 x P100 over PCIe, the paper's in-house box).
//!
//! Paper shape to reproduce: EinDecomp == SQRT on uniform sizes, a
//! consistent ~2x gap on skewed sizes; Dask (fixed square chunking +
//! p-blind task soup) trails both.

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::models::matchain::chain_graph;
use eindecomp::sim::{Cluster, NetworkProfile};

fn main() {
    let p = 4; // four P100s
    let roles = LabelRoles::by_convention();
    let cluster = Cluster::new(p, NetworkProfile::gpu_server_p100());
    // Dask's centralized Python scheduler costs ~0.5 ms/task (its own
    // documentation says "every task ... ~1ms of overhead"); our runtime
    // dispatches in ~2 us. Model the Dask baseline accordingly.
    let dask_cluster = Cluster::new(
        p,
        NetworkProfile::gpu_server_p100().with_sched_overhead(5e-4),
    );

    for skewed in [false, true] {
        println!(
            "\n=== Fig 8 ({}) | p={p}, P100 server ===",
            if skewed { "skewed" } else { "uniform" }
        );
        println!(
            "{:>7} {:>14} {:>14} {:>14} {:>16}",
            "s", "eindecomp", "sqrt", "dask", "ein/sqrt ratio"
        );
        for s in [640usize, 1280, 2560, 5120, 10240] {
            let chain = chain_graph(s, skewed).unwrap();
            let mut times = Vec::new();
            for strat in [
                Strategy::EinDecomp,
                Strategy::Sqrt,
                Strategy::DaskLike { chunk: (s / 8).max(64) },
            ] {
                let plan = assign(&chain.graph, &strat, p, &roles).unwrap();
                let cl = if matches!(strat, Strategy::DaskLike { .. }) {
                    &dask_cluster
                } else {
                    &cluster
                };
                let rep = cl.dry_run(&chain.graph, &plan).unwrap();
                times.push(rep.sim_makespan_s);
            }
            println!(
                "{s:>7} {:>14.6} {:>14.6} {:>14.6} {:>16.2}",
                times[0],
                times[1],
                times[2],
                times[1] / times[0]
            );
        }
    }
}

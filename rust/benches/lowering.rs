//! Lowering benchmark: the TRA-IR mid-layer's cost and wins.
//!
//! Measures, per workload and p:
//!
//! * emit time — frozen direct lowering (`lower_graph_reference`) vs the
//!   IR path (`from_plan` + passes + `emit_tasks`);
//! * per-pass change counts **and task/repart-byte deltas** (the same
//!   entries `Session::explain` surfaces), so wins are attributable to
//!   specific rewrites;
//! * total / repart / agg task counts and the repartition byte total,
//!   pipeline off vs fully on.
//!
//! Suite inputs are *storage-sharded*: each graph input arrives
//! partitioned along the reversed axis order of its consumer's needed
//! layout (a row-store feeding a column-sharded consumer), so the
//! unoptimized lowering pays real repartition traffic that
//! `propagate-partitions` can elide — the paper's free-offline-placement
//! assumption made load-bearing.
//!
//! Asserts in-bench:
//!
//! * the no-pass IR emission equals the direct lowering **exactly**
//!   (full `TaskGraph` equality — tasks, deps, bytes, flops);
//! * every suite workload executes **bitwise-identically** under
//!   `--passes all` and `--passes none`;
//! * at least one suite workload shows a strictly lower task count *and*
//!   repartition byte total with the pipeline on;
//! * `alias-refinement-repart` drops refinement-repart tasks to zero
//!   with bitwise-identical execution;
//! * `agg-tree` bounds aggregation fan-in by the tree arity;
//! * the topology sweep (p=8, flat / two-level / three-level) executes
//!   every workload bitwise-identically with `lower-collectives` on,
//!   and under the three-level topology at least one workload moves
//!   strictly fewer cross-node bytes (per-link-class ledger recorded
//!   in the JSON).
//!
//! Writes `BENCH_lowering.json` (uploaded as a CI artifact). Run with
//! `EINDECOMP_SMOKE=1` for capped iteration counts.
//!
//! ```sh
//! cargo bench --bench lowering
//! ```

use eindecomp::decomp::baselines::{assign, LabelRoles, Strategy};
use eindecomp::decomp::{Plan, PlannerConfig};
use eindecomp::einsum::expr::EinSum;
use eindecomp::einsum::graph::EinGraph;
use eindecomp::einsum::label::labels;
use eindecomp::models::ffnn::ffnn_step;
use eindecomp::models::llama::{llama_graph, LlamaConfig};
use eindecomp::models::matchain::chain_graph;
use eindecomp::runtime::NativeEngine;
use eindecomp::sim::{Cluster, NetworkProfile, Topology};
use eindecomp::taskgraph::lower::lower_graph_reference;
use eindecomp::taskgraph::{TaskGraph, TaskKind};
use eindecomp::tensor::Tensor;
use eindecomp::tra::passes::{PassManager, PassSelector};
use eindecomp::tra::program::from_plan;
use eindecomp::util::Json;
use std::collections::HashMap;
use std::time::Instant;

fn count(tg: &TaskGraph, pred: fn(&TaskKind) -> bool) -> usize {
    tg.tasks.iter().filter(|t| pred(&t.kind)).count()
}

fn is_repart(k: &TaskKind) -> bool {
    matches!(k, TaskKind::Repart { .. })
}

fn is_agg(k: &TaskKind) -> bool {
    matches!(k, TaskKind::Agg { .. })
}

/// Repartition-class movement: plain repart assembles plus collective
/// relay hops (`lower-collectives` turns the former into the latter, and
/// `TraProgram::task_stats` ledgers both as repart bytes — counting only
/// `Repart` here would make the per-pass deltas stop rolling up).
fn repart_bytes(tg: &TaskGraph) -> u64 {
    tg.tasks
        .iter()
        .filter(|t| {
            matches!(
                t.kind,
                TaskKind::Repart { .. } | TaskKind::Collective { .. }
            )
        })
        .map(|t| t.out_bytes as u64)
        .sum()
}

/// Re-shard every pre-partitioned input along the reversed axis order
/// (storage layout vs compute layout), so repartition chains exist for
/// the pipeline to optimize away.
fn storage_shard_inputs(plan: &mut Plan) {
    for part in plan.input_parts.values_mut() {
        part.reverse();
    }
}

/// Bitwise gate: `--passes all` and `--passes none` produce identical
/// output bytes on real execution.
fn assert_all_equals_none_bitwise(name: &str, g: &EinGraph, plan: &Plan) {
    let mut inputs = HashMap::new();
    for (i, v) in g.inputs().into_iter().enumerate() {
        inputs.insert(v, Tensor::random(&g.vertex(v).bound, 300 + i as u64));
    }
    let engine = NativeEngine::new();
    let base = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::None)
        .execute(g, plan, &engine, &inputs)
        .unwrap()
        .0;
    let opt = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::All)
        .execute(g, plan, &engine, &inputs)
        .unwrap()
        .0;
    for out in g.outputs() {
        assert_eq!(
            base[&out], opt[&out],
            "{name}: --passes all diverged bitwise from --passes none"
        );
    }
}

fn bench_workload(name: &str, g: &EinGraph, plan: &Plan, iters: usize) -> Json {
    // timing: direct reference vs IR path (build + emit, no passes)
    let t0 = Instant::now();
    for _ in 0..iters {
        lower_graph_reference(g, plan).unwrap();
    }
    let ref_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    let t1 = Instant::now();
    for _ in 0..iters {
        from_plan(g, plan).unwrap().emit_tasks().unwrap();
    }
    let ir_ms = t1.elapsed().as_secs_f64() * 1e3 / iters as f64;

    // equality gate: no-pass IR emission == direct lowering, bit for bit
    let reference = lower_graph_reference(g, plan).unwrap();
    let unoptimized = from_plan(g, plan).unwrap().emit_tasks().unwrap();
    assert_eq!(
        unoptimized, reference,
        "{name}: no-pass IR emission diverged from the reference lowering"
    );

    // per-pass change counts + task/byte deltas (the Session::explain
    // pass-log entries, verbatim)
    let mut optimized_prog = from_plan(g, plan).unwrap();
    let log = PassManager::new(&PassSelector::All).run(&mut optimized_prog);
    let optimized = optimized_prog.emit_tasks().unwrap();
    let passes: Vec<Json> = log
        .entries
        .iter()
        .map(|e| {
            Json::Obj(vec![
                ("pass".into(), Json::str(e.pass.clone())),
                ("changes".into(), Json::num(e.changes as f64)),
                ("tasks_delta".into(), Json::num(e.tasks_delta as f64)),
                (
                    "repart_bytes_delta".into(),
                    Json::num(e.repart_bytes_delta as f64),
                ),
            ])
        })
        .collect();

    assert_all_equals_none_bitwise(name, g, plan);

    // residency estimate (TraProgram::residency_stats): the traffic wins
    // above trade against peak live bytes — the offload bench's axis.
    let res_unopt = from_plan(g, plan).unwrap().residency_stats();
    let res_opt = optimized_prog.residency_stats();

    println!(
        "{name:<18} ref {ref_ms:8.3} ms | ir {ir_ms:8.3} ms | tasks {} -> {} \
         (repart {} -> {}, agg {} -> {}, repart bytes {} -> {}) \
         | residency peak {} -> {} B",
        reference.len(),
        optimized.len(),
        count(&reference, is_repart),
        count(&optimized, is_repart),
        count(&reference, is_agg),
        count(&optimized, is_agg),
        repart_bytes(&reference),
        repart_bytes(&optimized),
        res_unopt.peak_bytes,
        res_opt.peak_bytes,
    );

    Json::Obj(vec![
        ("workload".into(), Json::str(name)),
        ("lower_reference_ms".into(), Json::num(ref_ms)),
        ("lower_ir_ms".into(), Json::num(ir_ms)),
        ("tasks_unoptimized".into(), Json::num(reference.len() as f64)),
        ("tasks_optimized".into(), Json::num(optimized.len() as f64)),
        (
            "repart_tasks_unoptimized".into(),
            Json::num(count(&reference, is_repart) as f64),
        ),
        (
            "repart_tasks_optimized".into(),
            Json::num(count(&optimized, is_repart) as f64),
        ),
        (
            "agg_tasks_unoptimized".into(),
            Json::num(count(&reference, is_agg) as f64),
        ),
        (
            "agg_tasks_optimized".into(),
            Json::num(count(&optimized, is_agg) as f64),
        ),
        (
            "repart_bytes_unoptimized".into(),
            Json::num(repart_bytes(&reference) as f64),
        ),
        (
            "repart_bytes_optimized".into(),
            Json::num(repart_bytes(&optimized) as f64),
        ),
        (
            "strict_win".into(),
            Json::Bool(
                optimized.len() < reference.len()
                    && repart_bytes(&optimized) < repart_bytes(&reference),
            ),
        ),
        (
            "residency_peak_bytes_unoptimized".into(),
            Json::num(res_unopt.peak_bytes as f64),
        ),
        (
            "residency_peak_bytes_optimized".into(),
            Json::num(res_opt.peak_bytes as f64),
        ),
        (
            "residency_max_task_bytes_unoptimized".into(),
            Json::num(res_unopt.max_task_bytes as f64),
        ),
        (
            "residency_max_task_bytes_optimized".into(),
            Json::num(res_opt.max_task_bytes as f64),
        ),
        ("pass_log".into(), Json::Arr(passes)),
        ("bitwise_unoptimized_equals_reference".into(), Json::Bool(true)),
        ("bitwise_all_equals_none".into(), Json::Bool(true)),
    ])
}

fn main() {
    let smoke = std::env::var("EINDECOMP_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let iters = if smoke { 5 } else { 30 };
    let tag = if smoke { " (smoke)" } else { "" };
    println!("=== lowering: direct vs TRA-IR emission, per-pass deltas{tag} ===");

    let roles = LabelRoles::by_convention();
    // PlannerConfig carries the pass selector for plan-and-lower
    // toolchains like this bench: one config names both the planning
    // target and the pipeline the demos below lower with.
    let pcfg = PlannerConfig {
        p: 4,
        passes: PassSelector::All,
        ..Default::default()
    };

    let mut entries: Vec<Json> = Vec::new();
    for p in [2usize, 4] {
        let chain = chain_graph(if smoke { 32 } else { 64 }, false).unwrap().graph;
        let mut plan = assign(&chain, &Strategy::EinDecomp, p, &roles).unwrap();
        storage_shard_inputs(&mut plan);
        entries.push(bench_workload(&format!("matchain/p{p}"), &chain, &plan, iters));

        let ffnn = ffnn_step(32, 48, 24, 8).unwrap().graph;
        let mut plan = assign(&ffnn, &Strategy::EinDecomp, p, &roles).unwrap();
        storage_shard_inputs(&mut plan);
        entries.push(bench_workload(&format!("ffnn/p{p}"), &ffnn, &plan, iters));

        let llama_cfg = LlamaConfig {
            layers: 1,
            batch: 2,
            seq: 16,
            model_dim: 32,
            heads: 2,
            head_dim: 16,
            ffn_dim: 64,
        };
        let attn = llama_graph(&llama_cfg).unwrap().graph;
        let mut plan = assign(&attn, &Strategy::EinDecomp, p, &roles).unwrap();
        storage_shard_inputs(&mut plan);
        entries.push(bench_workload(&format!("attention/p{p}"), &attn, &plan, iters));
    }
    // acceptance: the pipeline must strictly beat no-passes somewhere
    fn is_strict_win(e: &Json) -> bool {
        match e {
            Json::Obj(kv) => kv
                .iter()
                .any(|(k, v)| k == "strict_win" && matches!(v, Json::Bool(true))),
            _ => false,
        }
    }
    let strict_wins = entries.iter().filter(|e| is_strict_win(e)).count();
    assert!(
        strict_wins > 0,
        "no suite workload showed a strict task+byte win with --passes all"
    );
    println!("strict task+byte wins: {strict_wins}/{} workloads", entries.len());

    // --- alias-refinement demo: refinement reparts drop to zero --------
    let mut g = EinGraph::new();
    let a = g.input("A", vec![32, 32]);
    let b = g.input("B", vec![32, 32]);
    let c = g.input("C", vec![32, 32]);
    let z1 = g
        .add(
            "Z1",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
    let z2 = g
        .add(
            "Z2",
            EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
            vec![z1, c],
        )
        .unwrap();
    let mut plan = Plan::default();
    plan.parts.insert(z1, vec![2, 1, 2]);
    plan.parts.insert(z2, vec![4, 4, 1]);
    plan.finalize_inputs(&g);
    let without = from_plan(&g, &plan).unwrap().emit_tasks().unwrap();
    let mut prog = from_plan(&g, &plan).unwrap();
    pcfg.passes.manager().run(&mut prog);
    let with = prog.emit_tasks().unwrap();
    let (r0, r1) = (count(&without, is_repart), count(&with, is_repart));
    assert!(r0 > 0 && r1 == 0, "alias pass must zero refinement reparts");
    // bitwise gate: aliased execution == un-aliased execution
    let mut inputs = HashMap::new();
    for v in g.inputs() {
        inputs.insert(v, Tensor::random(&[32, 32], 50 + v.0 as u64));
    }
    let engine = NativeEngine::new();
    let base = Cluster::new(4, NetworkProfile::loopback())
        .with_passes(PassSelector::None)
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    let aliased = Cluster::new(4, NetworkProfile::loopback())
        .with_passes("elide-identity-repart,alias-refinement-repart".parse().unwrap())
        .execute(&g, &plan, &engine, &inputs)
        .unwrap()
        .0;
    assert_eq!(base[&z2], aliased[&z2], "alias pass changed execution bytes");
    println!("alias demo        : repart tasks {r0} -> {r1} (bitwise-identical execution)");

    // --- agg-tree demo: fan-in bounded by the arity --------------------
    let mut ag = EinGraph::new();
    let aa = ag.input("A", vec![64, 64]);
    let ab = ag.input("B", vec![64, 64]);
    let az = ag
        .add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![aa, ab],
        )
        .unwrap();
    let mut aplan = Plan::default();
    aplan.parts.insert(az, vec![2, 16, 2]); // 16-way aggregation groups
    aplan.finalize_inputs(&ag);
    let serial = from_plan(&ag, &aplan).unwrap().emit_tasks().unwrap();
    let mut tprog = from_plan(&ag, &aplan).unwrap();
    pcfg.passes.manager().run(&mut tprog);
    let tree = tprog.emit_tasks().unwrap();
    let max_fanin = |tg: &TaskGraph| {
        tg.tasks
            .iter()
            .filter(|t| is_agg(&t.kind))
            .map(|t| t.deps.len())
            .max()
            .unwrap_or(0)
    };
    let (f0, f1) = (max_fanin(&serial), max_fanin(&tree));
    assert_eq!(f0, 16);
    assert!(f1 <= 4, "agg-tree fan-in {f1} exceeds the arity");
    println!("agg-tree demo     : max Agg fan-in {f0} -> {f1} (arity 4)");

    // --- topology sweep: per-link-class byte deltas from the collective
    // lowering at p=8, flat / two-level / three-level. The acceptance
    // bar: under the three-level topology at least one workload moves
    // strictly fewer cross-node bytes (link classes above the innermost)
    // with `lower-collectives` on — ring relays hop between neighboring
    // members, so most hops stay on the fast intra-node links where the
    // point-to-point pattern scattered them across the whole machine.
    println!("=== topology sweep at p=8: safe vs +lower-collectives ===");
    let p8 = 8usize;
    let net = NetworkProfile::cpu_cluster();
    let collective: PassSelector = "elide-identity-repart,lower-collectives,dead-rel-elim"
        .parse()
        .unwrap();
    let sweep_graphs: Vec<(&str, EinGraph)> = vec![
        (
            "matchain",
            chain_graph(if smoke { 32 } else { 64 }, false).unwrap().graph,
        ),
        ("ffnn", ffnn_step(32, 48, 24, 8).unwrap().graph),
        (
            "attention",
            llama_graph(&LlamaConfig {
                layers: 1,
                batch: 2,
                seq: 16,
                model_dim: 32,
                heads: 2,
                head_dim: 16,
                ffn_dim: 64,
            })
            .unwrap()
            .graph,
        ),
    ];
    // cross-node bytes: everything charged above the innermost class
    fn cross_bytes(by_link: &[(String, u64)]) -> u64 {
        by_link.iter().skip(1).map(|(_, b)| *b).sum()
    }
    let engine = NativeEngine::new();
    let mut sweep_entries: Vec<Json> = Vec::new();
    let mut cross_node_win = false;
    for (wname, g) in &sweep_graphs {
        let mut plan = assign(g, &Strategy::EinDecomp, p8, &roles).unwrap();
        storage_shard_inputs(&mut plan);
        let mut inputs = HashMap::new();
        for (i, v) in g.inputs().into_iter().enumerate() {
            inputs.insert(v, Tensor::random(&g.vertex(v).bound, 700 + i as u64));
        }
        for topo in [
            Topology::flat_of(&net, p8),
            Topology::two_level_of(&net, p8),
            Topology::three_level_of(&net, p8),
        ] {
            let safe_cluster = Cluster::new(p8, net.clone())
                .with_passes(PassSelector::Safe)
                .with_topology(topo.clone());
            let coll_cluster = Cluster::new(p8, net.clone())
                .with_passes(collective.clone())
                .with_topology(topo.clone());
            let (safe_out, safe_rep) =
                safe_cluster.execute(g, &plan, &engine, &inputs).unwrap();
            let (coll_out, coll_rep) =
                coll_cluster.execute(g, &plan, &engine, &inputs).unwrap();
            // bitwise gate, in-bench: the lowering must not change results
            for out in g.outputs() {
                assert_eq!(
                    safe_out[&out], coll_out[&out],
                    "{wname}/{}: collective lowering diverged bitwise",
                    topo.name()
                );
            }
            let (sc, cc) = (
                cross_bytes(&safe_rep.bytes_by_link),
                cross_bytes(&coll_rep.bytes_by_link),
            );
            if topo.levels() == 3 && cc < sc {
                cross_node_win = true;
            }
            let link_obj = |by: &[(String, u64)]| {
                Json::Obj(
                    by.iter()
                        .map(|(n, b)| (n.clone(), Json::num(*b as f64)))
                        .collect(),
                )
            };
            println!(
                "{wname:<10} {:<24} bytes {:>9} -> {:>9} | cross-node {:>9} -> {:>9}",
                topo.name(),
                safe_rep.bytes_moved,
                coll_rep.bytes_moved,
                sc,
                cc
            );
            sweep_entries.push(Json::Obj(vec![
                ("workload".into(), Json::str(*wname)),
                ("topology".into(), Json::str(topo.name())),
                ("levels".into(), Json::num(topo.levels() as f64)),
                ("p".into(), Json::num(p8 as f64)),
                ("bytes_moved_safe".into(), Json::num(safe_rep.bytes_moved as f64)),
                (
                    "bytes_moved_collective".into(),
                    Json::num(coll_rep.bytes_moved as f64),
                ),
                ("bytes_by_link_safe".into(), link_obj(&safe_rep.bytes_by_link)),
                (
                    "bytes_by_link_collective".into(),
                    link_obj(&coll_rep.bytes_by_link),
                ),
                ("cross_node_bytes_safe".into(), Json::num(sc as f64)),
                ("cross_node_bytes_collective".into(), Json::num(cc as f64)),
                ("bitwise_identical_execution".into(), Json::Bool(true)),
            ]));
        }
    }
    assert!(
        cross_node_win,
        "no workload reduced cross-node bytes under the three-level topology"
    );
    println!("cross-node byte reduction under three-level topology: confirmed");

    let report = Json::Obj(vec![
        ("iters".into(), Json::num(iters as f64)),
        ("workloads".into(), Json::Arr(entries)),
        (
            "alias_demo".into(),
            Json::Obj(vec![
                ("repart_tasks_without".into(), Json::num(r0 as f64)),
                ("repart_tasks_with".into(), Json::num(r1 as f64)),
                ("bitwise_identical_execution".into(), Json::Bool(true)),
            ]),
        ),
        (
            "agg_tree_demo".into(),
            Json::Obj(vec![
                ("max_fanin_serial".into(), Json::num(f0 as f64)),
                ("max_fanin_tree".into(), Json::num(f1 as f64)),
                ("arity".into(), Json::num(4.0)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_lowering.json", report.render()).expect("write BENCH_lowering.json");
    println!("wrote BENCH_lowering.json");

    let topo_report = Json::Obj(vec![
        ("p".into(), Json::num(p8 as f64)),
        ("topology_sweep".into(), Json::Arr(sweep_entries)),
    ]);
    std::fs::write("BENCH_topology.json", topo_report.render())
        .expect("write BENCH_topology.json");
    println!("wrote BENCH_topology.json");
}

//! Serving-path benchmark: cold per-call `Driver::run` (re-plans and
//! re-lowers every request) versus the compile-once / run-many `Session`
//! path (`compile` once, `Executable::run` per request) on the Experiment-1
//! matchain graph. Reports amortized request throughput — the cached
//! path's amortization *includes* its one-time compile — and asserts the
//! two paths produce bitwise-identical outputs. Timings are written to
//! `BENCH_serving.json` (uploaded as a CI artifact alongside
//! `BENCH_micro.json`). `EINDECOMP_SMOKE=1` caps the configuration for CI.
//!
//! ```sh
//! cargo bench --bench serving
//! ```

use eindecomp::coordinator::driver::{Driver, DriverConfig, PlanProvenance};
use eindecomp::coordinator::session::Session;
use eindecomp::models::matchain::{chain_graph, chain_inputs};
use eindecomp::runtime::Backend;
use eindecomp::sim::NetworkProfile;
use eindecomp::util::Json;

fn main() {
    let smoke = std::env::var("EINDECOMP_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let tag = if smoke { " (smoke)" } else { "" };
    println!("=== serving: cold per-call vs compile-once/run-many{tag} ===");

    let scale = if smoke { 48 } else { 96 };
    let repeat = if smoke { 15 } else { 40 };
    // p > workers sharpens the planner's share of each cold call — the
    // regime the paper's Sections 5–8 spend their effort on.
    let cfg = DriverConfig {
        workers: 4,
        p: 16,
        backend: Backend::Native,
        network: NetworkProfile::loopback(),
        ..Default::default()
    };
    let chain = chain_graph(scale, false).unwrap();
    let inputs = chain_inputs(&chain, 42);

    // --- cold: plan + lower + execute on every request -----------------
    let driver = Driver::new(cfg.clone()).unwrap();
    let (outs_cold, rep_cold) = driver.run(&chain.graph, &inputs).unwrap(); // warmup
    assert_eq!(rep_cold.provenance, PlanProvenance::Planned);
    let t0 = std::time::Instant::now();
    let mut outs_last = None;
    for _ in 0..repeat {
        let (outs, _) = driver.run(&chain.graph, &inputs).unwrap();
        outs_last = Some(outs);
    }
    let cold_total = t0.elapsed().as_secs_f64();
    let outs_last = outs_last.unwrap();
    assert_eq!(outs_last[&chain.z], outs_cold[&chain.z], "cold path drifted");
    let cold_rps = repeat as f64 / cold_total;
    println!(
        "driver per-call : {repeat} x {:7.3} ms -> {:8.1} req/s  (plan_s {:.3} ms/req)",
        cold_total * 1e3 / repeat as f64,
        cold_rps,
        rep_cold.plan_s * 1e3
    );

    // --- warm: compile once, run many ----------------------------------
    let session = Session::new(cfg).unwrap();
    let tc = std::time::Instant::now();
    let exe = session.compile(&chain.graph).unwrap();
    let compile_s = tc.elapsed().as_secs_f64();
    let (outs_warmup, _) = exe.run(&inputs).unwrap(); // warmup (pools, code)
    assert_eq!(outs_warmup[&chain.z], outs_cold[&chain.z], "session != driver");
    let t1 = std::time::Instant::now();
    let mut outs_warm = None;
    for _ in 0..repeat {
        let (outs, _) = exe.run(&inputs).unwrap();
        outs_warm = Some(outs);
    }
    let warm_total = t1.elapsed().as_secs_f64();
    let outs_warm = outs_warm.unwrap();
    // bitwise: cached runs == per-call driver runs, every byte
    assert_eq!(outs_warm[&chain.z], outs_cold[&chain.z], "cached run diverged");
    // recompiling is a cache hit with zero planner work
    let exe2 = session.compile(&chain.graph).unwrap();
    assert_eq!(exe2.provenance(), PlanProvenance::CacheHit);
    assert_eq!(session.stats().planner_runs, 1);
    let warm_rps_amortized = repeat as f64 / (compile_s + warm_total);
    let (plan_s, lower_s) = exe.compile_times();
    println!(
        "session cached  : {repeat} x {:7.3} ms -> {:8.1} req/s amortized (compile {:.3} ms = \
         plan {:.3} + lower {:.3})",
        warm_total * 1e3 / repeat as f64,
        warm_rps_amortized,
        compile_s * 1e3,
        plan_s * 1e3,
        lower_s * 1e3
    );
    let speedup = warm_rps_amortized / cold_rps;
    println!("amortized speedup (cached / per-call): {speedup:.2}x  (acceptance gate: >= 1.3x)");

    let entry = |mode: &str, total: f64, rps: f64, extra: Vec<(String, Json)>| {
        let mut fields = vec![
            ("workload".to_string(), Json::str("matchain")),
            ("scale".to_string(), Json::num(scale as f64)),
            ("repeat".to_string(), Json::num(repeat as f64)),
            ("mode".to_string(), Json::str(mode)),
            ("total_s".to_string(), Json::num(total)),
            ("ms_per_run".to_string(), Json::num(total * 1e3 / repeat as f64)),
            ("runs_per_s".to_string(), Json::num(rps)),
        ];
        fields.extend(extra);
        Json::Obj(fields)
    };
    let report = Json::Obj(vec![
        (
            "driver_per_call".to_string(),
            entry(
                "plan+lower+run per request",
                cold_total,
                cold_rps,
                vec![("plan_s_per_req".to_string(), Json::num(rep_cold.plan_s))],
            ),
        ),
        (
            "session_cached".to_string(),
            entry(
                "compile once, run many",
                warm_total,
                warm_rps_amortized,
                vec![
                    ("compile_s".to_string(), Json::num(compile_s)),
                    ("plan_s".to_string(), Json::num(plan_s)),
                    ("lower_s".to_string(), Json::num(lower_s)),
                ],
            ),
        ),
        ("speedup_amortized".to_string(), Json::num(speedup)),
        ("bitwise_identical".to_string(), Json::Bool(true)),
    ]);
    std::fs::write("BENCH_serving.json", report.render()).expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json");
}

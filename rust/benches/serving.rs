//! Serving-path benchmark, two halves:
//!
//! 1. Cold per-call `Driver::run` (re-plans and re-lowers every request)
//!    versus the compile-once / run-many `Session` path on the
//!    Experiment-1 matchain graph — amortized request throughput, with
//!    the cached path's amortization *including* its one-time compile,
//!    and a bitwise-identity assertion between the two paths.
//! 2. Multi-tenant serving arms: a closed-loop load generator drives
//!    `serve::Server` with batching off (`solo`) and on (`batched`,
//!    max_batch 8) across serving pool sizes, reporting p50/p95/p99
//!    latency and req/s per arm. Every arm's XOR-combined output
//!    checksum must equal the solo-reference XOR (bitwise parity), and
//!    the best batched arm must beat the best solo arm by >= 1.5x
//!    req/s (asserted here; the JSON schema is validated in CI by
//!    `scripts/check_serving_json.py`).
//!
//! Results land in `BENCH_serving.json` (uploaded as a CI artifact).
//! `EINDECOMP_SMOKE=1` caps scales, request counts, and pool sizes.
//!
//! ```sh
//! cargo bench --bench serving
//! ```

use eindecomp::coordinator::driver::{Driver, DriverConfig, PlanProvenance};
use eindecomp::coordinator::session::Session;
use eindecomp::models::matchain::{chain_graph, chain_inputs};
use eindecomp::runtime::Backend;
use eindecomp::serve::{output_checksum, run_load, LoadConfig, ServeConfig, Server};
use eindecomp::sim::NetworkProfile;
use eindecomp::util::Json;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let smoke = std::env::var("EINDECOMP_SMOKE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let tag = if smoke { " (smoke)" } else { "" };
    println!("=== serving: cold per-call vs compile-once/run-many{tag} ===");

    let scale = if smoke { 48 } else { 96 };
    let repeat = if smoke { 15 } else { 40 };
    // p > workers sharpens the planner's share of each cold call — the
    // regime the paper's Sections 5–8 spend their effort on.
    let cfg = DriverConfig {
        workers: 4,
        p: 16,
        backend: Backend::Native,
        network: NetworkProfile::loopback(),
        ..Default::default()
    };
    let chain = chain_graph(scale, false).unwrap();
    let inputs = chain_inputs(&chain, 42);

    // --- cold: plan + lower + execute on every request -----------------
    let driver = Driver::new(cfg.clone()).unwrap();
    let (outs_cold, rep_cold) = driver.run(&chain.graph, &inputs).unwrap(); // warmup
    assert_eq!(rep_cold.provenance, PlanProvenance::Planned);
    let t0 = std::time::Instant::now();
    let mut outs_last = None;
    for _ in 0..repeat {
        let (outs, _) = driver.run(&chain.graph, &inputs).unwrap();
        outs_last = Some(outs);
    }
    let cold_total = t0.elapsed().as_secs_f64();
    let outs_last = outs_last.unwrap();
    assert_eq!(outs_last[&chain.z], outs_cold[&chain.z], "cold path drifted");
    let cold_rps = repeat as f64 / cold_total;
    println!(
        "driver per-call : {repeat} x {:7.3} ms -> {:8.1} req/s  (plan_s {:.3} ms/req)",
        cold_total * 1e3 / repeat as f64,
        cold_rps,
        rep_cold.plan_s * 1e3
    );

    // --- warm: compile once, run many ----------------------------------
    let session = Session::new(cfg).unwrap();
    let tc = std::time::Instant::now();
    let exe = session.compile(&chain.graph).unwrap();
    let compile_s = tc.elapsed().as_secs_f64();
    let (outs_warmup, _) = exe.run(&inputs).unwrap(); // warmup (pools, code)
    assert_eq!(outs_warmup[&chain.z], outs_cold[&chain.z], "session != driver");
    let t1 = std::time::Instant::now();
    let mut outs_warm = None;
    for _ in 0..repeat {
        let (outs, _) = exe.run(&inputs).unwrap();
        outs_warm = Some(outs);
    }
    let warm_total = t1.elapsed().as_secs_f64();
    let outs_warm = outs_warm.unwrap();
    // bitwise: cached runs == per-call driver runs, every byte
    assert_eq!(outs_warm[&chain.z], outs_cold[&chain.z], "cached run diverged");
    // recompiling is a cache hit with zero planner work
    let exe2 = session.compile(&chain.graph).unwrap();
    assert_eq!(exe2.provenance(), PlanProvenance::CacheHit);
    assert_eq!(session.stats().planner_runs, 1);
    let warm_rps_amortized = repeat as f64 / (compile_s + warm_total);
    let (plan_s, lower_s) = exe.compile_times();
    println!(
        "session cached  : {repeat} x {:7.3} ms -> {:8.1} req/s amortized (compile {:.3} ms = \
         plan {:.3} + lower {:.3})",
        warm_total * 1e3 / repeat as f64,
        warm_rps_amortized,
        compile_s * 1e3,
        plan_s * 1e3,
        lower_s * 1e3
    );
    let speedup = warm_rps_amortized / cold_rps;
    println!("amortized speedup (cached / per-call): {speedup:.2}x  (acceptance gate: >= 1.3x)");

    // --- multi-tenant serving arms: solo vs dynamic batching -----------
    // Smaller graph than the cold/cached half on purpose: dynamic
    // batching pays off by amortizing per-execution overhead (scheduler
    // scope spawn, repartitioning, result plumbing) and by handing the
    // kernels batch entries to shard across — the short-request regime
    // a serving tier actually sees.
    println!("=== serving: multi-tenant load, solo vs dynamic batching{tag} ===");
    let serve_scale = if smoke { 32 } else { 48 };
    let clients = if smoke { 8 } else { 16 };
    let per_client = if smoke { 4 } else { 8 };
    let worker_arms: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let window = Duration::from_millis(2);
    let serve_driver = DriverConfig {
        workers: 2,
        p: 2,
        backend: Backend::Native,
        network: NetworkProfile::loopback(),
        ..Default::default()
    };
    let serve_chain = chain_graph(serve_scale, false).unwrap();
    let seeds: Vec<u64> = (0..8u64).map(|s| 500 + s).collect();
    let seed_at = |c: usize, i: usize| seeds[(c * per_client + i) % seeds.len()];

    // solo references: one direct run per distinct seed, XORed over the
    // exact request multiset every arm will issue
    let ref_session = Session::new(serve_driver.clone()).unwrap();
    let ref_exe = ref_session.compile(&serve_chain.graph).unwrap();
    let per_seed: HashMap<u64, u64> = seeds
        .iter()
        .map(|&s| {
            let (outs, _) = ref_exe.run(&chain_inputs(&serve_chain, s)).unwrap();
            (s, output_checksum(&outs))
        })
        .collect();
    let mut expected = 0u64;
    for c in 0..clients {
        for i in 0..per_client {
            expected ^= per_seed[&seed_at(c, i)];
        }
    }

    let mut arms = Vec::new();
    let mut best_solo: f64 = 0.0;
    let mut best_batched: f64 = 0.0;
    for &sw in worker_arms {
        for (mode, max_batch) in [("solo", 1usize), ("batched", 8usize)] {
            let session = Arc::new(Session::new(serve_driver.clone()).unwrap());
            let server = Server::with_session(
                Arc::clone(&session),
                ServeConfig {
                    serve_workers: sw,
                    max_batch,
                    batch_window: window,
                    ..Default::default()
                },
            );
            // warmup primes the compile cache and kernel buffer pools
            server
                .run(
                    "warmup",
                    &serve_chain.graph,
                    chain_inputs(&serve_chain, seeds[0]),
                )
                .unwrap();
            let load = LoadConfig {
                clients,
                requests_per_client: per_client,
            };
            let report = run_load(&server, &load, |c, i| {
                (
                    format!("tenant-{c}"),
                    serve_chain.graph.clone(),
                    chain_inputs(&serve_chain, seed_at(c, i)),
                )
            })
            .unwrap();
            server.shutdown();
            assert_eq!(
                report.rejected, 0,
                "{mode} x{sw}: load run must not reject under default queue depth"
            );
            assert_eq!(
                report.checksum, expected,
                "{mode} x{sw}: served outputs are not bitwise-identical to solo runs"
            );
            if mode == "solo" {
                assert_eq!(report.max_batched_with, 1, "solo arm must not coalesce");
                best_solo = best_solo.max(report.req_per_s);
            } else {
                best_batched = best_batched.max(report.req_per_s);
            }
            println!(
                "serve {mode:>7} x{sw} workers: {:8.1} req/s  p50 {:6.2} p95 {:6.2} p99 {:6.2} ms  \
                 mean batch {:.2} (max {})",
                report.req_per_s,
                report.latency.p50_ms,
                report.latency.p95_ms,
                report.latency.p99_ms,
                report.mean_batched_with,
                report.max_batched_with
            );
            let mut fields = vec![
                ("mode".to_string(), Json::str(mode)),
                ("serve_workers".to_string(), Json::num(sw as f64)),
                ("max_batch".to_string(), Json::num(max_batch as f64)),
            ];
            if let Json::Obj(rep_fields) = report.to_json() {
                fields.extend(rep_fields);
            }
            arms.push(Json::Obj(fields));
        }
    }
    let serving_speedup = best_batched / best_solo;
    println!(
        "dynamic batching speedup (best batched / best solo): {serving_speedup:.2}x  \
         (acceptance gate: >= 1.5x)"
    );
    assert!(
        serving_speedup >= 1.5,
        "dynamic batching gate failed: {serving_speedup:.2}x < 1.5x \
         (best batched {best_batched:.1} req/s, best solo {best_solo:.1} req/s)"
    );
    let serving_json = Json::Obj(vec![
        ("workload".to_string(), Json::str("matchain")),
        ("scale".to_string(), Json::num(serve_scale as f64)),
        ("clients".to_string(), Json::num(clients as f64)),
        (
            "requests_per_client".to_string(),
            Json::num(per_client as f64),
        ),
        (
            "batch_window_ms".to_string(),
            Json::num(window.as_secs_f64() * 1e3),
        ),
        (
            "expected_checksum".to_string(),
            Json::str(format!("{expected:016x}")),
        ),
        ("arms".to_string(), Json::Arr(arms)),
        ("best_solo_req_per_s".to_string(), Json::num(best_solo)),
        (
            "best_batched_req_per_s".to_string(),
            Json::num(best_batched),
        ),
        ("batched_speedup".to_string(), Json::num(serving_speedup)),
        ("parity_ok".to_string(), Json::Bool(true)),
        ("gate_1_5x".to_string(), Json::Bool(serving_speedup >= 1.5)),
    ]);

    let entry = |mode: &str, total: f64, rps: f64, extra: Vec<(String, Json)>| {
        let mut fields = vec![
            ("workload".to_string(), Json::str("matchain")),
            ("scale".to_string(), Json::num(scale as f64)),
            ("repeat".to_string(), Json::num(repeat as f64)),
            ("mode".to_string(), Json::str(mode)),
            ("total_s".to_string(), Json::num(total)),
            ("ms_per_run".to_string(), Json::num(total * 1e3 / repeat as f64)),
            ("runs_per_s".to_string(), Json::num(rps)),
        ];
        fields.extend(extra);
        Json::Obj(fields)
    };
    let report = Json::Obj(vec![
        (
            "driver_per_call".to_string(),
            entry(
                "plan+lower+run per request",
                cold_total,
                cold_rps,
                vec![("plan_s_per_req".to_string(), Json::num(rep_cold.plan_s))],
            ),
        ),
        (
            "session_cached".to_string(),
            entry(
                "compile once, run many",
                warm_total,
                warm_rps_amortized,
                vec![
                    ("compile_s".to_string(), Json::num(compile_s)),
                    ("plan_s".to_string(), Json::num(plan_s)),
                    ("lower_s".to_string(), Json::num(lower_s)),
                ],
            ),
        ),
        ("speedup_amortized".to_string(), Json::num(speedup)),
        ("bitwise_identical".to_string(), Json::Bool(true)),
        ("serving".to_string(), serving_json),
    ]);
    std::fs::write("BENCH_serving.json", report.render()).expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json");
}

//! TaskGraphs: the concrete, executable form of a planned EinGraph.
//!
//! Lowering (paper Figure 3: EinGraph + partitioning vectors -> TASKGRAPH)
//! expands every vertex into its TRA implementation — one *kernel call*
//! task per join tuple, *aggregation* tasks per output group, and
//! *repartition* tasks on every producer→consumer edge whose partitionings
//! disagree. Placement then assigns each task a worker; the simulated
//! cluster (see [`crate::sim`]) charges every cross-worker edge.

pub mod lower;
pub mod placement;

use crate::einsum::graph::VertexId;

/// Index of a task within its [`TaskGraph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// What a task does.
#[derive(Clone, Debug, PartialEq)]
pub enum TaskKind {
    /// A tile of a pre-partitioned graph input (materialized, no compute).
    InputTile { vertex: VertexId, key: Vec<usize> },
    /// One kernel call: the vertex's EinSum evaluated on operand tiles.
    /// `key` ranges over `I(d)` (the vertex's unique-label partitioning).
    Kernel { vertex: VertexId, key: Vec<usize> },
    /// Reduce a group of kernel outputs with the vertex's `(+)`.
    /// `key` ranges over `I(d_Z)`.
    Agg { vertex: VertexId, key: Vec<usize> },
    /// Build one consumer-layout tile of `producer`'s output from the
    /// producer-layout tiles overlapping it. `key` ranges over the
    /// consumer's required partitioning.
    Repart {
        producer: VertexId,
        consumer: VertexId,
        operand: usize,
        key: Vec<usize>,
    },
    /// One step of a collective schedule: a pure pass-through relay of a
    /// producer-layout tile toward collective member `member` (emitted
    /// by the `lower-collectives` IR pass). `key` is the *source* tile's
    /// key under the producer's partitioning — unlike `Repart`, whose
    /// `key` is a consumer-layout tile — so the executor can recover dep
    /// geometry without consulting `vertex_outputs`. Executes as a
    /// zero-copy view clone; the modeled ledger charges it as
    /// repartition traffic on whatever link the step crosses.
    Collective {
        producer: VertexId,
        consumer: VertexId,
        operand: usize,
        key: Vec<usize>,
        member: usize,
        step: usize,
    },
}

impl TaskKind {
    /// Transfer class for the byte ledger (mirrors the three cost-model
    /// components).
    pub fn class(&self) -> TransferClass {
        match self {
            TaskKind::InputTile { .. } => TransferClass::Input,
            TaskKind::Kernel { .. } => TransferClass::Join,
            TaskKind::Agg { .. } => TransferClass::Agg,
            TaskKind::Repart { .. } => TransferClass::Repart,
            TaskKind::Collective { .. } => TransferClass::Repart,
        }
    }
}

/// Which cost-model component a transfer belongs to (keyed by the
/// *consuming* task's kind).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransferClass {
    Input,
    Join,
    Agg,
    Repart,
}

/// A node of the task graph.
#[derive(Clone, Debug, PartialEq)]
pub struct Task {
    pub id: TaskId,
    pub kind: TaskKind,
    /// Tasks whose outputs this task reads, in operand order.
    pub deps: Vec<TaskId>,
    /// Bytes of the tile this task produces.
    pub out_bytes: usize,
    /// Estimated floating point operations of this task.
    pub flops: f64,
    /// Worker assignment. `None` until placement runs; every consumer of
    /// a placed graph reads it through [`Task::assigned_worker`], so an
    /// unplaced task can never silently land on a phantom worker id.
    pub worker: Option<usize>,
}

impl Task {
    /// The placed worker, for modeling/placement internals that only run
    /// on validated graphs. An unplaced task is a pipeline bug there, so
    /// debug builds panic with a diagnosable message; release builds fall
    /// back to worker 0 (the *run* path never takes that fallback — it
    /// reads placement through [`Task::worker_checked`] and surfaces a
    /// typed [`ExecCause::Unplaced`](crate::error::ExecCause) instead).
    #[inline]
    pub fn assigned_worker(&self) -> usize {
        debug_assert!(
            self.worker.is_some(),
            "task {} used before placement",
            self.id.0
        );
        self.worker.unwrap_or(0)
    }

    /// The placed worker as a typed result — the run-path accessor.
    /// Returns [`ExecCause::Unplaced`](crate::error::ExecCause) when
    /// placement never ran, instead of panicking mid-execution.
    #[inline]
    pub fn worker_checked(&self) -> crate::error::Result<usize> {
        self.worker.ok_or_else(|| {
            crate::error::Error::exec_failure(
                Some(self.id.0),
                0,
                crate::error::ExecCause::Unplaced,
            )
        })
    }
}

/// The lowered, placed task graph. `PartialEq` compares the full
/// structure (tasks, deps, bytes, flops, placement, vertex maps) — the
/// relation the IR-vs-direct-lowering differential tests assert.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TaskGraph {
    pub tasks: Vec<Task>,
    /// For each EinGraph vertex: the tasks producing its output tiles, in
    /// row-major `I(d_Z)` order.
    pub vertex_outputs: std::collections::HashMap<VertexId, Vec<TaskId>>,
    /// Output partitioning of each vertex (row-major key order of
    /// `vertex_outputs`).
    pub vertex_out_part: std::collections::HashMap<VertexId, Vec<usize>>,
    /// Pointwise ops the executor applies to a kernel task's output tile
    /// after evaluation, in order — placed by the `fuse-epilogue` IR
    /// pass. Kernels without an entry run bare. Empty map on every
    /// unfused lowering, so `PartialEq` against a reference lowering
    /// still holds bit-for-bit.
    pub kernel_epilogue:
        std::collections::HashMap<TaskId, Vec<crate::einsum::expr::UnaryOp>>,
    /// Set by IR emission when the `alias-refinement-repart` rewrite
    /// routed at least one kernel operand directly at a *coarser*
    /// producer tile. When `false` (every non-aliased lowering), the
    /// executor skips per-operand geometry recovery entirely — kernel
    /// deps are exactly the expected tiles.
    pub aliased_kernel_deps: bool,
}

impl TaskGraph {
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Append a task (unplaced) and return its id. Lowering builds the
    /// graph through this, which guarantees `id == index` and topological
    /// dep order by construction.
    pub fn push_task(
        &mut self,
        kind: TaskKind,
        deps: Vec<TaskId>,
        out_bytes: usize,
        flops: f64,
    ) -> TaskId {
        let id = TaskId(self.tasks.len());
        self.tasks.push(Task {
            id,
            kind,
            deps,
            out_bytes,
            flops,
            worker: None,
        });
        id
    }

    /// Occurrence-counted consumer adjacency: `consumers[p]` lists every
    /// task depending on `p`, once per dep occurrence. A task that reads
    /// the same producer tile through two operands therefore appears
    /// twice — matching [`indegrees`](Self::indegrees), so the scheduler's
    /// per-edge decrements balance exactly.
    pub fn consumers(&self) -> Vec<Vec<usize>> {
        let mut c: Vec<Vec<usize>> = vec![vec![]; self.tasks.len()];
        for t in &self.tasks {
            for &d in &t.deps {
                c[d.0].push(t.id.0);
            }
        }
        c
    }

    /// Dep-occurrence count per task (the scheduler's initial readiness
    /// counters; parallel to [`consumers`](Self::consumers)).
    pub fn indegrees(&self) -> Vec<usize> {
        self.tasks.iter().map(|t| t.deps.len()).collect()
    }

    /// Tasks grouped by ASAP level (level = longest dep chain length).
    /// Used by the retained level-barrier execution mode and by
    /// diagnostics; the work-stealing executor does not need levels.
    pub fn levels(&self) -> Vec<Vec<usize>> {
        let n = self.tasks.len();
        let mut level = vec![0usize; n];
        let mut max_level = 0usize;
        for t in &self.tasks {
            let l = t.deps.iter().map(|d| level[d.0] + 1).max().unwrap_or(0);
            level[t.id.0] = l;
            max_level = max_level.max(l);
        }
        let mut by_level: Vec<Vec<usize>> = vec![vec![]; if n == 0 { 0 } else { max_level + 1 }];
        for (i, &l) in level.iter().enumerate() {
            by_level[l].push(i);
        }
        by_level
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id.0]
    }

    /// The lineage closure of `roots`: every task some root transitively
    /// depends on, *including* the roots themselves, in ascending task-id
    /// order (which is topological — ids are emitted topologically).
    ///
    /// This is the recovery executor's recompute set: when a root's tile
    /// is gone, re-running its lineage in id order (skipping tasks whose
    /// tiles survive) rebuilds it bitwise-identically, because the graph
    /// is a pure function of its inputs and every task's fold order is
    /// fixed by `deps`.
    pub fn lineage(&self, roots: &[TaskId]) -> Vec<TaskId> {
        let mut in_set = vec![false; self.tasks.len()];
        let mut stack: Vec<usize> = Vec::new();
        for r in roots {
            if r.0 < self.tasks.len() && !in_set[r.0] {
                in_set[r.0] = true;
                stack.push(r.0);
            }
        }
        while let Some(t) = stack.pop() {
            for &d in &self.tasks[t].deps {
                if !in_set[d.0] {
                    in_set[d.0] = true;
                    stack.push(d.0);
                }
            }
        }
        in_set
            .iter()
            .enumerate()
            .filter(|(_, &m)| m)
            .map(|(i, _)| TaskId(i))
            .collect()
    }

    /// Number of kernel-call tasks (the paper's unit of parallel work).
    pub fn kernel_calls(&self) -> usize {
        self.tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Kernel { .. }))
            .count()
    }

    /// Validate the pre-placement structure: topological dep order, ids
    /// matching indices, non-empty aggregation fan-in, and vertex output
    /// maps referencing real tasks. Run unconditionally on every compile
    /// (`Session::compile` → `Cluster::lower`), so a malformed graph out
    /// of a new IR pass fails at compile time with a real error instead
    /// of at run time.
    pub fn validate_structure(&self) -> crate::error::Result<()> {
        for (i, t) in self.tasks.iter().enumerate() {
            if t.id.0 != i {
                return Err(crate::error::Error::TaskGraph(format!(
                    "task id {} at index {i}",
                    t.id.0
                )));
            }
            for &d in &t.deps {
                if d.0 >= t.id.0 {
                    return Err(crate::error::Error::TaskGraph(format!(
                        "task {} depends on later task {}",
                        t.id.0, d.0
                    )));
                }
            }
            if matches!(t.kind, TaskKind::Agg { .. }) && t.deps.is_empty() {
                return Err(crate::error::Error::TaskGraph(format!(
                    "aggregation task {} has no members",
                    t.id.0
                )));
            }
        }
        for (v, outs) in &self.vertex_outputs {
            if let Some(bad) = outs.iter().find(|t| t.0 >= self.tasks.len()) {
                return Err(crate::error::Error::TaskGraph(format!(
                    "vertex {v} output tile {} out of range",
                    bad.0
                )));
            }
            let part = self.vertex_out_part.get(v).ok_or_else(|| {
                crate::error::Error::TaskGraph(format!("vertex {v} has outputs but no part"))
            })?;
            let n: usize = part.iter().product();
            if outs.len() != n {
                return Err(crate::error::Error::TaskGraph(format!(
                    "vertex {v}: {} output tiles for part {part:?}",
                    outs.len()
                )));
            }
        }
        Ok(())
    }

    /// Validate structure ([`Self::validate_structure`]) plus placement:
    /// every task assigned to a worker in range.
    pub fn validate(&self, workers: usize) -> crate::error::Result<()> {
        self.validate_structure()?;
        for t in &self.tasks {
            match t.worker {
                None => {
                    return Err(crate::error::Error::TaskGraph(format!(
                        "task {} unplaced",
                        t.id.0
                    )))
                }
                Some(w) if w >= workers => {
                    return Err(crate::error::Error::TaskGraph(format!(
                        "task {} placed out of range (worker {w} of {workers})",
                        t.id.0
                    )));
                }
                Some(_) => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> TaskGraph {
        // 0, 1 inputs; 2 reads both; 3 reads 2 twice (duplicate edge)
        let mut tg = TaskGraph::default();
        let a = tg.push_task(
            TaskKind::InputTile { vertex: VertexId(0), key: vec![0] },
            vec![],
            4,
            0.0,
        );
        let b = tg.push_task(
            TaskKind::InputTile { vertex: VertexId(1), key: vec![0] },
            vec![],
            4,
            0.0,
        );
        let k = tg.push_task(
            TaskKind::Kernel { vertex: VertexId(2), key: vec![0] },
            vec![a, b],
            4,
            1.0,
        );
        tg.push_task(
            TaskKind::Kernel { vertex: VertexId(3), key: vec![0] },
            vec![k, k],
            4,
            1.0,
        );
        tg
    }

    #[test]
    fn consumers_and_indegrees_count_occurrences() {
        let tg = tiny_graph();
        let c = tg.consumers();
        assert_eq!(c[0], vec![2]);
        assert_eq!(c[1], vec![2]);
        // duplicate edge appears twice, balancing the indegree of 2
        assert_eq!(c[2], vec![3, 3]);
        assert_eq!(tg.indegrees(), vec![0, 0, 2, 2]);
    }

    #[test]
    fn levels_follow_longest_chain() {
        let tg = tiny_graph();
        let lv = tg.levels();
        assert_eq!(lv, vec![vec![0, 1], vec![2], vec![3]]);
        assert!(TaskGraph::default().levels().is_empty());
    }

    #[test]
    fn push_task_assigns_sequential_ids() {
        let tg = tiny_graph();
        for (i, t) in tg.tasks.iter().enumerate() {
            assert_eq!(t.id.0, i);
            assert_eq!(t.worker, None);
        }
    }

    #[test]
    fn lineage_closes_over_deps_in_id_order() {
        let tg = tiny_graph();
        // task 3 reads 2 (twice); 2 reads 0 and 1 — closure is everything
        assert_eq!(
            tg.lineage(&[TaskId(3)]),
            vec![TaskId(0), TaskId(1), TaskId(2), TaskId(3)]
        );
        // a root with no deps is its own lineage
        assert_eq!(tg.lineage(&[TaskId(1)]), vec![TaskId(1)]);
        // duplicate + out-of-range roots are deduped / ignored
        assert_eq!(
            tg.lineage(&[TaskId(2), TaskId(2), TaskId(99)]),
            vec![TaskId(0), TaskId(1), TaskId(2)]
        );
        assert!(tg.lineage(&[]).is_empty());
    }

    #[test]
    fn worker_checked_is_typed_where_assigned_worker_asserts() {
        let mut tg = tiny_graph();
        let err = tg.tasks[2].worker_checked().unwrap_err();
        let exec = err.as_exec().expect("typed exec error");
        assert_eq!(exec.task, Some(2));
        assert!(matches!(exec.cause, crate::error::ExecCause::Unplaced));
        tg.tasks[2].worker = Some(3);
        assert_eq!(tg.tasks[2].worker_checked().unwrap(), 3);
        assert_eq!(tg.tasks[2].assigned_worker(), 3);
    }

    #[test]
    fn validate_rejects_unplaced_and_malformed_graphs() {
        let mut tg = tiny_graph();
        tg.validate_structure().unwrap();
        // unplaced tasks fail placement validation but not structure
        assert!(tg.validate(4).is_err());
        for t in tg.tasks.iter_mut() {
            t.worker = Some(0);
        }
        tg.validate(4).unwrap();
        // out-of-range placement
        tg.tasks[1].worker = Some(9);
        assert!(tg.validate(4).is_err());
        tg.tasks[1].worker = Some(0);
        // an aggregation with no members is structurally invalid
        tg.push_task(
            TaskKind::Agg { vertex: VertexId(9), key: vec![0] },
            vec![],
            4,
            0.0,
        );
        assert!(tg.validate_structure().is_err());
        let _ = tg.tasks.pop();
        // vertex output map referencing a phantom task
        tg.vertex_outputs.insert(VertexId(7), vec![TaskId(99)]);
        tg.vertex_out_part.insert(VertexId(7), vec![1]);
        assert!(tg.validate_structure().is_err());
    }
}

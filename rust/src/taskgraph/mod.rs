//! TaskGraphs: the concrete, executable form of a planned EinGraph.
//!
//! Lowering (paper Figure 3: EinGraph + partitioning vectors -> TASKGRAPH)
//! expands every vertex into its TRA implementation — one *kernel call*
//! task per join tuple, *aggregation* tasks per output group, and
//! *repartition* tasks on every producer→consumer edge whose partitionings
//! disagree. Placement then assigns each task a worker; the simulated
//! cluster (see [`crate::sim`]) charges every cross-worker edge.

pub mod lower;
pub mod placement;

use crate::einsum::graph::VertexId;

/// Index of a task within its [`TaskGraph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub usize);

/// What a task does.
#[derive(Clone, Debug, PartialEq)]
pub enum TaskKind {
    /// A tile of a pre-partitioned graph input (materialized, no compute).
    InputTile { vertex: VertexId, key: Vec<usize> },
    /// One kernel call: the vertex's EinSum evaluated on operand tiles.
    /// `key` ranges over `I(d)` (the vertex's unique-label partitioning).
    Kernel { vertex: VertexId, key: Vec<usize> },
    /// Reduce a group of kernel outputs with the vertex's `(+)`.
    /// `key` ranges over `I(d_Z)`.
    Agg { vertex: VertexId, key: Vec<usize> },
    /// Build one consumer-layout tile of `producer`'s output from the
    /// producer-layout tiles overlapping it. `key` ranges over the
    /// consumer's required partitioning.
    Repart {
        producer: VertexId,
        consumer: VertexId,
        operand: usize,
        key: Vec<usize>,
    },
}

impl TaskKind {
    /// Transfer class for the byte ledger (mirrors the three cost-model
    /// components).
    pub fn class(&self) -> TransferClass {
        match self {
            TaskKind::InputTile { .. } => TransferClass::Input,
            TaskKind::Kernel { .. } => TransferClass::Join,
            TaskKind::Agg { .. } => TransferClass::Agg,
            TaskKind::Repart { .. } => TransferClass::Repart,
        }
    }
}

/// Which cost-model component a transfer belongs to (keyed by the
/// *consuming* task's kind).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TransferClass {
    Input,
    Join,
    Agg,
    Repart,
}

/// A node of the task graph.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub kind: TaskKind,
    /// Tasks whose outputs this task reads, in operand order.
    pub deps: Vec<TaskId>,
    /// Bytes of the tile this task produces.
    pub out_bytes: usize,
    /// Estimated floating point operations of this task.
    pub flops: f64,
    /// Worker assignment (filled by placement; usize::MAX = unassigned).
    pub worker: usize,
}

/// The lowered, placed task graph.
#[derive(Clone, Debug, Default)]
pub struct TaskGraph {
    pub tasks: Vec<Task>,
    /// For each EinGraph vertex: the tasks producing its output tiles, in
    /// row-major `I(d_Z)` order.
    pub vertex_outputs: std::collections::HashMap<VertexId, Vec<TaskId>>,
    /// Output partitioning of each vertex (row-major key order of
    /// `vertex_outputs`).
    pub vertex_out_part: std::collections::HashMap<VertexId, Vec<usize>>,
}

impl TaskGraph {
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id.0]
    }

    /// Number of kernel-call tasks (the paper's unit of parallel work).
    pub fn kernel_calls(&self) -> usize {
        self.tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Kernel { .. }))
            .count()
    }

    /// Validate topological ordering (deps precede users) and placement.
    pub fn validate(&self, workers: usize) -> crate::error::Result<()> {
        for t in &self.tasks {
            for &d in &t.deps {
                if d.0 >= t.id.0 {
                    return Err(crate::error::Error::TaskGraph(format!(
                        "task {} depends on later task {}",
                        t.id.0, d.0
                    )));
                }
            }
            if t.worker >= workers {
                return Err(crate::error::Error::TaskGraph(format!(
                    "task {} unplaced or out of range (worker {})",
                    t.id.0, t.worker
                )));
            }
        }
        Ok(())
    }
}

//! Lowering: (EinGraph, Plan) -> TaskGraph.
//!
//! Every non-input vertex becomes (paper §4.3/Eq. 5):
//!
//! 1. per operand: repartition tasks if the producer's output partitioning
//!    differs from `d[l_o; l_uniq]` (each consumer-layout tile depends on
//!    exactly the producer-layout tiles overlapping it);
//! 2. `prod(d)` kernel-call tasks, one per join tuple;
//! 3. if `prod(d[l_agg]) > 1`, one aggregation task per output tile,
//!    reducing its group of kernel outputs.
//!
//! Inputs become one `InputTile` task per tile of their pre-partitioning.
//!
//! Since the TRA IR landed, lowering proper goes through
//! [`crate::tra::program::from_plan`] + `emit_tasks` (`Cluster::lower`
//! runs the configured pass pipeline between the two steps; the one-time
//! `lower_graph` wrapper is gone). The pre-IR direct lowering survives
//! verbatim as [`lower_graph_reference`] — the frozen differential
//! baseline the equivalence tests and `benches/lowering.rs` compare
//! against.

use super::{TaskGraph, TaskId, TaskKind};
use crate::decomp::Plan;
use crate::einsum::expr::EinSum;
use crate::einsum::graph::EinGraph;
use crate::einsum::label::project;
use crate::error::{Error, Result};
use crate::tensor::index_space;
use crate::tra::relation::{
    linearize, overlapping_tiles, tile_bytes, tile_offset, tile_size,
};

/// The pre-IR direct lowering, one vertex at a time, with no
/// intermediate program. Frozen as the differential baseline:
/// `tests/tra_program.rs` and `benches/lowering.rs` assert the IR path
/// reproduces this function's output exactly (same tasks, deps, bytes,
/// flops).
pub fn lower_graph_reference(g: &EinGraph, plan: &Plan) -> Result<TaskGraph> {
    let mut tg = TaskGraph::default();

    for vert in g.vertices() {
        let v = vert.id;
        match &vert.op {
            EinSum::Input => {
                let part = plan
                    .input_parts
                    .get(&v)
                    .cloned()
                    .unwrap_or_else(|| vec![1; vert.bound.len()]);
                let mut outs = Vec::new();
                for key in index_space(&part) {
                    let bytes = tile_bytes(&vert.bound, &part, &key);
                    outs.push(tg.push_task(
                        TaskKind::InputTile { vertex: v, key },
                        vec![],
                        bytes,
                        0.0,
                    ));
                }
                tg.vertex_outputs.insert(v, outs);
                tg.vertex_out_part.insert(v, part);
            }
            op => {
                let d = plan
                    .parts
                    .get(&v)
                    .ok_or_else(|| Error::TaskGraph(format!("vertex {} unplanned", vert.name)))?;
                let uniq = op.unique_labels();
                let lz = op.lz().unwrap();
                let dz = project(d, lz, &uniq);
                let bz = &vert.bound;

                // 1. per-operand tile providers (repartitioned if needed)
                let mut operand_tiles: Vec<Vec<TaskId>> = Vec::new();
                let mut operand_parts: Vec<Vec<usize>> = Vec::new();
                for (o, &c) in vert.inputs.iter().enumerate() {
                    let need = project(d, op.operand_labels()[o], &uniq);
                    let have = tg.vertex_out_part[&c].clone();
                    let have_tiles = tg.vertex_outputs[&c].clone();
                    let cb = &g.vertex(c).bound;
                    if have == need {
                        operand_tiles.push(have_tiles);
                    } else {
                        // repartition: one task per needed tile
                        let mut tiles = Vec::new();
                        for key in index_space(&need) {
                            // deps: all producer tiles overlapping this region
                            let ranges: Vec<(usize, usize)> = key
                                .iter()
                                .enumerate()
                                .map(|(dim, &k)| {
                                    let origin = tile_offset(cb[dim], need[dim], k);
                                    let len = tile_size(cb[dim], need[dim], k);
                                    overlapping_tiles(cb[dim], have[dim], origin, len)
                                })
                                .collect();
                            let mut deps = Vec::new();
                            let range_dims: Vec<usize> =
                                ranges.iter().map(|(lo, hi)| hi - lo + 1).collect();
                            for rk in index_space(&range_dims) {
                                let pkey: Vec<usize> = rk
                                    .iter()
                                    .zip(&ranges)
                                    .map(|(&r, &(lo, _))| lo + r)
                                    .collect();
                                deps.push(have_tiles[linearize(&pkey, &have)]);
                            }
                            let bytes = tile_bytes(cb, &need, &key);
                            tiles.push(tg.push_task(
                                TaskKind::Repart {
                                    producer: c,
                                    consumer: v,
                                    operand: o,
                                    key,
                                },
                                deps,
                                bytes,
                                0.0,
                            ));
                        }
                        operand_tiles.push(tiles);
                    }
                    operand_parts.push(need);
                }

                // 2. kernel-call tasks, one per join tuple
                let in_bounds: Vec<&[usize]> = vert
                    .inputs
                    .iter()
                    .map(|&i| g.vertex(i).bound.as_slice())
                    .collect();
                let total_flops = op.flops(&in_bounds)?;
                let n_calls: usize = d.iter().product();
                let flops_per_call = total_flops / n_calls as f64;
                let mut kernel_by_key: Vec<TaskId> = Vec::with_capacity(n_calls);
                for key in index_space(d) {
                    let mut deps = Vec::new();
                    for (o, lo) in op.operand_labels().iter().enumerate() {
                        let okey = project(&key, lo, &uniq);
                        deps.push(operand_tiles[o][linearize(&okey, &operand_parts[o])]);
                    }
                    // output tile shape over lz under (bz, dz) at zkey
                    let zkey = project(&key, lz, &uniq);
                    let bytes = tile_bytes(bz, &dz, &zkey);
                    kernel_by_key.push(tg.push_task(
                        TaskKind::Kernel { vertex: v, key },
                        deps,
                        bytes,
                        flops_per_call,
                    ));
                }

                // 3. aggregation per output tile if needed
                let lagg = op.lagg();
                let n_agg: usize = project(d, &lagg, &uniq).iter().product();
                let outs: Vec<TaskId> = if n_agg > 1 {
                    let mut groups: std::collections::HashMap<Vec<usize>, Vec<TaskId>> =
                        std::collections::HashMap::new();
                    for (key, &tid) in index_space(d).zip(&kernel_by_key) {
                        groups
                            .entry(project(&key, lz, &uniq))
                            .or_default()
                            .push(tid);
                    }
                    let mut outs = Vec::new();
                    for zkey in index_space(&dz) {
                        let members = groups.remove(&zkey).ok_or_else(|| {
                            Error::TaskGraph(format!("missing agg group {zkey:?}"))
                        })?;
                        let bytes = tile_bytes(bz, &dz, &zkey);
                        let elems = (bytes / 4) as f64;
                        let flops = elems * (members.len() as f64 - 1.0);
                        outs.push(tg.push_task(
                            TaskKind::Agg {
                                vertex: v,
                                key: zkey,
                            },
                            members,
                            bytes,
                            flops,
                        ));
                    }
                    outs
                } else {
                    // No aggregation: the kernel tasks ARE the output
                    // tiles, but they were created in I(d) order (over the
                    // unique labels). Consumers index vertex outputs in
                    // row-major I(d_Z) order (over l_Z, possibly permuted
                    // relative to the unique labels), so reorder.
                    let mut outs = vec![TaskId(usize::MAX); kernel_by_key.len()];
                    for (key, &tid) in index_space(d).zip(&kernel_by_key) {
                        let zkey = project(&key, lz, &uniq);
                        outs[linearize(&zkey, &dz)] = tid;
                    }
                    debug_assert!(outs.iter().all(|t| t.0 != usize::MAX));
                    outs
                };
                tg.vertex_outputs.insert(v, outs);
                tg.vertex_out_part.insert(v, dz);
            }
        }
    }
    Ok(tg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{plan_graph, PlannerConfig};
    use crate::einsum::label::labels;

    /// The no-pass IR lowering every test compares or builds through —
    /// what the retired `lower_graph` wrapper did.
    fn lower_via_ir(g: &EinGraph, plan: &Plan) -> TaskGraph {
        crate::tra::program::from_plan(g, plan)
            .unwrap()
            .emit_tasks()
            .unwrap()
    }

    fn matmul_graph(s: usize) -> EinGraph {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        g.add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
        g
    }

    #[test]
    fn matmul_lowering_produces_p_kernels() {
        let g = matmul_graph(64);
        let plan = plan_graph(&g, &PlannerConfig { p: 16, ..Default::default() }).unwrap();
        let tg = lower_via_ir(&g, &plan);
        assert_eq!(tg.kernel_calls(), 16);
        // topological by construction
        for t in &tg.tasks {
            for &d in &t.deps {
                assert!(d.0 < t.id.0);
            }
        }
    }

    #[test]
    fn figure2_task_counts() {
        // d = [2,2,4] over (i,j,k) on an 8x8 matmul: 16 kernel calls, 8
        // output tiles each aggregated from 2 — exactly Figure 2's
        // bottom-right dataflow.
        let g = matmul_graph(8);
        let z = g.by_name("Z").unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(z, vec![2, 2, 4]);
        plan.finalize_inputs(&g);
        let tg = lower_via_ir(&g, &plan);
        assert_eq!(tg.kernel_calls(), 16);
        let aggs = tg
            .tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Agg { .. }))
            .count();
        assert_eq!(aggs, 8);
        for t in &tg.tasks {
            if let TaskKind::Agg { .. } = t.kind {
                assert_eq!(t.deps.len(), 2);
            }
        }
        // join-only cases have no aggregation tasks
        let mut plan2 = Plan::default();
        plan2.parts.insert(z, vec![4, 1, 4]);
        plan2.finalize_inputs(&g);
        let tg2 = lower_via_ir(&g, &plan2);
        assert_eq!(
            tg2.tasks
                .iter()
                .filter(|t| matches!(t.kind, TaskKind::Agg { .. }))
                .count(),
            0
        );
    }

    #[test]
    fn repart_tasks_created_on_mismatch() {
        // chain: Z1 = A@B with dz [2,4]; Z2 = Z1@C needing [4,1] -> repart
        let mut g = EinGraph::new();
        let a = g.input("A", vec![8, 8]);
        let b = g.input("B", vec![8, 8]);
        let c = g.input("C", vec![8, 8]);
        let z1 = g
            .add(
                "Z1",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let z2 = g
            .add(
                "Z2",
                EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
                vec![z1, c],
            )
            .unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(z1, vec![2, 2, 4]); // dz over (i,k) = [2,4]
        plan.parts.insert(z2, vec![4, 1, 4]); // needs z1 as [4,1]
        plan.finalize_inputs(&g);
        let tg = lower_via_ir(&g, &plan);
        let reparts: Vec<_> = tg
            .tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Repart { .. }))
            .collect();
        // consumer needs 4 tiles of Z1 under [4,1]
        assert_eq!(reparts.len(), 4);
        // each [4,1]-tile (2 rows x 8 cols) overlaps 1 row-block x 4
        // col-blocks of the [2,4] layout = 4 producer tiles
        for t in &reparts {
            assert_eq!(t.deps.len(), 4);
        }
    }

    #[test]
    fn overlap_ranges_balanced_tiling() {
        // bound 10 split 3 ways (4,3,3 at offsets 0,4,7); region [3,6)
        // overlaps tiles 0 and 1
        assert_eq!(overlapping_tiles(10, 3, 3, 3), (0, 1));
        assert_eq!(overlapping_tiles(10, 3, 7, 3), (2, 2));
        assert_eq!(overlapping_tiles(10, 3, 0, 10), (0, 2));
    }

    #[test]
    fn ir_reproduces_reference_lowering() {
        // The no-pass IR lowering must match the frozen direct lowering
        // exactly, including on graphs with repartitions and
        // aggregations.
        let mut g = EinGraph::new();
        let a = g.input("A", vec![12, 8]);
        let b = g.input("B", vec![8, 12]);
        let c = g.input("C", vec![12, 12]);
        let z1 = g
            .add(
                "Z1",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        g.add(
            "Z2",
            EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
            vec![z1, c],
        )
        .unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(z1, vec![2, 2, 4]);
        plan.parts.insert(g.by_name("Z2").unwrap(), vec![4, 1, 4]);
        plan.finalize_inputs(&g);
        let via_ir = lower_via_ir(&g, &plan);
        let direct = lower_graph_reference(&g, &plan).unwrap();
        assert_eq!(via_ir, direct);
    }

    #[test]
    fn input_tiles_match_pre_partitioning() {
        let g = matmul_graph(8);
        let z = g.by_name("Z").unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(z, vec![2, 1, 2]);
        plan.finalize_inputs(&g);
        let tg = lower_via_ir(&g, &plan);
        let a = g.by_name("A").unwrap();
        // A pre-partitioned [2,1] -> 2 input tiles of 4x8 = 128 bytes
        assert_eq!(tg.vertex_outputs[&a].len(), 2);
        assert_eq!(tg.task(tg.vertex_outputs[&a][0]).out_bytes, 4 * 8 * 4);
    }
}

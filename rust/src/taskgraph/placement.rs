//! Processor placement: assign every task a worker.
//!
//! The cost model's aggregation term assumes the reducer is co-located
//! with one group member, and its join term is an upper bound that good
//! placement undercuts via locality. Besides driving the modeled
//! timeline, the placed worker also seeds each task's *home deque* in the
//! real work-stealing executor (see [`crate::sim::cluster`]), so the two
//! views of locality stay aligned. Two policies:
//!
//! * [`Policy::RoundRobin`] — spread each vertex's tasks over workers by
//!   linear key. Simple, perfectly balanced, locality-blind.
//! * [`Policy::LocalityGreedy`] (default) — place each task on the worker
//!   holding the most input bytes, subject to a per-vertex load cap of
//!   `ceil(tasks/p)` so no worker hoards a vertex's work.

use super::{TaskGraph, TaskKind};
use std::collections::HashMap;

/// Placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Policy {
    RoundRobin,
    #[default]
    LocalityGreedy,
}

/// Assign a worker to every task, in place.
pub fn place(tg: &mut TaskGraph, workers: usize, policy: Policy) {
    let workers = workers.max(1);
    match policy {
        Policy::RoundRobin => place_round_robin(tg, workers),
        Policy::LocalityGreedy => place_locality(tg, workers),
    }
}

fn place_round_robin(tg: &mut TaskGraph, workers: usize) {
    // per-vertex counters so each vertex's tasks spread evenly
    let mut counters: HashMap<(u8, usize), usize> = HashMap::new();
    for i in 0..tg.tasks.len() {
        let keyv = match &tg.tasks[i].kind {
            TaskKind::InputTile { vertex, .. } => (0u8, vertex.0),
            TaskKind::Kernel { vertex, .. } => (1, vertex.0),
            TaskKind::Agg { vertex, .. } => (2, vertex.0),
            TaskKind::Repart { producer, .. } => (3, producer.0),
            // relay steps are pinned to their member's worker (below),
            // bypassing the counter — a relay on any other worker would
            // defeat the schedule
            TaskKind::Collective { producer, .. } => (4, producer.0),
        };
        if let TaskKind::Collective { member, .. } = &tg.tasks[i].kind {
            tg.tasks[i].worker = Some(member % workers);
            continue;
        }
        let c = counters.entry(keyv).or_insert(0);
        tg.tasks[i].worker = Some(*c % workers);
        *c += 1;
    }
}

fn place_locality(tg: &mut TaskGraph, workers: usize) {
    // group task indices by (kind-class, vertex) to apply per-vertex caps
    let mut load: HashMap<(u8, usize), Vec<usize>> = HashMap::new(); // per-group per-worker load
    let group_of = |k: &TaskKind| -> (u8, usize) {
        match k {
            TaskKind::InputTile { vertex, .. } => (0u8, vertex.0),
            TaskKind::Kernel { vertex, .. } => (1, vertex.0),
            TaskKind::Agg { vertex, .. } => (2, vertex.0),
            TaskKind::Repart { producer, .. } => (3, producer.0),
            TaskKind::Collective { producer, .. } => (4, producer.0),
        }
    };
    // group sizes for caps
    let mut group_size: HashMap<(u8, usize), usize> = HashMap::new();
    for t in &tg.tasks {
        *group_size.entry(group_of(&t.kind)).or_insert(0) += 1;
    }
    let mut rr: HashMap<(u8, usize), usize> = HashMap::new();
    for i in 0..tg.tasks.len() {
        let gid = group_of(&tg.tasks[i].kind);
        let cap = group_size[&gid].div_ceil(workers);
        let gl = load.entry(gid).or_insert_with(|| vec![0; workers]);
        let worker = match &tg.tasks[i].kind {
            TaskKind::Collective { member, .. } => {
                // relay steps belong to their member by definition — the
                // schedule's link pattern *is* the placement, so the
                // load-balancing cap does not apply
                member % workers
            }
            TaskKind::InputTile { .. } => {
                // inputs: pre-placed round-robin (offline, free)
                let c = rr.entry(gid).or_insert(0);
                let w = *c % workers;
                *c += 1;
                w
            }
            TaskKind::Agg { .. } => {
                // co-locate with the first group member whose worker still
                // has cap, else the least-loaded member worker (deps are
                // already placed: lowering is topological)
                let mut best: Option<usize> = None;
                for &d in &tg.tasks[i].deps {
                    let w = tg.tasks[d.0].assigned_worker();
                    if gl[w] < cap {
                        best = Some(w);
                        break;
                    }
                }
                best.unwrap_or_else(|| {
                    tg.tasks[i]
                        .deps
                        .iter()
                        .map(|d| tg.tasks[d.0].assigned_worker())
                        .min_by_key(|&w| gl[w])
                        .unwrap_or(0)
                })
            }
            _ => {
                // kernel / repart: worker with most local input bytes,
                // respecting the cap; fall back to least-loaded
                let mut bytes_by_worker: HashMap<usize, usize> = HashMap::new();
                for &d in &tg.tasks[i].deps {
                    let dep = &tg.tasks[d.0];
                    *bytes_by_worker.entry(dep.assigned_worker()).or_insert(0) += dep.out_bytes;
                }
                let mut cands: Vec<(usize, usize)> = bytes_by_worker.into_iter().collect();
                cands.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                cands
                    .iter()
                    .find(|(w, _)| gl[*w] < cap)
                    .map(|(w, _)| *w)
                    .unwrap_or_else(|| (0..workers).min_by_key(|&w| gl[w]).unwrap())
            }
        };
        tg.tasks[i].worker = Some(worker);
        load.get_mut(&gid).unwrap()[worker] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{plan_graph, PlannerConfig};
    use crate::einsum::expr::EinSum;
    use crate::einsum::graph::EinGraph;
    use crate::einsum::label::labels;
    use crate::tra::program::from_plan;

    fn lowered(p: usize) -> TaskGraph {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![64, 64]);
        let b = g.input("B", vec![64, 64]);
        let c = g.input("C", vec![64, 64]);
        let ab = g
            .add(
                "AB",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        g.add(
            "ABC",
            EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
            vec![ab, c],
        )
        .unwrap();
        let plan = plan_graph(&g, &PlannerConfig { p, ..Default::default() }).unwrap();
        from_plan(&g, &plan).unwrap().emit_tasks().unwrap()
    }

    #[test]
    fn round_robin_balances_kernels() {
        let mut tg = lowered(8);
        place(&mut tg, 8, Policy::RoundRobin);
        tg.validate(8).unwrap();
        // each vertex's 8 kernel calls spread over all 8 workers
        let mut per_worker = vec![0usize; 8];
        for t in &tg.tasks {
            if matches!(t.kind, TaskKind::Kernel { .. }) {
                per_worker[t.assigned_worker()] += 1;
            }
        }
        assert!(per_worker.iter().all(|&c| c == 2), "{per_worker:?}");
    }

    #[test]
    fn locality_respects_cap_and_validates() {
        let mut tg = lowered(8);
        place(&mut tg, 8, Policy::LocalityGreedy);
        tg.validate(8).unwrap();
        let mut per_worker = vec![0usize; 8];
        for t in &tg.tasks {
            if matches!(t.kind, TaskKind::Kernel { .. }) {
                per_worker[t.assigned_worker()] += 1;
            }
        }
        // cap = ceil(8/8) = 1 per vertex, two vertices -> exactly 2 each
        assert!(per_worker.iter().all(|&c| c == 2), "{per_worker:?}");
    }

    #[test]
    fn agg_colocated_with_a_member() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![8, 8]);
        let b = g.input("B", vec![8, 8]);
        let z = g
            .add(
                "Z",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z, vec![2, 2, 4]);
        plan.finalize_inputs(&g);
        let mut tg = from_plan(&g, &plan).unwrap().emit_tasks().unwrap();
        place(&mut tg, 4, Policy::LocalityGreedy);
        for t in &tg.tasks {
            if let TaskKind::Agg { .. } = t.kind {
                let member_workers: Vec<usize> =
                    t.deps.iter().map(|d| tg.tasks[d.0].assigned_worker()).collect();
                assert!(member_workers.contains(&t.assigned_worker()));
            }
        }
    }

    #[test]
    fn single_worker_placement() {
        let mut tg = lowered(4);
        place(&mut tg, 1, Policy::LocalityGreedy);
        tg.validate(1).unwrap();
        assert!(tg.tasks.iter().all(|t| t.worker == Some(0)));
    }
}

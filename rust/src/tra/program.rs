//! The TRA **intermediate representation**: the relational program of
//! Eq. 5, reified as a first-class, inspectable compiler stage.
//!
//! The planner fixes one partitioning vector per vertex; the paper's core
//! claim is that each vertex then *rewrites* into a tensor-relational
//! expression — partition, re-key, join, aggregate, plus repartitions on
//! every edge whose layouts disagree. Before this module, that program
//! existed only implicitly inside the task-graph lowering; now it is a
//! value:
//!
//! ```text
//!   (EinGraph, Plan) ──from_plan──▶ TraProgram ──passes──▶ TraProgram
//!                                                 │
//!                                           emit_tasks()
//!                                                 ▼
//!                                             TaskGraph
//! ```
//!
//! A [`TraProgram`] is a DAG of [`TraNode`]s over logical relations
//! ([`RelId`]s), each carrying a [`RelSchema`] — `(bound, part, labels)`.
//! [`from_plan`] builds the program; [`crate::tra::passes::PassManager`]
//! rewrites it; [`TraProgram::emit_tasks`] lowers it to a concrete
//! [`TaskGraph`]. With no passes applied, `emit_tasks` reproduces the
//! direct lowering ([`crate::taskgraph::lower::lower_graph_reference`])
//! **exactly** — same tasks, same ids, same deps, same bytes and flops —
//! a property `tests/tra_program.rs` asserts differentially.
//!
//! ```
//! use eindecomp::decomp::{plan_graph, PlannerConfig};
//! use eindecomp::einsum::expr::EinSum;
//! use eindecomp::einsum::graph::EinGraph;
//! use eindecomp::einsum::label::labels;
//! use eindecomp::tra::program::from_plan;
//!
//! let mut g = EinGraph::new();
//! let a = g.input("A", vec![16, 16]);
//! let b = g.input("B", vec![16, 16]);
//! g.add("Z", EinSum::contraction(labels("i j"), labels("j k"), labels("i k")), vec![a, b])?;
//! let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() })?;
//!
//! let prog = from_plan(&g, &plan)?;
//! let tg = prog.emit_tasks()?;
//! assert_eq!(tg.kernel_calls(), 4);
//! assert!(prog.render().contains("Join"));
//! # Ok::<(), eindecomp::Error>(())
//! ```

use crate::decomp::Plan;
use crate::einsum::expr::{AggOp, EinSum};
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::LabelList;
use crate::error::{Error, Result};
use crate::taskgraph::{TaskGraph, TaskId, TaskKind};
use crate::tensor::index_space;
use crate::tra::relation::{
    delinearize, linearize, overlapping_tiles, tile_bytes, tile_offset, tile_size,
};
use std::collections::HashMap;
use std::fmt::Write as _;

/// Index of a logical relation within its [`TraProgram`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RelId(pub usize);

/// Schema of a logical tensor relation: the dense bound it tiles, the
/// partitioning vector of its key space, and the labels the key
/// coordinates range over (empty for graph inputs, whose axes are
/// positional). For a [`TraOp::Join`] output the labels are the vertex's
/// *unique* labels and `bound[i]` is the extent of label `labels[i]`;
/// everywhere else labels/bound/part are parallel to the tensor's axes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RelSchema {
    pub bound: Vec<usize>,
    pub part: Vec<usize>,
    pub labels: LabelList,
}

impl RelSchema {
    /// Number of tuples, `prod(part)`.
    pub fn num_tiles(&self) -> usize {
        self.part.iter().product()
    }

    fn render(&self) -> String {
        let axes: Vec<String> = if self.labels.len() == self.bound.len() {
            self.labels
                .iter()
                .zip(self.bound.iter().zip(&self.part))
                .map(|(l, (b, d))| format!("{l}:{b}/{d}"))
                .collect()
        } else {
            self.bound
                .iter()
                .zip(&self.part)
                .map(|(b, d)| format!("{b}/{d}"))
                .collect()
        };
        format!("[{}]", axes.join(" "))
    }
}

/// How a collective's relay/fold chain is laid out across its members.
///
/// Both schedules are deterministic (fixed member order). `Ring` relays
/// neighbor-to-neighbor — the textbook bandwidth-optimal layout, and for
/// reductions it reproduces the serial left-fold order bit-for-bit.
/// `Tree` fans out/in over an `arity`-ary tree — fewer serialized steps,
/// but a *tree-scheduled reduction* re-associates the float fold and is
/// therefore opt-in only (see `PassManager::with_reduce_schedule` and
/// the agg-tree precedent).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveSchedule {
    /// Member `i` relays from member `i - 1`: `p - 1` serialized steps,
    /// `(p-1)/p` of the bytes per link.
    Ring,
    /// Member `i` relays from member `(i - 1) / arity`: depth
    /// `ceil(log_arity p)`.
    Tree { arity: usize },
}

/// One relational operation of the IR (paper §4.2 / Eq. 5).
#[derive(Clone, Debug, PartialEq)]
pub enum TraOp {
    /// `Π_d` over a graph input: the offline pre-partitioning. Emits one
    /// `InputTile` task per tuple.
    Partition { vertex: VertexId },
    /// `Π_need` on an operand edge whose producer layout (`src`'s part)
    /// differs from what the consumer requires. Emits one `Repart` task
    /// per needed tile — except when the node is an *identity* (equal
    /// parts forward tiles; see the `elide-identity-repart` pass) or
    /// `alias` is set (a pure refinement; see `alias-refinement-repart`),
    /// both of which emit **zero** tasks.
    Repartition {
        src: RelId,
        producer: VertexId,
        consumer: VertexId,
        operand: usize,
        /// Set by the `alias-refinement-repart` pass: every needed tile
        /// is contained in exactly one producer tile, so consumers read
        /// sub-views of the producer tiles directly.
        alias: bool,
    },
    /// The Eq.-5 join: match tuples agreeing on shared labels and apply
    /// the tile-local kernel. One `Kernel` task per tuple of `I(d)` (the
    /// output schema's part). A single-input join is the unary map case.
    Join {
        vertex: VertexId,
        inputs: Vec<RelId>,
        flops_per_call: f64,
        /// `Some(u)` when this join is a *pure elementwise map* (a
        /// [`EinSum::Unary`] whose output labels equal its input labels,
        /// so no permutation and no aggregation) — the shape the
        /// `fuse-epilogue` pass folds into its producer's kernel.
        map_op: Option<crate::einsum::expr::UnaryOp>,
        /// Pointwise maps fused *into* this join's kernel by the
        /// `fuse-epilogue` pass, applied in order to every output tile
        /// right after the kernel writes it (the `alpha`/`beta`-style
        /// epilogue position of [`crate::runtime::gemm`]). Empty until
        /// the pass runs.
        epilogue: Vec<crate::einsum::expr::UnaryOp>,
    },
    /// `(+)`-reduce groups of join tuples agreeing on the output labels.
    /// `tree_arity: None` emits one serial-fold `Agg` task per group;
    /// `Some(r)` (set by the `agg-tree` pass) emits a balanced `r`-ary
    /// reduction tree in fixed member order, bounding every task's
    /// fan-in by `r`.
    Aggregate {
        vertex: VertexId,
        src: RelId,
        agg: AggOp,
        tree_arity: Option<usize>,
    },
    /// Pure key relabeling `I(d) -> I(d_Z)` when nothing aggregates:
    /// the join tuples *are* the output tiles, reindexed row-major over
    /// the output labels. Emits zero tasks.
    ReKey { vertex: VertexId, src: RelId },
    /// Marks a graph output: the executor assembles the relation into a
    /// dense tensor after the run. Emits zero tasks.
    Assemble { vertex: VertexId, src: RelId },
    /// Placed by the `cse` pass where a duplicate vertex chain was
    /// merged into its first occurrence: `vertex`'s tiles *are* the
    /// tiles of `src` (the canonical chain's output relation). Emits
    /// zero tasks — emission forwards `src`'s tasks and registers them
    /// as `vertex`'s outputs so downstream repartition key recovery and
    /// output assembly still find the merged vertex.
    Reuse { vertex: VertexId, src: RelId },
    /// A broadcast-shaped `Π` lifted by the `lower-collectives` pass:
    /// source tiles read by two or more consumer tiles are relayed
    /// member-to-member along `schedule` (pure pass-through copies, so
    /// bitwise-identical to the point-to-point `Repartition`) instead of
    /// every member fetching from the producer — O(p) link crossings
    /// where the point-to-point pattern pays O(p²).
    AllGather {
        src: RelId,
        producer: VertexId,
        consumer: VertexId,
        operand: usize,
        schedule: CollectiveSchedule,
    },
    /// A serial-fold `Aggregate` lifted by `lower-collectives`: each
    /// group reduces along a chain of two-input `Agg` tasks. The `Ring`
    /// schedule is the serial left fold and stays bit-identical; `Tree`
    /// re-associates and is opt-in only.
    ReduceScatter {
        vertex: VertexId,
        src: RelId,
        agg: AggOp,
        schedule: CollectiveSchedule,
    },
    /// An `Aggregate` whose only consumer was a plain `Π`, fused by
    /// `lower-collectives`: reduce-scatter into the aggregate's own
    /// `d_Z` layout, then all-gather straight into the consumer's needed
    /// layout. `mid` is the aggregate's original output relation — its
    /// schema still carries the intermediate `d_Z` the reduce phase
    /// produces (relations are never deleted, so it stays valid).
    AllReduce {
        vertex: VertexId,
        src: RelId,
        agg: AggOp,
        mid: RelId,
        consumer: VertexId,
        operand: usize,
        reduce: CollectiveSchedule,
        bcast: CollectiveSchedule,
    },
}

impl TraOp {
    /// Kind tag for rendering and reports.
    pub fn kind_name(&self) -> &'static str {
        match self {
            TraOp::Partition { .. } => "Partition",
            TraOp::Repartition { .. } => "Repartition",
            TraOp::Join { .. } => "Join",
            TraOp::Aggregate { .. } => "Aggregate",
            TraOp::ReKey { .. } => "ReKey",
            TraOp::Assemble { .. } => "Assemble",
            TraOp::Reuse { .. } => "Reuse",
            TraOp::AllGather { .. } => "AllGather",
            TraOp::ReduceScatter { .. } => "ReduceScatter",
            TraOp::AllReduce { .. } => "AllReduce",
        }
    }

    /// Relations this op reads.
    pub fn input_rels(&self) -> Vec<RelId> {
        match self {
            TraOp::Partition { .. } => vec![],
            TraOp::Repartition { src, .. }
            | TraOp::Aggregate { src, .. }
            | TraOp::ReKey { src, .. }
            | TraOp::Assemble { src, .. }
            | TraOp::Reuse { src, .. }
            | TraOp::AllGather { src, .. }
            | TraOp::ReduceScatter { src, .. }
            | TraOp::AllReduce { src, .. } => vec![*src],
            TraOp::Join { inputs, .. } => inputs.clone(),
        }
    }

    fn input_rels_mut(&mut self) -> Vec<&mut RelId> {
        match self {
            TraOp::Partition { .. } => vec![],
            TraOp::Repartition { src, .. }
            | TraOp::Aggregate { src, .. }
            | TraOp::ReKey { src, .. }
            | TraOp::Assemble { src, .. }
            | TraOp::Reuse { src, .. }
            | TraOp::AllGather { src, .. }
            | TraOp::ReduceScatter { src, .. }
            | TraOp::AllReduce { src, .. } => vec![src],
            TraOp::Join { inputs, .. } => inputs.iter_mut().collect(),
        }
    }
}

/// A node of the program: the op, its output relation, and (private)
/// projection maps frozen at build time so [`TraProgram::emit_tasks`]
/// needs no access to the source graph. `zproj[j]` is the position of
/// the j-th output label within the vertex's unique labels; `oproj[o][j]`
/// the position of operand `o`'s j-th label. Positions stay valid under
/// pass rewiring because passes never change a vertex's label lists.
///
/// `Join` nodes additionally carry the vertex op's structural signature
/// (`sig`, [`crate::einsum::canon`]'s renumbered `op_sig`) and its
/// label-name-extended variant (`named_sig`), frozen at build time so
/// the `cse` pass can detect equal subprograms without the source graph
/// — and, under label-role-sensitive strategies, refuse to merge
/// same-shape vertices whose concrete label names differ.
#[derive(Clone, Debug)]
pub struct TraNode {
    pub op: TraOp,
    pub out: RelId,
    pub(crate) name: String,
    pub(crate) zproj: Vec<usize>,
    pub(crate) oproj: Vec<Vec<usize>>,
    pub(crate) sig: String,
    pub(crate) named_sig: String,
}

/// A typed TRA program: nodes in topological order over logical
/// relations. Built by [`from_plan`], optimized by
/// [`crate::tra::passes::PassManager`], lowered by
/// [`Self::emit_tasks`].
#[derive(Clone, Debug, Default)]
pub struct TraProgram {
    nodes: Vec<TraNode>,
    rels: Vec<RelSchema>,
}

/// Positions of `sub`'s labels within `full`.
fn proj_indices(sub: &LabelList, full: &LabelList) -> Result<Vec<usize>> {
    sub.iter()
        .map(|l| {
            full.iter().position(|m| m == l).ok_or_else(|| {
                Error::TaskGraph(format!("label {l} missing from unique labels (internal)"))
            })
        })
        .collect()
}

/// True when `need` is a pure refinement of `have` under balanced tiling:
/// every needed tile lies inside exactly one producer tile, in every
/// dimension — the precondition for the `alias-refinement-repart` pass
/// (the same containment fact [`crate::tra::ops::repartition_with_stats`]
/// exploits to alias tiles at zero bytes).
pub fn is_refinement(bound: &[usize], have: &[usize], need: &[usize]) -> bool {
    for dim in 0..bound.len() {
        for i in 0..need[dim] {
            let origin = tile_offset(bound[dim], need[dim], i);
            let len = tile_size(bound[dim], need[dim], i);
            let (lo, hi) = overlapping_tiles(bound[dim], have[dim], origin, len);
            if lo != hi {
                return false;
            }
        }
    }
    true
}

/// Per consumer tile (row-major over `need`), the linearized producer
/// tiles (under `have`) it reads — in exactly the range order
/// [`TraProgram::emit_tasks`]'s `Repartition` arm enumerates deps. The
/// single source of truth the point-to-point emission, the
/// `lower-collectives` detection, the collective emission, and
/// [`TraProgram::task_stats`] all share, which is what makes the
/// collective lowering bitwise-identical by construction.
pub(crate) fn pi_source_map(bound: &[usize], have: &[usize], need: &[usize]) -> Vec<Vec<usize>> {
    let mut map = Vec::new();
    for key in index_space(need) {
        let ranges: Vec<(usize, usize)> = key
            .iter()
            .enumerate()
            .map(|(dim, &k)| {
                let origin = tile_offset(bound[dim], need[dim], k);
                let len = tile_size(bound[dim], need[dim], k);
                overlapping_tiles(bound[dim], have[dim], origin, len)
            })
            .collect();
        let range_dims: Vec<usize> = ranges.iter().map(|(lo, hi)| hi - lo + 1).collect();
        let mut srcs = Vec::new();
        for rk in index_space(&range_dims) {
            let pkey: Vec<usize> = rk
                .iter()
                .zip(&ranges)
                .map(|(&r, &(lo, _))| lo + r)
                .collect();
            srcs.push(linearize(&pkey, have));
        }
        map.push(srcs);
    }
    map
}

/// Source tiles shared by two or more consumer tiles, ascending, paired
/// with their members (consumer linear keys, ascending).
fn shared_sources(smap: &[Vec<usize>]) -> Vec<(usize, Vec<usize>)> {
    let mut members_of: HashMap<usize, Vec<usize>> = HashMap::new();
    for (m, srcs) in smap.iter().enumerate() {
        for &s in srcs {
            members_of.entry(s).or_default().push(m);
        }
    }
    let mut shared: Vec<(usize, Vec<usize>)> = members_of
        .into_iter()
        .filter(|(_, ms)| ms.len() >= 2)
        .collect();
    shared.sort_unstable();
    shared
}

/// Emit the relay + assemble tasks of an all-gather over `src_tiles`
/// (the producer relation, `have` layout over `bound`), returning the
/// assembled tiles in `need` layout. Each source tile read by two or
/// more consumer tiles is relayed member-to-member along `schedule` as
/// [`TaskKind::Collective`] pass-through copies; every member then
/// assembles from *its own* relay via a standard `Repart` task whose
/// dep geometry (source tiles, range order) is identical to the
/// point-to-point emission — which is why the assembled bytes are
/// bitwise-identical to the `Repartition` this replaces.
#[allow(clippy::too_many_arguments)]
fn emit_all_gather(
    tg: &mut TaskGraph,
    src_tiles: &[TaskId],
    bound: &[usize],
    have: &[usize],
    need: &[usize],
    producer: VertexId,
    consumer: VertexId,
    operand: usize,
    schedule: CollectiveSchedule,
) -> Vec<TaskId> {
    let smap = pi_source_map(bound, have, need);
    // (source, member) -> that member's relay of the source tile
    let mut relay: HashMap<(usize, usize), TaskId> = HashMap::new();
    for (s, members) in shared_sources(&smap) {
        let skey = delinearize(s, have);
        let sbytes = tile_bytes(bound, have, &skey);
        let mut chain: Vec<TaskId> = Vec::with_capacity(members.len());
        for (i, &m) in members.iter().enumerate() {
            let dep = if i == 0 {
                src_tiles[s]
            } else {
                match schedule {
                    CollectiveSchedule::Ring => chain[i - 1],
                    CollectiveSchedule::Tree { arity } => chain[(i - 1) / arity.max(1)],
                }
            };
            let t = tg.push_task(
                TaskKind::Collective {
                    producer,
                    consumer,
                    operand,
                    key: skey.clone(),
                    member: m,
                    step: i,
                },
                vec![dep],
                sbytes,
                0.0,
            );
            chain.push(t);
            relay.insert((s, m), t);
        }
    }
    let mut tiles = Vec::new();
    for (m, key) in index_space(need).enumerate() {
        let deps: Vec<TaskId> = smap[m]
            .iter()
            .map(|&s| relay.get(&(s, m)).copied().unwrap_or(src_tiles[s]))
            .collect();
        let bytes = tile_bytes(bound, need, &key);
        tiles.push(tg.push_task(
            TaskKind::Repart {
                producer,
                consumer,
                operand,
                key,
            },
            deps,
            bytes,
            0.0,
        ));
    }
    tiles
}

/// Emit one reduce-scatter phase: group `kernels` (in `d` layout) by
/// `zproj` into `dz` groups and fold each along `schedule`. `Ring` is a
/// moving-accumulator chain of two-input `Agg` tasks whose combine
/// order equals the baseline serial fold — bitwise-identical; `Tree`
/// re-associates (the same caveat as the `agg-tree` pass) and is only
/// reachable through the explicit opt-in.
#[allow(clippy::too_many_arguments)]
fn emit_reduce_scatter(
    tg: &mut TaskGraph,
    kernels: &[TaskId],
    d: &[usize],
    zproj: &[usize],
    dz: &[usize],
    bz: &[usize],
    vertex: VertexId,
    schedule: CollectiveSchedule,
) -> Result<Vec<TaskId>> {
    let mut groups: HashMap<Vec<usize>, Vec<TaskId>> = HashMap::new();
    for (key, &tid) in index_space(d).zip(kernels) {
        let zkey: Vec<usize> = zproj.iter().map(|&i| key[i]).collect();
        groups.entry(zkey).or_default().push(tid);
    }
    let mut outs = Vec::new();
    for zkey in index_space(dz) {
        let members = groups
            .remove(&zkey)
            .ok_or_else(|| Error::TaskGraph(format!("missing collective group {zkey:?}")))?;
        let bytes = tile_bytes(bz, dz, &zkey);
        let elems = (bytes / 4) as f64;
        let root = match schedule {
            CollectiveSchedule::Ring => {
                let mut acc = members[0];
                for &m in &members[1..] {
                    acc = tg.push_task(
                        TaskKind::Agg {
                            vertex,
                            key: zkey.clone(),
                        },
                        vec![acc, m],
                        bytes,
                        elems,
                    );
                }
                acc
            }
            CollectiveSchedule::Tree { arity } if members.len() > arity.max(2) => {
                let arity = arity.max(2);
                let mut level = members;
                loop {
                    let mut next = Vec::with_capacity(level.len().div_ceil(arity));
                    for chunk in level.chunks(arity) {
                        if chunk.len() == 1 {
                            next.push(chunk[0]);
                            continue;
                        }
                        let flops = elems * (chunk.len() as f64 - 1.0);
                        next.push(tg.push_task(
                            TaskKind::Agg {
                                vertex,
                                key: zkey.clone(),
                            },
                            chunk.to_vec(),
                            bytes,
                            flops,
                        ));
                    }
                    if next.len() == 1 {
                        break next[0];
                    }
                    level = next;
                }
            }
            CollectiveSchedule::Tree { .. } => {
                let flops = elems * (members.len() as f64 - 1.0);
                tg.push_task(TaskKind::Agg { vertex, key: zkey }, members, bytes, flops)
            }
        };
        outs.push(root);
    }
    Ok(outs)
}

/// Task and repart-byte footprint of one all-gather phase — the member
/// assembles plus one relay per (shared source, member) pair — mirroring
/// [`emit_all_gather`] exactly. Relays move the *source* tile's bytes
/// and count as repartition traffic (they are `Repart`-class movement).
fn gather_stats(bound: &[usize], have: &[usize], need: &[usize]) -> (usize, u64) {
    let smap = pi_source_map(bound, have, need);
    let mut tasks = 0usize;
    let mut bytes = 0u64;
    for key in index_space(need) {
        tasks += 1;
        bytes += tile_bytes(bound, need, &key) as u64;
    }
    for (s, members) in shared_sources(&smap) {
        let skey = delinearize(s, have);
        tasks += members.len();
        bytes += (tile_bytes(bound, have, &skey) * members.len()) as u64;
    }
    (tasks, bytes)
}

/// Fold tasks one reduce-scatter group of `group` members emits under
/// `schedule`, mirroring [`emit_reduce_scatter`] exactly.
fn reduce_tasks_per_group(group: usize, schedule: CollectiveSchedule) -> usize {
    match schedule {
        CollectiveSchedule::Ring => group.saturating_sub(1),
        CollectiveSchedule::Tree { arity } if group > arity.max(2) => {
            let arity = arity.max(2);
            let mut tasks = 0usize;
            let mut level = group;
            loop {
                let mut next = 0usize;
                let mut i = 0usize;
                while i < level {
                    let chunk = arity.min(level - i);
                    if chunk > 1 {
                        tasks += 1;
                    }
                    next += 1;
                    i += chunk;
                }
                if next == 1 {
                    break;
                }
                level = next;
            }
            tasks
        }
        CollectiveSchedule::Tree { .. } => 1,
    }
}

/// Rewrite a planned EinGraph into its TRA program (Eq. 5, per vertex:
/// `Π` per operand → `Join` → `Aggregate`-or-`ReKey`), with an `Assemble`
/// marking each graph output. Repartition nodes are emitted on **every**
/// operand edge — including identity ones, which the IR shows explicitly
/// and [`TraProgram::emit_tasks`] forwards without tasks (the
/// `elide-identity-repart` pass removes them from the listing).
pub fn from_plan(g: &EinGraph, plan: &Plan) -> Result<TraProgram> {
    let mut p = TraProgram::default();
    let mut rel_of: Vec<Option<RelId>> = vec![None; g.len()];
    for vert in g.vertices() {
        let v = vert.id;
        match &vert.op {
            EinSum::Input => {
                let part = plan
                    .input_parts
                    .get(&v)
                    .cloned()
                    .unwrap_or_else(|| vec![1; vert.bound.len()]);
                let rel = p.push_rel(RelSchema {
                    bound: vert.bound.clone(),
                    part,
                    labels: vec![],
                });
                p.nodes.push(TraNode {
                    op: TraOp::Partition { vertex: v },
                    out: rel,
                    name: vert.name.clone(),
                    zproj: vec![],
                    oproj: vec![],
                    sig: String::new(),
                    named_sig: String::new(),
                });
                rel_of[v.0] = Some(rel);
            }
            op => {
                let d = plan
                    .parts
                    .get(&v)
                    .ok_or_else(|| Error::TaskGraph(format!("vertex {} unplanned", vert.name)))?;
                let uniq = op.unique_labels();
                if d.len() != uniq.len() {
                    return Err(Error::TaskGraph(format!(
                        "vertex {}: d {:?} not parallel to unique labels {uniq:?}",
                        vert.name, d
                    )));
                }
                let lz = op.lz().expect("non-input vertex has output labels");
                let zproj = proj_indices(lz, &uniq)?;
                // Per-unique-label extents (the join relation's bound).
                let mut uext = vec![0usize; uniq.len()];
                for (o, lo) in op.operand_labels().iter().enumerate() {
                    let cb = &g.vertex(vert.inputs[o]).bound;
                    for (j, l) in lo.iter().enumerate() {
                        let ui = uniq.iter().position(|m| m == l).expect("operand label");
                        uext[ui] = cb[j];
                    }
                }
                let mut in_rels = Vec::new();
                let mut oproj = Vec::new();
                for (o, lo) in op.operand_labels().iter().enumerate() {
                    let c = vert.inputs[o];
                    let opj = proj_indices(lo, &uniq)?;
                    let need: Vec<usize> = opj.iter().map(|&i| d[i]).collect();
                    let src = rel_of[c.0].expect("inputs precede consumers");
                    let rel = p.push_rel(RelSchema {
                        bound: p.rels[src.0].bound.clone(),
                        part: need,
                        labels: (*lo).clone(),
                    });
                    p.nodes.push(TraNode {
                        op: TraOp::Repartition {
                            src,
                            producer: c,
                            consumer: v,
                            operand: o,
                            alias: false,
                        },
                        out: rel,
                        name: vert.name.clone(),
                        zproj: vec![],
                        oproj: vec![],
                        sig: String::new(),
                        named_sig: String::new(),
                    });
                    in_rels.push(rel);
                    oproj.push(opj);
                }
                let in_bounds: Vec<&[usize]> = vert
                    .inputs
                    .iter()
                    .map(|&i| g.vertex(i).bound.as_slice())
                    .collect();
                let total_flops = op.flops(&in_bounds)?;
                let n_calls: usize = d.iter().product();
                let flops_per_call = total_flops / n_calls as f64;
                let jrel = p.push_rel(RelSchema {
                    bound: uext,
                    part: d.clone(),
                    labels: uniq.clone(),
                });
                // Pure elementwise maps (Unary with lz == lx: no
                // permutation, no aggregation) are what `fuse-epilogue`
                // folds into their producer's kernel.
                let map_op = match op {
                    EinSum::Unary {
                        lx, lz, op: uop, ..
                    } if lz == lx => Some(*uop),
                    _ => None,
                };
                let sig = crate::einsum::canon::op_sig(op);
                let mut named_sig = sig.clone();
                named_sig.push('|');
                for lo in op.operand_labels() {
                    for l in lo.iter() {
                        let _ = write!(named_sig, "{l},");
                    }
                    named_sig.push(';');
                }
                for l in lz.iter() {
                    let _ = write!(named_sig, "{l},");
                }
                p.nodes.push(TraNode {
                    op: TraOp::Join {
                        vertex: v,
                        inputs: in_rels,
                        flops_per_call,
                        map_op,
                        epilogue: vec![],
                    },
                    out: jrel,
                    name: vert.name.clone(),
                    zproj: zproj.clone(),
                    oproj,
                    sig,
                    named_sig,
                });
                let lagg = op.lagg();
                let n_agg: usize = crate::einsum::label::project(d, &lagg, &uniq)
                    .iter()
                    .product();
                let dz: Vec<usize> = zproj.iter().map(|&i| d[i]).collect();
                let orel = p.push_rel(RelSchema {
                    bound: vert.bound.clone(),
                    part: dz,
                    labels: lz.clone(),
                });
                let agg = match op {
                    EinSum::Unary { agg, .. } | EinSum::Binary { agg, .. } => *agg,
                    EinSum::Input => unreachable!("matched above"),
                };
                let node_op = if n_agg > 1 {
                    TraOp::Aggregate {
                        vertex: v,
                        src: jrel,
                        agg,
                        tree_arity: None,
                    }
                } else {
                    TraOp::ReKey { vertex: v, src: jrel }
                };
                p.nodes.push(TraNode {
                    op: node_op,
                    out: orel,
                    name: vert.name.clone(),
                    zproj,
                    oproj: vec![],
                    sig: String::new(),
                    named_sig: String::new(),
                });
                rel_of[v.0] = Some(orel);
            }
        }
    }
    for out in g.outputs() {
        let src = rel_of[out.0].expect("all vertices lowered");
        let s = p.rels[src.0].clone();
        let arel = p.push_rel(RelSchema {
            bound: s.bound.clone(),
            part: vec![1; s.bound.len()],
            labels: s.labels,
        });
        p.nodes.push(TraNode {
            op: TraOp::Assemble { vertex: out, src },
            out: arel,
            name: g.vertex(out).name.clone(),
            zproj: vec![],
            oproj: vec![],
            sig: String::new(),
            named_sig: String::new(),
        });
    }
    Ok(p)
}

/// Static task/byte footprint of a program, computed without emitting:
/// [`TraProgram::task_stats`] mirrors [`TraProgram::emit_tasks`]'s
/// arithmetic exactly (identity and aliased repartitions are free,
/// reduction trees count their internal fold nodes). The pass manager
/// snapshots it around every pass so each rewrite's task and
/// repartition-byte delta is attributed to that pass by name.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProgStats {
    /// Tasks `emit_tasks` would create.
    pub tasks: usize,
    /// `Repart` tasks among them.
    pub repart_tasks: usize,
    /// Total bytes those repartition tasks materialize.
    pub repart_bytes: u64,
}

/// Static peak-residency estimate of a program — see
/// [`TraProgram::residency_stats`]. All byte figures cover the whole
/// cluster; divide `peak_bytes` by the worker count for the balanced
/// per-worker estimate [`Self::fits`] uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Peak live relation bytes across the program, node by node.
    pub peak_bytes: u64,
    /// Upper bound on any single task's working set (largest output
    /// tile + read fan-in × largest input tile), in bytes. A per-worker
    /// budget at or above this always executes without
    /// `BudgetExceeded`.
    pub max_task_bytes: u64,
    /// Total bytes of all materialized relations (ignores liveness —
    /// the residency an executor with no reclamation at all would need).
    pub total_bytes: u64,
}

impl ResidencyStats {
    /// Whether a per-worker budget of `budget_bytes` should fit this
    /// program on `workers` workers *without spilling*: the balanced
    /// share of the peak must fit, and so must the largest single-task
    /// working set. A plan that fails this still *runs* under the
    /// out-of-core executor (spilling) as long as
    /// `budget_bytes >= max_task_bytes`.
    pub fn fits(&self, budget_bytes: u64, workers: usize) -> bool {
        let share = self.peak_bytes.div_ceil(workers.max(1) as u64);
        budget_bytes >= share.max(self.max_task_bytes)
    }
}

/// How a relation's tiles are reachable during emission: either as
/// materialized tasks (one per tile, row-major key order), or as an
/// alias of a coarser relation's tasks (the `alias-refinement-repart`
/// rewrite — consumers resolve each needed tile to its single containing
/// producer tile).
enum Provider {
    Direct(Vec<TaskId>),
    Aliased { tiles: Vec<TaskId>, have: Vec<usize> },
}

impl TraProgram {
    fn push_rel(&mut self, s: RelSchema) -> RelId {
        let id = RelId(self.rels.len());
        self.rels.push(s);
        id
    }

    /// Nodes in topological order.
    pub fn nodes(&self) -> &[TraNode] {
        &self.nodes
    }

    /// Schema of a relation.
    pub fn schema(&self, r: RelId) -> &RelSchema {
        &self.rels[r.0]
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// The IR-level lineage of relation `rel`: every relation it
    /// transitively derives from (via each producing node's
    /// [`TraOp::input_rels`]), including `rel` itself, in ascending
    /// [`RelId`] order. This is the relational statement of the recovery
    /// property the executor exploits at task granularity
    /// (`TaskGraph::lineage`): a lost relation is a pure function of its
    /// lineage inputs, so recomputing the closure rebuilds it exactly.
    pub fn lineage(&self, rel: RelId) -> Vec<RelId> {
        let mut in_set = vec![false; self.rels.len()];
        if rel.0 >= self.rels.len() {
            return vec![];
        }
        in_set[rel.0] = true;
        // nodes are topological, so one reverse sweep closes the set:
        // when a node's output is in the set, pull in its input rels.
        for node in self.nodes.iter().rev() {
            if in_set[node.out.0] {
                for r in node.op.input_rels() {
                    in_set[r.0] = true;
                }
            }
        }
        in_set
            .iter()
            .enumerate()
            .filter(|(_, &m)| m)
            .map(|(i, _)| RelId(i))
            .collect()
    }

    /// Lower the program to a concrete, unplaced [`TaskGraph`].
    ///
    /// On an unoptimized program this reproduces the direct lowering
    /// exactly (same task ids, deps, bytes, flops — the differential
    /// guarantee `tests/tra_program.rs` pins); pass rewrites change only
    /// what their contracts state: identity/aliased repartitions emit no
    /// tasks, tree aggregations emit their reduction levels in fixed
    /// member order.
    pub fn emit_tasks(&self) -> Result<TaskGraph> {
        let mut tg = TaskGraph::default();
        let mut prov: Vec<Option<Provider>> = (0..self.rels.len()).map(|_| None).collect();
        for node in &self.nodes {
            let out_s = &self.rels[node.out.0];
            match &node.op {
                TraOp::Partition { vertex } => {
                    let mut outs = Vec::new();
                    for key in index_space(&out_s.part) {
                        let bytes = tile_bytes(&out_s.bound, &out_s.part, &key);
                        outs.push(tg.push_task(
                            TaskKind::InputTile { vertex: *vertex, key },
                            vec![],
                            bytes,
                            0.0,
                        ));
                    }
                    tg.vertex_outputs.insert(*vertex, outs.clone());
                    tg.vertex_out_part.insert(*vertex, out_s.part.clone());
                    prov[node.out.0] = Some(Provider::Direct(outs));
                }
                TraOp::Repartition {
                    src,
                    producer,
                    consumer,
                    operand,
                    alias,
                } => {
                    let have = self.rels[src.0].part.clone();
                    let need = &out_s.part;
                    let src_tiles = match prov[src.0].as_ref() {
                        Some(Provider::Direct(t)) => t.clone(),
                        _ => {
                            return Err(Error::TaskGraph(
                                "repartition source is not a materialized relation (internal)"
                                    .into(),
                            ))
                        }
                    };
                    if have == *need {
                        // Identity Π: forward tiles, zero tasks (the
                        // inline `have == need` check of the direct
                        // lowering; the elide pass removes the node).
                        prov[node.out.0] = Some(Provider::Direct(src_tiles));
                        continue;
                    }
                    if *alias {
                        prov[node.out.0] = Some(Provider::Aliased {
                            tiles: src_tiles,
                            have,
                        });
                        continue;
                    }
                    let cb = &out_s.bound;
                    let smap = pi_source_map(cb, &have, need);
                    let mut tiles = Vec::new();
                    for (m, key) in index_space(need).enumerate() {
                        let deps: Vec<TaskId> =
                            smap[m].iter().map(|&s| src_tiles[s]).collect();
                        let bytes = tile_bytes(cb, need, &key);
                        tiles.push(tg.push_task(
                            TaskKind::Repart {
                                producer: *producer,
                                consumer: *consumer,
                                operand: *operand,
                                key,
                            },
                            deps,
                            bytes,
                            0.0,
                        ));
                    }
                    prov[node.out.0] = Some(Provider::Direct(tiles));
                }
                TraOp::Join {
                    vertex,
                    inputs,
                    flops_per_call,
                    epilogue,
                    ..
                } => {
                    let d = &out_s.part;
                    let bz: Vec<usize> = node.zproj.iter().map(|&i| out_s.bound[i]).collect();
                    let dz: Vec<usize> = node.zproj.iter().map(|&i| d[i]).collect();
                    let mut kernels = Vec::new();
                    for key in index_space(d) {
                        let mut deps = Vec::new();
                        for (o, rel) in inputs.iter().enumerate() {
                            let okey: Vec<usize> = node.oproj[o].iter().map(|&i| key[i]).collect();
                            let rs = &self.rels[rel.0];
                            match prov[rel.0].as_ref() {
                                Some(Provider::Direct(tiles)) => {
                                    deps.push(tiles[linearize(&okey, &rs.part)]);
                                }
                                Some(Provider::Aliased { tiles, have }) => {
                                    tg.aliased_kernel_deps = true;
                                    let mut pkey = Vec::with_capacity(okey.len());
                                    for (dim, &k) in okey.iter().enumerate() {
                                        let origin = tile_offset(rs.bound[dim], rs.part[dim], k);
                                        let len = tile_size(rs.bound[dim], rs.part[dim], k);
                                        let b = rs.bound[dim];
                                        let (lo, hi) =
                                            overlapping_tiles(b, have[dim], origin, len);
                                        if lo != hi {
                                            return Err(Error::TaskGraph(
                                                "aliased repartition is not a refinement \
                                                 (internal)"
                                                    .into(),
                                            ));
                                        }
                                        pkey.push(lo);
                                    }
                                    deps.push(tiles[linearize(&pkey, have)]);
                                }
                                None => {
                                    return Err(Error::TaskGraph(
                                        "join input relation not yet emitted (internal)".into(),
                                    ))
                                }
                            }
                        }
                        let zkey: Vec<usize> = node.zproj.iter().map(|&i| key[i]).collect();
                        let bytes = tile_bytes(&bz, &dz, &zkey);
                        let tid = tg.push_task(
                            TaskKind::Kernel { vertex: *vertex, key },
                            deps,
                            bytes,
                            *flops_per_call,
                        );
                        if !epilogue.is_empty() {
                            tg.kernel_epilogue.insert(tid, epilogue.clone());
                        }
                        kernels.push(tid);
                    }
                    prov[node.out.0] = Some(Provider::Direct(kernels));
                }
                TraOp::Aggregate {
                    vertex,
                    src,
                    tree_arity,
                    ..
                } => {
                    let d = self.rels[src.0].part.clone();
                    let kernels = match prov[src.0].as_ref() {
                        Some(Provider::Direct(t)) => t.clone(),
                        _ => {
                            return Err(Error::TaskGraph(
                                "aggregate source is not a materialized relation (internal)"
                                    .into(),
                            ))
                        }
                    };
                    let dz = &out_s.part;
                    let bz = &out_s.bound;
                    let mut groups: HashMap<Vec<usize>, Vec<TaskId>> = HashMap::new();
                    for (key, &tid) in index_space(&d).zip(&kernels) {
                        let zkey: Vec<usize> = node.zproj.iter().map(|&i| key[i]).collect();
                        groups.entry(zkey).or_default().push(tid);
                    }
                    let mut outs = Vec::new();
                    for zkey in index_space(dz) {
                        let members = groups.remove(&zkey).ok_or_else(|| {
                            Error::TaskGraph(format!("missing agg group {zkey:?}"))
                        })?;
                        let bytes = tile_bytes(bz, dz, &zkey);
                        let elems = (bytes / 4) as f64;
                        let root = match tree_arity {
                            Some(r) if members.len() > *r => {
                                // Balanced r-ary reduction tree, members
                                // chunked in fixed dep order level by
                                // level: deterministic shape, fan-in <= r.
                                let mut level = members;
                                loop {
                                    let mut next = Vec::with_capacity(level.len().div_ceil(*r));
                                    for chunk in level.chunks(*r) {
                                        if chunk.len() == 1 {
                                            // A remainder of one needs no
                                            // fold: carry the member up.
                                            next.push(chunk[0]);
                                            continue;
                                        }
                                        let flops = elems * (chunk.len() as f64 - 1.0);
                                        next.push(tg.push_task(
                                            TaskKind::Agg {
                                                vertex: *vertex,
                                                key: zkey.clone(),
                                            },
                                            chunk.to_vec(),
                                            bytes,
                                            flops,
                                        ));
                                    }
                                    if next.len() == 1 {
                                        break next[0];
                                    }
                                    level = next;
                                }
                            }
                            _ => {
                                let flops = elems * (members.len() as f64 - 1.0);
                                tg.push_task(
                                    TaskKind::Agg { vertex: *vertex, key: zkey },
                                    members,
                                    bytes,
                                    flops,
                                )
                            }
                        };
                        outs.push(root);
                    }
                    tg.vertex_outputs.insert(*vertex, outs.clone());
                    tg.vertex_out_part.insert(*vertex, dz.clone());
                    prov[node.out.0] = Some(Provider::Direct(outs));
                }
                TraOp::ReKey { vertex, src } => {
                    let d = self.rels[src.0].part.clone();
                    let kernels = match prov[src.0].as_ref() {
                        Some(Provider::Direct(t)) => t.clone(),
                        _ => {
                            return Err(Error::TaskGraph(
                                "rekey source is not a materialized relation (internal)".into(),
                            ))
                        }
                    };
                    let dz = &out_s.part;
                    let mut outs = vec![TaskId(usize::MAX); kernels.len()];
                    for (key, &tid) in index_space(&d).zip(&kernels) {
                        let zkey: Vec<usize> = node.zproj.iter().map(|&i| key[i]).collect();
                        outs[linearize(&zkey, dz)] = tid;
                    }
                    debug_assert!(outs.iter().all(|t| t.0 != usize::MAX));
                    tg.vertex_outputs.insert(*vertex, outs.clone());
                    tg.vertex_out_part.insert(*vertex, dz.clone());
                    prov[node.out.0] = Some(Provider::Direct(outs));
                }
                TraOp::Assemble { .. } => {
                    // Assembly is the executor's job (dense outputs are
                    // materialized after the run); the node only marks
                    // the relation as externally observed.
                }
                TraOp::Reuse { vertex, src } => {
                    // A merged duplicate (the `cse` pass): forward the
                    // canonical chain's tiles, zero tasks — but register
                    // them under the duplicate vertex too, so repartition
                    // key recovery and output assembly still resolve it.
                    let tiles = match prov[src.0].as_ref() {
                        Some(Provider::Direct(t)) => t.clone(),
                        _ => {
                            return Err(Error::TaskGraph(
                                "reuse source is not a materialized relation (internal)".into(),
                            ))
                        }
                    };
                    tg.vertex_outputs.insert(*vertex, tiles.clone());
                    tg.vertex_out_part.insert(*vertex, out_s.part.clone());
                    prov[node.out.0] = Some(Provider::Direct(tiles));
                }
                TraOp::AllGather {
                    src,
                    producer,
                    consumer,
                    operand,
                    schedule,
                } => {
                    let have = self.rels[src.0].part.clone();
                    let need = &out_s.part;
                    let src_tiles = match prov[src.0].as_ref() {
                        Some(Provider::Direct(t)) => t.clone(),
                        _ => {
                            return Err(Error::TaskGraph(
                                "all-gather source is not a materialized relation (internal)"
                                    .into(),
                            ))
                        }
                    };
                    if have == *need {
                        prov[node.out.0] = Some(Provider::Direct(src_tiles));
                        continue;
                    }
                    let tiles = emit_all_gather(
                        &mut tg,
                        &src_tiles,
                        &out_s.bound,
                        &have,
                        need,
                        *producer,
                        *consumer,
                        *operand,
                        *schedule,
                    );
                    prov[node.out.0] = Some(Provider::Direct(tiles));
                }
                TraOp::ReduceScatter {
                    vertex,
                    src,
                    schedule,
                    ..
                } => {
                    let d = self.rels[src.0].part.clone();
                    let kernels = match prov[src.0].as_ref() {
                        Some(Provider::Direct(t)) => t.clone(),
                        _ => {
                            return Err(Error::TaskGraph(
                                "reduce-scatter source is not a materialized relation (internal)"
                                    .into(),
                            ))
                        }
                    };
                    let outs = emit_reduce_scatter(
                        &mut tg,
                        &kernels,
                        &d,
                        &node.zproj,
                        &out_s.part,
                        &out_s.bound,
                        *vertex,
                        *schedule,
                    )?;
                    tg.vertex_outputs.insert(*vertex, outs.clone());
                    tg.vertex_out_part.insert(*vertex, out_s.part.clone());
                    prov[node.out.0] = Some(Provider::Direct(outs));
                }
                TraOp::AllReduce {
                    vertex,
                    src,
                    mid,
                    consumer,
                    operand,
                    reduce,
                    bcast,
                    ..
                } => {
                    let d = self.rels[src.0].part.clone();
                    let kernels = match prov[src.0].as_ref() {
                        Some(Provider::Direct(t)) => t.clone(),
                        _ => {
                            return Err(Error::TaskGraph(
                                "all-reduce source is not a materialized relation (internal)"
                                    .into(),
                            ))
                        }
                    };
                    // reduce phase into the aggregate's own d_Z layout
                    // (the fused `mid` relation still carries it) ...
                    let mid_s = &self.rels[mid.0];
                    let roots = emit_reduce_scatter(
                        &mut tg,
                        &kernels,
                        &d,
                        &node.zproj,
                        &mid_s.part,
                        &mid_s.bound,
                        *vertex,
                        *reduce,
                    )?;
                    tg.vertex_outputs.insert(*vertex, roots.clone());
                    tg.vertex_out_part.insert(*vertex, mid_s.part.clone());
                    // ... then gather straight into the consumer's layout
                    let tiles = emit_all_gather(
                        &mut tg,
                        &roots,
                        &out_s.bound,
                        &mid_s.part,
                        &out_s.part,
                        *vertex,
                        *consumer,
                        *operand,
                        *bcast,
                    );
                    prov[node.out.0] = Some(Provider::Direct(tiles));
                }
            }
        }
        Ok(tg)
    }

    /// Static task/byte footprint: what [`Self::emit_tasks`] would
    /// produce, without building the graph. Mirrors emission exactly —
    /// identity/aliased repartitions and ReKey/Assemble/Reuse nodes are
    /// free; tree aggregations count the internal fold tasks of the
    /// level-by-level chunking (a remainder of one carries up taskless).
    pub fn task_stats(&self) -> ProgStats {
        let mut s = ProgStats::default();
        for node in &self.nodes {
            let out_s = &self.rels[node.out.0];
            match &node.op {
                TraOp::Partition { .. } | TraOp::Join { .. } => s.tasks += out_s.num_tiles(),
                TraOp::Repartition { src, alias, .. } => {
                    let have = &self.rels[src.0].part;
                    let need = &out_s.part;
                    if have == need || *alias {
                        continue;
                    }
                    for key in index_space(need) {
                        s.repart_bytes += tile_bytes(&out_s.bound, need, &key) as u64;
                    }
                    s.tasks += out_s.num_tiles();
                    s.repart_tasks += out_s.num_tiles();
                }
                TraOp::Aggregate {
                    src, tree_arity, ..
                } => {
                    let groups = out_s.num_tiles();
                    let group = self.rels[src.0].num_tiles() / groups.max(1);
                    let per_group = match tree_arity {
                        Some(r) if group > *r => {
                            let mut tasks = 0usize;
                            let mut level = group;
                            loop {
                                let mut next = 0usize;
                                let mut i = 0usize;
                                while i < level {
                                    let chunk = (*r).min(level - i);
                                    if chunk > 1 {
                                        tasks += 1;
                                    }
                                    next += 1;
                                    i += chunk;
                                }
                                if next == 1 {
                                    break;
                                }
                                level = next;
                            }
                            tasks
                        }
                        _ => 1,
                    };
                    s.tasks += groups * per_group;
                }
                TraOp::AllGather { src, .. } => {
                    let have = &self.rels[src.0].part;
                    let need = &out_s.part;
                    if have == need {
                        continue;
                    }
                    let (tasks, bytes) = gather_stats(&out_s.bound, have, need);
                    s.tasks += tasks;
                    s.repart_tasks += tasks;
                    s.repart_bytes += bytes;
                }
                TraOp::ReduceScatter { src, schedule, .. } => {
                    let groups = out_s.num_tiles();
                    let group = self.rels[src.0].num_tiles() / groups.max(1);
                    s.tasks += groups * reduce_tasks_per_group(group, *schedule);
                }
                TraOp::AllReduce {
                    src,
                    mid,
                    reduce,
                    ..
                } => {
                    let mid_s = &self.rels[mid.0];
                    let groups = mid_s.num_tiles();
                    let group = self.rels[src.0].num_tiles() / groups.max(1);
                    s.tasks += groups * reduce_tasks_per_group(group, *reduce);
                    let (tasks, bytes) = gather_stats(&out_s.bound, &mid_s.part, &out_s.part);
                    s.tasks += tasks;
                    s.repart_tasks += tasks;
                    s.repart_bytes += bytes;
                }
                TraOp::ReKey { .. } | TraOp::Assemble { .. } | TraOp::Reuse { .. } => {}
            }
        }
        s
    }

    /// Static peak-residency estimate: how many bytes of relation
    /// storage are live at once if the program runs node by node —
    /// the planner-side mirror of the executor's measured
    /// `peak_resident_bytes`, used by `Session::explain` to report
    /// whether a plan fits a [`crate::runtime::spill::MemoryBudget`]
    /// before anything runs.
    ///
    /// Mirrors emission's aliasing exactly: identity/aliased
    /// repartitions, `ReKey`, `Reuse`, and identity `AllGather`s forward
    /// their source's storage (zero new bytes); `Assemble` is driver-side
    /// (zero worker bytes, but it keeps its source live). Every
    /// materializing node charges its full output relation
    /// (`4 * prod(bound)` — tiles cover the bound exactly) at its
    /// program position; a storage is freed after the last node that
    /// reads any alias of it. Relations nothing reads (graph outputs)
    /// stay live to the end.
    ///
    /// `max_task_bytes` is a per-*task* working-set **upper bound**
    /// (largest output tile plus largest input tile times the node's
    /// read fan-in), deliberately conservative: the executor's
    /// `BudgetExceeded` fires only when a real working set cannot fit,
    /// so a budget at or above this bound always runs.
    pub fn residency_stats(&self) -> ResidencyStats {
        let rel_bytes = |r: usize| -> u64 {
            4 * self.rels[r].bound.iter().product::<usize>() as u64
        };
        // largest single tile of a relation, in bytes (per-dim ceil)
        let max_tile = |r: usize| -> u64 {
            let s = &self.rels[r];
            4 * s
                .bound
                .iter()
                .zip(&s.part)
                .map(|(&b, &p)| b.div_ceil(p.max(1)))
                .product::<usize>() as u64
        };
        // storage roots: aliasing nodes forward their source's storage
        let mut root: Vec<usize> = (0..self.rels.len()).collect();
        let mut materialized_at: Vec<Option<usize>> = vec![None; self.rels.len()];
        let mut stats = ResidencyStats::default();
        for (i, node) in self.nodes.iter().enumerate() {
            let out = node.out.0;
            let out_s = &self.rels[out];
            let aliases = match &node.op {
                TraOp::ReKey { src, .. } | TraOp::Reuse { src, .. } => Some(src.0),
                TraOp::Repartition { src, alias, .. } => {
                    let same = self.rels[src.0].part == out_s.part;
                    (same || *alias).then_some(src.0)
                }
                TraOp::AllGather { src, .. } => {
                    (self.rels[src.0].part == out_s.part).then_some(src.0)
                }
                // driver-side: zero worker bytes, source stays live
                TraOp::Assemble { src, .. } => Some(src.0),
                _ => None,
            };
            if let Some(src) = aliases {
                root[out] = root[src];
                continue;
            }
            root[out] = out;
            materialized_at[out] = Some(i);
            stats.total_bytes += rel_bytes(out);
            // working-set upper bound for one task of this node
            let fanin: u64 = match &node.op {
                TraOp::Aggregate {
                    src, tree_arity, ..
                } => {
                    let group = (self.rels[src.0].num_tiles() / out_s.num_tiles().max(1)).max(1);
                    tree_arity.map_or(group, |r| r.max(2).min(group)) as u64
                }
                TraOp::ReduceScatter { src, schedule, .. }
                | TraOp::AllReduce {
                    src,
                    reduce: schedule,
                    ..
                } => {
                    let group = (self.rels[src.0].num_tiles() / out_s.num_tiles().max(1)).max(1);
                    match schedule {
                        CollectiveSchedule::Ring => 2usize.min(group) as u64,
                        CollectiveSchedule::Tree { arity } => (*arity).max(2).min(group) as u64,
                    }
                }
                TraOp::Repartition { src, .. } | TraOp::AllGather { src, .. } => {
                    // source tiles overlapping one destination tile,
                    // bounded per dimension
                    let have = &self.rels[src.0].part;
                    have.iter()
                        .zip(&out_s.part)
                        .map(|(&h, &n)| h.min(h.div_ceil(n.max(1)) + 1))
                        .product::<usize>() as u64
                }
                _ => 1,
            };
            let inputs_bytes: u64 = node
                .op
                .input_rels()
                .iter()
                .map(|r| max_tile(root[r.0]) * fanin)
                .sum();
            stats.max_task_bytes = stats.max_task_bytes.max(max_tile(out) + inputs_bytes);
        }
        // last reader per storage root (aliases extend their root's
        // lifetime); unread storages (graph outputs) live to the end
        let end = self.nodes.len().saturating_sub(1);
        let mut last_use: Vec<usize> = vec![0; self.rels.len()];
        let mut read: Vec<bool> = vec![false; self.rels.len()];
        for (i, node) in self.nodes.iter().enumerate() {
            for r in node.op.input_rels() {
                last_use[root[r.0]] = last_use[root[r.0]].max(i);
                read[root[r.0]] = true;
            }
        }
        for r in 0..self.rels.len() {
            if !read[r] {
                last_use[r] = end;
            }
        }
        // liveness sweep in program order
        let mut free_at: Vec<Vec<usize>> = vec![vec![]; self.nodes.len()];
        for r in 0..self.rels.len() {
            if materialized_at[r].is_some() {
                free_at[last_use[r].min(end)].push(r);
            }
        }
        let mut live = 0u64;
        for (i, node) in self.nodes.iter().enumerate() {
            let out = node.out.0;
            if materialized_at[out] == Some(i) {
                live += rel_bytes(out);
            }
            stats.peak_bytes = stats.peak_bytes.max(live);
            for &r in &free_at[i] {
                live -= rel_bytes(r);
            }
        }
        stats
    }

    /// Pretty-print the program: one line per node with its output
    /// relation's schema — the listing `Session::explain` and the CLI
    /// `explain` subcommand show.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "tra program: {} nodes over {} relations",
            self.nodes.len(),
            self.rels.len()
        );
        for (i, node) in self.nodes.iter().enumerate() {
            let ins = node
                .op
                .input_rels()
                .iter()
                .map(|r| format!("r{}", r.0))
                .collect::<Vec<_>>()
                .join(", ");
            let detail = match &node.op {
                TraOp::Partition { .. } => String::new(),
                TraOp::Repartition { src, operand, alias, .. } => {
                    let tag = if self.rels[src.0].part == self.rels[node.out.0].part {
                        " identity"
                    } else if *alias {
                        " alias"
                    } else {
                        ""
                    };
                    format!(" op{operand}{tag}")
                }
                TraOp::Join {
                    flops_per_call,
                    epilogue,
                    ..
                } => {
                    let fused = if epilogue.is_empty() {
                        String::new()
                    } else {
                        let ops: Vec<String> =
                            epilogue.iter().map(|e| format!("{e:?}")).collect();
                        format!(" epilogue[{}]", ops.join(","))
                    };
                    format!(
                        " {} calls, {:.3} Mflop/call{fused}",
                        self.rels[node.out.0].num_tiles(),
                        flops_per_call / 1e6
                    )
                }
                TraOp::Aggregate {
                    src,
                    agg,
                    tree_arity,
                    ..
                } => {
                    let group =
                        self.rels[src.0].num_tiles() / self.rels[node.out.0].num_tiles().max(1);
                    match tree_arity {
                        Some(r) => format!(" {agg:?} group={group} tree(arity {r})"),
                        None => format!(" {agg:?} group={group} serial-fold"),
                    }
                }
                TraOp::AllGather {
                    operand, schedule, ..
                } => format!(" op{operand} {schedule:?} relay"),
                TraOp::ReduceScatter {
                    src, agg, schedule, ..
                } => {
                    let group =
                        self.rels[src.0].num_tiles() / self.rels[node.out.0].num_tiles().max(1);
                    format!(" {agg:?} group={group} {schedule:?} chain")
                }
                TraOp::AllReduce {
                    src,
                    agg,
                    mid,
                    reduce,
                    bcast,
                    ..
                } => {
                    let group =
                        self.rels[src.0].num_tiles() / self.rels[mid.0].num_tiles().max(1);
                    format!(" {agg:?} group={group} {reduce:?} reduce + {bcast:?} gather")
                }
                TraOp::ReKey { .. } | TraOp::Assemble { .. } => String::new(),
                TraOp::Reuse { .. } => " (merged duplicate)".into(),
            };
            let _ = writeln!(
                s,
                "  %{i:<3} {:<11} {:<12} ({ins}){detail} -> r{} {}",
                node.op.kind_name(),
                node.name,
                node.out.0,
                self.rels[node.out.0].render()
            );
        }
        s
    }

    // ----- pass rewrites (driven by `tra::passes::PassManager`) --------

    /// Remove identity `Repartition` nodes (equal source and target
    /// parts), re-pointing consumers at the source relation. Emission
    /// already forwards identity Π's without tasks, so this changes only
    /// the IR listing, never the task graph.
    pub(crate) fn elide_identity_reparts(&mut self) -> Vec<String> {
        let mut notes = Vec::new();
        let mut redirect: Vec<usize> = (0..self.rels.len()).collect();
        let mut dead = vec![false; self.nodes.len()];
        for (ni, node) in self.nodes.iter().enumerate() {
            if let TraOp::Repartition { src, operand, .. } = &node.op {
                if self.rels[src.0].part == self.rels[node.out.0].part {
                    redirect[node.out.0] = src.0;
                    dead[ni] = true;
                    notes.push(format!("{}: operand {operand} identity Π elided", node.name));
                }
            }
        }
        if notes.is_empty() {
            return notes;
        }
        // One hop suffices: repartition sources are vertex relations,
        // never other repartitions.
        for node in &mut self.nodes {
            for r in node.op.input_rels_mut() {
                r.0 = redirect[r.0];
            }
        }
        let mut i = 0;
        self.nodes.retain(|_| {
            let keep = !dead[i];
            i += 1;
            keep
        });
        notes
    }

    /// Mark refinement `Repartition`s as aliases: when every needed tile
    /// is contained in exactly one producer tile, consumers read
    /// sub-views of the producer tiles directly and the repartition
    /// emits **zero** tasks (the IR form of the data-plane aliasing in
    /// [`crate::tra::ops::repartition_with_stats`]). Execution stays
    /// bitwise-identical: the kernel slices the same sub-view the
    /// repart task would have produced. The modeled byte ledger gets
    /// coarser, though — a cross-worker consumer is charged the whole
    /// producer tile rather than its sub-tile — which is one reason the
    /// pass is opt-in (`all`), not in the default `safe` set.
    pub(crate) fn alias_refinement_reparts(&mut self) -> Vec<String> {
        let mut notes = Vec::new();
        for ni in 0..self.nodes.len() {
            let (src, out) = match &self.nodes[ni].op {
                TraOp::Repartition { src, alias: false, .. } => (*src, self.nodes[ni].out),
                _ => continue,
            };
            let have = &self.rels[src.0].part;
            let need = &self.rels[out.0].part;
            if have == need || !is_refinement(&self.rels[out.0].bound, have, need) {
                continue;
            }
            let note = format!(
                "{}: Π {have:?} -> {need:?} is a refinement, aliased ({} tasks dropped)",
                self.nodes[ni].name,
                self.rels[out.0].num_tiles()
            );
            if let TraOp::Repartition { alias, .. } = &mut self.nodes[ni].op {
                *alias = true;
            }
            notes.push(note);
        }
        notes
    }

    /// Rewrite every serial-fold `Aggregate` whose group exceeds `arity`
    /// members into a balanced `arity`-ary reduction tree, bounding any
    /// task's fan-in by `arity`. Deterministic (fixed member order) but
    /// — for non-exact `(+)` like float `Sum` — associates differently
    /// than the serial fold, so results are bit-different (still within
    /// the usual tolerance of the dense reference).
    pub(crate) fn agg_tree(&mut self, arity: usize) -> Vec<String> {
        let arity = arity.max(2);
        let mut notes = Vec::new();
        for ni in 0..self.nodes.len() {
            let (src, out) = match &self.nodes[ni].op {
                TraOp::Aggregate {
                    src,
                    tree_arity: None,
                    ..
                } => (*src, self.nodes[ni].out),
                _ => continue,
            };
            let group = self.rels[src.0].num_tiles() / self.rels[out.0].num_tiles().max(1);
            if group <= arity {
                continue;
            }
            let mut depth = 0usize;
            let mut n = group;
            while n > 1 {
                n = n.div_ceil(arity);
                depth += 1;
            }
            let note = format!(
                "{}: {group}-way serial fold -> depth-{depth} {arity}-ary tree",
                self.nodes[ni].name
            );
            if let TraOp::Aggregate { tree_arity, .. } = &mut self.nodes[ni].op {
                *tree_arity = Some(arity);
            }
            notes.push(note);
        }
        notes
    }

    /// Lift point-to-point communication patterns into first-class
    /// collectives (the `lower-collectives` pass):
    ///
    /// 1. a serial-fold `Aggregate` whose output's only consumer is a
    ///    plain (non-identity, non-alias) `Repartition` fuses into one
    ///    [`TraOp::AllReduce`] — reduce-scatter in the aggregate's own
    ///    layout, then gather straight into the consumer's;
    /// 2. every remaining serial-fold `Aggregate` with two or more
    ///    members per group becomes a [`TraOp::ReduceScatter`] chain
    ///    (tree'd aggregates stay with the `agg-tree` rewrite);
    /// 3. every remaining plain non-identity `Repartition` with at least
    ///    one source tile read by two or more consumer tiles becomes an
    ///    [`TraOp::AllGather`] relay.
    ///
    /// With `Ring` schedules (the defaults) the emitted task chains are
    /// bitwise-identical to the point-to-point baseline: gather relays
    /// are pure copies and the ring reduce is the serial left fold.
    pub(crate) fn lower_collectives(
        &mut self,
        gather: CollectiveSchedule,
        reduce: CollectiveSchedule,
    ) -> Vec<String> {
        let mut notes = Vec::new();
        // Consumer count per relation, for the fusion's only-consumer test.
        let mut cons: Vec<Vec<usize>> = vec![Vec::new(); self.rels.len()];
        for (ni, node) in self.nodes.iter().enumerate() {
            for r in node.op.input_rels() {
                cons[r.0].push(ni);
            }
        }
        let mut dead = vec![false; self.nodes.len()];
        // 1. Aggregate whose out feeds exactly one plain Π -> AllReduce.
        for ai in 0..self.nodes.len() {
            let (src, agg, vertex) = match &self.nodes[ai].op {
                TraOp::Aggregate {
                    src,
                    agg,
                    vertex,
                    tree_arity: None,
                } => (*src, *agg, *vertex),
                _ => continue,
            };
            let mid = self.nodes[ai].out;
            let group = self.rels[src.0].num_tiles() / self.rels[mid.0].num_tiles().max(1);
            if group < 2 || cons[mid.0].len() != 1 {
                continue;
            }
            let pi = cons[mid.0][0];
            let (consumer, operand) = match &self.nodes[pi].op {
                TraOp::Repartition {
                    consumer,
                    operand,
                    alias: false,
                    ..
                } => (*consumer, *operand),
                _ => continue,
            };
            let pout = self.nodes[pi].out;
            if self.rels[mid.0].part == self.rels[pout.0].part {
                continue; // identity Π: elision gets it for free
            }
            notes.push(format!(
                "{}: {group}-way fold + Π fused into AllReduce ({reduce:?} reduce, {gather:?} gather)",
                self.nodes[ai].name
            ));
            self.nodes[ai].op = TraOp::AllReduce {
                vertex,
                src,
                agg,
                mid,
                consumer,
                operand,
                reduce,
                bcast: gather,
            };
            self.nodes[ai].out = pout;
            dead[pi] = true;
        }
        // 2 + 3. Remaining serial folds and broadcast-shaped Π's.
        for ni in 0..self.nodes.len() {
            if dead[ni] {
                continue;
            }
            let out = self.nodes[ni].out;
            match &self.nodes[ni].op {
                TraOp::Aggregate {
                    src,
                    agg,
                    vertex,
                    tree_arity: None,
                } => {
                    let (src, agg, vertex) = (*src, *agg, *vertex);
                    let group =
                        self.rels[src.0].num_tiles() / self.rels[out.0].num_tiles().max(1);
                    if group < 2 {
                        continue;
                    }
                    notes.push(format!(
                        "{}: {group}-way serial fold -> ReduceScatter ({reduce:?})",
                        self.nodes[ni].name
                    ));
                    self.nodes[ni].op = TraOp::ReduceScatter {
                        vertex,
                        src,
                        agg,
                        schedule: reduce,
                    };
                }
                TraOp::Repartition {
                    src,
                    producer,
                    consumer,
                    operand,
                    alias: false,
                } => {
                    let (src, producer, consumer, operand) =
                        (*src, *producer, *consumer, *operand);
                    if self.rels[src.0].part == self.rels[out.0].part {
                        continue;
                    }
                    let smap = pi_source_map(
                        &self.rels[out.0].bound,
                        &self.rels[src.0].part,
                        &self.rels[out.0].part,
                    );
                    let shared = shared_sources(&smap);
                    if shared.is_empty() {
                        continue;
                    }
                    notes.push(format!(
                        "{}: op {operand} Π broadcasts {} source tiles -> AllGather ({gather:?})",
                        self.nodes[ni].name,
                        shared.len()
                    ));
                    self.nodes[ni].op = TraOp::AllGather {
                        src,
                        producer,
                        consumer,
                        operand,
                        schedule: gather,
                    };
                }
                _ => {}
            }
        }
        if dead.iter().any(|&d| d) {
            let mut i = 0;
            self.nodes.retain(|_| {
                let keep = !dead[i];
                i += 1;
                keep
            });
        }
        notes
    }

    /// Remove nodes whose output relation nothing consumes and that are
    /// not `Assemble` markers, iterating to a fixpoint. `from_plan`
    /// programs never contain dead relations (an unconsumed vertex is by
    /// definition a graph output and gets an `Assemble`), so this is a
    /// safety net for pass-produced orphans and hand-built programs.
    pub(crate) fn dead_rel_elim(&mut self) -> Vec<String> {
        let mut notes = Vec::new();
        loop {
            let mut used = vec![false; self.rels.len()];
            for node in &self.nodes {
                for r in node.op.input_rels() {
                    used[r.0] = true;
                }
            }
            let dead: Vec<bool> = self
                .nodes
                .iter()
                .map(|n| !matches!(n.op, TraOp::Assemble { .. }) && !used[n.out.0])
                .collect();
            if !dead.iter().any(|&d| d) {
                break;
            }
            for (ni, node) in self.nodes.iter().enumerate() {
                if dead[ni] {
                    notes.push(format!(
                        "{}: dead {} removed",
                        node.name,
                        node.op.kind_name()
                    ));
                }
            }
            let mut i = 0;
            self.nodes.retain(|_| {
                let keep = !dead[i];
                i += 1;
                keep
            });
        }
        notes
    }

    /// Choose input pre-partitionings that elide whole repartition
    /// chains. The paper treats input placement as free and offline, so
    /// an input `Partition`'s layout is ours to pick: for each input
    /// relation consumed only through `Repartition` nodes, score the
    /// current layout and every consumer's needed layout with the §7
    /// repartition cost model ([`crate::decomp::cost::cost_repart`],
    /// summed over all consumers) and rewrite to a strict improvement
    /// (first minimum wins; the current layout wins ties). Newly-identity
    /// repartitions then emit zero tasks (and `elide-identity-repart`
    /// removes them from the listing). Bitwise-neutral: repartitioned
    /// tiles carry the same bytes regardless of the producer layout.
    pub(crate) fn propagate_partitions(&mut self) -> Vec<String> {
        use crate::decomp::cost::cost_repart;
        let mut notes = Vec::new();
        for ni in 0..self.nodes.len() {
            let out = match &self.nodes[ni].op {
                TraOp::Partition { .. } => self.nodes[ni].out,
                _ => continue,
            };
            let bound = self.rels[out.0].bound.clone();
            let current = self.rels[out.0].part.clone();
            // Consumers: only plain (non-alias) Repartition nodes may
            // read it, or the layout is pinned (a join or an aliased Π
            // reads the current tiling directly).
            let mut needs: Vec<Vec<usize>> = Vec::new();
            let mut pinned = false;
            for node in &self.nodes {
                match &node.op {
                    TraOp::Repartition { src, alias, .. } if *src == out => {
                        if *alias {
                            pinned = true;
                        } else {
                            needs.push(self.rels[node.out.0].part.clone());
                        }
                    }
                    op if op.input_rels().contains(&out) => pinned = true,
                    _ => {}
                }
            }
            if pinned || needs.is_empty() {
                continue;
            }
            let score = |cand: &[usize]| -> f64 {
                needs.iter().map(|n| cost_repart(n, cand, &bound)).sum()
            };
            let cur_cost = score(&current);
            let (mut best, mut best_cost) = (current.clone(), cur_cost);
            for cand in &needs {
                let c = score(cand);
                if c < best_cost {
                    best_cost = c;
                    best = cand.clone();
                }
            }
            if best == current {
                continue;
            }
            notes.push(format!(
                "{}: input pre-partitioning {current:?} -> {best:?} \
                 (modeled repart floats {cur_cost:.0} -> {best_cost:.0})",
                self.nodes[ni].name
            ));
            self.rels[out.0].part = best;
        }
        notes
    }

    /// IR-level common-subexpression elimination: value-number the nodes
    /// in topological order (key = op kind + frozen structural signature
    /// + resolved input relations + output partitioning + op parameters)
    /// and merge duplicates. Intermediate duplicates (`Repartition`,
    /// `Join`) are deleted outright with their consumers redirected to
    /// the first occurrence; a duplicate vertex *terminal* (`Aggregate` /
    /// `ReKey`) becomes a zero-task [`TraOp::Reuse`] so the merged
    /// vertex still registers its output tiles for downstream key
    /// recovery and assembly. With `label_sensitive` set (role-driven
    /// strategies that plan by label *name*), joins compare their
    /// label-name-extended signatures, so same-shape vertices whose
    /// label roles differ never merge — the same caveat the plan cache
    /// honors with `Canon::named_signature`.
    pub(crate) fn cse(&mut self, label_sensitive: bool) -> Vec<String> {
        let mut notes = Vec::new();
        // `redirect` rewires consumers of deleted intermediate dups;
        // `vn` additionally equates merged terminals for key purposes
        // (their relations stay live — the Reuse node provides them).
        let mut redirect: Vec<usize> = (0..self.rels.len()).collect();
        let mut vn: Vec<usize> = (0..self.rels.len()).collect();
        fn resolve(map: &[usize], mut r: usize) -> usize {
            while map[r] != r {
                r = map[r];
            }
            r
        }
        let mut seen: HashMap<String, (usize, String)> = HashMap::new();
        let mut dead = vec![false; self.nodes.len()];
        for ni in 0..self.nodes.len() {
            let node = &self.nodes[ni];
            let out_s = &self.rels[node.out.0];
            let key = match &node.op {
                // Tiles of a Π are a pure function of (source relation,
                // target partitioning) — producer/consumer/operand tags
                // are bookkeeping.
                TraOp::Repartition { src, alias, .. } => {
                    format!("R|{}|{:?}|{alias}", resolve(&vn, src.0), out_s.part)
                }
                TraOp::Join {
                    inputs,
                    map_op,
                    epilogue,
                    ..
                } => {
                    let sig = if label_sensitive {
                        &node.named_sig
                    } else {
                        &node.sig
                    };
                    let ins: Vec<usize> = inputs.iter().map(|r| resolve(&vn, r.0)).collect();
                    format!("J|{sig}|{ins:?}|{:?}|{map_op:?}|{epilogue:?}", out_s.part)
                }
                TraOp::Aggregate {
                    src,
                    agg,
                    tree_arity,
                    ..
                } => format!(
                    "A|{}|{agg:?}|{tree_arity:?}|{:?}|{:?}",
                    resolve(&vn, src.0),
                    out_s.part,
                    node.zproj
                ),
                TraOp::ReKey { src, .. } => format!(
                    "K|{}|{:?}|{:?}",
                    resolve(&vn, src.0),
                    out_s.part,
                    node.zproj
                ),
                // Partitions of distinct inputs hold distinct data;
                // Assemble/Reuse are markers. Never merged.
                _ => continue,
            };
            let hit = seen.get(&key).cloned();
            match hit {
                None => {
                    seen.insert(key, (node.out.0, node.name.clone()));
                }
                Some((canon, canon_name)) => {
                    let out = node.out.0;
                    match &node.op {
                        TraOp::Aggregate { vertex, .. } | TraOp::ReKey { vertex, .. } => {
                            let vertex = *vertex;
                            vn[out] = canon;
                            notes.push(format!(
                                "{}: duplicate of {canon_name}, reusing r{canon}",
                                node.name
                            ));
                            self.nodes[ni].op = TraOp::Reuse {
                                vertex,
                                src: RelId(canon),
                            };
                        }
                        _ => {
                            redirect[out] = canon;
                            vn[out] = canon;
                            dead[ni] = true;
                            notes.push(format!(
                                "{}: duplicate {} of {canon_name} merged",
                                node.name,
                                node.op.kind_name()
                            ));
                        }
                    }
                }
            }
        }
        if notes.is_empty() {
            return notes;
        }
        for node in &mut self.nodes {
            for r in node.op.input_rels_mut() {
                r.0 = resolve(&redirect, r.0);
            }
        }
        let mut i = 0;
        self.nodes.retain(|_| {
            let keep = !dead[i];
            i += 1;
            keep
        });
        notes
    }

    /// Fold pure elementwise map vertices into their producer's kernel
    /// epilogue. A candidate is a single-input `Join` with `map_op`
    /// whose operand relation is produced by a `ReKey` (kernel tiles,
    /// nothing aggregates between kernel and consumer — an epilogue must
    /// not commute past an `Aggregate`) and is consumed by this join
    /// alone. The map (plus anything already fused into the consumer)
    /// is appended to the producer `Join`'s epilogue, the producer's
    /// terminal takes over the consumer terminal's vertex identity, and
    /// the consumer's Join/ReKey pair disappears — its kernel tasks with
    /// it. Runs to fixpoint so map chains stack in application order.
    /// Requires identity Π's to be gone (`elide-identity-repart` runs
    /// earlier); a surviving Repartition between producer and consumer
    /// blocks fusion, as it must. Bitwise-neutral: the epilogue applies
    /// the identical pointwise op to the identical tile elements the
    /// fused vertex's own kernel would have.
    pub(crate) fn fuse_epilogues(&mut self) -> Vec<String> {
        let mut notes = Vec::new();
        loop {
            let mut consumers = vec![0usize; self.rels.len()];
            let mut producer_of: Vec<Option<usize>> = vec![None; self.rels.len()];
            for (ni, node) in self.nodes.iter().enumerate() {
                producer_of[node.out.0] = Some(ni);
                for r in node.op.input_rels() {
                    consumers[r.0] += 1;
                }
            }
            // (consumer Join, consumer ReKey, producer ReKey, producer Join)
            let mut found: Option<(usize, usize, usize, usize)> = None;
            for (ni, node) in self.nodes.iter().enumerate() {
                let src = match &node.op {
                    TraOp::Join {
                        inputs,
                        map_op: Some(_),
                        ..
                    } if inputs.len() == 1 => inputs[0],
                    _ => continue,
                };
                if consumers[src.0] != 1 || self.rels[node.out.0].part != self.rels[src.0].part {
                    continue;
                }
                let pi = match producer_of[src.0] {
                    Some(pi) if matches!(self.nodes[pi].op, TraOp::ReKey { .. }) => pi,
                    _ => continue,
                };
                let pj = match &self.nodes[pi].op {
                    TraOp::ReKey { src: jrel, .. } => match producer_of[jrel.0] {
                        Some(pj) if matches!(self.nodes[pj].op, TraOp::Join { .. }) => pj,
                        _ => continue,
                    },
                    _ => unreachable!("matched above"),
                };
                let mut ri = None;
                for (i, n) in self.nodes.iter().enumerate() {
                    if matches!(&n.op, TraOp::ReKey { src, .. } if *src == node.out) {
                        ri = Some(i);
                        break;
                    }
                }
                let Some(ri) = ri else { continue };
                found = Some((ni, ri, pi, pj));
                break;
            }
            let Some((ni, ri, pi, pj)) = found else {
                break;
            };
            let (map, mut absorbed) = match &self.nodes[ni].op {
                TraOp::Join {
                    map_op: Some(m),
                    epilogue,
                    ..
                } => (*m, epilogue.clone()),
                _ => unreachable!("candidate is a map join"),
            };
            let dropped = self.rels[self.nodes[ni].out.0].num_tiles();
            // The consumer terminal's *current* vertex identity (it may
            // already carry an even-later fused consumer) moves onto the
            // producer's terminal, along with its display name.
            let (cons_vertex, cons_rel) = match &self.nodes[ri].op {
                TraOp::ReKey { vertex, .. } => (*vertex, self.nodes[ri].out),
                _ => unreachable!("terminal is a rekey"),
            };
            let cons_name = self.nodes[ri].name.clone();
            let prod_rel = match &self.nodes[ni].op {
                TraOp::Join { inputs, .. } => inputs[0],
                _ => unreachable!("candidate is a map join"),
            };
            notes.push(format!(
                "{cons_name}: map {map:?} fused into {}'s kernel epilogue \
                 ({dropped} kernel tasks dropped)",
                self.nodes[pj].name
            ));
            if let TraOp::Join { epilogue, .. } = &mut self.nodes[pj].op {
                epilogue.push(map);
                epilogue.append(&mut absorbed);
            }
            if let TraOp::ReKey { vertex, .. } = &mut self.nodes[pi].op {
                *vertex = cons_vertex;
            }
            self.nodes[pi].name = cons_name;
            for node in &mut self.nodes {
                for r in node.op.input_rels_mut() {
                    if *r == cons_rel {
                        *r = prod_rel;
                    }
                }
            }
            let mut i = 0;
            self.nodes.retain(|_| {
                let keep = i != ni && i != ri;
                i += 1;
                keep
            });
        }
        notes
    }

    /// Test support: append a node verbatim (used to exercise
    /// `dead-rel-elim` on programs `from_plan` cannot produce).
    #[cfg(test)]
    pub(crate) fn push_node_for_test(&mut self, op: TraOp, out_schema: RelSchema, name: &str) {
        let out = self.push_rel(out_schema);
        self.nodes.push(TraNode {
            op,
            out,
            name: name.into(),
            zproj: vec![],
            oproj: vec![],
            sig: String::new(),
            named_sig: String::new(),
        });
    }
}

impl std::fmt::Display for TraProgram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;

    fn matmul_graph(s: usize) -> EinGraph {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        g.add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
        g
    }

    fn plan_for(g: &EinGraph, d: Vec<usize>) -> Plan {
        let z = g.by_name("Z").unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(z, d);
        plan.finalize_inputs(g);
        plan
    }

    #[test]
    fn lineage_closes_transitively_over_input_rels() {
        let g = matmul_graph(8);
        let prog = from_plan(&g, &plan_for(&g, vec![2, 2, 4])).unwrap();
        // the Assemble output's lineage is every relation in the program
        let last = prog.nodes().last().unwrap().out;
        let all: Vec<RelId> = (0..prog.rels.len()).map(RelId).collect();
        assert_eq!(prog.lineage(last), all);
        // a Partition output has no producers upstream of itself
        let first = prog.nodes().first().unwrap().out;
        assert_eq!(prog.lineage(first), vec![first]);
        // lineage is monotone along a producer chain
        let mid = prog.nodes()[4].out; // the Join relation
        let mid_lineage = prog.lineage(mid);
        assert!(mid_lineage.contains(&first));
        assert!(!mid_lineage.contains(&last));
        assert!(prog.lineage(RelId(9999)).is_empty());
    }

    #[test]
    fn from_plan_builds_eq5_shape() {
        let g = matmul_graph(8);
        let prog = from_plan(&g, &plan_for(&g, vec![2, 2, 4])).unwrap();
        // 2 Partition + 2 Repartition (identity) + Join + Aggregate + Assemble
        let kinds: Vec<&str> = prog.nodes().iter().map(|n| n.op.kind_name()).collect();
        assert_eq!(
            kinds,
            vec![
                "Partition",
                "Partition",
                "Repartition",
                "Repartition",
                "Join",
                "Aggregate",
                "Assemble"
            ]
        );
        let join = &prog.nodes()[4];
        assert_eq!(prog.schema(join.out).part, vec![2, 2, 4]);
        assert_eq!(prog.schema(join.out).labels, labels("i j k"));
        let agg = &prog.nodes()[5];
        assert_eq!(prog.schema(agg.out).part, vec![2, 4]);
        assert_eq!(prog.schema(agg.out).labels, labels("i k"));
    }

    #[test]
    fn residency_stats_sweeps_liveness_with_aliasing() {
        let g = matmul_graph(8);
        let prog = from_plan(&g, &plan_for(&g, vec![2, 2, 4])).unwrap();
        let r = prog.residency_stats();
        // A (256 B) + B (256 B) + the 8x8x8 joined relation (2048 B) are
        // live together at the Join; the identity repartitions alias
        // their sources and the driver-side Assemble charges nothing.
        assert_eq!(r.peak_bytes, 256 + 256 + 2048);
        // the aggregate output (256 B) materializes after A/B are freed
        assert_eq!(r.total_bytes, 256 + 256 + 2048 + 256);
        // largest working set is the Aggregate: one 8-float output tile
        // (32 B) plus a 2-tile fold group of 128-B joined tiles
        assert_eq!(r.max_task_bytes, 32 + 2 * 128);
        assert!(r.fits(r.peak_bytes, 1));
        assert!(!r.fits(r.max_task_bytes - 1, 1_000_000));
    }

    #[test]
    fn residency_rekey_plans_add_no_storage() {
        // j unpartitioned: the program re-keys the join output instead of
        // aggregating — ReKey forwards storage, so only A, B, and the
        // joined relation ever materialize.
        let g = matmul_graph(8);
        let prog = from_plan(&g, &plan_for(&g, vec![4, 1, 4])).unwrap();
        let r = prog.residency_stats();
        assert_eq!(r.total_bytes, 256 + 256 + 2048);
        assert_eq!(r.peak_bytes, r.total_bytes);
    }

    #[test]
    fn residency_fits_divides_peak_across_workers() {
        let r = ResidencyStats {
            peak_bytes: 1000,
            max_task_bytes: 300,
            total_bytes: 1200,
        };
        assert!(r.fits(500, 2)); // per-worker share 500 >= max task 300
        assert!(!r.fits(499, 2));
        assert!(!r.fits(299, 8)); // a single working set must always fit
        assert!(r.fits(300, 8));
        assert!(r.fits(1000, 0)); // workers clamp to 1
    }

    #[test]
    fn join_only_plans_use_rekey() {
        let g = matmul_graph(8);
        let prog = from_plan(&g, &plan_for(&g, vec![4, 1, 4])).unwrap();
        assert!(prog
            .nodes()
            .iter()
            .any(|n| matches!(n.op, TraOp::ReKey { .. })));
        assert!(!prog
            .nodes()
            .iter()
            .any(|n| matches!(n.op, TraOp::Aggregate { .. })));
    }

    #[test]
    fn emit_matches_figure2_counts() {
        let g = matmul_graph(8);
        let tg = from_plan(&g, &plan_for(&g, vec![2, 2, 4]))
            .unwrap()
            .emit_tasks()
            .unwrap();
        assert_eq!(tg.kernel_calls(), 16);
        let aggs = tg
            .tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Agg { .. }))
            .count();
        assert_eq!(aggs, 8);
    }

    #[test]
    fn identity_reparts_forward_without_tasks_and_elide() {
        let g = matmul_graph(8);
        let mut prog = from_plan(&g, &plan_for(&g, vec![2, 2, 4])).unwrap();
        let before = prog.emit_tasks().unwrap();
        assert!(!before
            .tasks
            .iter()
            .any(|t| matches!(t.kind, TaskKind::Repart { .. })));
        let notes = prog.elide_identity_reparts();
        assert_eq!(notes.len(), 2);
        assert!(!prog
            .nodes()
            .iter()
            .any(|n| matches!(n.op, TraOp::Repartition { .. })));
        let after = prog.emit_tasks().unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn refinement_detection() {
        assert!(is_refinement(&[8, 8], &[2, 2], &[4, 4]));
        assert!(is_refinement(&[8, 8], &[2, 2], &[2, 4]));
        assert!(is_refinement(&[7], &[1], &[3]));
        assert!(!is_refinement(&[8, 8], &[4, 4], &[2, 2])); // coarsening
        assert!(!is_refinement(&[8], &[3], &[2])); // misaligned
        // uneven balanced tiling: [2,1] tiles of 3 vs [1,1,1] — tile 1 of
        // need=[3] is [1,2) inside have-tile 0 ([0,2)): refinement.
        assert!(is_refinement(&[3], &[2], &[3]));
    }

    #[test]
    fn agg_tree_rewrites_large_groups_only() {
        let g = matmul_graph(16);
        let mut prog = from_plan(&g, &plan_for(&g, vec![1, 8, 2])).unwrap();
        let notes = prog.agg_tree(4);
        assert_eq!(notes.len(), 1, "{notes:?}");
        let tg = prog.emit_tasks().unwrap();
        for t in &tg.tasks {
            if matches!(t.kind, TaskKind::Agg { .. }) {
                assert!(t.deps.len() <= 4, "fan-in {} > arity", t.deps.len());
            }
        }
        // group of 2 with arity 4: untouched
        let mut small = from_plan(&g, &plan_for(&g, vec![2, 2, 4])).unwrap();
        assert!(small.agg_tree(4).is_empty());
    }

    #[test]
    fn dead_rel_elim_is_a_noop_on_from_plan_programs() {
        let g = matmul_graph(8);
        let mut prog = from_plan(&g, &plan_for(&g, vec![2, 2, 4])).unwrap();
        assert!(prog.dead_rel_elim().is_empty());
        // ... and removes a hand-planted orphan chain to fixpoint
        let n = prog.len();
        let orphan_src = RelId(prog.rels.len());
        prog.push_node_for_test(
            TraOp::Partition {
                vertex: VertexId(0),
            },
            RelSchema {
                bound: vec![4],
                part: vec![1],
                labels: vec![],
            },
            "orphan-base",
        );
        prog.push_node_for_test(
            TraOp::ReKey {
                vertex: VertexId(0),
                src: orphan_src,
            },
            RelSchema {
                bound: vec![4],
                part: vec![1],
                labels: vec![],
            },
            "orphan-user",
        );
        let notes = prog.dead_rel_elim();
        assert_eq!(notes.len(), 2, "{notes:?}");
        assert_eq!(prog.len(), n);
    }

    #[test]
    fn render_lists_every_node_with_schemas() {
        let g = matmul_graph(8);
        let prog = from_plan(&g, &plan_for(&g, vec![2, 2, 4])).unwrap();
        let text = prog.render();
        for kind in ["Partition", "Repartition", "Join", "Aggregate", "Assemble"] {
            assert!(text.contains(kind), "missing {kind} in:\n{text}");
        }
        assert!(text.contains("identity"));
        assert!(text.contains("i:8/2"));
        assert!(text.contains("group=2"));
    }
}

//! The three TRA operations (paper §4.2) and the EinSum -> TRA rewrite
//! (paper §4.3, Eq. 5).
//!
//! Conventions: a partitioning vector `d` is stored *parallel to the
//! EinSum's unique label list* (`op.unique_labels()`), which bakes in the
//! paper's co-partitioning constraint — repeated labels across `l_X`/`l_Y`
//! are one entry, so `d[l_X; l_XY]` and `d[l_Y; l_XY]` automatically agree
//! on shared labels. All per-operand partitionings are derived with the
//! `project` operation.

use crate::einsum::expr::{AggOp, EinSum};
use crate::einsum::label::{concat_dedup, project, LabelList};
use crate::error::{Error, Result};
use crate::runtime::KernelEngine;
use crate::tensor::{index_space, Tensor, TensorView};
use crate::tra::relation::{
    overlapping_tiles, tile_origin, tile_shape, validate_part, TensorRelation,
};

/// TRA join (paper §4.2): match tuples of `x` and `y` whose keys agree on
/// shared labels, and apply the kernel `K` to each matched pair.
///
/// Output keys range over `l_X (.) l_Y` (concat-dedup: natural-join
/// schema); the output tile for key `key` is
/// `K(x.tile(key[l_X]), y.tile(key[l_Y]))`. The kernel receives the
/// matched tiles as strided [`TensorView`]s — the join itself moves no
/// tile data.
pub fn join(
    x: &TensorRelation,
    y: &TensorRelation,
    lx: &LabelList,
    ly: &LabelList,
    kernel: &mut dyn FnMut(&TensorView, &TensorView) -> Result<Tensor>,
) -> Result<Vec<(Vec<usize>, Tensor)>> {
    if x.part().len() != lx.len() || y.part().len() != ly.len() {
        return Err(Error::InvalidPartitioning(format!(
            "join: relation ranks {:?}/{:?} vs labels {lx:?}/{ly:?}",
            x.part(),
            y.part()
        )));
    }
    let lj = concat_dedup(lx, ly);
    // partitioning of the join key space: first occurrence wins (they agree
    // on shared labels by the co-partitioning invariant, checked below).
    let mut dj = Vec::with_capacity(lj.len());
    for l in &lj {
        let from_x = lx.iter().position(|m| m == l).map(|i| x.part()[i]);
        let from_y = ly.iter().position(|m| m == l).map(|i| y.part()[i]);
        match (from_x, from_y) {
            (Some(a), Some(b)) if a != b => {
                return Err(Error::InvalidPartitioning(format!(
                    "join label {l} not co-partitioned: {a} vs {b}"
                )))
            }
            (Some(a), _) => dj.push(a),
            (None, Some(b)) => dj.push(b),
            (None, None) => unreachable!(),
        }
    }
    let mut out = Vec::new();
    for key in index_space(&dj) {
        let kx = project(&key, lx, &lj);
        let ky = project(&key, ly, &lj);
        let t = kernel(x.tile(&kx), y.tile(&ky))?;
        out.push((key, t));
    }
    Ok(out)
}

/// TRA aggregation (paper §4.2): group tuples whose keys agree on all
/// labels *not* in `l_agg`, and reduce each group's tensors elementwise
/// with `agg`. `lin` labels the input keys; `lout` labels the output keys
/// (a subset of `lin`, in output order).
pub fn aggregate(
    tuples: Vec<(Vec<usize>, Tensor)>,
    lin: &LabelList,
    lout: &LabelList,
    agg: AggOp,
) -> Result<Vec<(Vec<usize>, Tensor)>> {
    use std::collections::HashMap;
    let mut groups: HashMap<Vec<usize>, Tensor> = HashMap::new();
    for (key, t) in tuples {
        let gkey = project(&key, lout, lin);
        match groups.entry(gkey) {
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(t);
            }
            std::collections::hash_map::Entry::Occupied(mut e) => {
                e.get_mut().accumulate(&t, |a, b| agg.combine(a, b))?;
                // The merged-away kernel output is dead: return its
                // buffer to the thread's pool.
                t.recycle();
            }
        }
    }
    let mut out: Vec<_> = groups.into_iter().collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

/// Byte accounting for one tile-to-tile [`repartition`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepartStats {
    /// Bytes copied from producer tiles into consumer tiles. Each float
    /// moves at most once, so this is at most `4 * prod(bound)` — the
    /// floor the distributed cost model (`cost_repart`, which charges
    /// whole-tile shipments) upper-bounds.
    pub bytes_moved: usize,
    /// Consumer tiles that were zero-copy sub-views of a single producer
    /// tile (every pure refinement aliases all of its tiles).
    pub tiles_aliased: usize,
}

/// TRA repartition (paper §4.2): `Pi_d(X)` produces the relation with
/// partitioning `d` equivalent to the same dense tensor.
pub fn repartition(x: &TensorRelation, d: &[usize]) -> Result<TensorRelation> {
    repartition_with_stats(x, d).map(|(r, _)| r)
}

/// [`repartition`], reporting how many bytes actually moved.
///
/// Rather than assembling the full dense tensor and re-slicing it (two
/// full copies plus a dense allocation), each consumer tile is built
/// directly from the producer tiles overlapping it: a consumer tile
/// contained in a single producer tile becomes an O(1) sub-view (zero
/// bytes), and otherwise exactly the overlapping sub-regions are copied
/// — each element moves at most once, matching the transfer volume the
/// planner's `cost_repart` charge upper-bounds (`tests/zero_copy.rs`
/// pins both facts).
pub fn repartition_with_stats(
    x: &TensorRelation,
    d: &[usize],
) -> Result<(TensorRelation, RepartStats)> {
    validate_part(x.bound(), d)?;
    if x.part() == d {
        return Ok((x.clone(), RepartStats::default()));
    }
    let bound = x.bound().to_vec();
    let have = x.part().to_vec();
    let rank = bound.len();
    let mut stats = RepartStats::default();
    let mut tiles = Vec::with_capacity(d.iter().product());
    for key in index_space(d) {
        let t_origin = tile_origin(&bound, d, &key);
        let t_shape = tile_shape(&bound, d, &key);
        let ranges: Vec<(usize, usize)> = (0..rank)
            .map(|dim| overlapping_tiles(bound[dim], have[dim], t_origin[dim], t_shape[dim]))
            .collect();
        let range_dims: Vec<usize> = ranges.iter().map(|(lo, hi)| hi - lo + 1).collect();
        let n_overlap: usize = range_dims.iter().product();
        if n_overlap == 1 {
            // Contained in one producer tile: alias, don't copy.
            let pkey: Vec<usize> = ranges.iter().map(|&(lo, _)| lo).collect();
            let p_origin = tile_origin(&bound, &have, &pkey);
            let rel_off: Vec<usize> = t_origin
                .iter()
                .zip(&p_origin)
                .map(|(t, p)| t - p)
                .collect();
            tiles.push(x.tile(&pkey).slice(&rel_off, &t_shape)?);
            stats.tiles_aliased += 1;
            continue;
        }
        // The union of intersections covers the consumer tile exactly
        // once, so the pooled buffer is fully overwritten.
        let mut out = Tensor::full_pooled(&t_shape, 0.0);
        for rk in index_space(&range_dims) {
            let pkey: Vec<usize> = rk
                .iter()
                .zip(&ranges)
                .map(|(&r, &(lo, _))| lo + r)
                .collect();
            let p_origin = tile_origin(&bound, &have, &pkey);
            let p_shape = tile_shape(&bound, &have, &pkey);
            let mut src_off = vec![0usize; rank];
            let mut dst_off = vec![0usize; rank];
            let mut sz = vec![0usize; rank];
            for dim in 0..rank {
                let a = t_origin[dim].max(p_origin[dim]);
                let b = (t_origin[dim] + t_shape[dim]).min(p_origin[dim] + p_shape[dim]);
                debug_assert!(b > a, "overlap ranges yielded an empty intersection");
                src_off[dim] = a - p_origin[dim];
                dst_off[dim] = a - t_origin[dim];
                sz[dim] = b - a;
            }
            let piece = x.tile(&pkey).slice(&src_off, &sz)?;
            stats.bytes_moved += piece.bytes();
            out.write_slice_view(&dst_off, &piece)?;
        }
        tiles.push(out.into_view());
    }
    let rel = TensorRelation::from_views(bound, d.to_vec(), tiles)?;
    Ok((rel, stats))
}

/// Evaluate one EinSum expression through the TRA rewrite of Eq. 5:
/// partition inputs according to `d` (parallel to `op.unique_labels()`),
/// join with the tile-local kernel (the same EinSum evaluated by
/// `engine` on sub-tensors), aggregate with `(+)`, and return the result
/// as a relation partitioned `d[l_Z; l_XY]`.
///
/// This is the executable form of the paper's claim that the rewrite is
/// equivalence-preserving; tests compare it against direct dense
/// evaluation for many `d`.
///
/// ```
/// use eindecomp::einsum::expr::EinSum;
/// use eindecomp::einsum::label::labels;
/// use eindecomp::runtime::NativeEngine;
/// use eindecomp::tensor::Tensor;
/// use eindecomp::tra::eval_einsum_tra;
///
/// // Z[i,k] = sum_j X[i,j] * Y[j,k], decomposed with d = (2, 2, 1) over
/// // the unique labels (i, j, k): 2-way over i and the contracted j.
/// let x = Tensor::random(&[8, 6], 1);
/// let y = Tensor::random(&[6, 4], 2);
/// let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
/// let rel = eval_einsum_tra(&op, &[&x, &y], &[2, 2, 1], &NativeEngine::new())?;
///
/// // The result is a relation partitioned d[l_Z] = (2, 1); assembling it
/// // matches direct dense evaluation (Eq. 5 is equivalence-preserving).
/// assert_eq!(rel.part(), &[2, 1]);
/// let dense = eindecomp::runtime::native::eval_einsum(&op, &[&x, &y])?;
/// assert!(rel.assemble()?.allclose(&dense, 1e-4, 1e-5));
/// # Ok::<(), eindecomp::Error>(())
/// ```
pub fn eval_einsum_tra(
    op: &EinSum,
    inputs: &[&Tensor],
    d: &[usize],
    engine: &dyn KernelEngine,
) -> Result<TensorRelation> {
    let uniq = op.unique_labels();
    if d.len() != uniq.len() {
        return Err(Error::InvalidPartitioning(format!(
            "d {d:?} not parallel to unique labels {uniq:?}"
        )));
    }
    let lz = op
        .lz()
        .ok_or_else(|| Error::InvalidEinsum("cannot evaluate Input".into()))?
        .clone();
    let in_bounds: Vec<&[usize]> = inputs.iter().map(|t| t.shape()).collect();
    let bz = op.infer_bound(&in_bounds)?;
    let dz = project(d, &lz, &uniq);

    match op {
        EinSum::Input => unreachable!(),
        EinSum::Unary { lx, .. } => {
            let dx = project(d, lx, &uniq);
            let rx = TensorRelation::partition(inputs[0], &dx)?;
            // map/reduce each tile (a strided view) with the tile-local op
            let mut tuples = Vec::new();
            for (key, tile) in rx.iter() {
                tuples.push((key, engine.eval_view(op, &[tile])?));
            }
            let agg = match op {
                EinSum::Unary { agg, .. } => *agg,
                _ => unreachable!(),
            };
            let grouped = aggregate(tuples, lx, &lz, agg)?;
            let tiles: Vec<Tensor> = grouped.into_iter().map(|(_, t)| t).collect();
            TensorRelation::from_tiles(bz, dz, tiles)
        }
        EinSum::Binary {
            lx, ly, agg: aggop, ..
        } => {
            let dx = project(d, lx, &uniq);
            let dy = project(d, ly, &uniq);
            let rx = TensorRelation::partition(inputs[0], &dx)?;
            let ry = TensorRelation::partition(inputs[1], &dy)?;
            let mut kernel = |a: &TensorView, b: &TensorView| engine.eval_view(op, &[a, b]);
            let joined = join(&rx, &ry, lx, ly, &mut kernel)?;
            let lj = concat_dedup(lx, ly);
            let grouped = aggregate(joined, &lj, &lz, *aggop)?;
            let tiles: Vec<Tensor> = grouped.into_iter().map(|(_, t)| t).collect();
            TensorRelation::from_tiles(bz, dz, tiles)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::expr::{JoinOp, UnaryOp};
    use crate::einsum::label::labels;
    use crate::runtime::native::{eval_einsum, NativeEngine};

    fn engine() -> NativeEngine {
        NativeEngine::new()
    }

    /// Check Eq. 5 equivalence: TRA evaluation == dense evaluation.
    fn check_equiv(op: &EinSum, inputs: &[&Tensor], d: &[usize]) {
        let dense = eval_einsum(op, inputs).unwrap();
        let rel = eval_einsum_tra(op, inputs, d, &engine()).unwrap();
        let assembled = rel.assemble().unwrap();
        assert!(
            assembled.allclose(&dense, 1e-4, 1e-5),
            "TRA != dense for d={d:?}: max diff {}",
            assembled.max_abs_diff(&dense).unwrap()
        );
    }

    #[test]
    fn matmul_all_figure1_partitionings() {
        // The four partitionings of Figure 1 on an 8x8 matmul, d over
        // unique labels [i, j, k].
        let x = Tensor::random(&[8, 8], 1);
        let y = Tensor::random(&[8, 8], 2);
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        for d in [[4, 1, 4], [2, 1, 8], [2, 4, 2], [2, 2, 4]] {
            check_equiv(&op, &[&x, &y], &d);
        }
    }

    #[test]
    fn figure1_kernel_call_counts() {
        // Each Figure 1 partitioning produces exactly 16 kernel calls:
        // N = prod d[l_X (.) l_Y] = d_i * d_j * d_k.
        let x = Tensor::random(&[8, 8], 1);
        let y = Tensor::random(&[8, 8], 2);
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        for d in [[4usize, 1, 4], [2, 1, 8], [2, 4, 2], [2, 2, 4]] {
            let uniq = op.unique_labels();
            let (lx, ly) = (labels("i j"), labels("j k"));
            let rx =
                TensorRelation::partition(&x, &project(&d, &lx, &uniq)).unwrap();
            let ry =
                TensorRelation::partition(&y, &project(&d, &ly, &uniq)).unwrap();
            let mut calls = 0usize;
            let mut kernel = |a: &TensorView, b: &TensorView| {
                calls += 1;
                crate::runtime::native::eval_einsum_view(&op, &[a, b])
            };
            join(&rx, &ry, &lx, &ly, &mut kernel).unwrap();
            assert_eq!(calls, 16, "d={d:?}");
        }
    }

    #[test]
    fn matmul_uneven_bounds() {
        let x = Tensor::random(&[7, 10], 3);
        let y = Tensor::random(&[10, 5], 4);
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        for d in [[1usize, 1, 1], [3, 2, 2], [7, 10, 5], [2, 3, 1]] {
            check_equiv(&op, &[&x, &y], &d);
        }
    }

    #[test]
    fn extended_ops_decompose_correctly() {
        let x = Tensor::random(&[6, 8], 5);
        let y = Tensor::random(&[8, 4], 6);
        // squared-L2 with Sum
        let l2 = EinSum::Binary {
            lx: labels("i j"),
            ly: labels("j k"),
            lz: labels("i k"),
            join: JoinOp::SquaredDiff,
            agg: AggOp::Sum,
        };
        check_equiv(&l2, &[&x, &y], &[2, 4, 2]);
        // L-inf with Max — max aggregation across tiles must also hold
        let linf = EinSum::Binary {
            lx: labels("i j"),
            ly: labels("j k"),
            lz: labels("i k"),
            join: JoinOp::AbsDiff,
            agg: AggOp::Max,
        };
        check_equiv(&linf, &[&x, &y], &[3, 2, 4]);
    }

    #[test]
    fn broadcast_join_decomposes() {
        // softmax normalization: Y_ij <- E_ij / S_i; i co-partitioned.
        let e = Tensor::random(&[8, 6], 7);
        let s = Tensor::random(&[8], 8).reshape(vec![8]).unwrap();
        let op = EinSum::Binary {
            lx: labels("i j"),
            ly: labels("i"),
            lz: labels("i j"),
            join: JoinOp::Div,
            agg: AggOp::Sum,
        };
        for d in [[1usize, 1], [4, 2], [8, 3], [2, 6]] {
            check_equiv(&op, &[&e, &s], &d);
        }
    }

    #[test]
    fn unary_reduce_decomposes() {
        let x = Tensor::random(&[9, 12], 9);
        let op = EinSum::reduce(labels("i j"), labels("i"), AggOp::Max);
        for d in [[1usize, 1], [3, 4], [9, 12], [2, 5]] {
            check_equiv(&op, &[&x], &d);
        }
    }

    #[test]
    fn unary_map_transpose_decomposes() {
        let x = Tensor::random(&[6, 4], 10);
        let op = EinSum::Unary {
            lx: labels("i j"),
            lz: labels("j i"),
            op: UnaryOp::Exp,
            agg: AggOp::Sum,
        };
        for d in [[2usize, 2], [3, 4], [1, 1]] {
            check_equiv(&op, &[&x], &d);
        }
    }

    #[test]
    fn rank3_contraction_decomposes() {
        // Z_ik <- sum_{b,j} X_ijb Y_jbk
        let x = Tensor::random(&[4, 6, 2], 11);
        let y = Tensor::random(&[6, 2, 5], 12);
        let op = EinSum::contraction(labels("i j b"), labels("j b k"), labels("i k"));
        // unique labels: [i, j, b, k]
        for d in [[1usize, 1, 1, 1], [2, 3, 2, 5], [4, 2, 1, 1]] {
            check_equiv(&op, &[&x, &y], &d);
        }
    }

    #[test]
    fn join_rejects_non_copartitioned() {
        let x = Tensor::random(&[8, 8], 1);
        let y = Tensor::random(&[8, 8], 2);
        let rx = TensorRelation::partition(&x, &[2, 4]).unwrap();
        let ry = TensorRelation::partition(&y, &[2, 2]).unwrap(); // j: 4 vs 2
        let mut k = |a: &TensorView, _b: &TensorView| Ok(a.to_tensor());
        assert!(join(&rx, &ry, &labels("i j"), &labels("j k"), &mut k).is_err());
    }

    #[test]
    fn repartition_preserves_equivalence() {
        let t = Tensor::random(&[8, 12], 13);
        let r = TensorRelation::partition(&t, &[2, 3]).unwrap();
        let r2 = repartition(&r, &[4, 2]).unwrap();
        assert_eq!(r2.part(), &[4, 2]);
        assert_eq!(r2.assemble().unwrap(), t);
        // uneven bounds and a sweep of targets stay equivalent
        let u = Tensor::random(&[7, 10], 14);
        for have in [&[1usize, 1][..], &[3, 2], &[7, 5]] {
            let ru = TensorRelation::partition(&u, have).unwrap();
            for want in [&[1usize, 1][..], &[2, 3], &[4, 2], &[7, 10]] {
                let r3 = repartition(&ru, want).unwrap();
                assert_eq!(r3.assemble().unwrap(), u, "{have:?} -> {want:?}");
            }
        }
    }

    #[test]
    fn repartition_refinement_aliases_all_tiles() {
        // [2, 2] -> [4, 4] on a 8x8: every consumer tile sits inside one
        // producer tile — all sub-views, zero bytes moved.
        let t = Tensor::random(&[8, 8], 15);
        let r = TensorRelation::partition(&t, &[2, 2]).unwrap();
        let (r2, stats) = repartition_with_stats(&r, &[4, 4]).unwrap();
        assert_eq!(stats.bytes_moved, 0);
        assert_eq!(stats.tiles_aliased, 16);
        assert_eq!(r2.assemble().unwrap(), t);
    }

    #[test]
    fn repartition_coarsening_moves_each_float_once() {
        // [4, 4] -> [2, 2]: every consumer tile unions 4 producers, so
        // nothing aliases and each float is copied exactly once.
        let t = Tensor::random(&[8, 8], 16);
        let r = TensorRelation::partition(&t, &[4, 4]).unwrap();
        let (r2, stats) = repartition_with_stats(&r, &[2, 2]).unwrap();
        assert_eq!(stats.tiles_aliased, 0);
        assert_eq!(stats.bytes_moved, t.bytes());
        assert_eq!(r2.assemble().unwrap(), t);
    }

    #[test]
    fn aggregate_groups_correctly() {
        // keys over [i, j] with part [2, 2]; aggregate j out with Sum.
        let tuples = vec![
            (vec![0, 0], Tensor::full(&[2], 1.0)),
            (vec![0, 1], Tensor::full(&[2], 2.0)),
            (vec![1, 0], Tensor::full(&[2], 3.0)),
            (vec![1, 1], Tensor::full(&[2], 4.0)),
        ];
        let out = aggregate(tuples, &labels("i j"), &labels("i"), AggOp::Sum).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.data(), &[3.0, 3.0]);
        assert_eq!(out[1].1.data(), &[7.0, 7.0]);
    }

    use crate::einsum::label::project;
}

//! The three TRA operations (paper §4.2) and the EinSum -> TRA rewrite
//! (paper §4.3, Eq. 5).
//!
//! Conventions: a partitioning vector `d` is stored *parallel to the
//! EinSum's unique label list* (`op.unique_labels()`), which bakes in the
//! paper's co-partitioning constraint — repeated labels across `l_X`/`l_Y`
//! are one entry, so `d[l_X; l_XY]` and `d[l_Y; l_XY]` automatically agree
//! on shared labels. All per-operand partitionings are derived with the
//! `project` operation.

use crate::einsum::expr::{AggOp, EinSum};
use crate::einsum::label::{concat_dedup, project, LabelList};
use crate::error::{Error, Result};
use crate::runtime::KernelEngine;
use crate::tensor::{index_space, Tensor};
use crate::tra::relation::TensorRelation;

/// TRA join (paper §4.2): match tuples of `x` and `y` whose keys agree on
/// shared labels, and apply the kernel `K` to each matched pair.
///
/// Output keys range over `l_X (.) l_Y` (concat-dedup: natural-join
/// schema); the output tile for key `key` is
/// `K(x.tile(key[l_X]), y.tile(key[l_Y]))`.
///
/// `out_bound`/`out_part` describe the join output *as a relation* keyed
/// over the dedup schema (needed to size tiles); the kernel decides each
/// tile's actual shape, which is validated against them.
pub fn join(
    x: &TensorRelation,
    y: &TensorRelation,
    lx: &LabelList,
    ly: &LabelList,
    kernel: &mut dyn FnMut(&Tensor, &Tensor) -> Result<Tensor>,
) -> Result<Vec<(Vec<usize>, Tensor)>> {
    if x.part().len() != lx.len() || y.part().len() != ly.len() {
        return Err(Error::InvalidPartitioning(format!(
            "join: relation ranks {:?}/{:?} vs labels {lx:?}/{ly:?}",
            x.part(),
            y.part()
        )));
    }
    let lj = concat_dedup(lx, ly);
    // partitioning of the join key space: first occurrence wins (they agree
    // on shared labels by the co-partitioning invariant, checked below).
    let mut dj = Vec::with_capacity(lj.len());
    for l in &lj {
        let from_x = lx.iter().position(|m| m == l).map(|i| x.part()[i]);
        let from_y = ly.iter().position(|m| m == l).map(|i| y.part()[i]);
        match (from_x, from_y) {
            (Some(a), Some(b)) if a != b => {
                return Err(Error::InvalidPartitioning(format!(
                    "join label {l} not co-partitioned: {a} vs {b}"
                )))
            }
            (Some(a), _) => dj.push(a),
            (None, Some(b)) => dj.push(b),
            (None, None) => unreachable!(),
        }
    }
    let mut out = Vec::new();
    for key in index_space(&dj) {
        let kx = project(&key, lx, &lj);
        let ky = project(&key, ly, &lj);
        let t = kernel(x.tile(&kx), y.tile(&ky))?;
        out.push((key, t));
    }
    Ok(out)
}

/// TRA aggregation (paper §4.2): group tuples whose keys agree on all
/// labels *not* in `l_agg`, and reduce each group's tensors elementwise
/// with `agg`. `lin` labels the input keys; `lout` labels the output keys
/// (a subset of `lin`, in output order).
pub fn aggregate(
    tuples: Vec<(Vec<usize>, Tensor)>,
    lin: &LabelList,
    lout: &LabelList,
    agg: AggOp,
) -> Result<Vec<(Vec<usize>, Tensor)>> {
    use std::collections::HashMap;
    let mut groups: HashMap<Vec<usize>, Tensor> = HashMap::new();
    for (key, t) in tuples {
        let gkey = project(&key, lout, lin);
        match groups.entry(gkey) {
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(t);
            }
            std::collections::hash_map::Entry::Occupied(mut e) => {
                e.get_mut().accumulate(&t, |a, b| agg.combine(a, b))?;
            }
        }
    }
    let mut out: Vec<_> = groups.into_iter().collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

/// TRA repartition (paper §4.2): `Pi_d(X)` produces the relation with
/// partitioning `d` equivalent to the same dense tensor.
///
/// This semantic implementation assembles and re-partitions; the
/// distributed implementation in [`crate::taskgraph`] moves only the
/// overlapping sub-regions (and its transfer volume is what
/// `cost_repart` bounds).
pub fn repartition(x: &TensorRelation, d: &[usize]) -> Result<TensorRelation> {
    if x.part() == d {
        return Ok(x.clone());
    }
    let dense = x.assemble()?;
    TensorRelation::partition(&dense, d)
}

/// Evaluate one EinSum expression through the TRA rewrite of Eq. 5:
/// partition inputs according to `d` (parallel to `op.unique_labels()`),
/// join with the tile-local kernel (the same EinSum evaluated by
/// `engine` on sub-tensors), aggregate with `(+)`, and return the result
/// as a relation partitioned `d[l_Z; l_XY]`.
///
/// This is the executable form of the paper's claim that the rewrite is
/// equivalence-preserving; tests compare it against direct dense
/// evaluation for many `d`.
///
/// ```
/// use eindecomp::einsum::expr::EinSum;
/// use eindecomp::einsum::label::labels;
/// use eindecomp::runtime::NativeEngine;
/// use eindecomp::tensor::Tensor;
/// use eindecomp::tra::eval_einsum_tra;
///
/// // Z[i,k] = sum_j X[i,j] * Y[j,k], decomposed with d = (2, 2, 1) over
/// // the unique labels (i, j, k): 2-way over i and the contracted j.
/// let x = Tensor::random(&[8, 6], 1);
/// let y = Tensor::random(&[6, 4], 2);
/// let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
/// let rel = eval_einsum_tra(&op, &[&x, &y], &[2, 2, 1], &NativeEngine::new())?;
///
/// // The result is a relation partitioned d[l_Z] = (2, 1); assembling it
/// // matches direct dense evaluation (Eq. 5 is equivalence-preserving).
/// assert_eq!(rel.part(), &[2, 1]);
/// let dense = eindecomp::runtime::native::eval_einsum(&op, &[&x, &y])?;
/// assert!(rel.assemble()?.allclose(&dense, 1e-4, 1e-5));
/// # Ok::<(), eindecomp::Error>(())
/// ```
pub fn eval_einsum_tra(
    op: &EinSum,
    inputs: &[&Tensor],
    d: &[usize],
    engine: &dyn KernelEngine,
) -> Result<TensorRelation> {
    let uniq = op.unique_labels();
    if d.len() != uniq.len() {
        return Err(Error::InvalidPartitioning(format!(
            "d {d:?} not parallel to unique labels {uniq:?}"
        )));
    }
    let lz = op
        .lz()
        .ok_or_else(|| Error::InvalidEinsum("cannot evaluate Input".into()))?
        .clone();
    let in_bounds: Vec<&[usize]> = inputs.iter().map(|t| t.shape()).collect();
    let bz = op.infer_bound(&in_bounds)?;
    let dz = project(d, &lz, &uniq);

    match op {
        EinSum::Input => unreachable!(),
        EinSum::Unary { lx, .. } => {
            let dx = project(d, lx, &uniq);
            let rx = TensorRelation::partition(inputs[0], &dx)?;
            // map/reduce each tile with the tile-local op
            let mut tuples = Vec::new();
            for (key, tile) in rx.iter() {
                tuples.push((key, engine.eval(op, &[tile])?));
            }
            let agg = match op {
                EinSum::Unary { agg, .. } => *agg,
                _ => unreachable!(),
            };
            let grouped = aggregate(tuples, lx, &lz, agg)?;
            let tiles: Vec<Tensor> = grouped.into_iter().map(|(_, t)| t).collect();
            TensorRelation::from_tiles(bz, dz, tiles)
        }
        EinSum::Binary {
            lx, ly, agg: aggop, ..
        } => {
            let dx = project(d, lx, &uniq);
            let dy = project(d, ly, &uniq);
            let rx = TensorRelation::partition(inputs[0], &dx)?;
            let ry = TensorRelation::partition(inputs[1], &dy)?;
            let mut kernel = |a: &Tensor, b: &Tensor| engine.eval(op, &[a, b]);
            let joined = join(&rx, &ry, lx, ly, &mut kernel)?;
            let lj = concat_dedup(lx, ly);
            let grouped = aggregate(joined, &lj, &lz, *aggop)?;
            let tiles: Vec<Tensor> = grouped.into_iter().map(|(_, t)| t).collect();
            TensorRelation::from_tiles(bz, dz, tiles)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::expr::{JoinOp, UnaryOp};
    use crate::einsum::label::labels;
    use crate::runtime::native::{eval_einsum, NativeEngine};

    fn engine() -> NativeEngine {
        NativeEngine::new()
    }

    /// Check Eq. 5 equivalence: TRA evaluation == dense evaluation.
    fn check_equiv(op: &EinSum, inputs: &[&Tensor], d: &[usize]) {
        let dense = eval_einsum(op, inputs).unwrap();
        let rel = eval_einsum_tra(op, inputs, d, &engine()).unwrap();
        let assembled = rel.assemble().unwrap();
        assert!(
            assembled.allclose(&dense, 1e-4, 1e-5),
            "TRA != dense for d={d:?}: max diff {}",
            assembled.max_abs_diff(&dense).unwrap()
        );
    }

    #[test]
    fn matmul_all_figure1_partitionings() {
        // The four partitionings of Figure 1 on an 8x8 matmul, d over
        // unique labels [i, j, k].
        let x = Tensor::random(&[8, 8], 1);
        let y = Tensor::random(&[8, 8], 2);
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        for d in [[4, 1, 4], [2, 1, 8], [2, 4, 2], [2, 2, 4]] {
            check_equiv(&op, &[&x, &y], &d);
        }
    }

    #[test]
    fn figure1_kernel_call_counts() {
        // Each Figure 1 partitioning produces exactly 16 kernel calls:
        // N = prod d[l_X (.) l_Y] = d_i * d_j * d_k.
        let x = Tensor::random(&[8, 8], 1);
        let y = Tensor::random(&[8, 8], 2);
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        for d in [[4usize, 1, 4], [2, 1, 8], [2, 4, 2], [2, 2, 4]] {
            let uniq = op.unique_labels();
            let (lx, ly) = (labels("i j"), labels("j k"));
            let rx =
                TensorRelation::partition(&x, &project(&d, &lx, &uniq)).unwrap();
            let ry =
                TensorRelation::partition(&y, &project(&d, &ly, &uniq)).unwrap();
            let mut calls = 0usize;
            let mut kernel = |a: &Tensor, b: &Tensor| {
                calls += 1;
                eval_einsum(&op, &[a, b])
            };
            join(&rx, &ry, &lx, &ly, &mut kernel).unwrap();
            assert_eq!(calls, 16, "d={d:?}");
        }
    }

    #[test]
    fn matmul_uneven_bounds() {
        let x = Tensor::random(&[7, 10], 3);
        let y = Tensor::random(&[10, 5], 4);
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        for d in [[1usize, 1, 1], [3, 2, 2], [7, 10, 5], [2, 3, 1]] {
            check_equiv(&op, &[&x, &y], &d);
        }
    }

    #[test]
    fn extended_ops_decompose_correctly() {
        let x = Tensor::random(&[6, 8], 5);
        let y = Tensor::random(&[8, 4], 6);
        // squared-L2 with Sum
        let l2 = EinSum::Binary {
            lx: labels("i j"),
            ly: labels("j k"),
            lz: labels("i k"),
            join: JoinOp::SquaredDiff,
            agg: AggOp::Sum,
        };
        check_equiv(&l2, &[&x, &y], &[2, 4, 2]);
        // L-inf with Max — max aggregation across tiles must also hold
        let linf = EinSum::Binary {
            lx: labels("i j"),
            ly: labels("j k"),
            lz: labels("i k"),
            join: JoinOp::AbsDiff,
            agg: AggOp::Max,
        };
        check_equiv(&linf, &[&x, &y], &[3, 2, 4]);
    }

    #[test]
    fn broadcast_join_decomposes() {
        // softmax normalization: Y_ij <- E_ij / S_i; i co-partitioned.
        let e = Tensor::random(&[8, 6], 7);
        let s = Tensor::random(&[8], 8).reshape(vec![8]).unwrap();
        let op = EinSum::Binary {
            lx: labels("i j"),
            ly: labels("i"),
            lz: labels("i j"),
            join: JoinOp::Div,
            agg: AggOp::Sum,
        };
        for d in [[1usize, 1], [4, 2], [8, 3], [2, 6]] {
            check_equiv(&op, &[&e, &s], &d);
        }
    }

    #[test]
    fn unary_reduce_decomposes() {
        let x = Tensor::random(&[9, 12], 9);
        let op = EinSum::reduce(labels("i j"), labels("i"), AggOp::Max);
        for d in [[1usize, 1], [3, 4], [9, 12], [2, 5]] {
            check_equiv(&op, &[&x], &d);
        }
    }

    #[test]
    fn unary_map_transpose_decomposes() {
        let x = Tensor::random(&[6, 4], 10);
        let op = EinSum::Unary {
            lx: labels("i j"),
            lz: labels("j i"),
            op: UnaryOp::Exp,
            agg: AggOp::Sum,
        };
        for d in [[2usize, 2], [3, 4], [1, 1]] {
            check_equiv(&op, &[&x], &d);
        }
    }

    #[test]
    fn rank3_contraction_decomposes() {
        // Z_ik <- sum_{b,j} X_ijb Y_jbk
        let x = Tensor::random(&[4, 6, 2], 11);
        let y = Tensor::random(&[6, 2, 5], 12);
        let op = EinSum::contraction(labels("i j b"), labels("j b k"), labels("i k"));
        // unique labels: [i, j, b, k]
        for d in [[1usize, 1, 1, 1], [2, 3, 2, 5], [4, 2, 1, 1]] {
            check_equiv(&op, &[&x, &y], &d);
        }
    }

    #[test]
    fn join_rejects_non_copartitioned() {
        let x = Tensor::random(&[8, 8], 1);
        let y = Tensor::random(&[8, 8], 2);
        let rx = TensorRelation::partition(&x, &[2, 4]).unwrap();
        let ry = TensorRelation::partition(&y, &[2, 2]).unwrap(); // j: 4 vs 2
        let mut k = |a: &Tensor, _b: &Tensor| Ok(a.clone());
        assert!(join(&rx, &ry, &labels("i j"), &labels("j k"), &mut k).is_err());
    }

    #[test]
    fn repartition_preserves_equivalence() {
        let t = Tensor::random(&[8, 12], 13);
        let r = TensorRelation::partition(&t, &[2, 3]).unwrap();
        let r2 = repartition(&r, &[4, 2]).unwrap();
        assert_eq!(r2.part(), &[4, 2]);
        assert_eq!(r2.assemble().unwrap(), t);
    }

    #[test]
    fn aggregate_groups_correctly() {
        // keys over [i, j] with part [2, 2]; aggregate j out with Sum.
        let tuples = vec![
            (vec![0, 0], Tensor::full(&[2], 1.0)),
            (vec![0, 1], Tensor::full(&[2], 2.0)),
            (vec![1, 0], Tensor::full(&[2], 3.0)),
            (vec![1, 1], Tensor::full(&[2], 4.0)),
        ];
        let out = aggregate(tuples, &labels("i j"), &labels("i"), AggOp::Sum).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.data(), &[3.0, 3.0]);
        assert_eq!(out[1].1.data(), &[7.0, 7.0]);
    }

    use crate::einsum::label::project;
}

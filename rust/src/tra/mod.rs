//! The tensor-relational algebra (TRA) of Section 4: relations mapping
//! integer key vectors to *sub-tensors*, with three operations — `join`,
//! `aggregation`, `repartition` — sufficient to implement any EinSum
//! expression once a partitioning vector `d` is chosen.
//!
//! This module is the *semantic* (single-process, in-memory) implementation
//! used as an executable specification: [`ops::eval_einsum_tra`] rewrites an
//! EinSum into TRA exactly as Eq. 5 of the paper and must agree with direct
//! dense evaluation for every valid `d` (a property the test suite checks
//! exhaustively and via proptest). The *distributed* implementation of the
//! same algebra — where tuples live on workers and movement is accounted —
//! is [`crate::taskgraph`] + [`crate::sim`].
//!
//! Between the two sits the TRA **IR** ([`program`]): the relational
//! program of Eq. 5 reified as a typed DAG that the compiler builds from
//! `(EinGraph, Plan)`, rewrites with an optimizing pass pipeline
//! ([`passes`]), and only then lowers to a task graph. See
//! [`program::TraProgram`] and [`passes::PassManager`].

pub mod ops;
pub mod passes;
pub mod program;
pub mod relation;

pub use ops::{
    aggregate, eval_einsum_tra, join, repartition, repartition_with_stats, RepartStats,
};
pub use passes::{PassKind, PassLog, PassManager, PassSelector};
pub use program::{from_plan, RelId, RelSchema, TraOp, TraProgram};
pub use relation::TensorRelation;

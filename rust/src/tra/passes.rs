//! The optimizing pass pipeline over the TRA IR
//! ([`crate::tra::program::TraProgram`]).
//!
//! Passes are ordered, individually toggleable rewrites with a per-pass
//! change log. The canonical order is:
//!
//! 1. **`elide-identity-repart`** — remove `Π` nodes whose source and
//!    target parts are equal (the direct lowering's inline `have == need`
//!    check, generalized to an explicit IR rewrite). Task-graph neutral.
//! 2. **`alias-refinement-repart`** — mark refinement `Π`s (every needed
//!    tile contained in one producer tile) as aliases so they emit
//!    **zero** tasks; consuming kernels slice the producer tile directly.
//!    Bitwise-neutral to execution (the kernel reads the identical
//!    sub-view the repart task would have built). Note the *modeled*
//!    ledger trades granularity for tasks: a remote consumer is charged
//!    the whole coarse producer tile instead of its refined sub-tile, so
//!    `bytes_moved` can rise even as task counts fall — the win is task
//!    count, scheduling overhead, and zero-copy local reads.
//! 3. **`agg-tree`** — rewrite serial-fold aggregations whose group
//!    exceeds the tree arity into balanced reduction trees, bounding any
//!    task's fan-in by the arity. Deterministic, but float `Sum` folds
//!    associate differently than the serial chain (bit-different, still
//!    within dense-reference tolerance).
//! 4. **`dead-rel-elim`** — drop nodes whose relations nothing consumes.
//!
//! Selection is driven by a [`PassSelector`] (`--passes all|none|safe`
//! or a comma-separated subset on the CLI), carried by both
//! `DriverConfig` and `PlannerConfig`. The default, [`PassSelector::Safe`],
//! enables only the task-graph-neutral passes, so default lowering stays
//! byte-identical to the pre-IR pipeline; `all` opts into the
//! re-associating / re-routing rewrites.
//!
//! ```
//! use eindecomp::tra::passes::{PassManager, PassSelector};
//! let sel: PassSelector = "elide-identity-repart,agg-tree".parse()?;
//! let mgr = PassManager::new(&sel);
//! assert_eq!(mgr.names(), vec!["elide-identity-repart", "agg-tree"]);
//! # Ok::<(), eindecomp::Error>(())
//! ```

use crate::error::{Error, Result};
use crate::tra::program::TraProgram;
use crate::util::Json;

/// Default fan-in bound the `agg-tree` pass rewrites toward.
pub const DEFAULT_AGG_TREE_ARITY: usize = 4;

/// One rewrite of the pipeline, in canonical order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PassKind {
    ElideIdentityRepart,
    AliasRefinementRepart,
    AggTree,
    DeadRelElim,
}

impl PassKind {
    /// Every pass, in canonical pipeline order.
    pub const ALL: [PassKind; 4] = [
        PassKind::ElideIdentityRepart,
        PassKind::AliasRefinementRepart,
        PassKind::AggTree,
        PassKind::DeadRelElim,
    ];

    /// The task-graph-neutral subset enabled by default.
    pub const SAFE: [PassKind; 2] = [PassKind::ElideIdentityRepart, PassKind::DeadRelElim];

    pub fn name(self) -> &'static str {
        match self {
            PassKind::ElideIdentityRepart => "elide-identity-repart",
            PassKind::AliasRefinementRepart => "alias-refinement-repart",
            PassKind::AggTree => "agg-tree",
            PassKind::DeadRelElim => "dead-rel-elim",
        }
    }

    pub fn from_name(name: &str) -> Option<PassKind> {
        PassKind::ALL.into_iter().find(|k| k.name() == name)
    }
}

/// Which passes to run — the `passes` field of `DriverConfig` /
/// `PlannerConfig` and the CLI's `--passes` flag.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum PassSelector {
    /// Every pass, canonical order.
    All,
    /// No passes: the raw Eq.-5 program, lowered as-is (still
    /// task-graph-identical to the direct lowering).
    None,
    /// The default: only task-graph-neutral cleanups
    /// ([`PassKind::SAFE`]), so default lowering reproduces the pre-IR
    /// pipeline byte for byte.
    #[default]
    Safe,
    /// An explicit subset (run in canonical order regardless of the
    /// order given).
    Custom(Vec<PassKind>),
}

impl PassSelector {
    /// The selected passes, in canonical order, deduplicated.
    pub fn kinds(&self) -> Vec<PassKind> {
        match self {
            PassSelector::All => PassKind::ALL.to_vec(),
            PassSelector::None => vec![],
            PassSelector::Safe => PassKind::SAFE.to_vec(),
            PassSelector::Custom(ks) => PassKind::ALL
                .into_iter()
                .filter(|k| ks.contains(k))
                .collect(),
        }
    }

    /// Build the pass manager this selector describes.
    pub fn manager(&self) -> PassManager {
        PassManager::new(self)
    }
}

impl std::str::FromStr for PassSelector {
    type Err = Error;

    /// Parse `all`, `none`, `safe`/`default`, or a comma-separated list
    /// of pass names.
    fn from_str(s: &str) -> Result<PassSelector> {
        match s.trim() {
            "all" => Ok(PassSelector::All),
            "none" => Ok(PassSelector::None),
            "safe" | "default" => Ok(PassSelector::Safe),
            csv => {
                let mut kinds = Vec::new();
                for part in csv.split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        continue;
                    }
                    let k = PassKind::from_name(part).ok_or_else(|| {
                        Error::Parse(format!(
                            "unknown pass {part:?} (try all, none, safe, or a comma list of: {})",
                            PassKind::ALL.map(|k| k.name()).join(", ")
                        ))
                    })?;
                    kinds.push(k);
                }
                Ok(PassSelector::Custom(kinds))
            }
        }
    }
}

impl std::fmt::Display for PassSelector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PassSelector::All => f.write_str("all"),
            PassSelector::None => f.write_str("none"),
            PassSelector::Safe => f.write_str("safe"),
            PassSelector::Custom(ks) => {
                let names: Vec<&str> = PassKind::ALL
                    .into_iter()
                    .filter(|k| ks.contains(k))
                    .map(|k| k.name())
                    .collect();
                f.write_str(&names.join(","))
            }
        }
    }
}

/// What one pass did to one program.
#[derive(Clone, Debug)]
pub struct PassEntry {
    pub pass: String,
    /// Number of rewrites applied (0 = ran but found nothing).
    pub changes: usize,
    /// One human-readable line per rewrite.
    pub notes: Vec<String>,
}

/// Ordered per-pass change log of one [`PassManager::run`].
#[derive(Clone, Debug, Default)]
pub struct PassLog {
    pub entries: Vec<PassEntry>,
}

impl PassLog {
    /// Total rewrites across all passes.
    pub fn total_changes(&self) -> usize {
        self.entries.iter().map(|e| e.changes).sum()
    }

    /// Names of the passes that ran (whether or not they changed
    /// anything).
    pub fn applied(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.pass.clone()).collect()
    }

    pub fn render(&self) -> String {
        if self.entries.is_empty() {
            return "passes: (none)\n".into();
        }
        let mut s = String::from("passes:\n");
        for e in &self.entries {
            s.push_str(&format!("  {:<24} {} change(s)\n", e.pass, e.changes));
            for n in &e.notes {
                s.push_str(&format!("    - {n}\n"));
            }
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    Json::Obj(vec![
                        ("pass".into(), Json::str(e.pass.clone())),
                        ("changes".into(), Json::num(e.changes as f64)),
                        (
                            "notes".into(),
                            Json::Arr(e.notes.iter().map(|n| Json::str(n.clone())).collect()),
                        ),
                    ])
                })
                .collect(),
        )
    }
}

impl std::fmt::Display for PassLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

/// Runs an ordered, toggleable pass list over a [`TraProgram`], logging
/// every change.
#[derive(Clone, Debug)]
pub struct PassManager {
    kinds: Vec<PassKind>,
    /// Fan-in bound for the `agg-tree` rewrite (clamped to >= 2).
    pub agg_tree_arity: usize,
}

impl PassManager {
    pub fn new(selector: &PassSelector) -> PassManager {
        PassManager {
            kinds: selector.kinds(),
            agg_tree_arity: DEFAULT_AGG_TREE_ARITY,
        }
    }

    pub fn all() -> PassManager {
        PassManager::new(&PassSelector::All)
    }

    pub fn none() -> PassManager {
        PassManager::new(&PassSelector::None)
    }

    /// Override the `agg-tree` fan-in bound.
    pub fn with_agg_tree_arity(mut self, arity: usize) -> PassManager {
        self.agg_tree_arity = arity.max(2);
        self
    }

    /// Names of the passes this manager will run, in order.
    pub fn names(&self) -> Vec<String> {
        self.kinds.iter().map(|k| k.name().to_string()).collect()
    }

    /// Run every selected pass, in canonical order, and return the log.
    pub fn run(&self, prog: &mut TraProgram) -> PassLog {
        let mut log = PassLog::default();
        for k in &self.kinds {
            let notes = match k {
                PassKind::ElideIdentityRepart => prog.elide_identity_reparts(),
                PassKind::AliasRefinementRepart => prog.alias_refinement_reparts(),
                PassKind::AggTree => prog.agg_tree(self.agg_tree_arity),
                PassKind::DeadRelElim => prog.dead_rel_elim(),
            };
            log.entries.push(PassEntry {
                pass: k.name().to_string(),
                changes: notes.len(),
                notes,
            });
        }
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::Plan;
    use crate::einsum::expr::EinSum;
    use crate::einsum::graph::EinGraph;
    use crate::einsum::label::labels;
    use crate::tra::program::from_plan;

    #[test]
    fn selector_parses_and_roundtrips() {
        assert_eq!("all".parse::<PassSelector>().unwrap(), PassSelector::All);
        assert_eq!("none".parse::<PassSelector>().unwrap(), PassSelector::None);
        assert_eq!("safe".parse::<PassSelector>().unwrap(), PassSelector::Safe);
        assert_eq!(
            "default".parse::<PassSelector>().unwrap(),
            PassSelector::Safe
        );
        let custom: PassSelector = "agg-tree,elide-identity-repart".parse().unwrap();
        // canonical order regardless of the order given
        assert_eq!(
            custom.kinds(),
            vec![PassKind::ElideIdentityRepart, PassKind::AggTree]
        );
        assert_eq!(custom.to_string(), "elide-identity-repart,agg-tree");
        assert!("nonsense-pass".parse::<PassSelector>().is_err());
        assert_eq!(PassSelector::default(), PassSelector::Safe);
    }

    #[test]
    fn safe_subset_is_task_graph_neutral_by_construction() {
        assert_eq!(
            PassSelector::Safe.kinds(),
            vec![PassKind::ElideIdentityRepart, PassKind::DeadRelElim]
        );
    }

    #[test]
    fn manager_runs_in_order_and_logs() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let z = g
            .add(
                "Z",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(z, vec![1, 8, 2]); // 8-way aggregation groups
        plan.finalize_inputs(&g);
        let mut prog = from_plan(&g, &plan).unwrap();
        let mgr = PassManager::all().with_agg_tree_arity(2);
        let log = mgr.run(&mut prog);
        assert_eq!(
            log.applied(),
            vec![
                "elide-identity-repart",
                "alias-refinement-repart",
                "agg-tree",
                "dead-rel-elim"
            ]
        );
        // identity reparts elided (2 input edges), agg rewritten to a tree
        assert_eq!(log.entries[0].changes, 2);
        assert_eq!(log.entries[2].changes, 1);
        assert_eq!(log.entries[3].changes, 0);
        assert!(log.total_changes() >= 3);
        let text = log.render();
        assert!(text.contains("agg-tree"));
        assert!(text.contains("tree"));
        assert!(log.to_json().render().contains("\"pass\""));
    }

    #[test]
    fn none_manager_is_empty() {
        let mgr = PassManager::none();
        assert!(mgr.names().is_empty());
        let mut g = EinGraph::new();
        let a = g.input("A", vec![4, 4]);
        g.add("R", EinSum::map(labels("i j"), crate::einsum::expr::UnaryOp::Relu), vec![a])
            .unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(g.by_name("R").unwrap(), vec![2, 2]);
        plan.finalize_inputs(&g);
        let mut prog = from_plan(&g, &plan).unwrap();
        let n = prog.len();
        let log = mgr.run(&mut prog);
        assert!(log.entries.is_empty());
        assert_eq!(prog.len(), n);
    }
}

//! The optimizing pass pipeline over the TRA IR
//! ([`crate::tra::program::TraProgram`]).
//!
//! Passes are ordered, individually toggleable rewrites with a per-pass
//! change log and task/byte deltas. The canonical order is:
//!
//! 1. **`propagate-partitions`** — rewrite input `Partition` layouts to
//!    the consumer-need layout the `decomp/cost` repartition model scores
//!    cheapest (summed over all consumers), eliding whole repartition
//!    chains at the source. Input placement is offline in the paper's
//!    model, so this is free; bitwise-neutral.
//! 2. **`elide-identity-repart`** — remove `Π` nodes whose source and
//!    target parts are equal (the direct lowering's inline `have == need`
//!    check, generalized to an explicit IR rewrite — and the pass that
//!    cashes in `propagate-partitions`' newly-identity `Π`s). Task-graph
//!    neutral.
//! 3. **`cse`** — value-number the program and merge duplicate
//!    `Repartition`/`Join`/`Aggregate`/`ReKey` chains; duplicate vertex
//!    terminals become zero-task `Reuse` markers. Joins compare frozen
//!    structural signatures ([`crate::einsum::canon`]) — or
//!    label-name-extended ones under label-role-sensitive strategies, so
//!    same-shape vertices whose label roles differ never merge.
//!    Bitwise-neutral (duplicates compute identical bytes).
//! 4. **`alias-refinement-repart`** — mark refinement `Π`s (every needed
//!    tile contained in one producer tile) as aliases so they emit
//!    **zero** tasks; consuming kernels slice the producer tile directly.
//!    Bitwise-neutral to execution (the kernel reads the identical
//!    sub-view the repart task would have built). Note the *modeled*
//!    ledger trades granularity for tasks: a remote consumer is charged
//!    the whole coarse producer tile instead of its refined sub-tile, so
//!    `bytes_moved` can rise even as task counts fall — the win is task
//!    count, scheduling overhead, and zero-copy local reads.
//! 5. **`fuse-epilogue`** — fold single-consumer elementwise map
//!    vertices into their producer `Join`'s kernel epilogue (applied
//!    after the GEMM `alpha`/`beta` step, see `runtime/gemm.rs`),
//!    deleting the map's kernel tasks outright. Bitwise-neutral: the
//!    same pointwise op hits the same tile elements.
//! 6. **`agg-tree`** — rewrite serial-fold aggregations whose group
//!    exceeds the tree arity into balanced reduction trees, bounding any
//!    task's fan-in by the arity. Deterministic, but float `Sum` folds
//!    associate differently than the serial chain (bit-different, still
//!    within dense-reference tolerance).
//! 7. **`lower-collectives`** — lift O(p²) point-to-point patterns into
//!    first-class collectives: broadcast-shaped `Π`s become `AllGather`
//!    relay chains, remaining serial folds become `ReduceScatter`
//!    chains, and a fold feeding a single plain `Π` fuses into an
//!    `AllReduce`. With the default `Ring` schedules every emitted chain
//!    is bitwise-identical to the point-to-point baseline (relays are
//!    pure copies; the ring reduce is the serial left fold). A `Tree`
//!    *reduce* schedule re-associates float `Sum` like `agg-tree` does
//!    and is opt-in only ([`PassManager::with_reduce_schedule`]).
//! 8. **`dead-rel-elim`** — drop nodes whose relations nothing consumes.
//!
//! Selection is driven by a [`PassSelector`] (`--passes all|none|safe`
//! or a comma-separated subset on the CLI), carried by both
//! `DriverConfig` and `PlannerConfig`. The default, [`PassSelector::Safe`],
//! enables only the task-graph-neutral passes, so default lowering stays
//! byte-identical to the pre-IR pipeline; `all` opts into the
//! re-associating / re-routing rewrites.
//!
//! ```
//! use eindecomp::tra::passes::{PassManager, PassSelector};
//! let sel: PassSelector = "elide-identity-repart,agg-tree".parse()?;
//! let mgr = PassManager::new(&sel);
//! assert_eq!(mgr.names(), vec!["elide-identity-repart", "agg-tree"]);
//! # Ok::<(), eindecomp::Error>(())
//! ```

use crate::error::{Error, Result};
use crate::sim::network::Topology;
use crate::tra::program::{CollectiveSchedule, TraProgram};
use crate::util::Json;

/// Default fan-in bound the `agg-tree` pass rewrites toward.
pub const DEFAULT_AGG_TREE_ARITY: usize = 4;

/// One rewrite of the pipeline, in canonical order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PassKind {
    PropagatePartitions,
    ElideIdentityRepart,
    Cse,
    AliasRefinementRepart,
    FuseEpilogue,
    AggTree,
    LowerCollectives,
    DeadRelElim,
}

impl PassKind {
    /// Every pass, in canonical pipeline order. The order is load-bearing:
    /// `propagate-partitions` creates identity `Π`s for
    /// `elide-identity-repart` to remove; `cse` and `fuse-epilogue` both
    /// need those one-hop chains collapsed so producers and consumers
    /// read each other's relations directly.
    pub const ALL: [PassKind; 8] = [
        PassKind::PropagatePartitions,
        PassKind::ElideIdentityRepart,
        PassKind::Cse,
        PassKind::AliasRefinementRepart,
        PassKind::FuseEpilogue,
        PassKind::AggTree,
        PassKind::LowerCollectives,
        PassKind::DeadRelElim,
    ];

    /// The task-graph-neutral subset enabled by default.
    pub const SAFE: [PassKind; 2] = [PassKind::ElideIdentityRepart, PassKind::DeadRelElim];

    pub fn name(self) -> &'static str {
        match self {
            PassKind::PropagatePartitions => "propagate-partitions",
            PassKind::ElideIdentityRepart => "elide-identity-repart",
            PassKind::Cse => "cse",
            PassKind::AliasRefinementRepart => "alias-refinement-repart",
            PassKind::FuseEpilogue => "fuse-epilogue",
            PassKind::AggTree => "agg-tree",
            PassKind::LowerCollectives => "lower-collectives",
            PassKind::DeadRelElim => "dead-rel-elim",
        }
    }

    pub fn from_name(name: &str) -> Option<PassKind> {
        PassKind::ALL.into_iter().find(|k| k.name() == name)
    }
}

/// Which passes to run — the `passes` field of `DriverConfig` /
/// `PlannerConfig` and the CLI's `--passes` flag.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum PassSelector {
    /// Every pass, canonical order.
    All,
    /// No passes: the raw Eq.-5 program, lowered as-is (still
    /// task-graph-identical to the direct lowering).
    None,
    /// The default: only task-graph-neutral cleanups
    /// ([`PassKind::SAFE`]), so default lowering reproduces the pre-IR
    /// pipeline byte for byte.
    #[default]
    Safe,
    /// An explicit subset (run in canonical order regardless of the
    /// order given).
    Custom(Vec<PassKind>),
}

impl PassSelector {
    /// The selected passes, in canonical order, deduplicated.
    pub fn kinds(&self) -> Vec<PassKind> {
        match self {
            PassSelector::All => PassKind::ALL.to_vec(),
            PassSelector::None => vec![],
            PassSelector::Safe => PassKind::SAFE.to_vec(),
            PassSelector::Custom(ks) => PassKind::ALL
                .into_iter()
                .filter(|k| ks.contains(k))
                .collect(),
        }
    }

    /// Build the pass manager this selector describes.
    pub fn manager(&self) -> PassManager {
        PassManager::new(self)
    }
}

impl std::str::FromStr for PassSelector {
    type Err = Error;

    /// Parse `all`, `none`, `safe`/`default`, or a comma-separated list
    /// of pass names. Malformed lists are rejected, not tolerated: an
    /// empty segment (trailing comma, `a,,b`, or an empty string) and a
    /// repeated pass name are both errors, each listing the valid names —
    /// a silently-dropped segment would run a different pipeline than the
    /// one the user typed.
    fn from_str(s: &str) -> Result<PassSelector> {
        let valid = || PassKind::ALL.map(|k| k.name()).join(", ");
        match s.trim() {
            "all" => Ok(PassSelector::All),
            "none" => Ok(PassSelector::None),
            "safe" | "default" => Ok(PassSelector::Safe),
            csv => {
                let mut kinds = Vec::new();
                for part in csv.split(',') {
                    let part = part.trim();
                    if part.is_empty() {
                        return Err(Error::Parse(format!(
                            "empty pass name in {csv:?} (try all, none, safe, \
                             or a comma list of: {})",
                            valid()
                        )));
                    }
                    let k = PassKind::from_name(part).ok_or_else(|| {
                        Error::Parse(format!(
                            "unknown pass {part:?} (try all, none, safe, or a comma list of: {})",
                            valid()
                        ))
                    })?;
                    if kinds.contains(&k) {
                        return Err(Error::Parse(format!(
                            "duplicate pass {part:?} (each of {} may appear once)",
                            valid()
                        )));
                    }
                    kinds.push(k);
                }
                Ok(PassSelector::Custom(kinds))
            }
        }
    }
}

impl std::fmt::Display for PassSelector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PassSelector::All => f.write_str("all"),
            PassSelector::None => f.write_str("none"),
            PassSelector::Safe => f.write_str("safe"),
            PassSelector::Custom(ks) => {
                let names: Vec<&str> = PassKind::ALL
                    .into_iter()
                    .filter(|k| ks.contains(k))
                    .map(|k| k.name())
                    .collect();
                f.write_str(&names.join(","))
            }
        }
    }
}

/// What one pass did to one program.
#[derive(Clone, Debug)]
pub struct PassEntry {
    pub pass: String,
    /// Number of rewrites applied (0 = ran but found nothing).
    pub changes: usize,
    /// Change in the number of tasks the program will emit
    /// ([`TraProgram::task_stats`] after minus before). Negative =
    /// tasks saved; `agg-tree` is legitimately positive (it trades task
    /// count for bounded fan-in).
    pub tasks_delta: i64,
    /// Change in total modeled repartition bytes, same convention.
    pub repart_bytes_delta: i64,
    /// One human-readable line per rewrite.
    pub notes: Vec<String>,
}

/// Ordered per-pass change log of one [`PassManager::run`].
#[derive(Clone, Debug, Default)]
pub struct PassLog {
    pub entries: Vec<PassEntry>,
}

impl PassLog {
    /// Total rewrites across all passes.
    pub fn total_changes(&self) -> usize {
        self.entries.iter().map(|e| e.changes).sum()
    }

    /// Names of the passes that ran (whether or not they changed
    /// anything).
    pub fn applied(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.pass.clone()).collect()
    }

    pub fn render(&self) -> String {
        if self.entries.is_empty() {
            return "passes: (none)\n".into();
        }
        let mut s = String::from("passes:\n");
        for e in &self.entries {
            s.push_str(&format!(
                "  {:<24} {} change(s), tasks {:+}, repart bytes {:+}\n",
                e.pass, e.changes, e.tasks_delta, e.repart_bytes_delta
            ));
            for n in &e.notes {
                s.push_str(&format!("    - {n}\n"));
            }
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|e| {
                    Json::Obj(vec![
                        ("pass".into(), Json::str(e.pass.clone())),
                        ("changes".into(), Json::num(e.changes as f64)),
                        ("tasks_delta".into(), Json::num(e.tasks_delta as f64)),
                        (
                            "repart_bytes_delta".into(),
                            Json::num(e.repart_bytes_delta as f64),
                        ),
                        (
                            "notes".into(),
                            Json::Arr(e.notes.iter().map(|n| Json::str(n.clone())).collect()),
                        ),
                    ])
                })
                .collect(),
        )
    }
}

impl std::fmt::Display for PassLog {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

/// Runs an ordered, toggleable pass list over a [`TraProgram`], logging
/// every change.
#[derive(Clone, Debug)]
pub struct PassManager {
    kinds: Vec<PassKind>,
    /// Fan-in bound for the `agg-tree` rewrite (clamped to >= 2).
    pub agg_tree_arity: usize,
    /// When set, `cse` compares joins by label-name-extended signatures —
    /// required under strategies that plan by label *role* (data-parallel,
    /// megatron, sequence, attention-head), where same-shape vertices with
    /// different roles must not merge. Off by default: purely structural
    /// planners treat renamed-but-isomorphic chains as equal, which is
    /// both safe and strictly more merging.
    pub label_sensitive: bool,
    /// Relay schedule the `lower-collectives` pass gives `AllGather`
    /// chains (and the gather phase of `AllReduce`). Bitwise-neutral
    /// either way — relays are pure copies — so topology only steers the
    /// cost/latency shape: `Ring` by default and on hierarchical
    /// topologies (bandwidth-optimal; consecutive members land on
    /// neighboring workers, keeping hops on the fast inner links),
    /// `Tree` on explicitly-flat ones (fewer serialized steps).
    pub gather_schedule: CollectiveSchedule,
    /// Fold schedule for `ReduceScatter` / the reduce phase of
    /// `AllReduce`. `Ring` (default) is the serial left fold,
    /// bit-identical to the baseline; `Tree` re-associates float `Sum`
    /// and is reachable only through
    /// [`PassManager::with_reduce_schedule`] — the same opt-in contract
    /// as `agg-tree`.
    pub reduce_schedule: CollectiveSchedule,
}

impl PassManager {
    pub fn new(selector: &PassSelector) -> PassManager {
        PassManager {
            kinds: selector.kinds(),
            agg_tree_arity: DEFAULT_AGG_TREE_ARITY,
            label_sensitive: false,
            gather_schedule: CollectiveSchedule::Ring,
            reduce_schedule: CollectiveSchedule::Ring,
        }
    }

    pub fn all() -> PassManager {
        PassManager::new(&PassSelector::All)
    }

    pub fn none() -> PassManager {
        PassManager::new(&PassSelector::None)
    }

    /// Override the `agg-tree` fan-in bound.
    pub fn with_agg_tree_arity(mut self, arity: usize) -> PassManager {
        self.agg_tree_arity = arity.max(2);
        self
    }

    /// Set whether `cse` must honor label roles (see
    /// [`PassManager::label_sensitive`]).
    pub fn with_label_sensitivity(mut self, on: bool) -> PassManager {
        self.label_sensitive = on;
        self
    }

    /// Pick the `lower-collectives` gather schedule for a worker
    /// topology: `Ring` relays on hierarchical topologies (member order
    /// follows worker order, so ring hops mostly stay on the fast inner
    /// links), an explicit `Tree` fan-out sized by
    /// [`Topology::gather_arity`] on flat ones (every hop costs the
    /// same, so fewer serialized steps win). The reduce schedule is
    /// never changed here — see [`PassManager::with_reduce_schedule`].
    pub fn with_topology(mut self, topo: &Topology) -> PassManager {
        self.gather_schedule = if topo.is_flat() {
            CollectiveSchedule::Tree {
                arity: topo.gather_arity(),
            }
        } else {
            CollectiveSchedule::Ring
        };
        self
    }

    /// Opt into a non-default fold schedule for collective reductions.
    /// A `Tree` schedule re-associates float `Sum` (the agg-tree
    /// caveat), so it is never selected implicitly.
    pub fn with_reduce_schedule(mut self, schedule: CollectiveSchedule) -> PassManager {
        self.reduce_schedule = schedule;
        self
    }

    /// Names of the passes this manager will run, in order.
    pub fn names(&self) -> Vec<String> {
        self.kinds.iter().map(|k| k.name().to_string()).collect()
    }

    /// Run every selected pass, in canonical order, and return the log.
    /// Each entry carries the pass's task-count and repartition-byte
    /// deltas, measured by [`TraProgram::task_stats`] around the rewrite.
    pub fn run(&self, prog: &mut TraProgram) -> PassLog {
        let mut log = PassLog::default();
        for k in &self.kinds {
            let before = prog.task_stats();
            let notes = match k {
                PassKind::PropagatePartitions => prog.propagate_partitions(),
                PassKind::ElideIdentityRepart => prog.elide_identity_reparts(),
                PassKind::Cse => prog.cse(self.label_sensitive),
                PassKind::AliasRefinementRepart => prog.alias_refinement_reparts(),
                PassKind::FuseEpilogue => prog.fuse_epilogues(),
                PassKind::AggTree => prog.agg_tree(self.agg_tree_arity),
                PassKind::LowerCollectives => {
                    prog.lower_collectives(self.gather_schedule, self.reduce_schedule)
                }
                PassKind::DeadRelElim => prog.dead_rel_elim(),
            };
            let after = prog.task_stats();
            log.entries.push(PassEntry {
                pass: k.name().to_string(),
                changes: notes.len(),
                tasks_delta: after.tasks as i64 - before.tasks as i64,
                repart_bytes_delta: after.repart_bytes as i64 - before.repart_bytes as i64,
                notes,
            });
        }
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::Plan;
    use crate::einsum::expr::EinSum;
    use crate::einsum::graph::EinGraph;
    use crate::einsum::label::labels;
    use crate::tra::program::from_plan;

    #[test]
    fn selector_parses_and_roundtrips() {
        assert_eq!("all".parse::<PassSelector>().unwrap(), PassSelector::All);
        assert_eq!("none".parse::<PassSelector>().unwrap(), PassSelector::None);
        assert_eq!("safe".parse::<PassSelector>().unwrap(), PassSelector::Safe);
        assert_eq!(
            "default".parse::<PassSelector>().unwrap(),
            PassSelector::Safe
        );
        let custom: PassSelector = "agg-tree,elide-identity-repart".parse().unwrap();
        // canonical order regardless of the order given
        assert_eq!(
            custom.kinds(),
            vec![PassKind::ElideIdentityRepart, PassKind::AggTree]
        );
        assert_eq!(custom.to_string(), "elide-identity-repart,agg-tree");
        assert_eq!(PassSelector::default(), PassSelector::Safe);
    }

    #[test]
    fn selector_rejects_malformed_csv() {
        let unknown = "nonsense-pass".parse::<PassSelector>().unwrap_err();
        assert!(unknown.to_string().contains("unknown pass"));
        // every valid name is listed in the error
        for k in PassKind::ALL {
            assert!(unknown.to_string().contains(k.name()), "{k:?}");
        }
        let dup = "agg-tree,cse,agg-tree".parse::<PassSelector>().unwrap_err();
        assert!(dup.to_string().contains("duplicate pass \"agg-tree\""));
        for bad in ["", "agg-tree,", "agg-tree,,cse", " , "] {
            let e = bad.parse::<PassSelector>().unwrap_err();
            assert!(e.to_string().contains("empty pass name"), "{bad:?}: {e}");
        }
    }

    #[test]
    fn safe_subset_is_task_graph_neutral_by_construction() {
        assert_eq!(
            PassSelector::Safe.kinds(),
            vec![PassKind::ElideIdentityRepart, PassKind::DeadRelElim]
        );
    }

    #[test]
    fn manager_runs_in_order_and_logs() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let z = g
            .add(
                "Z",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(z, vec![1, 8, 2]); // 8-way aggregation groups
        plan.finalize_inputs(&g);
        let mut prog = from_plan(&g, &plan).unwrap();
        let mgr = PassManager::all().with_agg_tree_arity(2);
        let log = mgr.run(&mut prog);
        assert_eq!(
            log.applied(),
            vec![
                "propagate-partitions",
                "elide-identity-repart",
                "cse",
                "alias-refinement-repart",
                "fuse-epilogue",
                "agg-tree",
                "lower-collectives",
                "dead-rel-elim"
            ]
        );
        // inputs already sit at the consumer layout (finalize_inputs), so
        // propagation finds nothing; identity reparts elided (2 input
        // edges); agg rewritten to a tree — which lower-collectives then
        // leaves alone (tree'd folds are agg-tree's, and no plain Π's
        // remain to lift)
        assert_eq!(log.entries[0].changes, 0);
        assert_eq!(log.entries[1].changes, 2);
        assert_eq!(log.entries[5].changes, 1);
        assert_eq!(log.entries[6].changes, 0);
        assert_eq!(log.entries[7].changes, 0);
        assert!(log.total_changes() >= 3);
        // identity reparts already emitted zero tasks, so eliding them is
        // task-neutral; the tree rewrite trades tasks for bounded fan-in
        assert_eq!(log.entries[1].tasks_delta, 0);
        assert!(log.entries[5].tasks_delta > 0);
        assert_eq!(log.entries[5].repart_bytes_delta, 0);
        let text = log.render();
        assert!(text.contains("agg-tree"));
        assert!(text.contains("tree"));
        assert!(text.contains("tasks +"));
        let json = log.to_json().render();
        assert!(json.contains("\"pass\""));
        assert!(json.contains("\"tasks_delta\""));
        assert!(json.contains("\"repart_bytes_delta\""));
    }

    #[test]
    fn none_manager_is_empty() {
        let mgr = PassManager::none();
        assert!(mgr.names().is_empty());
        let mut g = EinGraph::new();
        let a = g.input("A", vec![4, 4]);
        g.add("R", EinSum::map(labels("i j"), crate::einsum::expr::UnaryOp::Relu), vec![a])
            .unwrap();
        let mut plan = Plan::default();
        plan.parts.insert(g.by_name("R").unwrap(), vec![2, 2]);
        plan.finalize_inputs(&g);
        let mut prog = from_plan(&g, &plan).unwrap();
        let n = prog.len();
        let log = mgr.run(&mut prog);
        assert!(log.entries.is_empty());
        assert_eq!(prog.len(), n);
    }
}

//! Tensor relations: `R : I(d) -> (I(b/d) -> R)` (paper §4.1).
//!
//! A [`TensorRelation`] with bound `b` and partitioning vector `d` stores a
//! tensor of bound `b` as `prod(d)` keyed sub-tensors. The paper assumes
//! `d` divides `b` exactly; real bounds (e.g. AmazonCat's 14,588 labels)
//! rarely oblige, so we use *balanced* tiling: along a dimension of size
//! `b` split `d` ways, tile `i` has size `b/d + (i < b mod d)`. When `d | b`
//! this degenerates to the paper's uniform `b/d` tiles, and all tiles that
//! share a co-partitioned label always agree on size.
//!
//! Tiles are stored as [`TensorView`]s: [`TensorRelation::partition`]
//! costs O(1) per tile (stride arithmetic into the shared dense buffer,
//! zero data copies), and kernels consume the views directly. The
//! copy-based [`TensorRelation::partition_owned`] is retained as the
//! differential baseline and A/B reference (`tests/zero_copy.rs`,
//! `benches/micro_hotpath.rs`).

use crate::error::{Error, Result};
use crate::tensor::{index_space, Tensor, TensorView};

/// Balanced tile size of tile `i` when `bound` is split `parts` ways.
#[inline]
pub fn tile_size(bound: usize, parts: usize, i: usize) -> usize {
    bound / parts + usize::from(i < bound % parts)
}

/// Offset of tile `i` when `bound` is split `parts` ways.
#[inline]
pub fn tile_offset(bound: usize, parts: usize, i: usize) -> usize {
    i * (bound / parts) + i.min(bound % parts)
}

/// Multi-dimensional tile shape for key `key` under `(bound, part)`.
pub fn tile_shape(bound: &[usize], part: &[usize], key: &[usize]) -> Vec<usize> {
    key.iter()
        .enumerate()
        .map(|(d, &k)| tile_size(bound[d], part[d], k))
        .collect()
}

/// Multi-dimensional tile offset for key `key` under `(bound, part)`.
pub fn tile_origin(bound: &[usize], part: &[usize], key: &[usize]) -> Vec<usize> {
    key.iter()
        .enumerate()
        .map(|(d, &k)| tile_offset(bound[d], part[d], k))
        .collect()
}

/// Size in bytes of the f32 tile at `key` under `(bound, part)` — the
/// single implementation the task-graph lowering charges transfers with.
pub fn tile_bytes(bound: &[usize], part: &[usize], key: &[usize]) -> usize {
    key.iter()
        .enumerate()
        .map(|(d, &k)| tile_size(bound[d], part[d], k))
        .product::<usize>()
        * std::mem::size_of::<f32>()
}

/// Validate a partitioning vector against a bound: every entry positive and
/// no larger than the dimension (so no tile is empty).
pub fn validate_part(bound: &[usize], part: &[usize]) -> Result<()> {
    if bound.len() != part.len() {
        return Err(Error::InvalidPartitioning(format!(
            "partitioning {part:?} rank != bound {bound:?}"
        )));
    }
    for (d, (&b, &p)) in bound.iter().zip(part).enumerate() {
        if p == 0 || p > b {
            return Err(Error::InvalidPartitioning(format!(
                "dim {d}: cannot split bound {b} into {p} non-empty tiles"
            )));
        }
    }
    Ok(())
}

/// A relation mapping keys in `I(d)` to sub-tensor views — the unit of
/// data the TRA runtime pushes between kernels. Cloning a relation is
/// cheap (views share their buffers).
#[derive(Clone, Debug)]
pub struct TensorRelation {
    bound: Vec<usize>,
    part: Vec<usize>,
    /// Tiles in row-major key order over `I(part)`.
    tiles: Vec<TensorView>,
}

impl TensorRelation {
    /// Number of tuples, `prod(d)`.
    pub fn num_tiles(&self) -> usize {
        self.part.iter().product()
    }

    pub fn bound(&self) -> &[usize] {
        &self.bound
    }

    pub fn part(&self) -> &[usize] {
        &self.part
    }

    /// Linearize a key over `I(d)` (row-major).
    pub fn key_index(&self, key: &[usize]) -> usize {
        linearize(key, &self.part)
    }

    /// The sub-tensor view at `key` (`R^key` in the paper).
    pub fn tile(&self, key: &[usize]) -> &TensorView {
        &self.tiles[self.key_index(key)]
    }

    pub fn tile_linear(&self, i: usize) -> &TensorView {
        &self.tiles[i]
    }

    /// Iterate `(key, tile)` pairs.
    pub fn iter(&self) -> impl Iterator<Item = (Vec<usize>, &TensorView)> {
        index_space(&self.part).zip(self.tiles.iter())
    }

    /// Build a relation from keyed owned tiles produced in row-major key
    /// order (each becomes a whole-tensor view, O(1)).
    pub fn from_tiles(bound: Vec<usize>, part: Vec<usize>, tiles: Vec<Tensor>) -> Result<Self> {
        Self::from_views(bound, part, tiles.into_iter().map(Tensor::into_view).collect())
    }

    /// Build a relation from keyed tile views produced in row-major key
    /// order.
    pub fn from_views(bound: Vec<usize>, part: Vec<usize>, tiles: Vec<TensorView>) -> Result<Self> {
        validate_part(&bound, &part)?;
        let n: usize = part.iter().product();
        if tiles.len() != n {
            return Err(Error::InvalidPartitioning(format!(
                "expected {} tiles for d={part:?}, got {}",
                n,
                tiles.len()
            )));
        }
        for (key, t) in index_space(&part).zip(&tiles) {
            let want = tile_shape(&bound, &part, &key);
            if t.shape() != want.as_slice() {
                return Err(Error::InvalidPartitioning(format!(
                    "tile {key:?}: shape {:?} != expected {want:?}",
                    t.shape()
                )));
            }
        }
        Ok(TensorRelation { bound, part, tiles })
    }

    /// Partition a dense tensor into an equivalent relation (`R ≡ 𝓡`):
    /// each tile is an O(1) strided view into `t`'s buffer — partitioning
    /// performs **zero data copies**, whatever `d` is.
    pub fn partition(t: &Tensor, part: &[usize]) -> Result<Self> {
        validate_part(t.shape(), part)?;
        let bound = t.shape().to_vec();
        let whole = t.view();
        let mut tiles = Vec::with_capacity(part.iter().product());
        for key in index_space(part) {
            let origin = tile_origin(&bound, part, &key);
            let shape = tile_shape(&bound, part, &key);
            tiles.push(whole.slice(&origin, &shape)?);
        }
        Ok(TensorRelation {
            bound,
            part: part.to_vec(),
            tiles,
        })
    }

    /// The pre-view partitioning: memcpy every tile out of `t` into its
    /// own contiguous buffer. Kept as the differential baseline the
    /// zero-copy suites and the `micro_hotpath` A/B compare against —
    /// production paths use [`partition`](Self::partition).
    pub fn partition_owned(t: &Tensor, part: &[usize]) -> Result<Self> {
        validate_part(t.shape(), part)?;
        let bound = t.shape().to_vec();
        let mut tiles = Vec::with_capacity(part.iter().product());
        for key in index_space(part) {
            let origin = tile_origin(&bound, part, &key);
            let shape = tile_shape(&bound, part, &key);
            tiles.push(t.slice(&origin, &shape)?.into_view());
        }
        Ok(TensorRelation {
            bound,
            part: part.to_vec(),
            tiles,
        })
    }

    /// Assemble the dense tensor this relation is equivalent to (inverse of
    /// [`partition`]).
    pub fn assemble(&self) -> Result<Tensor> {
        let mut out = Tensor::zeros(&self.bound);
        for (key, tile) in self.iter() {
            let origin = tile_origin(&self.bound, &self.part, &key);
            out.write_slice_view(&origin, tile)?;
        }
        Ok(out)
    }

    /// Total bytes held by all tiles.
    pub fn bytes(&self) -> usize {
        self.tiles.iter().map(|t| t.bytes()).sum()
    }

    /// Recycle every tile buffer this relation exclusively owns into the
    /// calling thread's [`crate::util::BufferPool`] (buffers still shared
    /// with other views or tensors are left alive and simply dropped).
    pub fn recycle(self) {
        for t in self.tiles {
            t.recycle();
        }
    }
}

/// Inclusive `(lo, hi)` range of tile indices overlapping the region
/// `[origin, origin + len)` when `bound` is split `parts` ways with
/// balanced tiling. Shared by the tile-to-tile repartition
/// ([`crate::tra::ops::repartition`]) and the task-graph lowering.
pub fn overlapping_tiles(bound: usize, parts: usize, origin: usize, len: usize) -> (usize, usize) {
    // balanced tiling boundaries are monotone; scan (parts is small)
    let mut lo = None;
    let mut hi = 0;
    for i in 0..parts {
        let o = tile_offset(bound, parts, i);
        let s = tile_size(bound, parts, i);
        if o < origin + len && o + s > origin {
            if lo.is_none() {
                lo = Some(i);
            }
            hi = i;
        }
    }
    (lo.unwrap_or(0), hi)
}

/// Row-major linearization of `key` within bound `dims`.
pub fn linearize(key: &[usize], dims: &[usize]) -> usize {
    debug_assert_eq!(key.len(), dims.len());
    let mut idx = 0usize;
    for (k, d) in key.iter().zip(dims) {
        debug_assert!(k < d);
        idx = idx * d + k;
    }
    idx
}

/// Inverse of [`linearize`].
pub fn delinearize(mut idx: usize, dims: &[usize]) -> Vec<usize> {
    let mut key = vec![0usize; dims.len()];
    for d in (0..dims.len()).rev() {
        key[d] = idx % dims[d];
        idx /= dims[d];
    }
    key
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's 4x4 matrix U.
    fn paper_u() -> Tensor {
        Tensor::new(
            vec![4, 4],
            vec![
                1., 2., 5., 6., 3., 4., 7., 8., 9., 10., 13., 14., 11., 12., 15., 16.,
            ],
        )
        .unwrap()
    }

    #[test]
    fn partition_d42_matches_paper() {
        // d = [4, 2]: sub-tensors with bound [1, 2]; tuple <0,1> is [2, 4]
        // as a 1x2... wait, the paper stores column vectors [2,4]^T with
        // bound [4,4]/[4,2] = [1,2]: tile <0,1> = [[2, 4]]? The paper shows
        // ( <0,1>, [2;4] ) with shape 1x2 sliced from rows 0..1, cols 2..4
        // = [2, 5]? No: the paper's U has u[0] = [1,2,5,6], so rows are
        // split 4 ways (each 1 row), cols 2 ways (each 2 cols):
        // tile <0,1> = [[5, 6]].
        let u = paper_u();
        let r = TensorRelation::partition(&u, &[4, 2]).unwrap();
        assert_eq!(r.num_tiles(), 8);
        assert_eq!(r.tile(&[0, 1]).to_vec(), &[5., 6.]);
        assert_eq!(r.tile(&[2, 0]).to_vec(), &[9., 10.]);
    }

    #[test]
    fn partition_d22_matches_paper() {
        // d = [2, 2]: tile <1,0> = [[9,10],[11,12]] — exactly the paper.
        let u = paper_u();
        let r = TensorRelation::partition(&u, &[2, 2]).unwrap();
        assert_eq!(r.tile(&[1, 0]).to_vec(), &[9., 10., 11., 12.]);
        assert_eq!(r.tile(&[0, 1]).to_vec(), &[5., 6., 7., 8.]);
    }

    #[test]
    fn partition_is_zero_copy_and_matches_owned() {
        let t = Tensor::random(&[6, 10], 77);
        for part in [&[1usize, 1][..], &[2, 5], &[3, 2], &[6, 10]] {
            let view_rel = TensorRelation::partition(&t, part).unwrap();
            let owned_rel = TensorRelation::partition_owned(&t, part).unwrap();
            for ((kv, tv), (ko, to)) in view_rel.iter().zip(owned_rel.iter()) {
                assert_eq!(kv, ko);
                // same bytes...
                assert_eq!(tv.to_vec(), to.to_vec(), "part {part:?} key {kv:?}");
                // ...but the view tile aliases the dense buffer (no copy)
                let origin = tile_origin(t.shape(), part, &kv);
                let flat = origin[0] * 10 + origin[1];
                assert!(std::ptr::eq(
                    tv.raw().as_ptr(),
                    t.data()[flat..].as_ptr()
                ));
            }
        }
    }

    #[test]
    fn partition_assemble_roundtrip() {
        let t = Tensor::random(&[6, 10, 4], 42);
        for part in [&[1usize, 1, 1][..], &[2, 5, 2], &[3, 2, 1], &[6, 10, 4]] {
            let r = TensorRelation::partition(&t, part).unwrap();
            assert_eq!(r.assemble().unwrap(), t, "part {part:?}");
        }
    }

    #[test]
    fn uneven_balanced_tiling() {
        // 7 split 3 ways: tiles 3, 2, 2
        assert_eq!(tile_size(7, 3, 0), 3);
        assert_eq!(tile_size(7, 3, 1), 2);
        assert_eq!(tile_size(7, 3, 2), 2);
        assert_eq!(tile_offset(7, 3, 0), 0);
        assert_eq!(tile_offset(7, 3, 1), 3);
        assert_eq!(tile_offset(7, 3, 2), 5);
        let t = Tensor::random(&[7, 5], 1);
        let r = TensorRelation::partition(&t, &[3, 2]).unwrap();
        assert_eq!(r.assemble().unwrap(), t);
    }

    #[test]
    fn invalid_partitionings_rejected() {
        let t = Tensor::zeros(&[4, 4]);
        assert!(TensorRelation::partition(&t, &[5, 1]).is_err()); // > bound
        assert!(TensorRelation::partition(&t, &[0, 1]).is_err()); // zero
        assert!(TensorRelation::partition(&t, &[2]).is_err()); // rank
    }

    #[test]
    fn tile_bytes_matches_shape_product() {
        // 7 split 3 ways: tiles 3,2,2; 5 split 2 ways: tiles 3,2.
        assert_eq!(tile_bytes(&[7, 5], &[3, 2], &[0, 0]), 3 * 3 * 4);
        assert_eq!(tile_bytes(&[7, 5], &[3, 2], &[2, 1]), 2 * 2 * 4);
    }

    #[test]
    fn linearize_roundtrip() {
        let dims = [3usize, 4, 5];
        for i in 0..60 {
            let k = delinearize(i, &dims);
            assert_eq!(linearize(&k, &dims), i);
        }
    }

    #[test]
    fn from_tiles_validates_shapes() {
        let tiles = vec![Tensor::zeros(&[2, 2]); 4];
        assert!(TensorRelation::from_tiles(vec![4, 4], vec![2, 2], tiles.clone()).is_ok());
        assert!(TensorRelation::from_tiles(vec![4, 4], vec![2, 2], tiles[..3].to_vec()).is_err());
        let bad = vec![Tensor::zeros(&[2, 3]); 4];
        assert!(TensorRelation::from_tiles(vec![4, 4], vec![2, 2], bad).is_err());
    }

    #[test]
    fn scalar_relation() {
        let t = Tensor::scalar(5.0);
        let r = TensorRelation::partition(&t, &[]).unwrap();
        assert_eq!(r.num_tiles(), 1);
        assert_eq!(r.assemble().unwrap().at(&[]), 5.0);
    }
}

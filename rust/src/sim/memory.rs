//! Memory-constrained execution modeling (paper Experiment 4 / Fig. 11).
//!
//! Einsummable's TURNIP engine pages GPU tiles out to CPU RAM instead of
//! OOMing; ZeRO-Inference keeps weights sharded and gathers per layer;
//! FlexGen streams weights from host RAM. This module models all three on
//! top of the same task graph:
//!
//! * every worker has `capacity_bytes` of device memory;
//! * produced tiles stay resident until their last consumer finishes;
//! * over-capacity allocation evicts least-recently-used tiles to host
//!   (`host_bps`), and faulting them back stalls the consumer;
//! * a [`WeightPolicy`] adds the baseline-specific weight movement.

use super::cluster::ExecReport;
use super::network::NetworkProfile;
use crate::einsum::graph::VertexId;
use crate::taskgraph::{TaskGraph, TaskKind, TransferClass};
use std::collections::{HashMap, HashSet};

/// How model weights are stored and moved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightPolicy {
    /// Weights resident on their owning device (Einsummable/TURNIP: they
    /// page like any other tile under memory pressure).
    Resident,
    /// ZeRO-Inference-like: weights sharded across devices; every consumer
    /// gathers its weight tiles over the interconnect each use.
    ZeroSharded,
    /// FlexGen-like: weights live in host RAM and stream to the device on
    /// every use at host bandwidth.
    HostStreamed,
}

/// Memory configuration for a modeled run.
#[derive(Clone, Debug)]
pub struct MemoryConfig {
    /// Device memory per worker, bytes.
    pub capacity_bytes: u64,
    pub weight_policy: WeightPolicy,
}

struct Tile {
    bytes: u64,
    resident: bool,
    last_use: u64,
    refs: usize,
    worker: usize,
}

/// Model a placed task graph under a memory budget. `weight_inputs` names
/// the input vertices holding model weights (for the weight policies).
pub fn model_with_memory(
    tg: &TaskGraph,
    net: &NetworkProfile,
    workers: usize,
    mem: &MemoryConfig,
    weight_inputs: &HashSet<VertexId>,
) -> ExecReport {
    let n = tg.tasks.len();
    let mut finish = vec![0.0f64; n];
    let mut clock = vec![0.0f64; workers];
    let mut nic = vec![0.0f64; workers]; // egress serialization (see Cluster::model)
    let mut busy = vec![0.0f64; workers];
    let mut report = ExecReport {
        tasks: n,
        kernel_calls: tg.kernel_calls(),
        ..Default::default()
    };
    // refcounts: how many tasks consume each task's tile
    let mut refs = vec![0usize; n];
    for t in &tg.tasks {
        for &d in &t.deps {
            refs[d.0] += 1;
        }
    }
    let mut tiles: HashMap<usize, Tile> = HashMap::new();
    let mut used: Vec<u64> = vec![0; workers];
    let mut tick: u64 = 0;

    let is_weight_tile = |ti: usize| -> bool {
        matches!(&tg.tasks[ti].kind, TaskKind::InputTile { vertex, .. } if weight_inputs.contains(vertex))
    };

    for t in &tg.tasks {
        let w = t.assigned_worker();
        tick += 1;
        let mut ready = 0.0f64;
        let mut stall = 0.0f64;
        let pinned: HashSet<usize> = t.deps.iter().map(|d| d.0).collect();

        for &d in &t.deps {
            let dep = &tg.tasks[d.0];
            let bytes = dep.out_bytes as u64;
            let mut arrive = finish[d.0];
            let weight = is_weight_tile(d.0);
            // weight policies add movement independent of placement
            match (weight, mem.weight_policy) {
                // A sharded weight tile already resident on the consuming
                // worker crosses no wire — the shard's owner *is* the
                // consumer. Charging it anyway (the pre-fix behaviour)
                // inflated the ZeRO ledger with phantom local traffic.
                (true, WeightPolicy::ZeroSharded) if dep.assigned_worker() != w => {
                    arrive += net.wire_s(dep.out_bytes);
                    report.bytes_moved += bytes;
                    report.bytes_input += bytes;
                }
                // same-worker sharded weights fall through to the
                // resident-tile path below (fault back in if paged out)
                (true, WeightPolicy::HostStreamed) => {
                    arrive += net.host_s(dep.out_bytes);
                    report.bytes_paged += bytes;
                    report.page_stall_s += net.host_s(dep.out_bytes);
                }
                _ => {
                    let dw = dep.assigned_worker();
                    if dw != w {
                        let send_start = finish[d.0].max(nic[dw]);
                        nic[dw] =
                            send_start + dep.out_bytes as f64 / net.bandwidth_bps;
                        arrive = send_start + net.wire_s(dep.out_bytes);
                        report.bytes_moved += bytes;
                        match t.kind.class() {
                            TransferClass::Join => report.bytes_join += bytes,
                            TransferClass::Agg => report.bytes_agg += bytes,
                            TransferClass::Repart => report.bytes_repart += bytes,
                            TransferClass::Input => report.bytes_input += bytes,
                        }
                    } else if let Some(tile) = tiles.get_mut(&d.0) {
                        // same-worker: fault back in if paged out
                        if !tile.resident {
                            let s = net.host_s(dep.out_bytes);
                            stall += s;
                            report.bytes_paged += bytes;
                            report.page_stall_s += s;
                            tile.resident = true;
                            used[w] += bytes;
                        }
                        tile.last_use = tick;
                    }
                }
            }
            ready = ready.max(arrive);
        }

        // allocate the output tile (host-streamed weights never occupy
        // device memory; everything else does)
        let out_bytes = t.out_bytes as u64;
        let occupies = !(is_weight_tile(t.id.0) && mem.weight_policy == WeightPolicy::HostStreamed);
        if occupies {
            used[w] += out_bytes;
            // evict LRU until under capacity
            while used[w] > mem.capacity_bytes {
                let victim = tiles
                    .iter()
                    .filter(|(id, tile)| {
                        tile.worker == w && tile.resident && !pinned.contains(id)
                    })
                    .min_by_key(|(_, tile)| tile.last_use)
                    .map(|(id, _)| *id);
                match victim {
                    Some(vid) => {
                        let tile = tiles.get_mut(&vid).unwrap();
                        tile.resident = false;
                        used[w] -= tile.bytes;
                        let s = net.host_s(tile.bytes as usize);
                        stall += s;
                        report.bytes_paged += tile.bytes;
                        report.page_stall_s += s;
                    }
                    None => break, // working set itself exceeds capacity
                }
            }
            tiles.insert(
                t.id.0,
                Tile {
                    bytes: out_bytes,
                    resident: true,
                    last_use: tick,
                    refs: refs[t.id.0],
                    worker: w,
                },
            );
        }

        let compute = net.compute_s(t.flops);
        let start = (ready + stall).max(clock[w]);
        finish[t.id.0] = start + compute;
        clock[w] = finish[t.id.0];
        busy[w] += compute;
        report.flops += t.flops;

        // release fully-consumed dep tiles
        for &d in &t.deps {
            if let Some(tile) = tiles.get_mut(&d.0) {
                tile.refs = tile.refs.saturating_sub(1);
                if tile.refs == 0 {
                    if tile.resident {
                        used[tile.worker] -= tile.bytes;
                    }
                    tiles.remove(&d.0);
                }
            }
        }
    }
    report.sim_makespan_s = finish.iter().copied().fold(0.0, f64::max);
    report.worker_busy_s = busy;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{plan_graph, PlannerConfig};
    use crate::einsum::expr::EinSum;
    use crate::einsum::graph::EinGraph;
    use crate::einsum::label::labels;
    use crate::sim::cluster::Cluster;

    fn chain(depth: usize, s: usize) -> (EinGraph, HashSet<VertexId>) {
        // x @ W1 @ W2 @ ... — weights tagged
        let mut g = EinGraph::new();
        let mut x = g.input("X", vec![s, s]);
        let mut weights = HashSet::new();
        for l in 0..depth {
            let w = g.input(&format!("W{l}"), vec![s, s]);
            weights.insert(w);
            x = g
                .add(
                    &format!("H{l}"),
                    EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                    vec![x, w],
                )
                .unwrap();
        }
        (g, weights)
    }

    fn lowered(
        g: &EinGraph,
        p: usize,
    ) -> (TaskGraph, NetworkProfile) {
        let plan = plan_graph(g, &PlannerConfig { p, ..Default::default() }).unwrap();
        let cluster = Cluster::new(p, NetworkProfile::gpu_server_a100());
        (cluster.lower(g, &plan).unwrap(), cluster.net)
    }

    #[test]
    fn ample_memory_no_paging() {
        let (g, weights) = chain(4, 64);
        let (tg, net) = lowered(&g, 4);
        let mem = MemoryConfig {
            capacity_bytes: 1 << 30,
            weight_policy: WeightPolicy::Resident,
        };
        let rep = model_with_memory(&tg, &net, 4, &mem, &weights);
        assert_eq!(rep.bytes_paged, 0);
        assert_eq!(rep.page_stall_s, 0.0);
    }

    #[test]
    fn tight_memory_pages_and_slows() {
        let (g, weights) = chain(6, 128);
        let (tg, net) = lowered(&g, 2);
        let roomy = MemoryConfig {
            capacity_bytes: 1 << 30,
            weight_policy: WeightPolicy::Resident,
        };
        let tight = MemoryConfig {
            capacity_bytes: 40 * 1024, // barely one tile
            weight_policy: WeightPolicy::Resident,
        };
        let r1 = model_with_memory(&tg, &net, 2, &roomy, &weights);
        let r2 = model_with_memory(&tg, &net, 2, &tight, &weights);
        assert!(r2.bytes_paged > 0);
        assert!(r2.sim_makespan_s >= r1.sim_makespan_s);
    }

    #[test]
    fn zero_policy_adds_weight_traffic() {
        let (g, weights) = chain(4, 64);
        let (tg, net) = lowered(&g, 4);
        let resident = MemoryConfig {
            capacity_bytes: 1 << 30,
            weight_policy: WeightPolicy::Resident,
        };
        let zero = MemoryConfig {
            capacity_bytes: 1 << 30,
            weight_policy: WeightPolicy::ZeroSharded,
        };
        let r1 = model_with_memory(&tg, &net, 4, &resident, &weights);
        let r2 = model_with_memory(&tg, &net, 4, &zero, &weights);
        // ZeRO gathers remote weight shards as *input* traffic on every
        // use; under the resident policy the same remote edges tally
        // against the consuming kernel (join class) instead.
        assert!(r2.bytes_input > 0);
        assert_eq!(r1.bytes_input, 0);
        // Since the same-worker fix, gathers replace — never inflate —
        // the resident ledger: a shard crosses the wire iff the resident
        // tile would have (same edges, same bytes, different class).
        assert_eq!(r2.bytes_moved, r1.bytes_moved);
    }

    #[test]
    fn zero_sharded_local_shards_are_free() {
        // Regression for the same-worker-transfer fix: a sharded weight
        // whose shard lives on the consuming worker crosses no wire. On a
        // single worker every shard is local, so the ZeRO policy must
        // model exactly zero traffic — it used to charge every weight use
        // as if gathered remotely.
        let (g, weights) = chain(3, 32);
        let plan = plan_graph(&g, &PlannerConfig { p: 1, ..Default::default() }).unwrap();
        let cluster = Cluster::new(1, NetworkProfile::gpu_server_a100());
        let tg = cluster.lower(&g, &plan).unwrap();
        let zero = MemoryConfig {
            capacity_bytes: 1 << 30,
            weight_policy: WeightPolicy::ZeroSharded,
        };
        let rep = model_with_memory(&tg, &cluster.net, 1, &zero, &weights);
        assert_eq!(rep.bytes_moved, 0);
        assert_eq!(rep.bytes_input, 0);
        assert_eq!(rep.bytes_paged, 0);
    }

    #[test]
    fn flexgen_policy_streams_from_host() {
        let (g, weights) = chain(4, 64);
        let (tg, net) = lowered(&g, 4);
        let fg = MemoryConfig {
            capacity_bytes: 1 << 30,
            weight_policy: WeightPolicy::HostStreamed,
        };
        let rep = model_with_memory(&tg, &net, 4, &fg, &weights);
        assert!(rep.bytes_paged > 0);
        assert!(rep.page_stall_s > 0.0);
    }
}

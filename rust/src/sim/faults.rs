//! Deterministic fault injection and per-run robustness options.
//!
//! The paper's program is *declarative*: a lowered task graph plus its
//! inputs determines every tile bitwise, so any lost tile is recomputable
//! from lineage alone. That property is only worth anything if failure is
//! a first-class, testable execution scenario — which requires faults to
//! be **deterministic**. A [`FaultPlan`] names exactly which tasks fail
//! and how (explicit task ids, or a seeded pseudo-random sweep that is a
//! pure function of `(seed, rate, task count)`), so a faulty run can be
//! replayed bit-for-bit and diffed against a clean one
//! (`scripts/chaos_smoke.sh` does exactly that in CI).
//!
//! Two fault shapes, mirroring real clusters:
//!
//! * **transient** — the task fails its first `failures` attempts and
//!   then succeeds (a flaky kernel, a dropped message). The executor
//!   retries in place with capped exponential backoff.
//! * **permanent** — the first attempt kills the task's simulated
//!   *worker*: every tile homed there is lost, pending tasks re-home to
//!   survivors, and lost tiles are recomputed from task-graph lineage
//!   (see `sim::cluster`'s recovery executor).
//!
//! [`RunOptions`] carries the per-run robustness knobs: retry budget,
//! wall-clock deadline, and opt-in non-finite input rejection.

use crate::error::{Error, Result};
use crate::util::Rng;
use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::time::Duration;

/// What an armed fault does to its task.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the task's first `failures` attempts, then let it succeed.
    Transient { failures: u32 },
    /// On the task's first attempt, mark its assigned worker dead: tiles
    /// homed there are dropped, pending tasks re-home to survivors, and
    /// the attempt itself fails (the retry runs on the re-homed worker).
    Permanent,
}

/// A deterministic fault schedule for one execution, threaded via
/// [`Cluster::with_faults`](crate::sim::Cluster::with_faults),
/// `DriverConfig::faults`, or the CLI's `--inject-faults`.
///
/// The plan is resolved against a concrete task graph at run time
/// ([`FaultPlan::arm`]); explicit task indices beyond the graph's task
/// count are ignored, so one plan can be swept across graphs of
/// different sizes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// Explicit per-task faults, `(task index, kind)`.
    explicit: Vec<(usize, FaultKind)>,
    /// Seeded sweep: every task independently receives a single
    /// transient failure with probability `rate`, drawn from a SplitMix64
    /// stream — a pure function of `(seed, rate, task count)`.
    seeded: Option<(u64, f64)>,
}

impl FaultPlan {
    /// An empty plan (injects nothing).
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Builder: fail task `task`'s first `failures` attempts.
    pub fn transient(mut self, task: usize, failures: u32) -> Self {
        self.explicit
            .push((task, FaultKind::Transient { failures }));
        self
    }

    /// Builder: kill task `task`'s worker on its first attempt.
    pub fn permanent(mut self, task: usize) -> Self {
        self.explicit.push((task, FaultKind::Permanent));
        self
    }

    /// A seeded sweep: each task fails once (transiently) with
    /// probability `rate`.
    pub fn seeded(seed: u64, rate: f64) -> Self {
        FaultPlan {
            explicit: Vec::new(),
            seeded: Some((seed, rate)),
        }
    }

    /// True when the plan can never inject anything.
    pub fn is_empty(&self) -> bool {
        self.explicit.is_empty() && self.seeded.is_none()
    }

    /// Resolve the plan against a graph of `n` tasks. Explicit entries
    /// win over the seeded draw on the same index; later explicit
    /// entries win over earlier ones.
    pub(crate) fn arm(&self, n: usize) -> ArmedFaults {
        let mut kinds: Vec<Option<FaultKind>> = vec![None; n];
        if let Some((seed, rate)) = self.seeded {
            let mut rng = Rng::seed_from_u64(seed);
            for k in kinds.iter_mut() {
                // one draw per task, in task order: a pure function of
                // (seed, rate, n) — replayable and diffable
                if (rng.next_f32() as f64) < rate {
                    *k = Some(FaultKind::Transient { failures: 1 });
                }
            }
        }
        for &(ti, kind) in &self.explicit {
            if ti < n {
                kinds[ti] = Some(kind);
            }
        }
        let remaining = kinds
            .iter()
            .map(|k| {
                AtomicU32::new(match k {
                    Some(FaultKind::Transient { failures }) => *failures,
                    _ => 0,
                })
            })
            .collect();
        let fired = kinds.iter().map(|_| AtomicBool::new(false)).collect();
        ArmedFaults {
            kinds,
            remaining,
            fired,
        }
    }

    /// Human-readable description (the canonical spec string).
    pub fn describe(&self) -> String {
        self.to_string()
    }
}

impl fmt::Display for FaultPlan {
    /// Canonical spec string — round-trips through [`FromStr`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut parts: Vec<String> = Vec::new();
        if let Some((seed, rate)) = self.seeded {
            parts.push(format!("seed:{seed}:{rate}"));
        }
        for (ti, kind) in &self.explicit {
            match kind {
                FaultKind::Transient { failures } => {
                    parts.push(format!("task:{ti}:transient:{failures}"))
                }
                FaultKind::Permanent => parts.push(format!("task:{ti}:permanent")),
            }
        }
        if parts.is_empty() {
            return f.write_str("none");
        }
        f.write_str(&parts.join(","))
    }
}

impl FromStr for FaultPlan {
    type Err = Error;

    /// Parse the CLI spec: comma-separated clauses, each either
    /// `seed:<u64>:<rate>` (seeded transient sweep),
    /// `task:<idx>:transient[:<n>]` (fail n times, default 1), or
    /// `task:<idx>:permanent` (kill the task's worker).
    fn from_str(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::new();
        if s == "none" || s.is_empty() {
            return Ok(plan);
        }
        for clause in s.split(',') {
            let fields: Vec<&str> = clause.split(':').collect();
            match fields.as_slice() {
                ["seed", seed, rate] => {
                    let seed: u64 = seed.parse().map_err(|_| {
                        Error::Parse(format!("fault spec {clause:?}: bad seed"))
                    })?;
                    let rate: f64 = rate.parse().map_err(|_| {
                        Error::Parse(format!("fault spec {clause:?}: bad rate"))
                    })?;
                    if !(0.0..=1.0).contains(&rate) {
                        return Err(Error::Parse(format!(
                            "fault spec {clause:?}: rate must be in [0, 1]"
                        )));
                    }
                    plan.seeded = Some((seed, rate));
                }
                ["task", idx, rest @ ..] => {
                    let ti: usize = idx.parse().map_err(|_| {
                        Error::Parse(format!("fault spec {clause:?}: bad task index"))
                    })?;
                    match rest {
                        ["transient"] => plan = plan.transient(ti, 1),
                        ["transient", n] => {
                            let n: u32 = n.parse().map_err(|_| {
                                Error::Parse(format!(
                                    "fault spec {clause:?}: bad failure count"
                                ))
                            })?;
                            plan = plan.transient(ti, n);
                        }
                        ["permanent"] => plan = plan.permanent(ti),
                        _ => {
                            return Err(Error::Parse(format!(
                                "fault spec {clause:?}: expected transient[:n] or permanent"
                            )))
                        }
                    }
                }
                _ => {
                    return Err(Error::Parse(format!(
                        "fault spec {clause:?}: expected seed:<seed>:<rate> or \
                         task:<idx>:transient[:n]|permanent"
                    )))
                }
            }
        }
        Ok(plan)
    }
}

/// A [`FaultPlan`] resolved against a concrete task count: per-task fault
/// kinds plus the consumable failure budgets. Shared read-only across the
/// executor's threads; consumption is atomic so each planned failure
/// fires exactly once even under racing attempts.
pub(crate) struct ArmedFaults {
    kinds: Vec<Option<FaultKind>>,
    /// Transient failures left per task.
    remaining: Vec<AtomicU32>,
    /// Whether a permanent fault has fired per task.
    fired: Vec<AtomicBool>,
}

impl ArmedFaults {
    /// Number of tasks the resolved plan will fault at least once.
    pub(crate) fn planned(&self) -> usize {
        self.kinds.iter().flatten().count()
    }

    /// Consume one failure event for task `ti`, if the plan has one left.
    pub(crate) fn next_failure(&self, ti: usize) -> Option<FaultKind> {
        match self.kinds.get(ti).copied().flatten()? {
            k @ FaultKind::Transient { .. } => {
                let mut cur = self.remaining[ti].load(Ordering::Acquire);
                while cur > 0 {
                    match self.remaining[ti].compare_exchange(
                        cur,
                        cur - 1,
                        Ordering::AcqRel,
                        Ordering::Acquire,
                    ) {
                        Ok(_) => return Some(k),
                        Err(now) => cur = now,
                    }
                }
                None
            }
            FaultKind::Permanent => {
                if !self.fired[ti].swap(true, Ordering::AcqRel) {
                    Some(FaultKind::Permanent)
                } else {
                    None
                }
            }
        }
    }
}

/// Per-run robustness options for `Executable::run_with` /
/// `Cluster::run_lowered_opts`: retry budget, deadline, input hygiene,
/// and the backoff schedule. The default is the pre-fault-tolerance
/// behaviour: no deadline, no non-finite screening, and a retry budget
/// that only matters when a [`FaultPlan`] is armed (non-injected kernel
/// errors are deterministic and are never retried).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RunOptions {
    /// Re-attempts allowed per task beyond the first try.
    pub max_retries: u32,
    /// Wall-clock budget for the whole run; exceeding it returns a typed
    /// [`ExecCause::DeadlineExceeded`](crate::error::ExecCause) carrying
    /// partial-progress stats.
    pub deadline: Option<Duration>,
    /// Reject NaN/Inf input tensors with a typed error before executing.
    pub reject_nonfinite: bool,
    /// First retry waits this long; attempt `k` waits `base << k`,
    /// capped at [`RunOptions::backoff_cap`].
    pub backoff_base: Duration,
    /// Upper bound of the exponential backoff.
    pub backoff_cap: Duration,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            max_retries: 3,
            deadline: None,
            reject_nonfinite: false,
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(16),
        }
    }
}

impl RunOptions {
    /// The capped exponential delay before retry attempt `attempt`
    /// (0-based): `base << attempt`, capped.
    pub fn backoff(&self, attempt: u32) -> Duration {
        crate::util::backoff_delay(self.backoff_base, self.backoff_cap, attempt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips() {
        for spec in [
            "seed:42:0.1",
            "task:3:transient:2",
            "task:7:permanent",
            "seed:9:0.25,task:0:transient:1,task:4:permanent",
        ] {
            let plan: FaultPlan = spec.parse().unwrap();
            assert_eq!(plan.to_string(), spec, "round trip of {spec}");
            // and the canonical form re-parses to the same plan
            let again: FaultPlan = plan.to_string().parse().unwrap();
            assert_eq!(again, plan);
        }
        assert!(FaultPlan::new().is_empty());
        assert_eq!(FaultPlan::new().to_string(), "none");
        assert_eq!("none".parse::<FaultPlan>().unwrap(), FaultPlan::new());
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in [
            "seed:x:0.1",
            "seed:1:2.0",
            "task:one:permanent",
            "task:3:sometimes",
            "bogus",
            "task:3",
        ] {
            assert!(bad.parse::<FaultPlan>().is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn armed_transient_consumes_exactly_n_failures() {
        let plan = FaultPlan::new().transient(2, 2);
        let armed = plan.arm(4);
        assert_eq!(armed.planned(), 1);
        assert!(armed.next_failure(0).is_none());
        assert!(matches!(
            armed.next_failure(2),
            Some(FaultKind::Transient { .. })
        ));
        assert!(armed.next_failure(2).is_some());
        assert!(armed.next_failure(2).is_none(), "budget exhausted");
    }

    #[test]
    fn armed_permanent_fires_once() {
        let armed = FaultPlan::new().permanent(1).arm(3);
        assert_eq!(armed.next_failure(1), Some(FaultKind::Permanent));
        assert!(armed.next_failure(1).is_none());
    }

    #[test]
    fn out_of_range_explicit_faults_are_ignored() {
        let armed = FaultPlan::new().transient(99, 1).arm(4);
        assert_eq!(armed.planned(), 0);
        assert!(armed.next_failure(3).is_none());
    }

    #[test]
    fn seeded_sweep_is_deterministic_and_rate_shaped() {
        let a = FaultPlan::seeded(7, 0.5).arm(64);
        let b = FaultPlan::seeded(7, 0.5).arm(64);
        for ti in 0..64 {
            assert_eq!(a.kinds[ti], b.kinds[ti], "task {ti}");
        }
        assert!(a.planned() > 0, "rate 0.5 over 64 tasks hit nothing");
        assert!(a.planned() < 64, "rate 0.5 over 64 tasks hit everything");
        assert_eq!(FaultPlan::seeded(7, 0.0).arm(64).planned(), 0);
        assert_eq!(FaultPlan::seeded(7, 1.0).arm(64).planned(), 64);
        // a different seed draws a different subset (overwhelmingly)
        let c = FaultPlan::seeded(8, 0.5).arm(64);
        assert!(
            (0..64).any(|ti| a.kinds[ti] != c.kinds[ti]),
            "seeds 7 and 8 drew identical 64-task subsets"
        );
    }

    #[test]
    fn explicit_overrides_seeded() {
        let plan = FaultPlan {
            explicit: vec![(0, FaultKind::Permanent)],
            seeded: Some((1, 1.0)),
        };
        let armed = plan.arm(2);
        assert_eq!(armed.kinds[0], Some(FaultKind::Permanent));
        assert_eq!(
            armed.kinds[1],
            Some(FaultKind::Transient { failures: 1 })
        );
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let opts = RunOptions::default();
        assert_eq!(opts.backoff(0), Duration::from_millis(1));
        assert_eq!(opts.backoff(1), Duration::from_millis(2));
        assert_eq!(opts.backoff(3), Duration::from_millis(8));
        assert_eq!(opts.backoff(10), Duration::from_millis(16), "capped");
        assert_eq!(opts.backoff(63), Duration::from_millis(16), "no overflow");
    }
}

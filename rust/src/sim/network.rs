//! Network and device profiles for the simulated cluster.

/// Bandwidth/latency model of the interconnect plus a device compute rate.
/// Transfers cost `latency_s + bytes / bandwidth_Bps`; compute costs
/// `flops / flops_per_s`.
#[derive(Clone, Debug)]
pub struct NetworkProfile {
    pub name: String,
    /// Interconnect bandwidth, bytes/second, per link.
    pub bandwidth_bps: f64,
    /// Per-message latency, seconds.
    pub latency_s: f64,
    /// Device compute throughput, flops/second (per worker).
    pub flops_per_s: f64,
    /// Host<->device bandwidth for paging/offload, bytes/second.
    pub host_bps: f64,
    /// Per-task scheduler/dispatch overhead, seconds. Our rust runtime
    /// dispatches in microseconds; systems with a centralized Python
    /// scheduler (Dask) pay ~0.1–1 ms per task — the fig8 bench models
    /// the Dask baseline with an elevated value.
    pub sched_overhead_s: f64,
}

impl NetworkProfile {
    /// The paper's CPU cluster: m6in.16xlarge, 100 Gb/s network, one
    /// worker = one machine (32 cores of Ice Lake ~ 1.5 TFLOP/s f32 at
    /// realistic GEMM efficiency).
    pub fn cpu_cluster() -> Self {
        NetworkProfile {
            name: "cpu-cluster-100gbps".into(),
            bandwidth_bps: 100e9 / 8.0,
            latency_s: 5e-6,
            flops_per_s: 1.5e12,
            host_bps: 12.5e9,
            sched_overhead_s: 2e-6,
        }
    }

    /// The paper's P100 GPU server: device-to-device over PCIe 3.0
    /// (~12 GB/s effective), P100 ~ 9 TFLOP/s f32.
    pub fn gpu_server_p100() -> Self {
        NetworkProfile {
            name: "gpu-server-p100-pcie".into(),
            bandwidth_bps: 12e9,
            latency_s: 10e-6,
            flops_per_s: 9e12,
            host_bps: 12e9,
            sched_overhead_s: 2e-6,
        }
    }

    /// The paper's A100 server: NVLink-class interconnect (~300 GB/s
    /// effective per GPU pair on P4d), A100 ~ 19.5 TFLOP/s f32.
    pub fn gpu_server_a100() -> Self {
        NetworkProfile {
            name: "gpu-server-a100-nvlink".into(),
            bandwidth_bps: 300e9,
            latency_s: 5e-6,
            flops_per_s: 19.5e12,
            host_bps: 25e9,
            sched_overhead_s: 2e-6,
        }
    }

    /// The V100 server used in Experiment 3 (8 GPUs, NVLink ~150 GB/s).
    pub fn gpu_server_v100() -> Self {
        NetworkProfile {
            name: "gpu-server-v100-nvlink".into(),
            bandwidth_bps: 150e9,
            latency_s: 5e-6,
            flops_per_s: 14e12,
            host_bps: 12e9,
            sched_overhead_s: 2e-6,
        }
    }

    /// Local testing profile: fast, negligible latency.
    pub fn loopback() -> Self {
        NetworkProfile {
            name: "loopback".into(),
            bandwidth_bps: 1e12,
            latency_s: 0.0,
            flops_per_s: 1e11,
            host_bps: 1e11,
            sched_overhead_s: 0.0,
        }
    }

    /// Time to move `bytes` across one link. A zero-byte transfer is no
    /// transfer at all — nothing crosses the wire, so no latency either.
    /// (Zero-byte edges are exactly what alias-refinement and identity
    /// repartitions produce; charging them latency modeled free rewrites
    /// as non-free.)
    #[inline]
    pub fn wire_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Time to page `bytes` to/from host memory. Zero bytes page in zero
    /// seconds (see [`Self::wire_s`]).
    #[inline]
    pub fn host_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        bytes as f64 / self.host_bps
    }

    /// Time to compute `flops` on one worker (plus dispatch overhead).
    #[inline]
    pub fn compute_s(&self, flops: f64) -> f64 {
        self.sched_overhead_s + flops / self.flops_per_s
    }

    /// Same profile with a different per-task scheduler overhead (used to
    /// model centralized-scheduler systems like Dask).
    pub fn with_sched_overhead(mut self, overhead_s: f64) -> Self {
        self.sched_overhead_s = overhead_s;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_monotone() {
        let n = NetworkProfile::cpu_cluster();
        assert!(n.wire_s(1 << 20) < n.wire_s(1 << 24));
        assert!(n.wire_s(1) >= n.latency_s);
    }

    #[test]
    fn zero_byte_transfers_are_free() {
        for p in [
            NetworkProfile::cpu_cluster(),
            NetworkProfile::gpu_server_p100(),
        ] {
            assert_eq!(p.wire_s(0), 0.0, "{}", p.name);
            assert_eq!(p.host_s(0), 0.0, "{}", p.name);
        }
    }

    #[test]
    fn profiles_sane() {
        for p in [
            NetworkProfile::cpu_cluster(),
            NetworkProfile::gpu_server_p100(),
            NetworkProfile::gpu_server_a100(),
            NetworkProfile::gpu_server_v100(),
            NetworkProfile::loopback(),
        ] {
            assert!(p.bandwidth_bps > 0.0 && p.flops_per_s > 0.0);
        }
    }
}

//! Network and device profiles for the simulated cluster, plus the
//! hierarchical [`Topology`] view of the worker set.
//!
//! The seed model priced every cross-worker transfer at one flat
//! [`NetworkProfile`] link. Real clusters are hierarchical — cores share
//! a socket, sockets a node, nodes a rack — and the link two workers
//! actually traverse is the one at their *lowest common group*.
//! [`Topology`] captures exactly that: a nested grouping of the workers
//! with one [`LinkClass`] (bandwidth + latency) per level. A `Cluster`
//! or planner without a topology (`None`) uses the flat profile
//! unchanged, byte-for-byte.

/// Bandwidth/latency model of the interconnect plus a device compute rate.
/// Transfers cost `latency_s + bytes / bandwidth_Bps`; compute costs
/// `flops / flops_per_s`.
#[derive(Clone, Debug)]
pub struct NetworkProfile {
    pub name: String,
    /// Interconnect bandwidth, bytes/second, per link.
    pub bandwidth_bps: f64,
    /// Per-message latency, seconds.
    pub latency_s: f64,
    /// Device compute throughput, flops/second (per worker).
    pub flops_per_s: f64,
    /// Host<->device bandwidth for paging/offload, bytes/second.
    pub host_bps: f64,
    /// Per-task scheduler/dispatch overhead, seconds. Our rust runtime
    /// dispatches in microseconds; systems with a centralized Python
    /// scheduler (Dask) pay ~0.1–1 ms per task — the fig8 bench models
    /// the Dask baseline with an elevated value.
    pub sched_overhead_s: f64,
}

impl NetworkProfile {
    /// The paper's CPU cluster: m6in.16xlarge, 100 Gb/s network, one
    /// worker = one machine (32 cores of Ice Lake ~ 1.5 TFLOP/s f32 at
    /// realistic GEMM efficiency).
    pub fn cpu_cluster() -> Self {
        NetworkProfile {
            name: "cpu-cluster-100gbps".into(),
            bandwidth_bps: 100e9 / 8.0,
            latency_s: 5e-6,
            flops_per_s: 1.5e12,
            host_bps: 12.5e9,
            sched_overhead_s: 2e-6,
        }
    }

    /// The paper's P100 GPU server: device-to-device over PCIe 3.0
    /// (~12 GB/s effective), P100 ~ 9 TFLOP/s f32.
    pub fn gpu_server_p100() -> Self {
        NetworkProfile {
            name: "gpu-server-p100-pcie".into(),
            bandwidth_bps: 12e9,
            latency_s: 10e-6,
            flops_per_s: 9e12,
            host_bps: 12e9,
            sched_overhead_s: 2e-6,
        }
    }

    /// The paper's A100 server: NVLink-class interconnect (~300 GB/s
    /// effective per GPU pair on P4d), A100 ~ 19.5 TFLOP/s f32.
    pub fn gpu_server_a100() -> Self {
        NetworkProfile {
            name: "gpu-server-a100-nvlink".into(),
            bandwidth_bps: 300e9,
            latency_s: 5e-6,
            flops_per_s: 19.5e12,
            host_bps: 25e9,
            sched_overhead_s: 2e-6,
        }
    }

    /// The V100 server used in Experiment 3 (8 GPUs, NVLink ~150 GB/s).
    pub fn gpu_server_v100() -> Self {
        NetworkProfile {
            name: "gpu-server-v100-nvlink".into(),
            bandwidth_bps: 150e9,
            latency_s: 5e-6,
            flops_per_s: 14e12,
            host_bps: 12e9,
            sched_overhead_s: 2e-6,
        }
    }

    /// Local testing profile: fast, negligible latency.
    pub fn loopback() -> Self {
        NetworkProfile {
            name: "loopback".into(),
            bandwidth_bps: 1e12,
            latency_s: 0.0,
            flops_per_s: 1e11,
            host_bps: 1e11,
            sched_overhead_s: 0.0,
        }
    }

    /// Time to move `bytes` across one link. A zero-byte transfer is no
    /// transfer at all — nothing crosses the wire, so no latency either.
    /// (Zero-byte edges are exactly what alias-refinement and identity
    /// repartitions produce; charging them latency modeled free rewrites
    /// as non-free.)
    #[inline]
    pub fn wire_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Time to page `bytes` to/from host memory. Zero bytes page in zero
    /// seconds (see [`Self::wire_s`]).
    #[inline]
    pub fn host_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        bytes as f64 / self.host_bps
    }

    /// Time to compute `flops` on one worker (plus dispatch overhead).
    #[inline]
    pub fn compute_s(&self, flops: f64) -> f64 {
        self.sched_overhead_s + flops / self.flops_per_s
    }

    /// Same profile with a different per-task scheduler overhead (used to
    /// model centralized-scheduler systems like Dask).
    pub fn with_sched_overhead(mut self, overhead_s: f64) -> Self {
        self.sched_overhead_s = overhead_s;
        self
    }
}

/// One class of links in a hierarchical [`Topology`]: the price of a
/// hop between two workers whose lowest common group sits at this
/// level.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkClass {
    pub name: String,
    /// Link bandwidth at this level, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-message latency at this level, seconds.
    pub latency_s: f64,
}

impl LinkClass {
    /// Time to move `bytes` across one link of this class. Mirrors
    /// [`NetworkProfile::wire_s`]: a zero-byte transfer is no transfer
    /// at all, so no latency either.
    #[inline]
    pub fn wire_s(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

/// A hierarchical view of the worker set: consecutive workers nest into
/// groups (cores into sockets into nodes into racks), and a transfer
/// between two workers is charged at the link class of their *lowest
/// common group* — the hierarchical analogue of the seed's single flat
/// link.
///
/// `spans[i]` is the number of consecutive workers per group at level
/// `i`, innermost first: workers `a` and `b` share a level-`i` group
/// iff `a / spans[i] == b / spans[i]`. Interior spans divide the next
/// level's span (groups nest), the outermost span covers every worker
/// (so [`Topology::link_class`] always resolves for distinct workers),
/// and `classes` is parallel to `spans`. The presets make the
/// *outermost* class equal to the underlying [`NetworkProfile`] and
/// every inner class at least as fast, so a hierarchical topology only
/// ever discounts the flat model — never exceeds it (the property
/// `tests/topology_cost.rs` pins).
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    name: String,
    workers: usize,
    spans: Vec<usize>,
    classes: Vec<LinkClass>,
}

impl Topology {
    /// Build a topology from explicit spans and link classes.
    ///
    /// Panics when the invariants above are violated; the `flat_of` /
    /// `two_level_of` / `three_level_of` presets always satisfy them.
    pub fn new(
        name: impl Into<String>,
        workers: usize,
        spans: Vec<usize>,
        classes: Vec<LinkClass>,
    ) -> Self {
        assert!(workers >= 1, "topology needs at least one worker");
        assert!(!spans.is_empty(), "topology needs at least one level");
        assert_eq!(
            spans.len(),
            classes.len(),
            "spans and link classes must be parallel"
        );
        for (i, &s) in spans.iter().enumerate() {
            assert!(s >= 1, "span at level {i} must be positive");
            if i > 0 {
                assert!(
                    s >= spans[i - 1] && s % spans[i - 1] == 0,
                    "span {s} at level {i} does not nest over {}",
                    spans[i - 1]
                );
            }
        }
        assert!(
            *spans.last().unwrap() >= workers,
            "outermost span must cover all {workers} workers"
        );
        Topology {
            name: name.into(),
            workers,
            spans,
            classes,
        }
    }

    /// Flat topology: one level whose single link class *is* `net`.
    /// Reproduces the seed model exactly.
    pub fn flat_of(net: &NetworkProfile, workers: usize) -> Self {
        let workers = workers.max(1);
        Topology::new(
            format!("flat({})", net.name),
            workers,
            vec![workers],
            vec![LinkClass {
                name: "flat".into(),
                bandwidth_bps: net.bandwidth_bps,
                latency_s: net.latency_s,
            }],
        )
    }

    /// Two-level socket/node split: workers pair off into two sockets
    /// of `ceil(workers/2)`; intra-socket links are 4x the profile
    /// bandwidth at a quarter of the latency, cross-socket links are
    /// the profile itself.
    pub fn two_level_of(net: &NetworkProfile, workers: usize) -> Self {
        let workers = workers.max(1);
        let socket = workers.div_ceil(2).max(1);
        Topology::new(
            format!("two-level({})", net.name),
            workers,
            vec![socket, socket * 2],
            vec![
                LinkClass {
                    name: "intra-socket".into(),
                    bandwidth_bps: net.bandwidth_bps * 4.0,
                    latency_s: net.latency_s / 4.0,
                },
                LinkClass {
                    name: "cross-socket".into(),
                    bandwidth_bps: net.bandwidth_bps,
                    latency_s: net.latency_s,
                },
            ],
        )
    }

    /// Three-level rack config: nodes of `workers/4`, a middle
    /// cross-node level of roughly half the workers, and a top rack
    /// level at the profile's own speed. Intra-node links run at 8x
    /// bandwidth / latency/8, cross-node at 2x / half latency.
    pub fn three_level_of(net: &NetworkProfile, workers: usize) -> Self {
        let workers = workers.max(1);
        let node = (workers / 4).max(1);
        // middle span: at least half the workers, rounded up to nest
        // over the node span (degenerate spans like [1, 1, 2] are fine:
        // a never-matching level simply never prices a link).
        let mid = node * (workers / 2).max(node).div_ceil(node);
        let top = mid * workers.div_ceil(mid);
        Topology::new(
            format!("three-level({})", net.name),
            workers,
            vec![node, mid, top],
            vec![
                LinkClass {
                    name: "intra-node".into(),
                    bandwidth_bps: net.bandwidth_bps * 8.0,
                    latency_s: net.latency_s / 8.0,
                },
                LinkClass {
                    name: "cross-node".into(),
                    bandwidth_bps: net.bandwidth_bps * 2.0,
                    latency_s: net.latency_s / 2.0,
                },
                LinkClass {
                    name: "cross-rack".into(),
                    bandwidth_bps: net.bandwidth_bps,
                    latency_s: net.latency_s,
                },
            ],
        )
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Number of hierarchy levels (== number of link classes).
    pub fn levels(&self) -> usize {
        self.classes.len()
    }

    /// A single-level topology prices every link identically — the
    /// planner and executor treat it as the seed flat model.
    pub fn is_flat(&self) -> bool {
        self.classes.len() == 1
    }

    pub fn classes(&self) -> &[LinkClass] {
        &self.classes
    }

    pub fn spans(&self) -> &[usize] {
        &self.spans
    }

    /// Index of the link class a transfer `a -> b` traverses: the
    /// innermost level whose groups contain both. `None` when `a == b`
    /// (no wire at all).
    pub fn link_class(&self, a: usize, b: usize) -> Option<usize> {
        if a == b {
            return None;
        }
        self.spans
            .iter()
            .position(|&s| a / s == b / s)
            .or(Some(self.classes.len() - 1))
    }

    /// The link class a transfer `a -> b` traverses, or `None` for a
    /// same-worker "transfer".
    pub fn link_of(&self, a: usize, b: usize) -> Option<&LinkClass> {
        self.link_class(a, b).map(|i| &self.classes[i])
    }

    /// Cost weight of level `i` relative to the outermost (flat) class:
    /// `outermost_bw / class_bw`. With the presets' faster inner links
    /// this is <= 1, which is what keeps hierarchical planner costs at
    /// or below flat for the same plan.
    pub fn class_weight(&self, i: usize) -> f64 {
        let outer = self.classes.last().unwrap();
        outer.bandwidth_bps / self.classes[i].bandwidth_bps
    }

    /// Branching factor at the top level: how many next-inner groups a
    /// tree-shaped collective fans out over. At least 2.
    pub fn gather_arity(&self) -> usize {
        if self.classes.len() < 2 {
            return 2;
        }
        let inner = self.spans[self.spans.len() - 2];
        self.workers.div_ceil(inner).max(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_monotone() {
        let n = NetworkProfile::cpu_cluster();
        assert!(n.wire_s(1 << 20) < n.wire_s(1 << 24));
        assert!(n.wire_s(1) >= n.latency_s);
    }

    #[test]
    fn zero_byte_transfers_are_free() {
        for p in [
            NetworkProfile::cpu_cluster(),
            NetworkProfile::gpu_server_p100(),
        ] {
            assert_eq!(p.wire_s(0), 0.0, "{}", p.name);
            assert_eq!(p.host_s(0), 0.0, "{}", p.name);
        }
    }

    #[test]
    fn profiles_sane() {
        for p in [
            NetworkProfile::cpu_cluster(),
            NetworkProfile::gpu_server_p100(),
            NetworkProfile::gpu_server_a100(),
            NetworkProfile::gpu_server_v100(),
            NetworkProfile::loopback(),
        ] {
            assert!(p.bandwidth_bps > 0.0 && p.flops_per_s > 0.0);
        }
    }

    #[test]
    fn flat_topology_is_the_seed_link() {
        let net = NetworkProfile::cpu_cluster();
        let t = Topology::flat_of(&net, 8);
        assert!(t.is_flat());
        assert_eq!(t.levels(), 1);
        assert_eq!(t.workers(), 8);
        for a in 0..8 {
            for b in 0..8 {
                if a == b {
                    assert_eq!(t.link_class(a, b), None);
                } else {
                    assert_eq!(t.link_class(a, b), Some(0));
                    let l = t.link_of(a, b).unwrap();
                    assert_eq!(l.bandwidth_bps, net.bandwidth_bps);
                    assert_eq!(l.latency_s, net.latency_s);
                    assert_eq!(l.wire_s(1 << 20), net.wire_s(1 << 20));
                }
            }
        }
        assert_eq!(t.class_weight(0), 1.0);
    }

    #[test]
    fn two_level_groups_by_socket() {
        let net = NetworkProfile::cpu_cluster();
        let t = Topology::two_level_of(&net, 8);
        assert_eq!(t.levels(), 2);
        // sockets of 4: {0..3} and {4..7}
        assert_eq!(t.link_class(0, 3), Some(0));
        assert_eq!(t.link_class(1, 2), Some(0));
        assert_eq!(t.link_class(3, 4), Some(1));
        assert_eq!(t.link_class(0, 7), Some(1));
        assert_eq!(t.link_class(5, 5), None);
        // inner class is faster, outer class is the profile
        assert!(t.class_weight(0) < 1.0);
        assert_eq!(t.class_weight(1), 1.0);
        assert_eq!(t.classes()[1].bandwidth_bps, net.bandwidth_bps);
    }

    #[test]
    fn three_level_lca_lookup() {
        let net = NetworkProfile::cpu_cluster();
        let t = Topology::three_level_of(&net, 8);
        assert_eq!(t.spans(), &[2, 4, 8]);
        assert_eq!(t.link_class(0, 1), Some(0)); // same node
        assert_eq!(t.link_class(1, 2), Some(1)); // same half, other node
        assert_eq!(t.link_class(2, 3), Some(0));
        assert_eq!(t.link_class(3, 4), Some(2)); // across the rack split
        assert_eq!(t.link_class(0, 7), Some(2));
        assert_eq!(t.gather_arity(), 2);
        // weights strictly improve toward the leaves
        assert!(t.class_weight(0) < t.class_weight(1));
        assert!(t.class_weight(1) < t.class_weight(2));
        assert_eq!(t.class_weight(2), 1.0);
    }

    #[test]
    fn degenerate_spans_never_match() {
        // three-level on 2 workers degenerates to [1, 1, 2]: the two
        // inner levels can never group two distinct workers, so the
        // only priced class is the top one.
        let net = NetworkProfile::loopback();
        let t = Topology::three_level_of(&net, 2);
        assert_eq!(t.spans(), &[1, 1, 2]);
        assert_eq!(t.link_class(0, 1), Some(2));
    }
}

//! The simulated cluster executor.
//!
//! Two modes over the same task graph:
//!
//! * **real** ([`Cluster::execute`]) — actually computes every kernel call
//!   multi-threaded on the host's cores and returns the assembled output
//!   tensors, together with the modeled report. Used by the examples, the
//!   end-to-end training driver, and all numerics tests.
//! * **dry** ([`Cluster::dry_run`]) — models time and traffic only, which
//!   is how paper-scale configurations (LLaMA-7B/65B shapes) are costed
//!   without materializing terabytes.
//!
//! The modeled timeline is event-driven: a task becomes ready when all
//! producer tiles have arrived (cross-worker edges pay latency +
//! bytes/bandwidth), each worker executes its tasks in graph order, and
//! compute costs `flops / flops_per_s`.
//!
//! # Real-execution scheduling
//!
//! Real execution mirrors that event-driven model with a dependency-
//! counted, work-stealing scheduler ([`ExecMode::WorkStealing`], the
//! default — see [`crate::util::execute_dag`] for the queue protocol):
//!
//! * every task carries a readiness counter initialized to its dep
//!   occurrence count; the worker thread that performs a counter's final
//!   decrement owns the hand-off and pushes the now-ready task onto its
//!   own deque, so a consumer usually runs where its freshest input was
//!   just produced;
//! * idle threads steal from the front of other deques (oldest-first), so
//!   independent subgraphs overlap instead of waiting for a level barrier;
//! * threads that find no ready *task* steal **shards** of tasks other
//!   workers are running (nested work stealing, see
//!   [`crate::util::execute_dag_scoped`]): kernel bodies split their GEMM
//!   row blocks, batch entries, elementwise chunks, and aggregation folds
//!   into `intra_op`-many independent pieces, so a 2-vertex plan on 16
//!   cores no longer runs at 2/16 utilization. The fan-out is set by
//!   [`Cluster::with_intra_op`] (default: the executor's thread count);
//! * task *results* are deterministic regardless of interleaving: each
//!   task writes only its own result slot, kernel inputs are fixed by
//!   the task graph, aggregations combine their deps in the fixed `deps`
//!   order — never in completion order — and every sharded kernel is
//!   bitwise-identical to its serial form (shard boundaries are a pure
//!   function of the problem shape). `cargo test` locks this in with
//!   bitwise-determinism differential suites (`tests/
//!   scheduler_differential.rs`, `tests/gemm_parallel.rs`);
//! * the data plane is zero-copy: tiles move between tasks as strided
//!   [`TensorView`]s (input pre-slicing is O(1), kernels read through
//!   strides, repartition tiles contained in one producer tile alias it),
//!   and a tile's buffer is recycled into the per-worker
//!   [`crate::util::BufferPool`] the moment its last consumer has read
//!   it — reclamation frees buffers, never values, so determinism is
//!   untouched.
//!
//! [`ExecMode::LevelBarrier`] retains the previous implementation — a
//! persistent thread team synchronized per ASAP level with a barrier — as
//! a reference mode for differential tests and A/B benchmarks
//! (`cargo bench micro_hotpath` reports both). Both modes produce
//! bitwise-identical outputs; the barrier mode simply idles cores
//! whenever a level drains unevenly, which is exactly where the paper's
//! event-driven cost model (§7) says work should overlap.
//!
//! Determinism here is also what makes the serving layer's dynamic
//! batching safe: [`crate::serve::Server`] stacks concurrent same-plan
//! requests along a fresh leading batch label and runs the batched twin
//! through this same executor, relying on the guarantees above (fixed
//! `deps`-order aggregation, shape-determined shard boundaries) plus
//! intra-op kernel sharding over the batch entries for its parallelism —
//! the batch dimension itself is left unsplit by the twin's plan.
//!
//! The modeled makespan/traffic accounting ([`Cluster::model`]) is shared
//! by both modes and unchanged by the scheduler choice: `ExecReport`'s
//! `sim_*`/`bytes_*` fields describe the modeled cluster, `wall_s` the
//! real host execution.

use super::faults::{ArmedFaults, FaultKind, FaultPlan, RunOptions};
use super::network::{NetworkProfile, Topology};
use crate::decomp::Plan;
use crate::einsum::expr::{AggOp, EinSum};
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::project;
use crate::error::{Error, ExecCause, Result};
use crate::runtime::spill::{lock_slot, MemoryBudget, ResultSlot, TileStore, PREFETCH_WINDOW};
use crate::runtime::KernelEngine;
use crate::taskgraph::placement::{place, Policy};
use crate::taskgraph::{TaskGraph, TaskKind, TransferClass};
use crate::tensor::{Tensor, TensorView};
use crate::tra::passes::{PassLog, PassSelector};
use crate::tra::program::{from_plan, TraProgram};
use crate::tra::relation::{overlapping_tiles, tile_origin, tile_shape};
use crate::util::{chunk_bounds, serial_scope, ShardScope, SyncPtr, SHARD_MIN};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

// `ResultSlot` / `lock_slot` moved to [`crate::runtime::spill`] with the
// out-of-core tile store that now owns slot lifecycle (re-exported via
// the `use` above so this module reads unchanged).

/// How [`Cluster::execute`] schedules real task execution on host threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Dependency-counted work stealing (default): tasks start the moment
    /// their producers finish, independent subgraphs overlap.
    #[default]
    WorkStealing,
    /// Reference mode: execute level by level with a full barrier between
    /// levels. Kept for differential testing and as the A/B baseline the
    /// work-stealing speedup is measured against.
    LevelBarrier,
}

/// Execution summary for one run.
#[derive(Clone, Debug, Default)]
pub struct ExecReport {
    /// Real wall-clock time of the multi-threaded execution (0 for dry).
    pub wall_s: f64,
    /// Modeled makespan under the network profile.
    pub sim_makespan_s: f64,
    /// Bytes moved across workers, total.
    pub bytes_moved: u64,
    /// Bytes moved, by cost-model class.
    pub bytes_join: u64,
    pub bytes_agg: u64,
    pub bytes_repart: u64,
    pub bytes_input: u64,
    /// Extra traffic and stall time from memory paging (Fig. 11 runs).
    pub bytes_paged: u64,
    pub page_stall_s: f64,
    /// Kernel-call count and total task count.
    pub kernel_calls: usize,
    pub tasks: usize,
    /// Total modeled flops.
    pub flops: f64,
    /// Per-worker modeled busy time.
    pub worker_busy_s: Vec<f64>,
    /// Modeled bytes per link class, `(class name, bytes)` innermost
    /// first, summing to `bytes_moved`. Without a [`Topology`] every
    /// transfer rides the flat profile: `[("flat", bytes_moved)]`.
    /// Empty only on reports that never went through [`Cluster::model`]
    /// (e.g. the memory-policy simulator).
    pub bytes_by_link: Vec<(String, u64)>,
    /// Fault events the armed [`FaultPlan`] actually fired during this
    /// run. All fault-tolerance fields below default to zero/empty, so a
    /// fault-free run's ledger is byte-identical to the pre-recovery
    /// executor's.
    pub faults_injected: u64,
    /// Task re-attempts taken after injected failures (plus the rare
    /// repair retry when a racing worker death yanks a dependency
    /// mid-read).
    pub retries: u64,
    /// Tiles the lineage walk rebuilt because worker death reclaimed
    /// them (input-tile re-slices are free and not counted). Like
    /// `wall_s` this is schedule-dependent: it counts what was actually
    /// lost at the moment of death, which depends on thread interleaving.
    pub recomputed_tasks: u64,
    /// Modeled extra repartition bytes charged when a dead worker's
    /// pending tasks re-home to survivors and their formerly-local
    /// dependency tiles must now cross the wire.
    pub recovery_bytes: u64,
    /// Workers the fault plan killed permanently.
    pub workers_lost: usize,
    /// Backoff time charged to the modeled timeline (added to
    /// `sim_makespan_s` on faulty runs) — the same capped exponential
    /// schedule the wall executor actually slept.
    pub recovery_stall_s: f64,
    /// `recovery_bytes` split per link class (same naming as
    /// `bytes_by_link`). Empty when no recovery traffic was charged.
    pub recovery_by_link: Vec<(String, u64)>,
    /// Per-worker high-water mark of resident tile bytes, tracked by the
    /// [`crate::runtime::spill::TileStore`] even when no budget is set.
    /// Under a [`MemoryBudget`] every entry is `<= budget` by
    /// construction. Like `wall_s`, schedule-dependent.
    pub peak_resident_bytes: Vec<u64>,
    /// Bytes evicted off workers by budget pressure (disk-tier writes of
    /// intermediates plus dropped input views). Zero when unbudgeted, so
    /// an unbudgeted ledger stays byte-identical to the pre-spill
    /// executor's.
    pub spill_bytes: u64,
    /// Evicted tiles faulted back in (demand reads, prefetches, and
    /// input re-slices).
    pub spill_faults: u64,
    /// Wall time spent writing spill files and demand-reading them back
    /// (prefetch reads overlap compute and are not charged).
    pub spill_stall_s: f64,
}

impl ExecReport {
    /// Modeled parallel efficiency: total busy time / (makespan * workers).
    pub fn efficiency(&self) -> f64 {
        let p = self.worker_busy_s.len().max(1) as f64;
        if self.sim_makespan_s <= 0.0 {
            return 1.0;
        }
        self.worker_busy_s.iter().sum::<f64>() / (self.sim_makespan_s * p)
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "tasks={} kernels={} moved={:.2}MiB (join {:.2} agg {:.2} repart {:.2}) sim={:.3}ms wall={:.3}ms eff={:.0}%",
            self.tasks,
            self.kernel_calls,
            self.bytes_moved as f64 / (1 << 20) as f64,
            self.bytes_join as f64 / (1 << 20) as f64,
            self.bytes_agg as f64 / (1 << 20) as f64,
            self.bytes_repart as f64 / (1 << 20) as f64,
            self.sim_makespan_s * 1e3,
            self.wall_s * 1e3,
            self.efficiency() * 100.0
        );
        // fault-free summaries stay byte-identical to the pre-recovery
        // executor's output
        if self.faults_injected > 0 {
            s.push_str(&format!(
                " faults={} retries={} recomputed={} workers_lost={} recovery={:.2}MiB stall={:.3}ms",
                self.faults_injected,
                self.retries,
                self.recomputed_tasks,
                self.workers_lost,
                self.recovery_bytes as f64 / (1 << 20) as f64,
                self.recovery_stall_s * 1e3,
            ));
        }
        // likewise: unbudgeted runs never spill, keeping their summary
        // byte-identical as well (peak residency is schedule-dependent
        // and lives in `to_json`, not here)
        if self.spill_bytes > 0 || self.spill_faults > 0 {
            s.push_str(&format!(
                " spilled={:.2}MiB faults={} spill_stall={:.3}ms",
                self.spill_bytes as f64 / (1 << 20) as f64,
                self.spill_faults,
                self.spill_stall_s * 1e3,
            ));
        }
        s
    }
}

/// A simulated cluster of `workers` devices joined by `net`.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub workers: usize,
    pub net: NetworkProfile,
    pub placement: Policy,
    /// Host-thread scheduling of real execution (modeled accounting is
    /// independent of this).
    pub exec_mode: ExecMode,
    /// Intra-op shard fan-out for real execution under
    /// [`ExecMode::WorkStealing`]: how many independent shards a kernel
    /// splits into so idle workers can help. `0` (the default) means
    /// "match the executor's thread count". Purely a scheduling knob —
    /// results are bitwise-identical for every value.
    pub intra_op: usize,
    /// TRA-IR pass pipeline applied between planning and task emission
    /// (see [`crate::tra::passes`]). The default,
    /// [`PassSelector::Safe`], is task-graph-neutral, so default
    /// lowering reproduces the pre-IR pipeline byte for byte.
    pub passes: PassSelector,
    /// Hierarchical worker topology. `None` (default) models every
    /// cross-worker transfer on the flat `net` profile — byte-for-byte
    /// the seed model; `Some` charges each transfer at the link class of
    /// the two workers' lowest common group, tallies
    /// [`ExecReport::bytes_by_link`], and steers the
    /// `lower-collectives` gather schedule
    /// ([`crate::tra::passes::PassManager::with_topology`]).
    pub topology: Option<Topology>,
    /// Deterministic fault schedule for real execution (see
    /// [`crate::sim::faults`]). `None` (default): nothing is injected and
    /// the executor behaves identically to the pre-recovery
    /// implementation. Faults only affect [`Cluster::execute`]-family
    /// runs; [`Cluster::model`] and [`Cluster::dry_run`] always model the
    /// fault-free timeline.
    pub faults: Option<FaultPlan>,
    /// Per-worker device-memory budget for real execution (the CLI's
    /// `--mem-budget-mb`). `None` (default) and the zero sentinel run the
    /// pre-spill executor with residency tracking only; `Some` arms the
    /// [`crate::runtime::spill::TileStore`]'s spill tier so runs whose
    /// tiles exceed the budget still complete, bitwise-identical. Only
    /// affects [`Cluster::execute`]-family runs, in both [`ExecMode`]s.
    pub mem_budget: Option<MemoryBudget>,
}

impl Cluster {
    pub fn new(workers: usize, net: NetworkProfile) -> Self {
        Cluster {
            workers,
            net,
            placement: Policy::LocalityGreedy,
            exec_mode: ExecMode::WorkStealing,
            intra_op: 0,
            passes: PassSelector::default(),
            topology: None,
            faults: None,
            mem_budget: None,
        }
    }

    /// Builder-style per-worker memory budget (see [`Cluster::mem_budget`]).
    /// The zero sentinel ("unlimited") is normalized to `None`, so
    /// `--mem-budget-mb 0` runs the exact unbudgeted executor.
    pub fn with_mem_budget(mut self, budget: MemoryBudget) -> Self {
        self.mem_budget = if budget.is_unlimited() {
            None
        } else {
            Some(budget)
        };
        self
    }

    /// Builder-style override of the real-execution scheduler.
    pub fn with_exec_mode(mut self, mode: ExecMode) -> Self {
        self.exec_mode = mode;
        self
    }

    /// Builder-style override of the intra-op shard fan-out (`0` = match
    /// the executor's thread count).
    pub fn with_intra_op(mut self, intra_op: usize) -> Self {
        self.intra_op = intra_op;
        self
    }

    /// Builder-style override of the TRA pass pipeline.
    pub fn with_passes(mut self, passes: PassSelector) -> Self {
        self.passes = passes;
        self
    }

    /// Builder-style worker topology (see [`Cluster::topology`]).
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = Some(topology);
        self
    }

    /// Builder-style deterministic fault schedule (see
    /// [`Cluster::faults`]). An empty plan is normalized to `None`, so
    /// `--inject-faults none` runs the exact fault-free executor.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = if plan.is_empty() { None } else { Some(plan) };
        self
    }

    /// Lower + place a planned graph: build the TRA program (Eq. 5), run
    /// the configured pass pipeline, emit and place the task graph. Every
    /// compile validates the placed result (structure + placement, one
    /// walk), so malformed graphs from IR rewrites fail here, not at run
    /// time.
    pub fn lower(&self, g: &EinGraph, plan: &Plan) -> Result<TaskGraph> {
        Ok(self.lower_explain(g, plan)?.0)
    }

    /// [`Self::lower`], also returning the optimized [`TraProgram`] and
    /// the per-pass change log — what `Session::compile` stores so
    /// `Session::explain` / `Executable::tra_program` can show the IR
    /// behind a compiled artifact.
    pub fn lower_explain(
        &self,
        g: &EinGraph,
        plan: &Plan,
    ) -> Result<(TaskGraph, TraProgram, PassLog)> {
        let mut prog = from_plan(g, plan)?;
        // Role-driven baselines plan by label *name*, so IR CSE must
        // compare label-extended join signatures — the same caveat the
        // plan cache honors with `Canon::named_signature`.
        let label_sensitive = matches!(
            plan.strategy.as_str(),
            "data-parallel" | "megatron" | "sequence" | "attention"
        );
        let mut mgr = self.passes.manager().with_label_sensitivity(label_sensitive);
        if let Some(t) = &self.topology {
            mgr = mgr.with_topology(t);
        }
        let log = mgr.run(&mut prog);
        let mut tg = prog.emit_tasks()?;
        place(&mut tg, self.workers, self.placement);
        // validate() re-checks structure (placement cannot invalidate
        // it), so one post-place walk covers both.
        tg.validate(self.workers)?;
        Ok((tg, prog, log))
    }

    /// Model the timeline and traffic of a placed task graph.
    ///
    /// Event-driven LogP-style model: each cross-worker edge pays latency
    /// + bytes/bandwidth, and a sender's NIC serializes its outgoing
    /// transfers (a master distributing everything becomes a bottleneck —
    /// the behaviour that sinks centralized redistribution schemes).
    pub fn model(&self, tg: &TaskGraph) -> ExecReport {
        let n = tg.tasks.len();
        let mut finish = vec![0.0f64; n];
        let mut clock = vec![0.0f64; self.workers];
        let mut nic = vec![0.0f64; self.workers]; // egress availability
        let mut busy = vec![0.0f64; self.workers];
        let mut report = ExecReport {
            tasks: n,
            kernel_calls: tg.kernel_calls(),
            ..Default::default()
        };
        // per-link-class byte tally when a topology is set
        let mut by_link: Vec<u64> = self
            .topology
            .as_ref()
            .map(|t| vec![0u64; t.classes().len()])
            .unwrap_or_default();
        for t in &tg.tasks {
            let w = t.assigned_worker();
            let mut ready = 0.0f64;
            for &d in &t.deps {
                let dep = &tg.tasks[d.0];
                let dw = dep.assigned_worker();
                let mut arrive = finish[d.0];
                if dw != w {
                    let send_start = finish[d.0].max(nic[dw]);
                    // lowest-common-group link class when a topology is
                    // set; `None` is exactly the seed flat-profile math
                    let (bandwidth, wire) = match &self.topology {
                        Some(topo) => {
                            let lc = topo
                                .link_class(dw, w)
                                .unwrap_or(topo.classes().len() - 1);
                            by_link[lc] += dep.out_bytes as u64;
                            let class = &topo.classes()[lc];
                            (class.bandwidth_bps, class.wire_s(dep.out_bytes))
                        }
                        None => (self.net.bandwidth_bps, self.net.wire_s(dep.out_bytes)),
                    };
                    let occupancy = dep.out_bytes as f64 / bandwidth;
                    nic[dw] = send_start + occupancy;
                    arrive = send_start + wire;
                    report.bytes_moved += dep.out_bytes as u64;
                    match t.kind.class() {
                        TransferClass::Join => report.bytes_join += dep.out_bytes as u64,
                        TransferClass::Agg => report.bytes_agg += dep.out_bytes as u64,
                        TransferClass::Repart => report.bytes_repart += dep.out_bytes as u64,
                        TransferClass::Input => report.bytes_input += dep.out_bytes as u64,
                    }
                }
                ready = ready.max(arrive);
            }
            let compute = self.net.compute_s(t.flops);
            let start = ready.max(clock[w]);
            finish[t.id.0] = start + compute;
            clock[w] = finish[t.id.0];
            busy[w] += compute;
            report.flops += t.flops;
        }
        report.sim_makespan_s = finish.iter().copied().fold(0.0, f64::max);
        report.worker_busy_s = busy;
        report.bytes_by_link = match &self.topology {
            Some(topo) => topo
                .classes()
                .iter()
                .zip(&by_link)
                .map(|(c, &b)| (c.name.clone(), b))
                .collect(),
            None => vec![("flat".into(), report.bytes_moved)],
        };
        report
    }

    /// Dry run: plan-level modeling only (no tensors materialized).
    pub fn dry_run(&self, g: &EinGraph, plan: &Plan) -> Result<ExecReport> {
        let tg = self.lower(g, plan)?;
        Ok(self.model(&tg))
    }

    /// Execute for real: compute every task with `engine`, multi-threaded
    /// per [`ExecMode`], and return the dense outputs of the graph's
    /// output vertices plus the report (modeled timeline + measured wall
    /// time). Convenience for [`Self::lower`] + [`Self::run_lowered`];
    /// run-many callers (the `Session` API) lower once and call
    /// [`Self::run_lowered`] directly.
    pub fn execute(
        &self,
        g: &EinGraph,
        plan: &Plan,
        engine: &dyn KernelEngine,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, ExecReport)> {
        let tg = self.lower(g, plan)?;
        self.run_lowered(g, plan, &tg, engine, inputs)
    }

    /// Execute an already lowered + placed task graph. Performs **zero**
    /// planning and **zero** lowering work: `tg` is read-only and can be
    /// reused across any number of calls (each run allocates only its
    /// per-run result slots). This is the run-many half of the
    /// compile-once / run-many split; results are bitwise-identical from
    /// run to run for identical inputs. The modeled timeline is
    /// recomputed here; run-many callers that hold a precomputed
    /// [`Self::model`] report should use [`Self::run_lowered_modeled`].
    pub fn run_lowered(
        &self,
        g: &EinGraph,
        plan: &Plan,
        tg: &TaskGraph,
        engine: &dyn KernelEngine,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, ExecReport)> {
        let base = self.model(tg);
        self.run_lowered_modeled(g, plan, tg, &base, engine, inputs)
    }

    /// [`Self::run_lowered`] with the modeled-timeline report supplied by
    /// the caller (it is a pure function of the frozen `tg`, so the
    /// `Session` API computes it once at compile time instead of paying
    /// the O(tasks + deps) event simulation per request). Only `wall_s`
    /// is stamped fresh on the returned copy. Runs under
    /// [`RunOptions::default`]; callers with a deadline, retry budget, or
    /// input-hygiene needs use [`Self::run_lowered_modeled_opts`].
    pub fn run_lowered_modeled(
        &self,
        g: &EinGraph,
        plan: &Plan,
        tg: &TaskGraph,
        base: &ExecReport,
        engine: &dyn KernelEngine,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, ExecReport)> {
        self.run_lowered_modeled_opts(g, plan, tg, base, engine, inputs, &RunOptions::default())
    }

    /// [`Self::execute`] with explicit [`RunOptions`] — the one-shot
    /// convenience the fault-injection suites use (lower + model + run).
    pub fn execute_opts(
        &self,
        g: &EinGraph,
        plan: &Plan,
        engine: &dyn KernelEngine,
        inputs: &HashMap<VertexId, Tensor>,
        opts: &RunOptions,
    ) -> Result<(HashMap<VertexId, Tensor>, ExecReport)> {
        let tg = self.lower(g, plan)?;
        let base = self.model(&tg);
        self.run_lowered_modeled_opts(g, plan, &tg, &base, engine, inputs, opts)
    }

    /// The full run entry point: typed input validation, fault-injected
    /// execution with lineage recovery, deadline enforcement, and the
    /// recovery counters stamped into the returned report.
    ///
    /// With no armed faults and default options this is behaviorally
    /// identical to the pre-recovery executor: outputs bitwise-equal,
    /// ledger byte-identical (all recovery fields zero).
    #[allow(clippy::too_many_arguments)]
    pub fn run_lowered_modeled_opts(
        &self,
        g: &EinGraph,
        plan: &Plan,
        tg: &TaskGraph,
        base: &ExecReport,
        engine: &dyn KernelEngine,
        inputs: &HashMap<VertexId, Tensor>,
        opts: &RunOptions,
    ) -> Result<(HashMap<VertexId, Tensor>, ExecReport)> {
        // check inputs present, correctly shaped, and (opt-in) finite —
        // typed errors, so serving front-ends can branch without string
        // matching. Extraneous entries in `inputs` are ignored.
        for vid in g.inputs() {
            let vert = g.vertex(vid);
            let t = inputs.get(&vid).ok_or_else(|| {
                Error::exec_failure(
                    None,
                    0,
                    ExecCause::MissingInput {
                        vertex: vert.name.clone(),
                    },
                )
            })?;
            if t.shape() != vert.bound.as_slice() {
                return Err(Error::exec_failure(
                    None,
                    0,
                    ExecCause::ShapeMismatch {
                        vertex: vert.name.clone(),
                        got: t.shape().to_vec(),
                        want: vert.bound.clone(),
                    },
                ));
            }
            if opts.reject_nonfinite {
                if let Some(index) = t.data().iter().position(|v| !v.is_finite()) {
                    return Err(Error::exec_failure(
                        None,
                        0,
                        ExecCause::NonFinite {
                            vertex: vert.name.clone(),
                            index,
                        },
                    ));
                }
            }
        }
        let mut report = base.clone();

        let n = tg.tasks.len();
        let results: Vec<ResultSlot> = (0..n).map(|_| Mutex::new(None)).collect();
        // Output-vertex tiles must survive until assembly below; every
        // other tile is recycled once its last consumer has read it.
        let mut keep = vec![false; n];
        for out in g.outputs() {
            for tid in &tg.vertex_outputs[&out] {
                keep[tid.0] = true;
            }
        }
        let ctx = RunCtx::new(self, tg, g, plan, engine, inputs, &results, *opts)?;
        // Pre-slice all input tiles serially (they carry no deps and model
        // the paper's free, offline pre-partitioning). With views this is
        // O(1) per tile — no input bytes are copied. Published through the
        // tile store so input bytes count against their placed worker's
        // budget: inputs that exceed it (the llama over-budget story) are
        // evicted to the zero-cost `Input` tier and re-sliced on fault.
        for t in &tg.tasks {
            if matches!(t.kind, TaskKind::InputTile { .. }) {
                let view = slice_input(tg, g, plan, inputs, t.id.0)?;
                let w = ctx.home(t.id.0);
                ctx.store.publish(&results, t.id.0, w, view, &ctx.completed)?;
                ctx.mark_completed(t.id.0);
            }
        }
        let threads = std::thread::available_parallelism()
            .map(|x| x.get())
            .unwrap_or(4)
            .min(self.workers.max(1) * 2)
            .max(1);
        match self.exec_mode {
            ExecMode::WorkStealing => self.run_work_stealing(&ctx, threads, &keep)?,
            ExecMode::LevelBarrier => self.run_level_barrier(&ctx, threads)?,
        }
        // A worker death late in the run may have dropped output tiles
        // whose producing tasks had already completed; rebuild them (and
        // any missing lineage under them) before assembly.
        if ctx.armed.is_some() {
            ctx.check_deadline()?;
            for out in g.outputs() {
                for tid in &tg.vertex_outputs[&out] {
                    ctx.ensure_tile(tid.0, &serial_scope())?;
                }
            }
        }
        report.wall_s = ctx.start.elapsed().as_secs_f64();

        // assemble outputs
        let mut outputs = HashMap::new();
        for out in g.outputs() {
            let vert = g.vertex(out);
            let part = &tg.vertex_out_part[&out];
            let tiles = &tg.vertex_outputs[&out];
            let mut dense = Tensor::zeros(&vert.bound);
            for (key, &tid) in crate::tensor::index_space(part).zip(tiles) {
                // An output tile may itself have been evicted by later
                // budget pressure; fault it back before reading.
                if ctx.store.budgeted() {
                    let w = ctx.home(tid.0);
                    ctx.store.fault_if_spilled(&results, tid.0, w, &ctx.completed, &|| {
                        slice_input(tg, g, plan, inputs, tid.0)
                    })?;
                }
                // Borrow, don't take: after IR CSE two output vertices
                // can share one set of result tiles, and each assembly
                // must read them. The drain below recycles every slot
                // exactly once.
                let slot = lock_slot(&results, tid.0)?;
                let tile = slot
                    .as_ref()
                    .ok_or_else(|| Error::Exec("missing result tile".into()))?;
                let origin = tile_origin(&vert.bound, part, &key);
                dense.write_slice_view(&origin, tile)?;
            }
            outputs.insert(out, dense);
        }
        // Drain whatever is left (un-reclaimed tiles, level-barrier runs)
        // into the calling thread's pool, and delete any leftover spill
        // files. Note the reuse horizon: buffers reclaimed mid-run land in
        // scoped *worker* threads' pools and are reused within this
        // execute() only (those pools die with the thread scope); what is
        // drained here survives across executes.
        for i in 0..results.len() {
            ctx.store.reclaim(&results, i)?;
        }
        ctx.stamp(&mut report);
        Ok((outputs, report))
    }

    /// Dependency-counted work-stealing execution (default mode). Input
    /// tiles are already materialized in `results`; their tasks are
    /// no-ops that exist only to release their consumers' counters.
    ///
    /// Kernel bodies receive the scheduler's [`ShardScope`] so idle
    /// workers steal intra-op shards of running tasks — the fan-out is
    /// `self.intra_op`, defaulting to the thread count.
    ///
    /// After a task completes it decrements each dependency's
    /// remaining-reader counter (initialized to the occurrence-counted
    /// consumer count the scheduler also uses); the reader performing the
    /// final decrement takes the tile out of its slot and recycles its
    /// buffer into that worker's [`crate::util::BufferPool`] — unless the
    /// tile belongs to a graph output, which assembly consumes later.
    /// Worker pools are thread-local to scoped threads, so this
    /// reclamation feeds allocation reuse *within* the run; cross-run
    /// reuse comes from the end-of-`execute` drain on the caller's
    /// thread. Reclamation only recycles buffers with no remaining
    /// references, so it cannot affect values (and aliased tiles keep
    /// shared buffers alive).
    fn run_work_stealing(&self, ctx: &RunCtx<'_>, threads: usize, keep: &[bool]) -> Result<()> {
        let consumers = ctx.tg.consumers();
        let indegree = ctx.tg.indegrees();
        // Placement seeds initial deque affinity: a task's home deque is
        // its placed worker (mod nothing — out-of-range homes fall into
        // the shared injector, which is exactly the case threads < workers).
        // Homes are affinity hints only, so the frozen snapshot is fine
        // even if a mid-run death later re-homes tasks in the overlay.
        let home: Vec<usize> = ctx
            .effective
            .iter()
            .map(|w| w.load(Ordering::Relaxed))
            .collect();
        let intra_op = if self.intra_op == 0 {
            threads
        } else {
            self.intra_op
        };
        // `reads_left[d]` counts the decrements d's consumers have not yet
        // performed. Consumers decrement only after success, so clearing a
        // slot on worker death needs no counter surgery: the recomputed
        // tile simply absorbs the remaining decrements, and the final one
        // recycles it exactly as it would have the original.
        let reads_left: Vec<AtomicUsize> =
            consumers.iter().map(|c| AtomicUsize::new(c.len())).collect();
        crate::util::execute_dag_scoped(
            &consumers,
            &indegree,
            &home,
            threads,
            intra_op,
            |ti, scope| {
                ctx.exec_recovering(ti, scope)?;
                for &d in &ctx.tg.tasks[ti].deps {
                    if reads_left[d.0].fetch_sub(1, Ordering::AcqRel) == 1 && !keep[d.0] {
                        // Routed through the store: a fully-consumed tile
                        // may have been evicted, in which case reclamation
                        // deletes its spill file instead of recycling a
                        // resident buffer (and releases its residency
                        // charge either way).
                        ctx.store.reclaim(ctx.results, d.0)?;
                    }
                }
                Ok(())
            },
        )
    }

    /// Reference mode: one persistent thread team, synchronized per ASAP
    /// level with a barrier. Retained so differential tests and benches
    /// can compare against the work-stealing scheduler.
    fn run_level_barrier(&self, ctx: &RunCtx<'_>, threads: usize) -> Result<()> {
        let by_level = ctx.tg.levels();
        if threads == 1 {
            for lvl in &by_level {
                for &ti in lvl {
                    ctx.exec_recovering(ti, &serial_scope())?;
                }
            }
            return Ok(());
        }
        let err = std::sync::Mutex::new(None::<Error>);
        let counters: Vec<AtomicUsize> = by_level.iter().map(|_| AtomicUsize::new(0)).collect();
        let barrier = std::sync::Barrier::new(threads);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    for (li, lvl) in by_level.iter().enumerate() {
                        loop {
                            // first error wins; stop claiming more work but
                            // keep hitting every barrier so siblings drain
                            if err.lock().map(|e| e.is_some()).unwrap_or(true) {
                                break;
                            }
                            let i = counters[li].fetch_add(1, Ordering::Relaxed);
                            if i >= lvl.len() {
                                break;
                            }
                            if let Err(e) = ctx.exec_recovering(lvl[i], &serial_scope()) {
                                if let Ok(mut slot) = err.lock() {
                                    slot.get_or_insert(e);
                                }
                            }
                        }
                        barrier.wait();
                    }
                });
            }
        });
        match err.into_inner() {
            Ok(Some(e)) => Err(e),
            Ok(None) => Ok(()),
            Err(_) => Err(Error::exec_failure(
                None,
                0,
                ExecCause::LockPoisoned {
                    what: "level-barrier error slot",
                },
            )),
        }
    }
}

/// Shared state of one recovering execution: the frozen task graph plus
/// its per-run slots, the armed fault plan, the re-homable placement
/// overlay, and the recovery counters that end up in [`ExecReport`].
///
/// The frozen [`TaskGraph`] is never mutated — worker death is recorded
/// in the `effective` overlay (task → live worker) — so compile-once /
/// run-many artifacts survive a faulty run untouched.
struct RunCtx<'a> {
    cluster: &'a Cluster,
    tg: &'a TaskGraph,
    g: &'a EinGraph,
    plan: &'a Plan,
    engine: &'a dyn KernelEngine,
    inputs: &'a HashMap<VertexId, Tensor>,
    results: &'a [ResultSlot],
    opts: RunOptions,
    armed: Option<ArmedFaults>,
    start: Instant,
    /// Per-task effective worker: placement, overridden on re-homing.
    effective: Vec<AtomicUsize>,
    /// Per-worker death flags.
    dead: Vec<AtomicBool>,
    /// Tasks whose tile has been produced (and not lost to a death since)
    /// — the "pending" predicate the re-homing accountant uses, and the
    /// progress numerator of a deadline error.
    completed: Vec<AtomicBool>,
    completed_count: AtomicUsize,
    /// Serializes worker deaths: re-home + slot clearing is multi-step.
    kill_lock: Mutex<()>,
    /// Out-of-core tile store: owns residency accounting, the spill/fault
    /// tier, and eviction. Unbudgeted it only tracks per-worker peaks.
    store: TileStore,
    /// Tasks per initial-placement worker, ascending id — the frozen
    /// prefetch order (next-k tasks per worker are known at placement).
    worker_tasks: Vec<Vec<usize>>,
    /// Each task's index within its home worker's `worker_tasks` list.
    home_pos: Vec<usize>,
    faults_injected: AtomicU64,
    retries: AtomicU64,
    recomputed: AtomicU64,
    recovery_bytes: AtomicU64,
    recovery_by_link: Vec<AtomicU64>,
    workers_lost: AtomicUsize,
    stall_ns: AtomicU64,
}

impl<'a> RunCtx<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        cluster: &'a Cluster,
        tg: &'a TaskGraph,
        g: &'a EinGraph,
        plan: &'a Plan,
        engine: &'a dyn KernelEngine,
        inputs: &'a HashMap<VertexId, Tensor>,
        results: &'a [ResultSlot],
        opts: RunOptions,
    ) -> Result<Self> {
        let mut effective = Vec::with_capacity(tg.tasks.len());
        for t in &tg.tasks {
            // the run path reads placement through the typed accessor
            effective.push(AtomicUsize::new(t.worker_checked()?));
        }
        let armed = cluster
            .faults
            .as_ref()
            .filter(|f| !f.is_empty())
            .map(|f| f.arm(tg.tasks.len()));
        let classes = cluster
            .topology
            .as_ref()
            .map(|t| t.classes().len())
            .unwrap_or(1);
        // Occurrence-counted consumer lists double as the store's
        // next-use oracle (ascending by construction: `consumers` walks
        // tasks in id order). Input tiles spill by dropping their view.
        let consumers = tg.consumers();
        let input_tile: Vec<bool> = tg
            .tasks
            .iter()
            .map(|t| matches!(t.kind, TaskKind::InputTile { .. }))
            .collect();
        let store = TileStore::new(cluster.workers, cluster.mem_budget, consumers, input_tile);
        let workers = cluster.workers.max(1);
        let mut worker_tasks: Vec<Vec<usize>> = vec![vec![]; workers];
        let mut home_pos = vec![0usize; tg.tasks.len()];
        for (i, e) in effective.iter().enumerate() {
            let w = e.load(Ordering::Relaxed).min(workers - 1);
            home_pos[i] = worker_tasks[w].len();
            worker_tasks[w].push(i);
        }
        Ok(RunCtx {
            cluster,
            tg,
            g,
            plan,
            engine,
            inputs,
            results,
            opts,
            armed,
            start: Instant::now(),
            effective,
            dead: (0..cluster.workers.max(1)).map(|_| AtomicBool::new(false)).collect(),
            completed: (0..tg.tasks.len()).map(|_| AtomicBool::new(false)).collect(),
            completed_count: AtomicUsize::new(0),
            kill_lock: Mutex::new(()),
            store,
            worker_tasks,
            home_pos,
            faults_injected: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            recomputed: AtomicU64::new(0),
            recovery_bytes: AtomicU64::new(0),
            recovery_by_link: (0..classes).map(|_| AtomicU64::new(0)).collect(),
            workers_lost: AtomicUsize::new(0),
            stall_ns: AtomicU64::new(0),
        })
    }

    fn slot(&self, i: usize) -> Result<MutexGuard<'a, Option<TensorView>>> {
        lock_slot(self.results, i)
    }

    /// Task `ti`'s effective worker, clamped into the store's range.
    fn home(&self, ti: usize) -> usize {
        self.effective[ti]
            .load(Ordering::Acquire)
            .min(self.dead.len() - 1)
    }

    /// Pin task `ti`'s dependency tiles resident on its worker, faulting
    /// spilled ones back in. On failure the already-pinned prefix is
    /// unpinned so no pin leaks. Budgeted runs only.
    fn pin_deps(&self, ti: usize) -> Result<()> {
        let w = self.home(ti);
        let deps = &self.tg.tasks[ti].deps;
        for (k, &d) in deps.iter().enumerate() {
            let r = self.store.pin(self.results, d.0, w, &self.completed, &|| {
                slice_input(self.tg, self.g, self.plan, self.inputs, d.0)
            });
            if let Err(e) = r {
                for &p in &deps[..k] {
                    self.store.unpin(p.0);
                }
                return Err(e);
            }
        }
        Ok(())
    }

    fn unpin_deps(&self, ti: usize) {
        for &d in &self.tg.tasks[ti].deps {
            self.store.unpin(d.0);
        }
    }

    /// Best-effort read-ahead: the task graph is frozen, so the next
    /// [`PREFETCH_WINDOW`] tasks initially placed on `ti`'s worker are
    /// known now — fault their spilled dependencies into free headroom
    /// while `ti` computes. Never evicts; skips anything contended.
    fn prefetch_window(&self, ti: usize) -> Result<()> {
        let w = self.home(ti);
        let list = &self.worker_tasks[w];
        let pos = self.home_pos[ti];
        for &nt in list.iter().skip(pos + 1).take(PREFETCH_WINDOW) {
            if self.completed[nt].load(Ordering::Acquire) {
                continue;
            }
            for &d in &self.tg.tasks[nt].deps {
                self.store.prefetch(self.results, d.0, w, &|| {
                    slice_input(self.tg, self.g, self.plan, self.inputs, d.0)
                })?;
            }
        }
        Ok(())
    }

    fn mark_completed(&self, ti: usize) {
        if !self.completed[ti].swap(true, Ordering::AcqRel) {
            self.completed_count.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Typed timeout: past the deadline, every subsequent task attempt
    /// fails with the run's partial-progress stats (the scheduler aborts
    /// on the first error, so the run returns promptly).
    fn check_deadline(&self) -> Result<()> {
        if let Some(d) = self.opts.deadline {
            let elapsed = self.start.elapsed();
            if elapsed >= d {
                return Err(Error::exec_failure(
                    None,
                    0,
                    ExecCause::DeadlineExceeded {
                        elapsed_s: elapsed.as_secs_f64(),
                        completed: self.completed_count.load(Ordering::Relaxed),
                        total: self.tg.tasks.len(),
                        retries: self.retries.load(Ordering::Relaxed),
                    },
                ));
            }
        }
        Ok(())
    }

    /// Sleep the capped exponential backoff for retry `attempt` (real
    /// time) and charge the same delay to the modeled ledger (virtual
    /// time, surfaced as `recovery_stall_s`).
    fn backoff_and_count(&self, attempt: u32) {
        let d = self.opts.backoff(attempt);
        self.stall_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
        self.retries.fetch_add(1, Ordering::Relaxed);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
    }

    /// Produce task `ti`'s tile. Input tiles re-slice the caller's dense
    /// tensor (graph inputs live in driver memory, outside any worker, so
    /// they are always recoverable); everything else runs the kernel.
    fn compute_tile(&self, ti: usize, scope: &ShardScope) -> Result<TensorView> {
        if matches!(self.tg.tasks[ti].kind, TaskKind::InputTile { .. }) {
            slice_input(self.tg, self.g, self.plan, self.inputs, ti)
        } else {
            exec_task(self.tg, self.g, self.plan, self.engine, self.results, ti, scope)
        }
    }

    /// The scheduler's task body: deterministic fault injection, retry
    /// with capped exponential backoff, and lineage repair of missing
    /// dependency tiles. Non-injected kernel errors are deterministic
    /// (same inputs → same failure), so they propagate immediately — only
    /// injected faults and racing-death dep losses are retried.
    fn exec_recovering(&self, ti: usize, scope: &ShardScope) -> Result<()> {
        let mut attempt: u32 = 0;
        let mut budget_attempt: u32 = 0;
        loop {
            self.check_deadline()?;
            if let Some(kind) = self.armed.as_ref().and_then(|a| a.next_failure(ti)) {
                self.faults_injected.fetch_add(1, Ordering::Relaxed);
                let permanent = matches!(kind, FaultKind::Permanent);
                if permanent {
                    // the fault kills the task's *worker*: every tile
                    // homed there dies with it, pending tasks re-home
                    self.kill_worker(self.effective[ti].load(Ordering::Acquire))?;
                }
                if attempt >= self.opts.max_retries {
                    return Err(Error::exec_failure(
                        Some(ti),
                        attempt + 1,
                        ExecCause::Injected { permanent },
                    ));
                }
                self.backoff_and_count(attempt);
                attempt += 1;
                continue;
            }
            // lineage repair: recompute whatever upstream tiles a worker
            // death reclaimed, minimal subgraph only (resident tiles are
            // reused as-is)
            let ensured = (|| {
                for &d in &self.tg.tasks[ti].deps {
                    self.ensure_tile(d.0, scope)?;
                }
                Ok(())
            })();
            if let Err(e) = ensured {
                if is_missing_dep(&e) && attempt < self.opts.max_retries {
                    // a racing death yanked a tile mid-walk; back off and
                    // re-walk (deaths are finite: each worker dies once)
                    self.backoff_and_count(attempt);
                    attempt += 1;
                    continue;
                }
                return Err(retag(e, ti, attempt + 1));
            }
            // pre-sliced input tiles (and tiles an eager recovery walk
            // already rebuilt) are done the moment we observe them — a
            // *spilled* tile counts: it was produced, and its consumers
            // fault it back rather than recompute it
            if self.slot(ti)?.is_some()
                || (self.store.budgeted() && self.store.is_spilled(ti))
            {
                self.mark_completed(ti);
                return Ok(());
            }
            // Budgeted: pin the working set resident (faulting spilled
            // deps back in) so kernel reads cannot race eviction, then
            // overlap read-ahead for the next tasks on this worker with
            // the kernel below. Unbudgeted runs skip both entirely.
            //
            // Pinning is two-phase with abort: concurrent tasks whose
            // pinned working sets contend for one worker's budget could
            // otherwise deadlock (each waiting for the other's pins), so
            // a failed reservation releases *all* pins held here (done
            // inside `pin_deps`), backs off, and retries — by then the
            // contender has typically finished and unpinned. Only after
            // `BUDGET_RETRIES` staggered attempts is the typed
            // `BudgetExceeded` allowed to surface: at that point the
            // working set genuinely does not fit alone.
            if self.store.budgeted() {
                if let Err(e) = self.pin_deps(ti) {
                    if is_missing_dep(&e) && attempt < self.opts.max_retries {
                        // a racing death purged a dep from both tiers;
                        // back off and re-walk its lineage
                        self.backoff_and_count(attempt);
                        attempt += 1;
                        continue;
                    }
                    if is_budget_exceeded(&e) && budget_attempt < BUDGET_RETRIES {
                        budget_attempt += 1;
                        budget_backoff(ti, budget_attempt);
                        continue;
                    }
                    return Err(retag(e, ti, attempt + 1));
                }
                self.prefetch_window(ti)?;
            }
            let computed = self.compute_tile(ti, scope);
            if self.store.budgeted() {
                self.unpin_deps(ti);
            }
            match computed {
                Ok(tile) => {
                    // the store reserves budget room (evicting cold tiles
                    // as needed) and handles the lost-publish race by
                    // recycling our bitwise-identical duplicate
                    let w = self.home(ti);
                    match self.store.publish(self.results, ti, w, tile, &self.completed) {
                        Ok(_) => {
                            self.mark_completed(ti);
                            return Ok(());
                        }
                        Err(e) if is_budget_exceeded(&e) && budget_attempt < BUDGET_RETRIES => {
                            // no pins held here, so this is pure foreign
                            // contention; the recompute is wasteful but
                            // rare, and bitwise-identical by construction
                            budget_attempt += 1;
                            budget_backoff(ti, budget_attempt);
                        }
                        Err(e) => return Err(retag(e, ti, attempt + 1)),
                    }
                }
                Err(e) if is_missing_dep(&e) && attempt < self.opts.max_retries => {
                    self.backoff_and_count(attempt);
                    attempt += 1;
                }
                Err(e) => return Err(retag(e, ti, attempt + 1)),
            }
        }
    }

    /// Lineage-based recovery: make task `d`'s tile present, recomputing
    /// the minimal missing upstream subgraph first (depth-first over
    /// `deps`; recursion depth is the graph's level count — tens, not
    /// thousands). Recomputation is bitwise-identical to the original
    /// execution because tasks are pure functions of their deps and every
    /// fold order is fixed by the graph. Racing repairs of one tile are
    /// benign: both compute identical bytes, one wins the slot, the
    /// loser's buffer is recycled.
    fn ensure_tile(&self, d: usize, scope: &ShardScope) -> Result<()> {
        if self.slot(d)?.is_some() {
            return Ok(());
        }
        self.check_deadline()?;
        // an evicted tile was produced and is still the authoritative
        // copy: fault it back (counts as a spill fault, never as a
        // recompute) instead of re-running its lineage
        if self.store.budgeted() {
            let w = self.home(d);
            let restored = self.store.fault_if_spilled(self.results, d, w, &self.completed, &|| {
                slice_input(self.tg, self.g, self.plan, self.inputs, d)
            })?;
            if restored {
                return Ok(());
            }
        }
        for &dd in &self.tg.tasks[d].deps {
            self.ensure_tile(dd.0, scope)?;
        }
        if self.store.budgeted() {
            self.pin_deps(d)?;
        }
        let computed = self.compute_tile(d, scope);
        if self.store.budgeted() {
            self.unpin_deps(d);
        }
        let w = self.home(d);
        if self.store.publish(self.results, d, w, computed?, &self.completed)? {
            if !matches!(self.tg.tasks[d].kind, TaskKind::InputTile { .. }) {
                self.recomputed.fetch_add(1, Ordering::Relaxed);
            }
            self.mark_completed(d);
        }
        Ok(())
    }

    /// Permanent-fault handler: mark `w` dead, re-home everything placed
    /// there onto the survivors (round-robin by task id — deterministic),
    /// drop every tile homed on `w` (its memory is gone with it), and
    /// charge the modeled ledger for the formerly-local dependency bytes
    /// that pending victims must now pull across the wire to their new
    /// homes.
    fn kill_worker(&self, w: usize) -> Result<()> {
        let _guard = self.kill_lock.lock().map_err(|_| {
            Error::exec_failure(None, 0, ExecCause::LockPoisoned { what: "kill lock" })
        })?;
        if self.dead[w].swap(true, Ordering::AcqRel) {
            return Ok(()); // the plan faulted two tasks on one worker
        }
        self.workers_lost.fetch_add(1, Ordering::Relaxed);
        let survivors: Vec<usize> = (0..self.dead.len())
            .filter(|&i| !self.dead[i].load(Ordering::Acquire))
            .collect();
        if survivors.is_empty() {
            return Err(Error::exec_failure(None, 0, ExecCause::NoSurvivors));
        }
        let n = self.tg.tasks.len();
        let victim: Vec<bool> = (0..n)
            .map(|i| self.effective[i].load(Ordering::Acquire) == w)
            .collect();
        let new_home = |i: usize| survivors[i % survivors.len()];
        // Modeled accounting: a pending victim's formerly-*local* deps
        // (both ends on `w`, so the base ledger charged nothing) must now
        // be rebuilt on the dep's new home and shipped to the task's.
        // Deps that already crossed workers stay charged by the base
        // ledger. Snapshot-based, so like `wall_s` it depends on how far
        // execution had progressed when the fault fired.
        for i in 0..n {
            if !victim[i] || self.completed[i].load(Ordering::Acquire) {
                continue;
            }
            let s = new_home(i);
            for &dp in &self.tg.tasks[i].deps {
                if victim[dp.0] {
                    let nd = new_home(dp.0);
                    if nd != s {
                        self.charge_recovery(nd, s, self.tg.tasks[dp.0].out_bytes as u64);
                    }
                }
            }
        }
        // Re-home the overlay and drop dead tiles — including *spilled*
        // ones: the spill tier models worker-local disk, which dies with
        // the worker, so `purge` clears both residency and disk state.
        // `reads_left` counters need no surgery: they count *future*
        // consumer decrements, which clearing a slot does not change —
        // the recomputed tile simply absorbs them (see
        // `run_work_stealing`).
        for i in 0..n {
            if !victim[i] {
                continue;
            }
            self.effective[i].store(new_home(i), Ordering::Release);
            if self.store.purge(self.results, i)?
                && self.completed[i].swap(false, Ordering::AcqRel)
            {
                self.completed_count.fetch_sub(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    fn charge_recovery(&self, from: usize, to: usize, bytes: u64) {
        self.recovery_bytes.fetch_add(bytes, Ordering::Relaxed);
        let class = match &self.cluster.topology {
            Some(t) => t.link_class(from, to).unwrap_or(t.classes().len() - 1),
            None => 0,
        };
        self.recovery_by_link[class].fetch_add(bytes, Ordering::Relaxed);
    }

    /// Stamp the recovery counters into the report. On a fault-free run
    /// every field stays at its zero default, leaving the ledger
    /// byte-identical to the pre-recovery executor's.
    fn stamp(&self, report: &mut ExecReport) {
        report.faults_injected = self.faults_injected.load(Ordering::Relaxed);
        report.retries = self.retries.load(Ordering::Relaxed);
        report.recomputed_tasks = self.recomputed.load(Ordering::Relaxed);
        report.recovery_bytes = self.recovery_bytes.load(Ordering::Relaxed);
        report.workers_lost = self.workers_lost.load(Ordering::Relaxed);
        report.recovery_stall_s = self.stall_ns.load(Ordering::Relaxed) as f64 * 1e-9;
        if report.recovery_bytes > 0 {
            report.recovery_by_link = match &self.cluster.topology {
                Some(t) => t
                    .classes()
                    .iter()
                    .zip(&self.recovery_by_link)
                    .map(|(c, b)| (c.name.clone(), b.load(Ordering::Relaxed)))
                    .collect(),
                None => vec![("flat".into(), report.recovery_bytes)],
            };
        }
        if report.faults_injected > 0 {
            // injected failures stall the modeled timeline by the same
            // backoff schedule the wall executor slept
            report.sim_makespan_s += report.recovery_stall_s;
        }
        // Out-of-core ledger. Peak residency is tracked even unbudgeted;
        // the spill counters stay zero without a budget, and
        // `sim_makespan_s` is deliberately untouched by spill traffic
        // (host-transfer pricing of it lives in the memory-policy model
        // and the fig11 bench, which charge `net.host_s` explicitly).
        report.peak_resident_bytes = self.store.peak_resident();
        report.spill_bytes = self.store.spill_bytes();
        report.spill_faults = self.store.spill_faults();
        report.spill_stall_s = self.store.spill_stall_s();
    }
}

/// True for the typed missing-dependency error the recovery loop treats
/// as retryable (a racing worker death can clear a dep between the
/// lineage walk and the read).
fn is_missing_dep(e: &Error) -> bool {
    matches!(
        e.as_exec().map(|x| &x.cause),
        Some(ExecCause::MissingDep { .. })
    )
}

/// True for the typed budget-overflow error. Retryable inside
/// `exec_recovering`: a reservation that fails while *other* tasks hold
/// pins on the same worker is contention, not a genuine misfit, and
/// resolves once the contenders unpin.
fn is_budget_exceeded(e: &Error) -> bool {
    matches!(
        e.as_exec().map(|x| &x.cause),
        Some(ExecCause::BudgetExceeded { .. })
    )
}

/// How many release-all-pins-and-retry rounds a task gets before a
/// failed budget reservation is accepted as a genuine single-task
/// misfit. Generous because each round is cheap and a false
/// `BudgetExceeded` aborts the whole run.
const BUDGET_RETRIES: u32 = 64;

/// Stagger budget-contention retries so symmetric contenders don't
/// re-collide: linear per-attempt backoff, capped, skewed by task id.
fn budget_backoff(ti: usize, attempt: u32) {
    std::thread::yield_now();
    let us = (u64::from(attempt) * (50 + (ti as u64 % 7) * 17)).min(2_000);
    std::thread::sleep(std::time::Duration::from_micros(us));
}

/// Attribute an execution error to the task the scheduler was running:
/// typed causes keep their cause with `task`/`attempts` filled in;
/// legacy string errors (kernel internals) wrap as [`ExecCause::Kernel`].
fn retag(e: Error, ti: usize, attempts: u32) -> Error {
    match e {
        Error::ExecFailure(mut x) => {
            if x.task.is_none() {
                x.task = Some(ti);
            }
            x.attempts = attempts;
            Error::ExecFailure(x)
        }
        other => Error::exec_failure(
            Some(ti),
            attempts,
            ExecCause::Kernel {
                detail: other.to_string(),
            },
        ),
    }
}

/// Slice one pre-partitioned input tile out of the caller-provided dense
/// input tensor — O(1), views only. Used both by the up-front pre-slice
/// pass and by the recovery walk when a worker death dropped an input
/// tile. The emitted graph is the authority on input layout: the
/// `propagate-partitions` pass may have rewritten it away from the
/// plan's `input_parts`. (Direct-lowered graphs register the plan layout
/// verbatim, so the fallback only covers unpartitioned inputs.)
fn slice_input(
    tg: &TaskGraph,
    g: &EinGraph,
    plan: &Plan,
    inputs: &HashMap<VertexId, Tensor>,
    ti: usize,
) -> Result<TensorView> {
    let (vertex, key) = match &tg.tasks[ti].kind {
        TaskKind::InputTile { vertex, key } => (vertex, key),
        _ => {
            return Err(Error::Exec(
                "slice_input called on a non-input task (internal)".into(),
            ))
        }
    };
    let vert = g.vertex(*vertex);
    let part = tg
        .vertex_out_part
        .get(vertex)
        .or_else(|| plan.input_parts.get(vertex))
        .cloned()
        .unwrap_or_else(|| vec![1; vert.bound.len()]);
    let origin = tile_origin(&vert.bound, &part, key);
    let shape = tile_shape(&vert.bound, &part, key);
    let src = inputs.get(vertex).ok_or_else(|| {
        Error::exec_failure(
            Some(ti),
            0,
            ExecCause::MissingInput {
                vertex: vert.name.clone(),
            },
        )
    })?;
    src.slice_view(&origin, &shape)
}

/// Execute a single task; all deps already computed. `scope` is the
/// executor's intra-op shard capability (serial in the level-barrier
/// reference mode); every sharded path is bitwise-identical to serial.
///
/// Dependencies are read as cheap view clones (an `Arc` bump) out of
/// their slots, so a concurrent reclamation of *other* tasks' slots can
/// never invalidate them.
fn exec_task(
    tg: &TaskGraph,
    g: &EinGraph,
    plan: &Plan,
    engine: &dyn KernelEngine,
    results: &[ResultSlot],
    ti: usize,
    scope: &ShardScope,
) -> Result<TensorView> {
    let task = &tg.tasks[ti];
    let dep_view = |d: crate::taskgraph::TaskId| -> Result<TensorView> {
        lock_slot(results, d.0)?.clone().ok_or_else(|| {
            // typed so the recovery loop can distinguish "tile reclaimed
            // by a racing worker death" (repairable) from kernel errors
            Error::exec_failure(None, 0, ExecCause::MissingDep { dep: d.0 })
        })
    };
    match &task.kind {
        TaskKind::InputTile { .. } => Err(Error::Exec(
            "input tiles are pre-sliced by execute() (internal)".into(),
        )),
        TaskKind::Kernel { vertex, key } => {
            let vert = g.vertex(*vertex);
            let op = &vert.op;
            // `fuse-epilogue` attaches retired map vertices here; empty
            // on every unfused lowering.
            let epi = tg.kernel_epilogue.get(&task.id).map(Vec::as_slice);
            let eval = |refs: &[&TensorView]| -> Result<Tensor> {
                match epi {
                    Some(eps) => engine.eval_view_epilogue_scoped(op, refs, eps, scope),
                    None => engine.eval_view_scoped(op, refs, scope),
                }
            };
            // Fast path (every non-aliased lowering, incl. the default
            // `safe` pipeline): deps are exactly the expected operand
            // tiles — no per-operand geometry work on the hot path.
            if !tg.aliased_kernel_deps {
                let ins: Vec<TensorView> = task
                    .deps
                    .iter()
                    .map(|&d| dep_view(d))
                    .collect::<Result<_>>()?;
                let refs: Vec<&TensorView> = ins.iter().collect();
                return eval(&refs).map(Tensor::into_view);
            }
            let uniq = op.unique_labels();
            let mut ins: Vec<TensorView> = Vec::with_capacity(task.deps.len());
            for (o, &dt) in task.deps.iter().enumerate() {
                let view = dep_view(dt)?;
                let c = vert.inputs[o];
                let cb = &g.vertex(c).bound;
                let need = plan.required_in_part(g, *vertex, o);
                let okey = project(key, op.operand_labels()[o], &uniq);
                let shape = tile_shape(cb, &need, &okey);
                if view.shape() == shape.as_slice() {
                    ins.push(view);
                } else {
                    // `alias-refinement-repart` rewrite: the dep is the
                    // single producer tile *containing* the needed
                    // region (same containment math as the IR emission —
                    // geometry only, no search). Slice the exact
                    // sub-view the elided repart task would have
                    // produced: bitwise-identical bytes and strides,
                    // zero copies.
                    let have = &tg.vertex_out_part[&c];
                    let origin = tile_origin(cb, &need, &okey);
                    let pkey: Vec<usize> = (0..cb.len())
                        .map(|dim| {
                            overlapping_tiles(cb[dim], have[dim], origin[dim], shape[dim]).0
                        })
                        .collect();
                    let p_origin = tile_origin(cb, have, &pkey);
                    let rel_off: Vec<usize> =
                        origin.iter().zip(&p_origin).map(|(t, p)| t - p).collect();
                    ins.push(view.slice(&rel_off, &shape)?);
                }
            }
            let refs: Vec<&TensorView> = ins.iter().collect();
            eval(&refs).map(Tensor::into_view)
        }
        TaskKind::Agg { vertex, .. } => {
            let agg = match &g.vertex(*vertex).op {
                EinSum::Unary { agg, .. } => *agg,
                EinSum::Binary { agg, .. } => *agg,
                EinSum::Input => AggOp::Sum,
            };
            // Deterministic regardless of scheduling: combine in fixed
            // `deps` order, never completion order. Large folds chunk the
            // output buffer across shards — each cell still combines its
            // deps in the same order, so chunking cannot change bits.
            // `acc` may hold a pooled buffer; every error exit below
            // recycles it so a failing task leaks nothing from the pool.
            let mut acc = dep_view(task.deps[0])?.to_tensor();
            let rest: Vec<TensorView> = match task.deps[1..]
                .iter()
                .map(|&d| dep_view(d))
                .collect::<Result<_>>()
            {
                Ok(r) => r,
                Err(e) => {
                    acc.recycle();
                    return Err(e);
                }
            };
            for t in &rest {
                if t.shape() != acc.shape() {
                    let msg = format!(
                        "aggregate shape mismatch: {:?} vs {:?}",
                        acc.shape(),
                        t.shape()
                    );
                    acc.recycle();
                    return Err(Error::Shape(msg));
                }
            }
            // Kernel outputs are contiguous whole-buffer views; fold over
            // their flat slices. (A non-contiguous dep — impossible today
            // — would materialize below.)
            let p = scope.parallelism();
            if p > 1
                && !rest.is_empty()
                && acc.len() >= SHARD_MIN
                && rest.iter().all(|t| t.is_contiguous())
            {
                let len = acc.len();
                let aptr = SyncPtr::new(acc.data_mut().as_mut_ptr());
                let rslices: Vec<&[f32]> =
                    rest.iter().map(|t| t.as_contiguous().unwrap()).collect();
                scope.fork_join(p, |ci| {
                    let (lo, hi) = chunk_bounds(len, p, ci);
                    let base = aptr.get();
                    for td in &rslices {
                        // SAFETY: [lo, hi) chunks are pairwise disjoint.
                        let ad = unsafe { std::slice::from_raw_parts_mut(base.add(lo), hi - lo) };
                        for (a, &b) in ad.iter_mut().zip(&td[lo..hi]) {
                            *a = agg.combine(*a, b);
                        }
                    }
                });
            } else {
                for t in &rest {
                    let owned = t.to_tensor();
                    if let Err(e) = acc.accumulate(&owned, |a, b| agg.combine(a, b)) {
                        owned.recycle();
                        acc.recycle();
                        return Err(e);
                    }
                    owned.recycle();
                }
            }
            Ok(acc.into_view())
        }
        TaskKind::Repart {
            producer,
            consumer,
            operand,
            key,
        } => {
            let pb = &g.vertex(*producer).bound;
            let have = &tg.vertex_out_part[producer];
            let need = plan.required_in_part(g, *consumer, *operand);
            let t_origin = tile_origin(pb, &need, key);
            let t_shape = tile_shape(pb, &need, key);
            // Producer tile keys are recovered from each dep's position in
            // the producer's output list (row-major I(d_Z) order) — the
            // task's own `key` field may range over different labels (a
            // Kernel task keys over the unique labels).
            let vouts = &tg.vertex_outputs[producer];
            let dep_key = |d: crate::taskgraph::TaskId| -> Result<Vec<usize>> {
                // Collective relays are not producer outputs; they carry
                // their source tile's producer-layout key themselves.
                if let TaskKind::Collective { key, .. } = &tg.tasks[d.0].kind {
                    return Ok(key.clone());
                }
                let pos = vouts
                    .iter()
                    .position(|&t| t == d)
                    .ok_or_else(|| Error::Exec("repart dep not a producer output".into()))?;
                Ok(crate::tra::relation::delinearize(pos, have))
            };
            // A single overlapping producer tile contains the whole
            // consumer region: alias it as a zero-copy sub-view.
            if task.deps.len() == 1 {
                let pkey = dep_key(task.deps[0])?;
                let p_origin = tile_origin(pb, have, &pkey);
                let rel_off: Vec<usize> = t_origin
                    .iter()
                    .zip(&p_origin)
                    .map(|(t, p)| t - p)
                    .collect();
                return dep_view(task.deps[0])?.slice(&rel_off, &t_shape);
            }
            // Otherwise move exactly the overlapping sub-regions. The
            // union of intersections covers the tile once, so the pooled
            // buffer is fully overwritten. The fill runs in a closure so
            // any error path hands the pooled buffer back instead of
            // leaking it.
            let mut out = Tensor::full_pooled(&t_shape, 0.0);
            let fill = (|| -> Result<()> {
                for &d in &task.deps {
                    let pkey = dep_key(d)?;
                    let p_origin = tile_origin(pb, have, &pkey);
                    let p_shape = tile_shape(pb, have, &pkey);
                    let ptile = dep_view(d)?;
                    // intersection in global coords
                    let rank = pb.len();
                    let mut lo = vec![0usize; rank];
                    let mut sz = vec![0usize; rank];
                    let mut empty = false;
                    for dim in 0..rank {
                        let a = t_origin[dim].max(p_origin[dim]);
                        let b = (t_origin[dim] + t_shape[dim]).min(p_origin[dim] + p_shape[dim]);
                        if b <= a {
                            empty = true;
                            break;
                        }
                        lo[dim] = a;
                        sz[dim] = b - a;
                    }
                    if empty {
                        continue;
                    }
                    let src_off: Vec<usize> =
                        lo.iter().zip(&p_origin).map(|(a, o)| a - o).collect();
                    let dst_off: Vec<usize> =
                        lo.iter().zip(&t_origin).map(|(a, o)| a - o).collect();
                    let piece = ptile.slice(&src_off, &sz)?;
                    out.write_slice_view(&dst_off, &piece)?;
                }
                Ok(())
            })();
            match fill {
                Ok(()) => Ok(out.into_view()),
                Err(e) => {
                    out.recycle();
                    Err(e)
                }
            }
        }
        TaskKind::Collective { .. } => {
            // A relay step is a pure pass-through copy of its single
            // dependency — a zero-copy view clone (Arc bump), so relayed
            // bytes are bitwise the source tile's bytes by construction.
            dep_view(task.deps[0])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{plan_graph, PlannerConfig};
    use crate::einsum::label::labels;
    use crate::runtime::NativeEngine;

    fn matmul_graph(s: usize) -> EinGraph {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        g.add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
        g
    }

    #[test]
    fn zero_byte_cross_worker_edges_model_zero_seconds() {
        // Regression: `wire_s` used to charge `latency_s` on zero-byte
        // transfers, so free rewrites (aliased / elided repartitions)
        // modeled as non-free. A cross-worker edge carrying no bytes must
        // contribute exactly nothing to the ledger or the timeline.
        let mut tg = TaskGraph::default();
        let t0 = tg.push_task(
            TaskKind::InputTile {
                vertex: VertexId(0),
                key: vec![0],
            },
            vec![],
            0,
            0.0,
        );
        tg.push_task(
            TaskKind::Kernel {
                vertex: VertexId(1),
                key: vec![0],
            },
            vec![t0],
            0,
            0.0,
        );
        tg.tasks[0].worker = Some(0);
        tg.tasks[1].worker = Some(1);
        let mut net = NetworkProfile::cpu_cluster();
        net.sched_overhead_s = 0.0;
        assert!(net.latency_s > 0.0, "test needs a latency-bearing profile");
        let rep = Cluster::new(2, net).model(&tg);
        assert_eq!(rep.sim_makespan_s, 0.0);
        assert_eq!(rep.bytes_moved, 0);
    }

    #[test]
    fn model_reports_positive_makespan() {
        let g = matmul_graph(64);
        let plan = plan_graph(&g, &PlannerConfig { p: 8, ..Default::default() }).unwrap();
        let cluster = Cluster::new(8, NetworkProfile::cpu_cluster());
        let rep = cluster.dry_run(&g, &plan).unwrap();
        assert!(rep.sim_makespan_s > 0.0);
        assert_eq!(rep.kernel_calls, 8);
        assert!(rep.flops > 0.0);
    }

    #[test]
    fn fewer_workers_longer_makespan() {
        // Use a compute-bound size: at tiny scales network latency
        // dominates and one worker (no transfers) wins — which the model
        // correctly captures.
        let g = matmul_graph(1024);
        let plan = plan_graph(&g, &PlannerConfig { p: 8, ..Default::default() }).unwrap();
        let net = NetworkProfile::cpu_cluster();
        let t8 = Cluster::new(8, net.clone()).dry_run(&g, &plan).unwrap();
        let t1 = Cluster::new(1, net).dry_run(&g, &plan).unwrap();
        assert!(t1.sim_makespan_s > t8.sim_makespan_s);
    }

    #[test]
    fn execute_matches_dense_eval() {
        let g = matmul_graph(32);
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let a = Tensor::random(&[32, 32], 1);
        let b = Tensor::random(&[32, 32], 2);
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), a.clone());
        inputs.insert(g.by_name("B").unwrap(), b.clone());
        let engine = NativeEngine::new();
        let z = g.by_name("Z").unwrap();
        let want = crate::runtime::native::eval_einsum(&g.vertex(z).op, &[&a, &b]).unwrap();
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let cluster = Cluster::new(4, NetworkProfile::loopback()).with_exec_mode(mode);
            let (outs, rep) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
            assert!(outs[&z].allclose(&want, 1e-4, 1e-5), "{mode:?}");
            assert!(rep.wall_s > 0.0);
        }
    }

    #[test]
    fn run_lowered_reuses_one_task_graph_bitwise() {
        // The run-many half of the compile-once split: lower exactly once,
        // execute the frozen task graph repeatedly, outputs bitwise-equal
        // to the one-shot execute() path.
        let g = matmul_graph(32);
        let z = g.by_name("Z").unwrap();
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), Tensor::random(&[32, 32], 21));
        inputs.insert(g.by_name("B").unwrap(), Tensor::random(&[32, 32], 22));
        let engine = NativeEngine::new();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let (once, _) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
        let tg = cluster.lower(&g, &plan).unwrap();
        for _ in 0..3 {
            let (outs, rep) = cluster
                .run_lowered(&g, &plan, &tg, &engine, &inputs)
                .unwrap();
            assert_eq!(outs[&z], once[&z]);
            assert!(rep.wall_s > 0.0);
        }
    }

    #[test]
    fn execute_chain_with_repartitions() {
        // force mismatched partitionings so repart tasks execute for real
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let c = g.input("C", vec![16, 16]);
        let z1 = g
            .add(
                "Z1",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let z2 = g
            .add(
                "Z2",
                EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
                vec![z1, c],
            )
            .unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z1, vec![2, 2, 4]); // dz = [2,4]
        plan.parts.insert(z2, vec![4, 1, 4]); // needs [4,1]
        plan.finalize_inputs(&g);
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let ta = Tensor::random(&[16, 16], 3);
        let tb = Tensor::random(&[16, 16], 4);
        let tc = Tensor::random(&[16, 16], 5);
        let mut inputs = HashMap::new();
        inputs.insert(a, ta.clone());
        inputs.insert(b, tb.clone());
        inputs.insert(c, tc.clone());
        let engine = NativeEngine::new();
        let (outs, rep) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
        let w1 = crate::runtime::native::eval_einsum(&g.vertex(z1).op, &[&ta, &tb]).unwrap();
        let want = crate::runtime::native::eval_einsum(&g.vertex(z2).op, &[&w1, &tc]).unwrap();
        assert!(outs[&z2].allclose(&want, 1e-4, 1e-5));
        assert!(rep.bytes_repart > 0 || rep.bytes_moved > 0);
    }

    #[test]
    fn exec_modes_agree_bitwise() {
        let g = matmul_graph(24);
        let z = g.by_name("Z").unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z, vec![2, 3, 2]); // forces aggregation tasks
        plan.finalize_inputs(&g);
        let a = Tensor::random(&[24, 24], 6);
        let b = Tensor::random(&[24, 24], 7);
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), a);
        inputs.insert(g.by_name("B").unwrap(), b);
        let engine = NativeEngine::new();
        let ws = Cluster::new(4, NetworkProfile::loopback())
            .with_exec_mode(ExecMode::WorkStealing)
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        let lb = Cluster::new(4, NetworkProfile::loopback())
            .with_exec_mode(ExecMode::LevelBarrier)
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        // bitwise: the two schedulers evaluate identical task graphs
        assert_eq!(ws[&z], lb[&z]);
    }

    #[test]
    fn intra_op_degrees_agree_bitwise() {
        // The intra-op fan-out is a scheduling knob only: every degree
        // must produce identical bytes (shard boundaries are a pure
        // function of shape, never of idleness).
        let g = matmul_graph(48);
        let z = g.by_name("Z").unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z, vec![2, 2, 2]); // forces aggregation tasks
        plan.finalize_inputs(&g);
        let a = Tensor::random(&[48, 48], 8);
        let b = Tensor::random(&[48, 48], 9);
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), a);
        inputs.insert(g.by_name("B").unwrap(), b);
        let engine = NativeEngine::new();
        let base = Cluster::new(4, NetworkProfile::loopback())
            .with_intra_op(1)
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        for intra in [0usize, 2, 8] {
            let got = Cluster::new(4, NetworkProfile::loopback())
                .with_intra_op(intra)
                .execute(&g, &plan, &engine, &inputs)
                .unwrap()
                .0;
            assert_eq!(got[&z], base[&z], "intra_op {intra}");
        }
    }

    #[test]
    fn topology_model_tallies_per_link_bytes() {
        let g = matmul_graph(64);
        let plan = plan_graph(&g, &PlannerConfig { p: 8, ..Default::default() }).unwrap();
        let net = NetworkProfile::cpu_cluster();
        let flat = Cluster::new(8, net.clone());
        let tg = flat.lower(&g, &plan).unwrap();
        let base = flat.model(&tg);
        assert_eq!(
            base.bytes_by_link,
            vec![("flat".to_string(), base.bytes_moved)]
        );
        // an explicit flat topology is the seed model, byte for byte
        let rep = flat
            .clone()
            .with_topology(Topology::flat_of(&net, 8))
            .model(&tg);
        assert_eq!(rep.bytes_moved, base.bytes_moved);
        assert_eq!(rep.sim_makespan_s, base.sim_makespan_s);
        assert_eq!(rep.bytes_by_link.len(), 1);
        assert_eq!(rep.bytes_by_link[0].1, base.bytes_moved);
        // three-level: per-class tallies roll up to the same total, and
        // faster inner links can only shorten the modeled makespan
        let rep3 = flat
            .clone()
            .with_topology(Topology::three_level_of(&net, 8))
            .model(&tg);
        assert_eq!(rep3.bytes_moved, base.bytes_moved);
        assert_eq!(rep3.bytes_by_link.len(), 3);
        assert_eq!(
            rep3.bytes_by_link.iter().map(|(_, b)| *b).sum::<u64>(),
            rep3.bytes_moved
        );
        assert!(rep3.sim_makespan_s <= base.sim_makespan_s + 1e-12);
    }

    #[test]
    fn collective_lowering_executes_bitwise() {
        // The forced-repart chain of `execute_chain_with_repartitions`:
        // lower-collectives lifts the Π into an AllGather relay chain and
        // the serial folds into ReduceScatter chains; outputs must be
        // bitwise the point-to-point run in both exec modes.
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let c = g.input("C", vec![16, 16]);
        let z1 = g
            .add(
                "Z1",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let z2 = g
            .add(
                "Z2",
                EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
                vec![z1, c],
            )
            .unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z1, vec![2, 2, 4]); // dz = [2,4]
        plan.parts.insert(z2, vec![4, 1, 4]); // needs [4,1]
        plan.finalize_inputs(&g);
        let mut inputs = HashMap::new();
        inputs.insert(a, Tensor::random(&[16, 16], 3));
        inputs.insert(b, Tensor::random(&[16, 16], 4));
        inputs.insert(c, Tensor::random(&[16, 16], 5));
        let engine = NativeEngine::new();
        let net = NetworkProfile::loopback();
        let base = Cluster::new(4, net.clone())
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        let sel: PassSelector = "elide-identity-repart,lower-collectives,dead-rel-elim"
            .parse()
            .unwrap();
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let coll = Cluster::new(4, net.clone())
                .with_passes(sel.clone())
                .with_topology(Topology::three_level_of(&net, 4))
                .with_exec_mode(mode);
            // the rewrite actually fired: Z1's fold + Π fuse into an
            // AllReduce (its dz rel has exactly one consumer, the Π)
            let (_, prog, _) = coll.lower_explain(&g, &plan).unwrap();
            assert!(prog.render().contains("AllReduce"), "{}", prog.render());
            let outs = coll.execute(&g, &plan, &engine, &inputs).unwrap().0;
            assert_eq!(outs[&z2], base[&z2], "{mode:?}");
        }
    }

    #[test]
    fn missing_input_rejected() {
        let g = matmul_graph(8);
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let engine = NativeEngine::new();
        assert!(cluster.execute(&g, &plan, &engine, &HashMap::new()).is_err());
    }

    #[test]
    fn input_validation_is_typed() {
        let g = matmul_graph(8);
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let engine = NativeEngine::new();
        // missing input
        let err = cluster
            .execute(&g, &plan, &engine, &HashMap::new())
            .unwrap_err();
        assert!(matches!(
            err.as_exec().map(|e| &e.cause),
            Some(ExecCause::MissingInput { .. })
        ));
        // shape mismatch
        let mut bad = HashMap::new();
        bad.insert(g.by_name("A").unwrap(), Tensor::random(&[4, 4], 1));
        bad.insert(g.by_name("B").unwrap(), Tensor::random(&[8, 8], 2));
        let err = cluster.execute(&g, &plan, &engine, &bad).unwrap_err();
        match err.as_exec().map(|e| &e.cause) {
            Some(ExecCause::ShapeMismatch { got, want, .. }) => {
                assert_eq!(got, &vec![4, 4]);
                assert_eq!(want, &vec![8, 8]);
            }
            other => panic!("expected ShapeMismatch, got {other:?}"),
        }
        // non-finite screening is opt-in
        let mut nan_in = HashMap::new();
        let mut a = Tensor::random(&[8, 8], 1);
        a.data_mut()[5] = f32::NAN;
        nan_in.insert(g.by_name("A").unwrap(), a);
        nan_in.insert(g.by_name("B").unwrap(), Tensor::random(&[8, 8], 2));
        assert!(cluster.execute(&g, &plan, &engine, &nan_in).is_ok());
        let opts = RunOptions {
            reject_nonfinite: true,
            ..Default::default()
        };
        let err = cluster
            .execute_opts(&g, &plan, &engine, &nan_in, &opts)
            .unwrap_err();
        match err.as_exec().map(|e| &e.cause) {
            Some(ExecCause::NonFinite { index, .. }) => assert_eq!(*index, 5),
            other => panic!("expected NonFinite, got {other:?}"),
        }
    }

    #[test]
    fn infeasible_budget_is_typed_and_roomy_budget_never_spills() {
        let g = matmul_graph(16);
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), Tensor::random(&[16, 16], 8));
        inputs.insert(g.by_name("B").unwrap(), Tensor::random(&[16, 16], 9));
        let engine = NativeEngine::new();
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            // a budget smaller than any single task's working set cannot be
            // satisfied by eviction; it must surface as a typed error, not
            // a hang or a silent over-allocation
            let err = Cluster::new(4, NetworkProfile::loopback())
                .with_exec_mode(mode)
                .with_mem_budget(MemoryBudget::per_worker_bytes(8))
                .execute(&g, &plan, &engine, &inputs)
                .unwrap_err();
            match err.as_exec().map(|e| &e.cause) {
                Some(ExecCause::BudgetExceeded {
                    needed_bytes,
                    budget_bytes,
                    ..
                }) => {
                    assert_eq!(*budget_bytes, 8, "{mode:?}");
                    assert!(*needed_bytes > 8, "{mode:?}");
                }
                other => panic!("expected BudgetExceeded, got {other:?}"),
            }
            // a budget far above the whole problem admits everything: the
            // budgeted executor still tracks residency but never evicts
            let base = Cluster::new(4, NetworkProfile::loopback())
                .with_exec_mode(mode)
                .execute(&g, &plan, &engine, &inputs)
                .unwrap();
            let roomy = 64u64 << 20;
            let (outs, rep) = Cluster::new(4, NetworkProfile::loopback())
                .with_exec_mode(mode)
                .with_mem_budget(MemoryBudget::per_worker_bytes(roomy))
                .execute(&g, &plan, &engine, &inputs)
                .unwrap();
            assert_eq!(outs[&g.by_name("Z").unwrap()], base.0[&g.by_name("Z").unwrap()]);
            assert_eq!(rep.spill_bytes, 0, "{mode:?}");
            assert_eq!(rep.spill_faults, 0, "{mode:?}");
            assert!(rep.peak_resident_bytes.iter().any(|&b| b > 0), "{mode:?}");
            assert!(rep.peak_resident_bytes.iter().all(|&b| b <= roomy), "{mode:?}");
        }
    }

    #[test]
    fn injected_faults_recover_bitwise_with_counters() {
        let g = matmul_graph(24);
        let z = g.by_name("Z").unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z, vec![2, 3, 2]); // forces aggregation tasks
        plan.finalize_inputs(&g);
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), Tensor::random(&[24, 24], 6));
        inputs.insert(g.by_name("B").unwrap(), Tensor::random(&[24, 24], 7));
        let engine = NativeEngine::new();
        let (clean, clean_rep) = Cluster::new(4, NetworkProfile::loopback())
            .execute(&g, &plan, &engine, &inputs)
            .unwrap();
        // fault-free ledgers carry zero recovery overhead
        assert_eq!(clean_rep.faults_injected, 0);
        assert_eq!(clean_rep.retries, 0);
        assert_eq!(clean_rep.recomputed_tasks, 0);
        assert_eq!(clean_rep.recovery_bytes, 0);
        assert_eq!(clean_rep.workers_lost, 0);
        assert_eq!(clean_rep.recovery_stall_s, 0.0);
        assert!(clean_rep.recovery_by_link.is_empty());
        assert!(!clean_rep.summary().contains("faults="));
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let faulty = Cluster::new(4, NetworkProfile::loopback())
                .with_exec_mode(mode)
                .with_faults(FaultPlan::new().transient(4, 2).permanent(7));
            let (outs, rep) = faulty.execute(&g, &plan, &engine, &inputs).unwrap();
            assert_eq!(outs[&z], clean[&z], "{mode:?}");
            assert_eq!(rep.faults_injected, 3, "{mode:?}"); // 2 transient + 1 permanent
            assert!(rep.retries >= 3, "{mode:?}");
            assert_eq!(rep.workers_lost, 1, "{mode:?}");
            assert!(rep.recovery_stall_s > 0.0, "{mode:?}");
            assert!(rep.summary().contains("faults=3"), "{mode:?}");
        }
    }

    #[test]
    fn retry_budget_exhaustion_is_typed() {
        let g = matmul_graph(8);
        let plan = plan_graph(&g, &PlannerConfig { p: 2, ..Default::default() }).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), Tensor::random(&[8, 8], 1));
        inputs.insert(g.by_name("B").unwrap(), Tensor::random(&[8, 8], 2));
        let engine = NativeEngine::new();
        // a task that fails more times than the retry budget allows
        let cluster = Cluster::new(2, NetworkProfile::loopback())
            .with_faults(FaultPlan::new().transient(0, 10));
        let opts = RunOptions {
            max_retries: 2,
            ..Default::default()
        };
        let err = cluster
            .execute_opts(&g, &plan, &engine, &inputs, &opts)
            .unwrap_err();
        let exec = err.as_exec().expect("typed exec error");
        assert_eq!(exec.task, Some(0));
        assert_eq!(exec.attempts, 3); // 1 try + 2 retries
        assert!(matches!(exec.cause, ExecCause::Injected { permanent: false }));
    }

    #[test]
    fn zero_deadline_times_out_typed_and_promptly() {
        let g = matmul_graph(16);
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), Tensor::random(&[16, 16], 1));
        inputs.insert(g.by_name("B").unwrap(), Tensor::random(&[16, 16], 2));
        let engine = NativeEngine::new();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let opts = RunOptions {
            deadline: Some(std::time::Duration::ZERO),
            ..Default::default()
        };
        let t0 = Instant::now();
        let err = cluster
            .execute_opts(&g, &plan, &engine, &inputs, &opts)
            .unwrap_err();
        assert!(t0.elapsed() < std::time::Duration::from_secs(5), "not prompt");
        assert!(err.is_deadline(), "{err}");
        match err.as_exec().unwrap().cause {
            ExecCause::DeadlineExceeded { total, .. } => assert!(total > 0),
            ref other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    #[test]
    fn agg_error_path_returns_pooled_buffers() {
        use crate::util::BufferPool;
        // An Agg whose accumulator draws a pooled buffer (first dep is a
        // strided view, so `to_tensor` pools a copy) and then hits a shape
        // mismatch: the error path must hand the buffer back.
        let g = matmul_graph(8);
        let z = g.by_name("Z").unwrap();
        let mut tg = TaskGraph::default();
        let d0 = tg.push_task(
            TaskKind::InputTile { vertex: z, key: vec![0] },
            vec![],
            0,
            0.0,
        );
        let d1 = tg.push_task(
            TaskKind::InputTile { vertex: z, key: vec![1] },
            vec![],
            0,
            0.0,
        );
        let agg = tg.push_task(TaskKind::Agg { vertex: z, key: vec![0] }, vec![d0, d1], 0, 0.0);
        let results: Vec<ResultSlot> = (0..3).map(|_| Mutex::new(None)).collect();
        let big = Tensor::random(&[4, 4], 11);
        *results[0].lock().unwrap() = Some(big.slice_view(&[0, 0], &[2, 2]).unwrap());
        *results[1].lock().unwrap() = Some(Tensor::random(&[3, 3], 12).into_view());
        let plan = crate::decomp::Plan::default();
        let engine = NativeEngine::new();
        let before = BufferPool::stats();
        let r = exec_task(&tg, &g, &plan, &engine, &results, agg.0, &serial_scope());
        assert!(r.is_err());
        let after = BufferPool::stats();
        assert!(after.takes > before.takes, "accumulator should be pooled");
        assert_eq!(
            after.takes - before.takes,
            after.gives - before.gives,
            "aggregation error path leaked pooled buffers"
        );
    }

    #[test]
    fn repart_error_path_returns_pooled_buffer() {
        use crate::util::BufferPool;
        // A gathering Repart fails on its missing deps *after* drawing its
        // output buffer from the pool; the error path must return it.
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let c = g.input("C", vec![16, 16]);
        let z1 = g
            .add(
                "Z1",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let z2 = g
            .add(
                "Z2",
                EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
                vec![z1, c],
            )
            .unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z1, vec![2, 2, 4]);
        plan.parts.insert(z2, vec![4, 1, 4]);
        plan.finalize_inputs(&g);
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let tg = cluster.lower(&g, &plan).unwrap();
        let ri = tg
            .tasks
            .iter()
            .find(|t| matches!(t.kind, TaskKind::Repart { .. }) && t.deps.len() > 1)
            .expect("mismatched chain lowers a gathering repart")
            .id
            .0;
        let results: Vec<ResultSlot> = (0..tg.tasks.len()).map(|_| Mutex::new(None)).collect();
        let engine = NativeEngine::new();
        let before = BufferPool::stats();
        let err = exec_task(&tg, &g, &plan, &engine, &results, ri, &serial_scope()).unwrap_err();
        assert!(is_missing_dep(&err), "{err}");
        let after = BufferPool::stats();
        assert!(after.takes > before.takes, "repart output should be pooled");
        assert_eq!(
            after.takes - before.takes,
            after.gives - before.gives,
            "repart error path leaked pooled buffers"
        );
    }
}

//! The simulated cluster executor.
//!
//! Two modes over the same task graph:
//!
//! * **real** ([`Cluster::execute`]) — actually computes every kernel call
//!   multi-threaded on the host's cores and returns the assembled output
//!   tensors, together with the modeled report. Used by the examples, the
//!   end-to-end training driver, and all numerics tests.
//! * **dry** ([`Cluster::dry_run`]) — models time and traffic only, which
//!   is how paper-scale configurations (LLaMA-7B/65B shapes) are costed
//!   without materializing terabytes.
//!
//! The modeled timeline is event-driven: a task becomes ready when all
//! producer tiles have arrived (cross-worker edges pay latency +
//! bytes/bandwidth), each worker executes its tasks in graph order, and
//! compute costs `flops / flops_per_s`.
//!
//! # Real-execution scheduling
//!
//! Real execution mirrors that event-driven model with a dependency-
//! counted, work-stealing scheduler ([`ExecMode::WorkStealing`], the
//! default — see [`crate::util::execute_dag`] for the queue protocol):
//!
//! * every task carries a readiness counter initialized to its dep
//!   occurrence count; the worker thread that performs a counter's final
//!   decrement owns the hand-off and pushes the now-ready task onto its
//!   own deque, so a consumer usually runs where its freshest input was
//!   just produced;
//! * idle threads steal from the front of other deques (oldest-first), so
//!   independent subgraphs overlap instead of waiting for a level barrier;
//! * threads that find no ready *task* steal **shards** of tasks other
//!   workers are running (nested work stealing, see
//!   [`crate::util::execute_dag_scoped`]): kernel bodies split their GEMM
//!   row blocks, batch entries, elementwise chunks, and aggregation folds
//!   into `intra_op`-many independent pieces, so a 2-vertex plan on 16
//!   cores no longer runs at 2/16 utilization. The fan-out is set by
//!   [`Cluster::with_intra_op`] (default: the executor's thread count);
//! * task *results* are deterministic regardless of interleaving: each
//!   task writes only its own result slot, kernel inputs are fixed by
//!   the task graph, aggregations combine their deps in the fixed `deps`
//!   order — never in completion order — and every sharded kernel is
//!   bitwise-identical to its serial form (shard boundaries are a pure
//!   function of the problem shape). `cargo test` locks this in with
//!   bitwise-determinism differential suites (`tests/
//!   scheduler_differential.rs`, `tests/gemm_parallel.rs`);
//! * the data plane is zero-copy: tiles move between tasks as strided
//!   [`TensorView`]s (input pre-slicing is O(1), kernels read through
//!   strides, repartition tiles contained in one producer tile alias it),
//!   and a tile's buffer is recycled into the per-worker
//!   [`crate::util::BufferPool`] the moment its last consumer has read
//!   it — reclamation frees buffers, never values, so determinism is
//!   untouched.
//!
//! [`ExecMode::LevelBarrier`] retains the previous implementation — a
//! persistent thread team synchronized per ASAP level with a barrier — as
//! a reference mode for differential tests and A/B benchmarks
//! (`cargo bench micro_hotpath` reports both). Both modes produce
//! bitwise-identical outputs; the barrier mode simply idles cores
//! whenever a level drains unevenly, which is exactly where the paper's
//! event-driven cost model (§7) says work should overlap.
//!
//! The modeled makespan/traffic accounting ([`Cluster::model`]) is shared
//! by both modes and unchanged by the scheduler choice: `ExecReport`'s
//! `sim_*`/`bytes_*` fields describe the modeled cluster, `wall_s` the
//! real host execution.

use super::network::{NetworkProfile, Topology};
use crate::decomp::Plan;
use crate::einsum::expr::{AggOp, EinSum};
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::project;
use crate::error::{Error, Result};
use crate::runtime::KernelEngine;
use crate::taskgraph::placement::{place, Policy};
use crate::taskgraph::{TaskGraph, TaskKind, TransferClass};
use crate::tensor::{Tensor, TensorView};
use crate::tra::passes::{PassLog, PassSelector};
use crate::tra::program::{from_plan, TraProgram};
use crate::tra::relation::{overlapping_tiles, tile_origin, tile_shape};
use crate::util::{chunk_bounds, serial_scope, ShardScope, SyncPtr, SHARD_MIN};
use std::collections::HashMap;
use std::sync::Mutex;

/// A task's result slot: the produced tile as a zero-copy view. Slots
/// are `Option` so the executor can *take* a tile back once every
/// consumer has read it and recycle its buffer into the
/// [`crate::util::BufferPool`].
type ResultSlot = Mutex<Option<TensorView>>;

/// How [`Cluster::execute`] schedules real task execution on host threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Dependency-counted work stealing (default): tasks start the moment
    /// their producers finish, independent subgraphs overlap.
    #[default]
    WorkStealing,
    /// Reference mode: execute level by level with a full barrier between
    /// levels. Kept for differential testing and as the A/B baseline the
    /// work-stealing speedup is measured against.
    LevelBarrier,
}

/// Execution summary for one run.
#[derive(Clone, Debug, Default)]
pub struct ExecReport {
    /// Real wall-clock time of the multi-threaded execution (0 for dry).
    pub wall_s: f64,
    /// Modeled makespan under the network profile.
    pub sim_makespan_s: f64,
    /// Bytes moved across workers, total.
    pub bytes_moved: u64,
    /// Bytes moved, by cost-model class.
    pub bytes_join: u64,
    pub bytes_agg: u64,
    pub bytes_repart: u64,
    pub bytes_input: u64,
    /// Extra traffic and stall time from memory paging (Fig. 11 runs).
    pub bytes_paged: u64,
    pub page_stall_s: f64,
    /// Kernel-call count and total task count.
    pub kernel_calls: usize,
    pub tasks: usize,
    /// Total modeled flops.
    pub flops: f64,
    /// Per-worker modeled busy time.
    pub worker_busy_s: Vec<f64>,
    /// Modeled bytes per link class, `(class name, bytes)` innermost
    /// first, summing to `bytes_moved`. Without a [`Topology`] every
    /// transfer rides the flat profile: `[("flat", bytes_moved)]`.
    /// Empty only on reports that never went through [`Cluster::model`]
    /// (e.g. the memory-policy simulator).
    pub bytes_by_link: Vec<(String, u64)>,
}

impl ExecReport {
    /// Modeled parallel efficiency: total busy time / (makespan * workers).
    pub fn efficiency(&self) -> f64 {
        let p = self.worker_busy_s.len().max(1) as f64;
        if self.sim_makespan_s <= 0.0 {
            return 1.0;
        }
        self.worker_busy_s.iter().sum::<f64>() / (self.sim_makespan_s * p)
    }

    pub fn summary(&self) -> String {
        format!(
            "tasks={} kernels={} moved={:.2}MiB (join {:.2} agg {:.2} repart {:.2}) sim={:.3}ms wall={:.3}ms eff={:.0}%",
            self.tasks,
            self.kernel_calls,
            self.bytes_moved as f64 / (1 << 20) as f64,
            self.bytes_join as f64 / (1 << 20) as f64,
            self.bytes_agg as f64 / (1 << 20) as f64,
            self.bytes_repart as f64 / (1 << 20) as f64,
            self.sim_makespan_s * 1e3,
            self.wall_s * 1e3,
            self.efficiency() * 100.0
        )
    }
}

/// A simulated cluster of `workers` devices joined by `net`.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub workers: usize,
    pub net: NetworkProfile,
    pub placement: Policy,
    /// Host-thread scheduling of real execution (modeled accounting is
    /// independent of this).
    pub exec_mode: ExecMode,
    /// Intra-op shard fan-out for real execution under
    /// [`ExecMode::WorkStealing`]: how many independent shards a kernel
    /// splits into so idle workers can help. `0` (the default) means
    /// "match the executor's thread count". Purely a scheduling knob —
    /// results are bitwise-identical for every value.
    pub intra_op: usize,
    /// TRA-IR pass pipeline applied between planning and task emission
    /// (see [`crate::tra::passes`]). The default,
    /// [`PassSelector::Safe`], is task-graph-neutral, so default
    /// lowering reproduces the pre-IR pipeline byte for byte.
    pub passes: PassSelector,
    /// Hierarchical worker topology. `None` (default) models every
    /// cross-worker transfer on the flat `net` profile — byte-for-byte
    /// the seed model; `Some` charges each transfer at the link class of
    /// the two workers' lowest common group, tallies
    /// [`ExecReport::bytes_by_link`], and steers the
    /// `lower-collectives` gather schedule
    /// ([`crate::tra::passes::PassManager::with_topology`]).
    pub topology: Option<Topology>,
}

impl Cluster {
    pub fn new(workers: usize, net: NetworkProfile) -> Self {
        Cluster {
            workers,
            net,
            placement: Policy::LocalityGreedy,
            exec_mode: ExecMode::WorkStealing,
            intra_op: 0,
            passes: PassSelector::default(),
            topology: None,
        }
    }

    /// Builder-style override of the real-execution scheduler.
    pub fn with_exec_mode(mut self, mode: ExecMode) -> Self {
        self.exec_mode = mode;
        self
    }

    /// Builder-style override of the intra-op shard fan-out (`0` = match
    /// the executor's thread count).
    pub fn with_intra_op(mut self, intra_op: usize) -> Self {
        self.intra_op = intra_op;
        self
    }

    /// Builder-style override of the TRA pass pipeline.
    pub fn with_passes(mut self, passes: PassSelector) -> Self {
        self.passes = passes;
        self
    }

    /// Builder-style worker topology (see [`Cluster::topology`]).
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = Some(topology);
        self
    }

    /// Lower + place a planned graph: build the TRA program (Eq. 5), run
    /// the configured pass pipeline, emit and place the task graph. Every
    /// compile validates the placed result (structure + placement, one
    /// walk), so malformed graphs from IR rewrites fail here, not at run
    /// time.
    pub fn lower(&self, g: &EinGraph, plan: &Plan) -> Result<TaskGraph> {
        Ok(self.lower_explain(g, plan)?.0)
    }

    /// [`Self::lower`], also returning the optimized [`TraProgram`] and
    /// the per-pass change log — what `Session::compile` stores so
    /// `Session::explain` / `Executable::tra_program` can show the IR
    /// behind a compiled artifact.
    pub fn lower_explain(
        &self,
        g: &EinGraph,
        plan: &Plan,
    ) -> Result<(TaskGraph, TraProgram, PassLog)> {
        let mut prog = from_plan(g, plan)?;
        // Role-driven baselines plan by label *name*, so IR CSE must
        // compare label-extended join signatures — the same caveat the
        // plan cache honors with `Canon::named_signature`.
        let label_sensitive = matches!(
            plan.strategy.as_str(),
            "data-parallel" | "megatron" | "sequence" | "attention"
        );
        let mut mgr = self.passes.manager().with_label_sensitivity(label_sensitive);
        if let Some(t) = &self.topology {
            mgr = mgr.with_topology(t);
        }
        let log = mgr.run(&mut prog);
        let mut tg = prog.emit_tasks()?;
        place(&mut tg, self.workers, self.placement);
        // validate() re-checks structure (placement cannot invalidate
        // it), so one post-place walk covers both.
        tg.validate(self.workers)?;
        Ok((tg, prog, log))
    }

    /// Model the timeline and traffic of a placed task graph.
    ///
    /// Event-driven LogP-style model: each cross-worker edge pays latency
    /// + bytes/bandwidth, and a sender's NIC serializes its outgoing
    /// transfers (a master distributing everything becomes a bottleneck —
    /// the behaviour that sinks centralized redistribution schemes).
    pub fn model(&self, tg: &TaskGraph) -> ExecReport {
        let n = tg.tasks.len();
        let mut finish = vec![0.0f64; n];
        let mut clock = vec![0.0f64; self.workers];
        let mut nic = vec![0.0f64; self.workers]; // egress availability
        let mut busy = vec![0.0f64; self.workers];
        let mut report = ExecReport {
            tasks: n,
            kernel_calls: tg.kernel_calls(),
            ..Default::default()
        };
        // per-link-class byte tally when a topology is set
        let mut by_link: Vec<u64> = self
            .topology
            .as_ref()
            .map(|t| vec![0u64; t.classes().len()])
            .unwrap_or_default();
        for t in &tg.tasks {
            let w = t.assigned_worker();
            let mut ready = 0.0f64;
            for &d in &t.deps {
                let dep = &tg.tasks[d.0];
                let dw = dep.assigned_worker();
                let mut arrive = finish[d.0];
                if dw != w {
                    let send_start = finish[d.0].max(nic[dw]);
                    // lowest-common-group link class when a topology is
                    // set; `None` is exactly the seed flat-profile math
                    let (bandwidth, wire) = match &self.topology {
                        Some(topo) => {
                            let lc = topo
                                .link_class(dw, w)
                                .unwrap_or(topo.classes().len() - 1);
                            by_link[lc] += dep.out_bytes as u64;
                            let class = &topo.classes()[lc];
                            (class.bandwidth_bps, class.wire_s(dep.out_bytes))
                        }
                        None => (self.net.bandwidth_bps, self.net.wire_s(dep.out_bytes)),
                    };
                    let occupancy = dep.out_bytes as f64 / bandwidth;
                    nic[dw] = send_start + occupancy;
                    arrive = send_start + wire;
                    report.bytes_moved += dep.out_bytes as u64;
                    match t.kind.class() {
                        TransferClass::Join => report.bytes_join += dep.out_bytes as u64,
                        TransferClass::Agg => report.bytes_agg += dep.out_bytes as u64,
                        TransferClass::Repart => report.bytes_repart += dep.out_bytes as u64,
                        TransferClass::Input => report.bytes_input += dep.out_bytes as u64,
                    }
                }
                ready = ready.max(arrive);
            }
            let compute = self.net.compute_s(t.flops);
            let start = ready.max(clock[w]);
            finish[t.id.0] = start + compute;
            clock[w] = finish[t.id.0];
            busy[w] += compute;
            report.flops += t.flops;
        }
        report.sim_makespan_s = finish.iter().copied().fold(0.0, f64::max);
        report.worker_busy_s = busy;
        report.bytes_by_link = match &self.topology {
            Some(topo) => topo
                .classes()
                .iter()
                .zip(&by_link)
                .map(|(c, &b)| (c.name.clone(), b))
                .collect(),
            None => vec![("flat".into(), report.bytes_moved)],
        };
        report
    }

    /// Dry run: plan-level modeling only (no tensors materialized).
    pub fn dry_run(&self, g: &EinGraph, plan: &Plan) -> Result<ExecReport> {
        let tg = self.lower(g, plan)?;
        Ok(self.model(&tg))
    }

    /// Execute for real: compute every task with `engine`, multi-threaded
    /// per [`ExecMode`], and return the dense outputs of the graph's
    /// output vertices plus the report (modeled timeline + measured wall
    /// time). Convenience for [`Self::lower`] + [`Self::run_lowered`];
    /// run-many callers (the `Session` API) lower once and call
    /// [`Self::run_lowered`] directly.
    pub fn execute(
        &self,
        g: &EinGraph,
        plan: &Plan,
        engine: &dyn KernelEngine,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, ExecReport)> {
        let tg = self.lower(g, plan)?;
        self.run_lowered(g, plan, &tg, engine, inputs)
    }

    /// Execute an already lowered + placed task graph. Performs **zero**
    /// planning and **zero** lowering work: `tg` is read-only and can be
    /// reused across any number of calls (each run allocates only its
    /// per-run result slots). This is the run-many half of the
    /// compile-once / run-many split; results are bitwise-identical from
    /// run to run for identical inputs. The modeled timeline is
    /// recomputed here; run-many callers that hold a precomputed
    /// [`Self::model`] report should use [`Self::run_lowered_modeled`].
    pub fn run_lowered(
        &self,
        g: &EinGraph,
        plan: &Plan,
        tg: &TaskGraph,
        engine: &dyn KernelEngine,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, ExecReport)> {
        let base = self.model(tg);
        self.run_lowered_modeled(g, plan, tg, &base, engine, inputs)
    }

    /// [`Self::run_lowered`] with the modeled-timeline report supplied by
    /// the caller (it is a pure function of the frozen `tg`, so the
    /// `Session` API computes it once at compile time instead of paying
    /// the O(tasks + deps) event simulation per request). Only `wall_s`
    /// is stamped fresh on the returned copy.
    pub fn run_lowered_modeled(
        &self,
        g: &EinGraph,
        plan: &Plan,
        tg: &TaskGraph,
        base: &ExecReport,
        engine: &dyn KernelEngine,
        inputs: &HashMap<VertexId, Tensor>,
    ) -> Result<(HashMap<VertexId, Tensor>, ExecReport)> {
        // check inputs present and correctly shaped
        for vid in g.inputs() {
            let vert = g.vertex(vid);
            let t = inputs.get(&vid).ok_or_else(|| {
                Error::Exec(format!("missing input tensor for {}", vert.name))
            })?;
            if t.shape() != vert.bound.as_slice() {
                return Err(Error::Exec(format!(
                    "input {}: shape {:?} != bound {:?}",
                    vert.name,
                    t.shape(),
                    vert.bound
                )));
            }
        }
        let mut report = base.clone();

        let n = tg.tasks.len();
        let results: Vec<ResultSlot> = (0..n).map(|_| Mutex::new(None)).collect();
        // Pre-slice all input tiles serially (they carry no deps and model
        // the paper's free, offline pre-partitioning). With views this is
        // O(1) per tile — no input bytes are copied.
        for t in &tg.tasks {
            if let TaskKind::InputTile { vertex, key } = &t.kind {
                let vert = g.vertex(*vertex);
                // The emitted graph is the authority on input layout: the
                // `propagate-partitions` pass may have rewritten it away
                // from the plan's `input_parts`. (Direct-lowered graphs
                // register the plan layout verbatim, so the fallback only
                // covers unpartitioned inputs.)
                let part = tg
                    .vertex_out_part
                    .get(vertex)
                    .or_else(|| plan.input_parts.get(vertex))
                    .cloned()
                    .unwrap_or_else(|| vec![1; vert.bound.len()]);
                let origin = tile_origin(&vert.bound, &part, key);
                let shape = tile_shape(&vert.bound, &part, key);
                let tile = inputs[vertex].slice_view(&origin, &shape)?;
                *results[t.id.0].lock().unwrap() = Some(tile);
            }
        }
        // Output-vertex tiles must survive until assembly below; every
        // other tile is recycled once its last consumer has read it.
        let mut keep = vec![false; n];
        for out in g.outputs() {
            for tid in &tg.vertex_outputs[&out] {
                keep[tid.0] = true;
            }
        }
        let threads = std::thread::available_parallelism()
            .map(|x| x.get())
            .unwrap_or(4)
            .min(self.workers.max(1) * 2)
            .max(1);
        let t0 = std::time::Instant::now();
        match self.exec_mode {
            ExecMode::WorkStealing => {
                self.run_work_stealing(tg, g, plan, engine, &results, threads, &keep)?
            }
            ExecMode::LevelBarrier => {
                self.run_level_barrier(tg, g, plan, engine, &results, threads)?
            }
        }
        report.wall_s = t0.elapsed().as_secs_f64();

        // assemble outputs
        let mut outputs = HashMap::new();
        for out in g.outputs() {
            let vert = g.vertex(out);
            let part = &tg.vertex_out_part[&out];
            let tiles = &tg.vertex_outputs[&out];
            let mut dense = Tensor::zeros(&vert.bound);
            for (key, &tid) in crate::tensor::index_space(part).zip(tiles) {
                // Borrow, don't take: after IR CSE two output vertices
                // can share one set of result tiles, and each assembly
                // must read them. The drain below recycles every slot
                // exactly once.
                let slot = results[tid.0].lock().unwrap();
                let tile = slot
                    .as_ref()
                    .ok_or_else(|| Error::Exec("missing result tile".into()))?;
                let origin = tile_origin(&vert.bound, part, &key);
                dense.write_slice_view(&origin, tile)?;
            }
            outputs.insert(out, dense);
        }
        // Drain whatever is left (un-reclaimed tiles, level-barrier runs)
        // into the calling thread's pool. Note the reuse horizon: buffers
        // reclaimed mid-run land in scoped *worker* threads' pools and are
        // reused within this execute() only (those pools die with the
        // thread scope); what is drained here survives across executes.
        for slot in &results {
            if let Some(v) = slot.lock().unwrap().take() {
                v.recycle();
            }
        }
        Ok((outputs, report))
    }

    /// Dependency-counted work-stealing execution (default mode). Input
    /// tiles are already materialized in `results`; their tasks are
    /// no-ops that exist only to release their consumers' counters.
    ///
    /// Kernel bodies receive the scheduler's [`ShardScope`] so idle
    /// workers steal intra-op shards of running tasks — the fan-out is
    /// `self.intra_op`, defaulting to the thread count.
    ///
    /// After a task completes it decrements each dependency's
    /// remaining-reader counter (initialized to the occurrence-counted
    /// consumer count the scheduler also uses); the reader performing the
    /// final decrement takes the tile out of its slot and recycles its
    /// buffer into that worker's [`crate::util::BufferPool`] — unless the
    /// tile belongs to a graph output, which assembly consumes later.
    /// Worker pools are thread-local to scoped threads, so this
    /// reclamation feeds allocation reuse *within* the run; cross-run
    /// reuse comes from the end-of-`execute` drain on the caller's
    /// thread. Reclamation only recycles buffers with no remaining
    /// references, so it cannot affect values (and aliased tiles keep
    /// shared buffers alive).
    #[allow(clippy::too_many_arguments)]
    fn run_work_stealing(
        &self,
        tg: &TaskGraph,
        g: &EinGraph,
        plan: &Plan,
        engine: &dyn KernelEngine,
        results: &[ResultSlot],
        threads: usize,
        keep: &[bool],
    ) -> Result<()> {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let consumers = tg.consumers();
        let indegree = tg.indegrees();
        // Placement seeds initial deque affinity: a task's home deque is
        // its placed worker (mod nothing — out-of-range homes fall into
        // the shared injector, which is exactly the case threads < workers).
        let home: Vec<usize> = tg.tasks.iter().map(|t| t.assigned_worker()).collect();
        let intra_op = if self.intra_op == 0 {
            threads
        } else {
            self.intra_op
        };
        let reads_left: Vec<AtomicUsize> =
            consumers.iter().map(|c| AtomicUsize::new(c.len())).collect();
        crate::util::execute_dag_scoped(
            &consumers,
            &indegree,
            &home,
            threads,
            intra_op,
            |ti, scope| {
                let precomputed = results[ti].lock().unwrap().is_some();
                if !precomputed {
                    let t = exec_task(tg, g, plan, engine, results, ti, scope)?;
                    *results[ti].lock().unwrap() = Some(t);
                }
                for &d in &tg.tasks[ti].deps {
                    if reads_left[d.0].fetch_sub(1, Ordering::AcqRel) == 1 && !keep[d.0] {
                        if let Some(v) = results[d.0].lock().unwrap().take() {
                            v.recycle();
                        }
                    }
                }
                Ok(())
            },
        )
    }

    /// Reference mode: one persistent thread team, synchronized per ASAP
    /// level with a barrier. Retained so differential tests and benches
    /// can compare against the work-stealing scheduler.
    fn run_level_barrier(
        &self,
        tg: &TaskGraph,
        g: &EinGraph,
        plan: &Plan,
        engine: &dyn KernelEngine,
        results: &[ResultSlot],
        threads: usize,
    ) -> Result<()> {
        let by_level = tg.levels();
        if threads == 1 {
            for lvl in &by_level {
                for &ti in lvl {
                    if results[ti].lock().unwrap().is_some() {
                        continue;
                    }
                    let t = exec_task(tg, g, plan, engine, results, ti, &serial_scope())?;
                    *results[ti].lock().unwrap() = Some(t);
                }
            }
            return Ok(());
        }
        use std::sync::atomic::{AtomicUsize, Ordering};
        let err = std::sync::Mutex::new(None::<Error>);
        let counters: Vec<AtomicUsize> = by_level.iter().map(|_| AtomicUsize::new(0)).collect();
        let barrier = std::sync::Barrier::new(threads);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    for (li, lvl) in by_level.iter().enumerate() {
                        loop {
                            let i = counters[li].fetch_add(1, Ordering::Relaxed);
                            if i >= lvl.len() {
                                break;
                            }
                            let ti = lvl[i];
                            if results[ti].lock().unwrap().is_some() {
                                continue; // pre-sliced input tile
                            }
                            match exec_task(tg, g, plan, engine, results, ti, &serial_scope()) {
                                Ok(t) => {
                                    *results[ti].lock().unwrap() = Some(t);
                                }
                                Err(e) => {
                                    *err.lock().unwrap() = Some(e);
                                }
                            }
                        }
                        barrier.wait();
                    }
                });
            }
        });
        match err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Execute a single task; all deps already computed. `scope` is the
/// executor's intra-op shard capability (serial in the level-barrier
/// reference mode); every sharded path is bitwise-identical to serial.
///
/// Dependencies are read as cheap view clones (an `Arc` bump) out of
/// their slots, so a concurrent reclamation of *other* tasks' slots can
/// never invalidate them.
fn exec_task(
    tg: &TaskGraph,
    g: &EinGraph,
    plan: &Plan,
    engine: &dyn KernelEngine,
    results: &[ResultSlot],
    ti: usize,
    scope: &ShardScope,
) -> Result<TensorView> {
    let task = &tg.tasks[ti];
    let dep_view = |d: crate::taskgraph::TaskId| -> Result<TensorView> {
        results[d.0]
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| Error::Exec(format!("dep {} not computed", d.0)))
    };
    match &task.kind {
        TaskKind::InputTile { .. } => Err(Error::Exec(
            "input tiles are pre-sliced by execute() (internal)".into(),
        )),
        TaskKind::Kernel { vertex, key } => {
            let vert = g.vertex(*vertex);
            let op = &vert.op;
            // `fuse-epilogue` attaches retired map vertices here; empty
            // on every unfused lowering.
            let epi = tg.kernel_epilogue.get(&task.id).map(Vec::as_slice);
            let eval = |refs: &[&TensorView]| -> Result<Tensor> {
                match epi {
                    Some(eps) => engine.eval_view_epilogue_scoped(op, refs, eps, scope),
                    None => engine.eval_view_scoped(op, refs, scope),
                }
            };
            // Fast path (every non-aliased lowering, incl. the default
            // `safe` pipeline): deps are exactly the expected operand
            // tiles — no per-operand geometry work on the hot path.
            if !tg.aliased_kernel_deps {
                let ins: Vec<TensorView> = task
                    .deps
                    .iter()
                    .map(|&d| dep_view(d))
                    .collect::<Result<_>>()?;
                let refs: Vec<&TensorView> = ins.iter().collect();
                return eval(&refs).map(Tensor::into_view);
            }
            let uniq = op.unique_labels();
            let mut ins: Vec<TensorView> = Vec::with_capacity(task.deps.len());
            for (o, &dt) in task.deps.iter().enumerate() {
                let view = dep_view(dt)?;
                let c = vert.inputs[o];
                let cb = &g.vertex(c).bound;
                let need = plan.required_in_part(g, *vertex, o);
                let okey = project(key, op.operand_labels()[o], &uniq);
                let shape = tile_shape(cb, &need, &okey);
                if view.shape() == shape.as_slice() {
                    ins.push(view);
                } else {
                    // `alias-refinement-repart` rewrite: the dep is the
                    // single producer tile *containing* the needed
                    // region (same containment math as the IR emission —
                    // geometry only, no search). Slice the exact
                    // sub-view the elided repart task would have
                    // produced: bitwise-identical bytes and strides,
                    // zero copies.
                    let have = &tg.vertex_out_part[&c];
                    let origin = tile_origin(cb, &need, &okey);
                    let pkey: Vec<usize> = (0..cb.len())
                        .map(|dim| {
                            overlapping_tiles(cb[dim], have[dim], origin[dim], shape[dim]).0
                        })
                        .collect();
                    let p_origin = tile_origin(cb, have, &pkey);
                    let rel_off: Vec<usize> =
                        origin.iter().zip(&p_origin).map(|(t, p)| t - p).collect();
                    ins.push(view.slice(&rel_off, &shape)?);
                }
            }
            let refs: Vec<&TensorView> = ins.iter().collect();
            eval(&refs).map(Tensor::into_view)
        }
        TaskKind::Agg { vertex, .. } => {
            let agg = match &g.vertex(*vertex).op {
                EinSum::Unary { agg, .. } => *agg,
                EinSum::Binary { agg, .. } => *agg,
                EinSum::Input => AggOp::Sum,
            };
            // Deterministic regardless of scheduling: combine in fixed
            // `deps` order, never completion order. Large folds chunk the
            // output buffer across shards — each cell still combines its
            // deps in the same order, so chunking cannot change bits.
            let mut acc = dep_view(task.deps[0])?.to_tensor();
            let rest: Vec<TensorView> = task.deps[1..]
                .iter()
                .map(|&d| dep_view(d))
                .collect::<Result<_>>()?;
            for t in &rest {
                if t.shape() != acc.shape() {
                    return Err(Error::Shape(format!(
                        "aggregate shape mismatch: {:?} vs {:?}",
                        acc.shape(),
                        t.shape()
                    )));
                }
            }
            // Kernel outputs are contiguous whole-buffer views; fold over
            // their flat slices. (A non-contiguous dep — impossible today
            // — would materialize below.)
            let p = scope.parallelism();
            if p > 1
                && !rest.is_empty()
                && acc.len() >= SHARD_MIN
                && rest.iter().all(|t| t.is_contiguous())
            {
                let len = acc.len();
                let aptr = SyncPtr::new(acc.data_mut().as_mut_ptr());
                let rslices: Vec<&[f32]> =
                    rest.iter().map(|t| t.as_contiguous().unwrap()).collect();
                scope.fork_join(p, |ci| {
                    let (lo, hi) = chunk_bounds(len, p, ci);
                    let base = aptr.get();
                    for td in &rslices {
                        // SAFETY: [lo, hi) chunks are pairwise disjoint.
                        let ad = unsafe { std::slice::from_raw_parts_mut(base.add(lo), hi - lo) };
                        for (a, &b) in ad.iter_mut().zip(&td[lo..hi]) {
                            *a = agg.combine(*a, b);
                        }
                    }
                });
            } else {
                for t in &rest {
                    let owned = t.to_tensor();
                    acc.accumulate(&owned, |a, b| agg.combine(a, b))?;
                    owned.recycle();
                }
            }
            Ok(acc.into_view())
        }
        TaskKind::Repart {
            producer,
            consumer,
            operand,
            key,
        } => {
            let pb = &g.vertex(*producer).bound;
            let have = &tg.vertex_out_part[producer];
            let need = plan.required_in_part(g, *consumer, *operand);
            let t_origin = tile_origin(pb, &need, key);
            let t_shape = tile_shape(pb, &need, key);
            // Producer tile keys are recovered from each dep's position in
            // the producer's output list (row-major I(d_Z) order) — the
            // task's own `key` field may range over different labels (a
            // Kernel task keys over the unique labels).
            let vouts = &tg.vertex_outputs[producer];
            let dep_key = |d: crate::taskgraph::TaskId| -> Result<Vec<usize>> {
                // Collective relays are not producer outputs; they carry
                // their source tile's producer-layout key themselves.
                if let TaskKind::Collective { key, .. } = &tg.tasks[d.0].kind {
                    return Ok(key.clone());
                }
                let pos = vouts
                    .iter()
                    .position(|&t| t == d)
                    .ok_or_else(|| Error::Exec("repart dep not a producer output".into()))?;
                Ok(crate::tra::relation::delinearize(pos, have))
            };
            // A single overlapping producer tile contains the whole
            // consumer region: alias it as a zero-copy sub-view.
            if task.deps.len() == 1 {
                let pkey = dep_key(task.deps[0])?;
                let p_origin = tile_origin(pb, have, &pkey);
                let rel_off: Vec<usize> = t_origin
                    .iter()
                    .zip(&p_origin)
                    .map(|(t, p)| t - p)
                    .collect();
                return dep_view(task.deps[0])?.slice(&rel_off, &t_shape);
            }
            // Otherwise move exactly the overlapping sub-regions. The
            // union of intersections covers the tile once, so the pooled
            // buffer is fully overwritten.
            let mut out = Tensor::full_pooled(&t_shape, 0.0);
            for &d in &task.deps {
                let pkey = dep_key(d)?;
                let p_origin = tile_origin(pb, have, &pkey);
                let p_shape = tile_shape(pb, have, &pkey);
                let ptile = dep_view(d)?;
                // intersection in global coords
                let rank = pb.len();
                let mut lo = vec![0usize; rank];
                let mut sz = vec![0usize; rank];
                let mut empty = false;
                for dim in 0..rank {
                    let a = t_origin[dim].max(p_origin[dim]);
                    let b = (t_origin[dim] + t_shape[dim]).min(p_origin[dim] + p_shape[dim]);
                    if b <= a {
                        empty = true;
                        break;
                    }
                    lo[dim] = a;
                    sz[dim] = b - a;
                }
                if empty {
                    continue;
                }
                let src_off: Vec<usize> =
                    lo.iter().zip(&p_origin).map(|(a, o)| a - o).collect();
                let dst_off: Vec<usize> =
                    lo.iter().zip(&t_origin).map(|(a, o)| a - o).collect();
                let piece = ptile.slice(&src_off, &sz)?;
                out.write_slice_view(&dst_off, &piece)?;
            }
            Ok(out.into_view())
        }
        TaskKind::Collective { .. } => {
            // A relay step is a pure pass-through copy of its single
            // dependency — a zero-copy view clone (Arc bump), so relayed
            // bytes are bitwise the source tile's bytes by construction.
            dep_view(task.deps[0])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{plan_graph, PlannerConfig};
    use crate::einsum::label::labels;
    use crate::runtime::NativeEngine;

    fn matmul_graph(s: usize) -> EinGraph {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        g.add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
        g
    }

    #[test]
    fn zero_byte_cross_worker_edges_model_zero_seconds() {
        // Regression: `wire_s` used to charge `latency_s` on zero-byte
        // transfers, so free rewrites (aliased / elided repartitions)
        // modeled as non-free. A cross-worker edge carrying no bytes must
        // contribute exactly nothing to the ledger or the timeline.
        let mut tg = TaskGraph::default();
        let t0 = tg.push_task(
            TaskKind::InputTile {
                vertex: VertexId(0),
                key: vec![0],
            },
            vec![],
            0,
            0.0,
        );
        tg.push_task(
            TaskKind::Kernel {
                vertex: VertexId(1),
                key: vec![0],
            },
            vec![t0],
            0,
            0.0,
        );
        tg.tasks[0].worker = Some(0);
        tg.tasks[1].worker = Some(1);
        let mut net = NetworkProfile::cpu_cluster();
        net.sched_overhead_s = 0.0;
        assert!(net.latency_s > 0.0, "test needs a latency-bearing profile");
        let rep = Cluster::new(2, net).model(&tg);
        assert_eq!(rep.sim_makespan_s, 0.0);
        assert_eq!(rep.bytes_moved, 0);
    }

    #[test]
    fn model_reports_positive_makespan() {
        let g = matmul_graph(64);
        let plan = plan_graph(&g, &PlannerConfig { p: 8, ..Default::default() }).unwrap();
        let cluster = Cluster::new(8, NetworkProfile::cpu_cluster());
        let rep = cluster.dry_run(&g, &plan).unwrap();
        assert!(rep.sim_makespan_s > 0.0);
        assert_eq!(rep.kernel_calls, 8);
        assert!(rep.flops > 0.0);
    }

    #[test]
    fn fewer_workers_longer_makespan() {
        // Use a compute-bound size: at tiny scales network latency
        // dominates and one worker (no transfers) wins — which the model
        // correctly captures.
        let g = matmul_graph(1024);
        let plan = plan_graph(&g, &PlannerConfig { p: 8, ..Default::default() }).unwrap();
        let net = NetworkProfile::cpu_cluster();
        let t8 = Cluster::new(8, net.clone()).dry_run(&g, &plan).unwrap();
        let t1 = Cluster::new(1, net).dry_run(&g, &plan).unwrap();
        assert!(t1.sim_makespan_s > t8.sim_makespan_s);
    }

    #[test]
    fn execute_matches_dense_eval() {
        let g = matmul_graph(32);
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let a = Tensor::random(&[32, 32], 1);
        let b = Tensor::random(&[32, 32], 2);
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), a.clone());
        inputs.insert(g.by_name("B").unwrap(), b.clone());
        let engine = NativeEngine::new();
        let z = g.by_name("Z").unwrap();
        let want = crate::runtime::native::eval_einsum(&g.vertex(z).op, &[&a, &b]).unwrap();
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let cluster = Cluster::new(4, NetworkProfile::loopback()).with_exec_mode(mode);
            let (outs, rep) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
            assert!(outs[&z].allclose(&want, 1e-4, 1e-5), "{mode:?}");
            assert!(rep.wall_s > 0.0);
        }
    }

    #[test]
    fn run_lowered_reuses_one_task_graph_bitwise() {
        // The run-many half of the compile-once split: lower exactly once,
        // execute the frozen task graph repeatedly, outputs bitwise-equal
        // to the one-shot execute() path.
        let g = matmul_graph(32);
        let z = g.by_name("Z").unwrap();
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), Tensor::random(&[32, 32], 21));
        inputs.insert(g.by_name("B").unwrap(), Tensor::random(&[32, 32], 22));
        let engine = NativeEngine::new();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let (once, _) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
        let tg = cluster.lower(&g, &plan).unwrap();
        for _ in 0..3 {
            let (outs, rep) = cluster
                .run_lowered(&g, &plan, &tg, &engine, &inputs)
                .unwrap();
            assert_eq!(outs[&z], once[&z]);
            assert!(rep.wall_s > 0.0);
        }
    }

    #[test]
    fn execute_chain_with_repartitions() {
        // force mismatched partitionings so repart tasks execute for real
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let c = g.input("C", vec![16, 16]);
        let z1 = g
            .add(
                "Z1",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let z2 = g
            .add(
                "Z2",
                EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
                vec![z1, c],
            )
            .unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z1, vec![2, 2, 4]); // dz = [2,4]
        plan.parts.insert(z2, vec![4, 1, 4]); // needs [4,1]
        plan.finalize_inputs(&g);
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let ta = Tensor::random(&[16, 16], 3);
        let tb = Tensor::random(&[16, 16], 4);
        let tc = Tensor::random(&[16, 16], 5);
        let mut inputs = HashMap::new();
        inputs.insert(a, ta.clone());
        inputs.insert(b, tb.clone());
        inputs.insert(c, tc.clone());
        let engine = NativeEngine::new();
        let (outs, rep) = cluster.execute(&g, &plan, &engine, &inputs).unwrap();
        let w1 = crate::runtime::native::eval_einsum(&g.vertex(z1).op, &[&ta, &tb]).unwrap();
        let want = crate::runtime::native::eval_einsum(&g.vertex(z2).op, &[&w1, &tc]).unwrap();
        assert!(outs[&z2].allclose(&want, 1e-4, 1e-5));
        assert!(rep.bytes_repart > 0 || rep.bytes_moved > 0);
    }

    #[test]
    fn exec_modes_agree_bitwise() {
        let g = matmul_graph(24);
        let z = g.by_name("Z").unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z, vec![2, 3, 2]); // forces aggregation tasks
        plan.finalize_inputs(&g);
        let a = Tensor::random(&[24, 24], 6);
        let b = Tensor::random(&[24, 24], 7);
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), a);
        inputs.insert(g.by_name("B").unwrap(), b);
        let engine = NativeEngine::new();
        let ws = Cluster::new(4, NetworkProfile::loopback())
            .with_exec_mode(ExecMode::WorkStealing)
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        let lb = Cluster::new(4, NetworkProfile::loopback())
            .with_exec_mode(ExecMode::LevelBarrier)
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        // bitwise: the two schedulers evaluate identical task graphs
        assert_eq!(ws[&z], lb[&z]);
    }

    #[test]
    fn intra_op_degrees_agree_bitwise() {
        // The intra-op fan-out is a scheduling knob only: every degree
        // must produce identical bytes (shard boundaries are a pure
        // function of shape, never of idleness).
        let g = matmul_graph(48);
        let z = g.by_name("Z").unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z, vec![2, 2, 2]); // forces aggregation tasks
        plan.finalize_inputs(&g);
        let a = Tensor::random(&[48, 48], 8);
        let b = Tensor::random(&[48, 48], 9);
        let mut inputs = HashMap::new();
        inputs.insert(g.by_name("A").unwrap(), a);
        inputs.insert(g.by_name("B").unwrap(), b);
        let engine = NativeEngine::new();
        let base = Cluster::new(4, NetworkProfile::loopback())
            .with_intra_op(1)
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        for intra in [0usize, 2, 8] {
            let got = Cluster::new(4, NetworkProfile::loopback())
                .with_intra_op(intra)
                .execute(&g, &plan, &engine, &inputs)
                .unwrap()
                .0;
            assert_eq!(got[&z], base[&z], "intra_op {intra}");
        }
    }

    #[test]
    fn topology_model_tallies_per_link_bytes() {
        let g = matmul_graph(64);
        let plan = plan_graph(&g, &PlannerConfig { p: 8, ..Default::default() }).unwrap();
        let net = NetworkProfile::cpu_cluster();
        let flat = Cluster::new(8, net.clone());
        let tg = flat.lower(&g, &plan).unwrap();
        let base = flat.model(&tg);
        assert_eq!(
            base.bytes_by_link,
            vec![("flat".to_string(), base.bytes_moved)]
        );
        // an explicit flat topology is the seed model, byte for byte
        let rep = flat
            .clone()
            .with_topology(Topology::flat_of(&net, 8))
            .model(&tg);
        assert_eq!(rep.bytes_moved, base.bytes_moved);
        assert_eq!(rep.sim_makespan_s, base.sim_makespan_s);
        assert_eq!(rep.bytes_by_link.len(), 1);
        assert_eq!(rep.bytes_by_link[0].1, base.bytes_moved);
        // three-level: per-class tallies roll up to the same total, and
        // faster inner links can only shorten the modeled makespan
        let rep3 = flat
            .clone()
            .with_topology(Topology::three_level_of(&net, 8))
            .model(&tg);
        assert_eq!(rep3.bytes_moved, base.bytes_moved);
        assert_eq!(rep3.bytes_by_link.len(), 3);
        assert_eq!(
            rep3.bytes_by_link.iter().map(|(_, b)| *b).sum::<u64>(),
            rep3.bytes_moved
        );
        assert!(rep3.sim_makespan_s <= base.sim_makespan_s + 1e-12);
    }

    #[test]
    fn collective_lowering_executes_bitwise() {
        // The forced-repart chain of `execute_chain_with_repartitions`:
        // lower-collectives lifts the Π into an AllGather relay chain and
        // the serial folds into ReduceScatter chains; outputs must be
        // bitwise the point-to-point run in both exec modes.
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let c = g.input("C", vec![16, 16]);
        let z1 = g
            .add(
                "Z1",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let z2 = g
            .add(
                "Z2",
                EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
                vec![z1, c],
            )
            .unwrap();
        let mut plan = crate::decomp::Plan::default();
        plan.parts.insert(z1, vec![2, 2, 4]); // dz = [2,4]
        plan.parts.insert(z2, vec![4, 1, 4]); // needs [4,1]
        plan.finalize_inputs(&g);
        let mut inputs = HashMap::new();
        inputs.insert(a, Tensor::random(&[16, 16], 3));
        inputs.insert(b, Tensor::random(&[16, 16], 4));
        inputs.insert(c, Tensor::random(&[16, 16], 5));
        let engine = NativeEngine::new();
        let net = NetworkProfile::loopback();
        let base = Cluster::new(4, net.clone())
            .execute(&g, &plan, &engine, &inputs)
            .unwrap()
            .0;
        let sel: PassSelector = "elide-identity-repart,lower-collectives,dead-rel-elim"
            .parse()
            .unwrap();
        for mode in [ExecMode::WorkStealing, ExecMode::LevelBarrier] {
            let coll = Cluster::new(4, net.clone())
                .with_passes(sel.clone())
                .with_topology(Topology::three_level_of(&net, 4))
                .with_exec_mode(mode);
            // the rewrite actually fired: Z1's fold + Π fuse into an
            // AllReduce (its dz rel has exactly one consumer, the Π)
            let (_, prog, _) = coll.lower_explain(&g, &plan).unwrap();
            assert!(prog.render().contains("AllReduce"), "{}", prog.render());
            let outs = coll.execute(&g, &plan, &engine, &inputs).unwrap().0;
            assert_eq!(outs[&z2], base[&z2], "{mode:?}");
        }
    }

    #[test]
    fn missing_input_rejected() {
        let g = matmul_graph(8);
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let engine = NativeEngine::new();
        assert!(cluster.execute(&g, &plan, &engine, &HashMap::new()).is_err());
    }
}

//! The simulated distributed runtime.
//!
//! The paper evaluated on a 16-node AWS CPU cluster and multi-GPU servers;
//! neither exists in this container, so (per the reproduction's
//! substitution rule) we execute task graphs on a *simulated cluster*:
//! `p` workers with per-worker tensor storage, a configurable
//! bandwidth/latency [`network::NetworkProfile`], byte-accurate transfer
//! accounting (split into the cost model's join/agg/repartition classes),
//! and an event-driven makespan model. Real kernel execution runs
//! multi-threaded on the host CPU, so wall-clock speedups are real; the
//! simulated timeline adds the network the paper's clusters had.
//!
//! [`memory`] adds per-device memory capacity with LRU paging to host —
//! the TURNIP-style offloading that Experiment 4 (Fig. 11) exercises.

pub mod cluster;
pub mod faults;
pub mod memory;
pub mod network;

pub use cluster::{Cluster, ExecMode, ExecReport};
pub use crate::runtime::spill::MemoryBudget;
pub use faults::{FaultKind, FaultPlan, RunOptions};
pub use network::{LinkClass, NetworkProfile, Topology};

//! Synthetic workload data.
//!
//! The paper's Experiment 2 uses AmazonCat-14K (14,588 labels, 597,540
//! features). That dataset is not available here, so we generate synthetic
//! batches with matching dimensions: the experiment measures *throughput
//! versus feature count*, which depends on shapes, not values (see
//! DESIGN.md §Deviations). A planted linear model makes the learning
//! problem solvable, so the end-to-end training example shows a genuinely
//! decreasing loss curve.

use crate::tensor::Tensor;
use crate::util::Rng;

/// A synthetic classifier batch: `X [batch, features]` with the given
/// nonzero density, and soft targets `T [batch, classes]` produced by a
/// planted random linear map (so the task is learnable).
pub fn classifier_batch(
    batch: usize,
    features: usize,
    classes: usize,
    density: f32,
    seed: u64,
) -> (Tensor, Tensor) {
    let mut rng = Rng::seed_from_u64(seed);
    let mut x = Tensor::zeros(&[batch, features]);
    for v in x.data_mut() {
        if rng.next_f32() < density {
            *v = rng.next_centered() * 2.0;
        }
    }
    // planted weights: deterministic per (features, classes), independent
    // of the batch seed so every batch shares the same ground truth
    let mut wrng = Rng::seed_from_u64(0xFEED ^ (features as u64) ^ ((classes as u64) << 20));
    let planted: Vec<f32> = (0..features * classes)
        .map(|_| wrng.next_centered() * (2.0 / features as f32).sqrt() * 4.0)
        .collect();
    let mut t = Tensor::zeros(&[batch, classes]);
    for bi in 0..batch {
        for c in 0..classes {
            let mut acc = 0.0f32;
            for f in 0..features {
                let xv = x.at(&[bi, f]);
                if xv != 0.0 {
                    acc += xv * planted[f * classes + c];
                }
            }
            t.set(&[bi, c], acc.tanh()); // squash into a bounded target
        }
    }
    (x, t)
}

/// AmazonCat-14K-like dimensions (paper §9.2 Experiment 2).
pub struct AmazonCatDims;

impl AmazonCatDims {
    pub const LABELS: usize = 14_588;
    pub const FEATURES: usize = 597_540;
    pub const HIDDEN: usize = 8_192;
}

/// A synthetic token stream for the tiny-corpus transformer demo: a
/// repeating Markov-ish pattern so a model can learn something.
pub fn token_stream(len: usize, vocab: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut out = Vec::with_capacity(len);
    let mut state = 0usize;
    for _ in 0..len {
        // mostly deterministic cycle with occasional jumps
        state = if rng.next_f32() < 0.85 {
            (state * 7 + 3) % vocab
        } else {
            rng.next_below(vocab)
        };
        out.push(state);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_density() {
        let (x, t) = classifier_batch(32, 100, 8, 0.3, 1);
        assert_eq!(x.shape(), &[32, 100]);
        assert_eq!(t.shape(), &[32, 8]);
        let nz = x.data().iter().filter(|&&v| v != 0.0).count();
        let frac = nz as f32 / x.len() as f32;
        assert!((0.2..0.4).contains(&frac), "density {frac}");
    }

    #[test]
    fn targets_bounded_and_learnable() {
        let (_, t) = classifier_batch(16, 50, 4, 0.5, 2);
        assert!(t.data().iter().all(|v| v.abs() <= 1.0));
        // same planted model across seeds: two batches with identical X
        // rows would give identical targets; spot-check determinism
        let (x1, t1) = classifier_batch(4, 10, 2, 1.0, 3);
        let (x2, t2) = classifier_batch(4, 10, 2, 1.0, 3);
        assert_eq!(x1, x2);
        assert_eq!(t1, t2);
    }

    #[test]
    fn token_stream_in_vocab() {
        let toks = token_stream(1000, 64, 4);
        assert_eq!(toks.len(), 1000);
        assert!(toks.iter().all(|&t| t < 64));
    }
}

//! Crate-wide error type (hand-rolled `Display`/`Error` impls — this
//! crate is dependency-free, so no `thiserror`).

use std::fmt;

/// All errors surfaced by the eindecomp library.
#[derive(Debug)]
pub enum Error {
    /// An EinSum expression is structurally invalid (label/bound mismatch,
    /// repeated labels within one operand, rank mismatch, ...).
    InvalidEinsum(String),

    /// The textual einsum spec could not be parsed.
    Parse(String),

    /// An EinGraph is malformed (dangling input, cycle, bound mismatch).
    InvalidGraph(String),

    /// Shape/bound error in a tensor operation.
    Shape(String),

    /// A partitioning vector is invalid for the bound it is applied to.
    InvalidPartitioning(String),

    /// The planner could not find any viable decomposition.
    NoViablePlan(String),

    /// Task graph construction/validation failure.
    TaskGraph(String),

    /// Simulated cluster execution failure.
    Exec(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Artifact (AOT-compiled HLO) missing or unreadable.
    Artifact(String),

    /// Device memory capacity exceeded and paging disabled.
    Oom(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidEinsum(m) => write!(f, "invalid einsum: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::InvalidGraph(m) => write!(f, "invalid graph: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::InvalidPartitioning(m) => write!(f, "invalid partitioning: {m}"),
            Error::NoViablePlan(m) => write!(f, "no viable decomposition: {m}"),
            Error::TaskGraph(m) => write!(f, "task graph error: {m}"),
            Error::Exec(m) => write!(f, "execution error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Oom(m) => write!(f, "out of device memory: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_variant() {
        assert!(format!("{}", Error::Parse("x".into())).starts_with("parse error"));
        assert!(format!("{}", Error::Exec("x".into())).starts_with("execution error"));
    }

    #[test]
    fn io_error_converts() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}

//! Crate-wide error type (hand-rolled `Display`/`Error` impls — this
//! crate is dependency-free, so no `thiserror`).
//!
//! Two layers coexist:
//!
//! * the original string-payload variants (`Parse`, `Shape`, ...), kept
//!   for the construction-time checks whose only consumer is a human
//!   reading the message;
//! * a structured taxonomy for the `Session::compile` /
//!   `Executable::run` path — [`PlanError`], [`LowerError`] and
//!   [`ExecError`] — so serving front-ends can branch on *what* failed
//!   (which task, after how many attempts, for which [`ExecCause`])
//!   instead of string-matching. [`ExecCause::DeadlineExceeded`] carries
//!   partial-progress stats; [`ExecCause::Injected`] marks deterministic
//!   fault-plan failures (see [`crate::sim::faults`]).

use std::fmt;

/// Planning failed for a configured strategy (the typed face of the
/// `Session::compile` planner stage).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanError {
    /// Strategy name the planner ran under.
    pub strategy: String,
    pub detail: String,
}

/// Lowering (IR build, pass pipeline, task emission, placement or
/// validation) failed — the typed face of the `Cluster::lower` stage.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LowerError {
    /// Pipeline stage that failed (`"ir-build"`, `"emit"`, ...).
    pub stage: &'static str,
    pub detail: String,
}

/// Execution failed. `task` is the task-graph index when the failure is
/// attributable to one task (`None` for run-level failures such as input
/// validation), `attempts` counts how many times that task was tried
/// before the executor gave up (0 when no retry loop was involved).
#[derive(Clone, Debug, PartialEq)]
pub struct ExecError {
    pub task: Option<usize>,
    pub attempts: u32,
    pub cause: ExecCause,
}

/// Why execution failed — the run-path taxonomy.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecCause {
    /// A task reached the run path without a placed worker.
    Unplaced,
    /// A required graph input tensor was not supplied.
    MissingInput { vertex: String },
    /// A supplied input's shape disagrees with the graph's bound.
    ShapeMismatch {
        vertex: String,
        got: Vec<usize>,
        want: Vec<usize>,
    },
    /// A supplied input contains NaN/Inf and the run opted into
    /// `RunOptions::reject_nonfinite`.
    NonFinite { vertex: String, index: usize },
    /// A fault-plan permanent failure killed this worker.
    WorkerDead { worker: usize },
    /// Every simulated worker is dead — no survivor to re-home onto.
    NoSurvivors,
    /// The run exceeded `RunOptions::deadline`. Carries partial-progress
    /// stats: elapsed wall time, tasks completed out of total, and the
    /// retries spent before the budget ran out.
    DeadlineExceeded {
        elapsed_s: f64,
        completed: usize,
        total: usize,
        retries: u64,
    },
    /// A deterministic fault-plan failure (transient unless `permanent`).
    Injected { permanent: bool },
    /// A result-slot mutex was poisoned by a panicking thread.
    LockPoisoned { what: &'static str },
    /// A dependency tile was missing and could not be recomputed.
    MissingDep { dep: usize },
    /// A single-task working set cannot fit in the per-worker
    /// [`MemoryBudget`](crate::runtime::spill::MemoryBudget) even after
    /// evicting every cold tile — the budget is below the plan's
    /// irreducible floor (see `TraProgram::residency_stats`).
    BudgetExceeded {
        worker: usize,
        needed_bytes: u64,
        budget_bytes: u64,
    },
    /// The kernel/engine failed for a non-injected reason.
    Kernel { detail: String },
}

/// A serving request was rejected (or abandoned) by the [`crate::serve`]
/// front-end — the typed face of the `Server::submit` admission path,
/// mirroring [`ExecError`] so load generators can branch on the cause.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeError {
    /// Tenant that issued the rejected request.
    pub tenant: String,
    pub cause: ServeCause,
}

/// Why the serving layer rejected or failed a request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeCause {
    /// Admission control: the bounded request queue is at capacity.
    QueueFull { depth: usize, limit: usize },
    /// The server is draining and no longer admits requests.
    ShuttingDown,
    /// The coalesced execution this request was batched into failed;
    /// `detail` renders the underlying error.
    BatchFailed { batched_with: usize, detail: String },
    /// The worker processing this request disappeared before replying
    /// (its response channel closed without a result).
    Disconnected,
}

impl fmt::Display for ServeCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeCause::QueueFull { depth, limit } => {
                write!(f, "queue full ({depth}/{limit} requests pending)")
            }
            ServeCause::ShuttingDown => write!(f, "server shutting down"),
            ServeCause::BatchFailed {
                batched_with,
                detail,
            } => write!(f, "batched execution ({batched_with} requests) failed: {detail}"),
            ServeCause::Disconnected => write!(f, "worker disconnected before replying"),
        }
    }
}

impl fmt::Display for ExecCause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecCause::Unplaced => write!(f, "task used before placement"),
            ExecCause::MissingInput { vertex } => {
                write!(f, "missing input tensor for {vertex}")
            }
            ExecCause::ShapeMismatch { vertex, got, want } => {
                write!(f, "input {vertex}: shape {got:?} != bound {want:?}")
            }
            ExecCause::NonFinite { vertex, index } => {
                write!(f, "input {vertex}: non-finite value at flat index {index}")
            }
            ExecCause::WorkerDead { worker } => write!(f, "worker {worker} died"),
            ExecCause::NoSurvivors => write!(f, "all workers dead, nothing to re-home onto"),
            ExecCause::DeadlineExceeded {
                elapsed_s,
                completed,
                total,
                retries,
            } => write!(
                f,
                "deadline exceeded after {:.3}s ({completed}/{total} tasks done, {retries} retries)",
                elapsed_s
            ),
            ExecCause::Injected { permanent } => write!(
                f,
                "injected {} fault",
                if *permanent { "permanent" } else { "transient" }
            ),
            ExecCause::LockPoisoned { what } => write!(f, "{what} mutex poisoned"),
            ExecCause::MissingDep { dep } => {
                write!(f, "dependency tile {dep} missing and unrecoverable")
            }
            ExecCause::BudgetExceeded {
                worker,
                needed_bytes,
                budget_bytes,
            } => write!(
                f,
                "worker {worker}: working set needs {needed_bytes} more bytes but the \
                 per-worker budget is {budget_bytes} bytes even after evicting all cold tiles"
            ),
            ExecCause::Kernel { detail } => write!(f, "{detail}"),
        }
    }
}

/// All errors surfaced by the eindecomp library.
#[derive(Debug)]
pub enum Error {
    /// An EinSum expression is structurally invalid (label/bound mismatch,
    /// repeated labels within one operand, rank mismatch, ...).
    InvalidEinsum(String),

    /// The textual einsum spec could not be parsed.
    Parse(String),

    /// An EinGraph is malformed (dangling input, cycle, bound mismatch).
    InvalidGraph(String),

    /// Shape/bound error in a tensor operation.
    Shape(String),

    /// A partitioning vector is invalid for the bound it is applied to.
    InvalidPartitioning(String),

    /// The planner could not find any viable decomposition.
    NoViablePlan(String),

    /// Task graph construction/validation failure.
    TaskGraph(String),

    /// Simulated cluster execution failure (legacy string form; the run
    /// path raises [`Error::ExecFailure`]).
    Exec(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Artifact (AOT-compiled HLO) missing or unreadable.
    Artifact(String),

    /// Device memory capacity exceeded and paging disabled.
    Oom(String),

    Io(std::io::Error),

    /// Structured planner failure (`Session::compile` path).
    PlanFailure(PlanError),

    /// Structured lowering failure (`Session::compile` path).
    LowerFailure(LowerError),

    /// Structured execution failure (`Executable::run` path).
    ExecFailure(ExecError),

    /// Structured serving rejection (`Server::submit` / ticket path).
    ServeRejected(ServeError),
}

impl Error {
    /// Construct a structured execution failure.
    pub fn exec_failure(task: Option<usize>, attempts: u32, cause: ExecCause) -> Error {
        Error::ExecFailure(ExecError {
            task,
            attempts,
            cause,
        })
    }

    /// The structured execution error, if this is one.
    pub fn as_exec(&self) -> Option<&ExecError> {
        match self {
            Error::ExecFailure(e) => Some(e),
            _ => None,
        }
    }

    /// True when this error is a [`ExecCause::DeadlineExceeded`] timeout.
    pub fn is_deadline(&self) -> bool {
        matches!(
            self,
            Error::ExecFailure(ExecError {
                cause: ExecCause::DeadlineExceeded { .. },
                ..
            })
        )
    }

    /// Construct a structured serving rejection.
    pub fn serve_rejected(tenant: impl Into<String>, cause: ServeCause) -> Error {
        Error::ServeRejected(ServeError {
            tenant: tenant.into(),
            cause,
        })
    }

    /// The structured serving rejection, if this is one.
    pub fn as_serve(&self) -> Option<&ServeError> {
        match self {
            Error::ServeRejected(e) => Some(e),
            _ => None,
        }
    }

    /// True when this error is a [`ServeCause::QueueFull`] admission
    /// rejection (the one a load generator should treat as back-pressure
    /// rather than failure).
    pub fn is_queue_full(&self) -> bool {
        matches!(
            self,
            Error::ServeRejected(ServeError {
                cause: ServeCause::QueueFull { .. },
                ..
            })
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidEinsum(m) => write!(f, "invalid einsum: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::InvalidGraph(m) => write!(f, "invalid graph: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::InvalidPartitioning(m) => write!(f, "invalid partitioning: {m}"),
            Error::NoViablePlan(m) => write!(f, "no viable decomposition: {m}"),
            Error::TaskGraph(m) => write!(f, "task graph error: {m}"),
            Error::Exec(m) => write!(f, "execution error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Oom(m) => write!(f, "out of device memory: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::PlanFailure(e) => {
                write!(f, "plan error [{}]: {}", e.strategy, e.detail)
            }
            Error::LowerFailure(e) => {
                write!(f, "lower error [{}]: {}", e.stage, e.detail)
            }
            Error::ExecFailure(e) => match e.task {
                Some(t) => write!(
                    f,
                    "execution error [task {t}, {} attempt(s)]: {}",
                    e.attempts, e.cause
                ),
                None => write!(f, "execution error: {}", e.cause),
            },
            Error::ServeRejected(e) => {
                write!(f, "serve rejected [tenant {}]: {}", e.tenant, e.cause)
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_by_variant() {
        assert!(format!("{}", Error::Parse("x".into())).starts_with("parse error"));
        assert!(format!("{}", Error::Exec("x".into())).starts_with("execution error"));
    }

    #[test]
    fn io_error_converts() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn exec_failure_carries_task_and_attempts() {
        let e = Error::exec_failure(Some(7), 3, ExecCause::Injected { permanent: false });
        let s = e.to_string();
        assert!(s.contains("task 7"), "{s}");
        assert!(s.contains("3 attempt(s)"), "{s}");
        assert!(s.contains("transient"), "{s}");
        let inner = e.as_exec().unwrap();
        assert_eq!(inner.task, Some(7));
        assert_eq!(inner.attempts, 3);
    }

    #[test]
    fn budget_exceeded_renders_sizes() {
        let e = Error::exec_failure(
            None,
            0,
            ExecCause::BudgetExceeded {
                worker: 2,
                needed_bytes: 4096,
                budget_bytes: 1024,
            },
        );
        let s = e.to_string();
        assert!(s.contains("worker 2"), "{s}");
        assert!(s.contains("4096"), "{s}");
        assert!(s.contains("budget is 1024"), "{s}");
        assert!(matches!(
            e.as_exec().unwrap().cause,
            ExecCause::BudgetExceeded { worker: 2, .. }
        ));
    }

    #[test]
    fn deadline_is_detectable_and_carries_progress() {
        let e = Error::exec_failure(
            None,
            0,
            ExecCause::DeadlineExceeded {
                elapsed_s: 1.25,
                completed: 3,
                total: 10,
                retries: 2,
            },
        );
        assert!(e.is_deadline());
        let s = e.to_string();
        assert!(s.contains("3/10"), "{s}");
        assert!(s.contains("2 retries"), "{s}");
        assert!(!Error::Exec("x".into()).is_deadline());
    }

    #[test]
    fn serve_rejection_is_typed_and_detectable() {
        let e = Error::serve_rejected("tenant-3", ServeCause::QueueFull { depth: 64, limit: 64 });
        assert!(e.is_queue_full());
        let s = e.to_string();
        assert!(s.contains("tenant-3"), "{s}");
        assert!(s.contains("64/64"), "{s}");
        assert_eq!(e.as_serve().unwrap().tenant, "tenant-3");
        let b = Error::serve_rejected(
            "t",
            ServeCause::BatchFailed {
                batched_with: 4,
                detail: "boom".into(),
            },
        );
        assert!(!b.is_queue_full());
        assert!(b.to_string().contains("4 requests"), "{b}");
        assert!(!Error::serve_rejected("t", ServeCause::ShuttingDown).is_queue_full());
    }

    #[test]
    fn structured_variants_render_their_context() {
        let p = Error::PlanFailure(PlanError {
            strategy: "eindecomp".into(),
            detail: "no viable partitioning".into(),
        });
        assert!(p.to_string().starts_with("plan error [eindecomp]"));
        let l = Error::LowerFailure(LowerError {
            stage: "emit",
            detail: "bad rel".into(),
        });
        assert!(l.to_string().starts_with("lower error [emit]"));
        let u = Error::exec_failure(Some(0), 0, ExecCause::Unplaced);
        assert!(u.to_string().contains("before placement"));
    }
}

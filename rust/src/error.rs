//! Crate-wide error type.

use thiserror::Error;

/// All errors surfaced by the eindecomp library.
#[derive(Error, Debug)]
pub enum Error {
    /// An EinSum expression is structurally invalid (label/bound mismatch,
    /// repeated labels within one operand, rank mismatch, ...).
    #[error("invalid einsum: {0}")]
    InvalidEinsum(String),

    /// The textual einsum spec could not be parsed.
    #[error("parse error: {0}")]
    Parse(String),

    /// An EinGraph is malformed (dangling input, cycle, bound mismatch).
    #[error("invalid graph: {0}")]
    InvalidGraph(String),

    /// Shape/bound error in a tensor operation.
    #[error("shape error: {0}")]
    Shape(String),

    /// A partitioning vector is invalid for the bound it is applied to.
    #[error("invalid partitioning: {0}")]
    InvalidPartitioning(String),

    /// The planner could not find any viable decomposition.
    #[error("no viable decomposition: {0}")]
    NoViablePlan(String),

    /// Task graph construction/validation failure.
    #[error("task graph error: {0}")]
    TaskGraph(String),

    /// Simulated cluster execution failure.
    #[error("execution error: {0}")]
    Exec(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact (AOT-compiled HLO) missing or unreadable.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Device memory capacity exceeded and paging disabled.
    #[error("out of device memory: {0}")]
    Oom(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

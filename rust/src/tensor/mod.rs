//! Dense row-major `f32` tensors and strided views over them.
//!
//! [`Tensor`] is the owned value type pushed through the tensor-relational
//! runtime; [`TensorView`] is the zero-copy window type the
//! data plane moves instead of copies — a tensor relation stores
//! *sub-tensor views* keyed by partition index (see
//! [`crate::tra::relation`]). Tensor buffers are reference-counted
//! (`Arc`), so cloning a tensor, taking a whole-tensor view, and the
//! identity permutation are all O(1); mutation goes through copy-on-write
//! ([`Tensor::data_mut`]).

use crate::error::{Error, Result};
use crate::util::{BufferPool, Rng};
use std::sync::Arc;

mod view;
pub use view::TensorView;

/// A dense, row-major (C-order), `f32` tensor of arbitrary rank.
///
/// Rank-0 tensors (scalars) are represented with an empty shape and a
/// single element. The buffer is shared (`Arc`): `clone()` is O(1) and
/// [`data_mut`](Self::data_mut) copies-on-write only when the buffer is
/// actually shared.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Arc<Vec<f32>>,
}

impl Tensor {
    /// Create a tensor from a shape and a flat row-major buffer.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} implies {} elements, buffer has {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor {
            shape,
            data: Arc::new(data),
        })
    }

    /// Build a tensor around an already-shared buffer (no copy). Internal:
    /// used by [`TensorView::to_tensor`] and the pooled constructors.
    pub(crate) fn from_shared(shape: Vec<usize>, data: Arc<Vec<f32>>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    /// All-zeros tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new(vec![0.0; n]),
        }
    }

    /// Tensor filled with a constant.
    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new(vec![v; n]),
        }
    }

    /// Like [`full`](Self::full), but drawing the buffer from the calling
    /// thread's [`BufferPool`] — the hot-path constructor for kernel
    /// outputs (recycled later via [`recycle`](Self::recycle)).
    pub fn full_pooled(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new(BufferPool::take_filled(n, v)),
        }
    }

    /// Deterministic pseudo-random tensor in `[-0.5, 0.5)`, seeded so tests
    /// and benches are reproducible.
    pub fn random(shape: &[usize], seed: u64) -> Self {
        let n: usize = shape.iter().product();
        let mut rng = Rng::seed_from_u64(seed);
        let data = (0..n).map(|_| rng.next_centered()).collect();
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new(data),
        }
    }

    /// `iota`: 0,1,2,... useful in partitioning tests (matches the paper's
    /// worked 4x4 example when reshaped).
    pub fn iota(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: Arc::new((0..n).map(|i| i as f32).collect()),
        }
    }

    /// Scalar tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor {
            shape: vec![],
            data: Arc::new(vec![v]),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size of the tensor in bytes (f32 elements).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access to the buffer, copying-on-write if it is shared
    /// with views or clones.
    pub fn data_mut(&mut self) -> &mut [f32] {
        Arc::make_mut(&mut self.data)
    }

    pub fn into_data(self) -> Vec<f32> {
        match Arc::try_unwrap(self.data) {
            Ok(v) => v,
            Err(shared) => (*shared).clone(),
        }
    }

    /// Return this tensor's buffer to the calling thread's
    /// [`BufferPool`] if this was its last reference (views and clones
    /// keep it alive); otherwise just drop.
    pub fn recycle(self) {
        if let Ok(v) = Arc::try_unwrap(self.data) {
            BufferPool::give(v);
        }
    }

    /// O(1) whole-tensor [`TensorView`] (shares the buffer).
    pub fn view(&self) -> TensorView {
        TensorView::from_parts(self.data.clone(), 0, self.shape.clone(), self.strides())
    }

    /// O(1) conversion into a whole-tensor [`TensorView`].
    pub fn into_view(self) -> TensorView {
        let strides = self.strides();
        TensorView::from_parts(self.data, 0, self.shape, strides)
    }

    /// O(1) view of the hyper-rectangle at `offset` with size `size` —
    /// the zero-copy counterpart of [`slice`](Self::slice).
    pub fn slice_view(&self, offset: &[usize], size: &[usize]) -> Result<TensorView> {
        self.view().slice(offset, size)
    }

    /// Row-major strides for the current shape.
    pub fn strides(&self) -> Vec<usize> {
        strides_of(&self.shape)
    }

    /// Read the element at a multi-index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[flat_offset(&self.shape, idx)]
    }

    /// Write the element at a multi-index (copy-on-write if shared).
    pub fn set(&mut self, idx: &[usize], v: f32) {
        let off = flat_offset(&self.shape, idx);
        Arc::make_mut(&mut self.data)[off] = v;
    }

    /// Reshape without moving data (element count must match).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} -> {:?}",
                self.shape, shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Extract the hyper-rectangle starting at `offset` with size `size`.
    ///
    /// This is the tile-extraction primitive used to turn a tensor into a
    /// tensor relation (`TensorRelation::partition`) and to slice producer
    /// sub-tensors during repartitioning.
    pub fn slice(&self, offset: &[usize], size: &[usize]) -> Result<Tensor> {
        if offset.len() != self.rank() || size.len() != self.rank() {
            return Err(Error::Shape(format!(
                "slice rank mismatch: tensor {:?}, offset {:?}, size {:?}",
                self.shape, offset, size
            )));
        }
        for d in 0..self.rank() {
            if offset[d] + size[d] > self.shape[d] {
                return Err(Error::Shape(format!(
                    "slice out of bounds on dim {}: {}+{} > {}",
                    d, offset[d], size[d], self.shape[d]
                )));
            }
        }
        let out_n: usize = size.iter().product();
        let mut out = Vec::with_capacity(out_n);
        if self.rank() == 0 {
            return Tensor::new(vec![], vec![self.data[0]]);
        }
        // Iterate over all rows of the slice (all dims but the last), and
        // memcpy the contiguous innermost runs.
        let in_strides = self.strides();
        let last = self.rank() - 1;
        let row_len = size[last];
        let outer: usize = size[..last].iter().product();
        let mut idx = vec![0usize; last];
        for _ in 0..outer.max(1) {
            let mut base = offset[last] * in_strides[last];
            for d in 0..last {
                base += (offset[d] + idx[d]) * in_strides[d];
            }
            out.extend_from_slice(&self.data[base..base + row_len]);
            // increment odometer over size[..last]
            for d in (0..last).rev() {
                idx[d] += 1;
                if idx[d] < size[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        Tensor::new(size.to_vec(), out)
    }

    /// Write `src` into this tensor at `offset` (inverse of [`slice`]).
    pub fn write_slice(&mut self, offset: &[usize], src: &Tensor) -> Result<()> {
        if offset.len() != self.rank() || src.rank() != self.rank() {
            return Err(Error::Shape(format!(
                "write_slice rank mismatch: dst {:?}, offset {:?}, src {:?}",
                self.shape, offset, src.shape
            )));
        }
        for d in 0..self.rank() {
            if offset[d] + src.shape[d] > self.shape[d] {
                return Err(Error::Shape(format!(
                    "write_slice out of bounds on dim {}: {}+{} > {}",
                    d, offset[d], src.shape[d], self.shape[d]
                )));
            }
        }
        if self.rank() == 0 {
            self.data_mut()[0] = src.data[0];
            return Ok(());
        }
        let dst_strides = self.strides();
        let last = self.rank() - 1;
        let row_len = src.shape[last];
        let outer: usize = src.shape[..last].iter().product();
        let mut idx = vec![0usize; last];
        let mut src_pos = 0usize;
        let dst = Arc::make_mut(&mut self.data);
        for _ in 0..outer.max(1) {
            let mut base = offset[last] * dst_strides[last];
            for d in 0..last {
                base += (offset[d] + idx[d]) * dst_strides[d];
            }
            dst[base..base + row_len].copy_from_slice(&src.data[src_pos..src_pos + row_len]);
            src_pos += row_len;
            for d in (0..last).rev() {
                idx[d] += 1;
                if idx[d] < src.shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        Ok(())
    }

    /// Write a [`TensorView`]'s elements into this tensor at `offset` —
    /// the strided-source counterpart of [`write_slice`](Self::write_slice)
    /// (used to assemble relations of view tiles back into dense form).
    pub fn write_slice_view(&mut self, offset: &[usize], src: &TensorView) -> Result<()> {
        if offset.len() != self.rank() || src.rank() != self.rank() {
            return Err(Error::Shape(format!(
                "write_slice_view rank mismatch: dst {:?}, offset {:?}, src {:?}",
                self.shape,
                offset,
                src.shape()
            )));
        }
        for d in 0..self.rank() {
            if offset[d] + src.shape()[d] > self.shape[d] {
                return Err(Error::Shape(format!(
                    "write_slice_view out of bounds on dim {}: {}+{} > {}",
                    d,
                    offset[d],
                    src.shape()[d],
                    self.shape[d]
                )));
            }
        }
        if src.is_empty() {
            return Ok(());
        }
        if self.rank() == 0 {
            self.data_mut()[0] = src.at(&[]);
            return Ok(());
        }
        let dst_strides = self.strides();
        let last = self.rank() - 1;
        let row_len = src.shape()[last];
        let src_strides = src.strides().to_vec();
        let src_data = src.raw();
        let outer: usize = src.shape()[..last].iter().product();
        let mut idx = vec![0usize; last];
        let dst = Arc::make_mut(&mut self.data);
        for _ in 0..outer.max(1) {
            let mut base = offset[last] * dst_strides[last];
            let mut sbase = 0usize;
            for d in 0..last {
                base += (offset[d] + idx[d]) * dst_strides[d];
                sbase += idx[d] * src_strides[d];
            }
            if src_strides[last] == 1 {
                dst[base..base + row_len].copy_from_slice(&src_data[sbase..sbase + row_len]);
            } else {
                for j in 0..row_len {
                    dst[base + j] = src_data[sbase + j * src_strides[last]];
                }
            }
            for d in (0..last).rev() {
                idx[d] += 1;
                if idx[d] < src.shape()[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        Ok(())
    }

    /// Permute axes: output dim `i` is input dim `perm[i]`.
    pub fn permute(&self, perm: &[usize]) -> Result<Tensor> {
        if perm.len() != self.rank() {
            return Err(Error::Shape(format!(
                "permute rank mismatch: {:?} vs {:?}",
                self.shape, perm
            )));
        }
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            if p >= perm.len() || seen[p] {
                return Err(Error::Shape(format!("invalid permutation {perm:?}")));
            }
            seen[p] = true;
        }
        // Identity fast path (hot in the executor: most kernel calls are
        // already in canonical layout). O(1): the clone shares the
        // reference-counted buffer, no floats move.
        if perm.iter().enumerate().all(|(i, &p)| i == p) {
            return Ok(self.clone());
        }
        let out_shape: Vec<usize> = perm.iter().map(|&p| self.shape[p]).collect();
        let in_strides = self.strides();
        // stride in the input for each output dim
        let perm_strides: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
        let n = self.data.len();
        let mut out = vec![0.0f32; n];
        if self.rank() == 0 {
            out[0] = self.data[0];
            return Tensor::new(out_shape, out);
        }
        // Rank-2 transpose fast path: 32x32 cache tiles (the strided-read
        // generic path manages <1 GB/s on large matrices; tiling restores
        // ~memory bandwidth — §Perf lever 3).
        if self.rank() == 2 && perm == [1, 0] {
            let (r, ccols) = (self.shape[0], self.shape[1]);
            const TB: usize = 32;
            let src = &self.data;
            for i0 in (0..r).step_by(TB) {
                let imax = (i0 + TB).min(r);
                for j0 in (0..ccols).step_by(TB) {
                    let jmax = (j0 + TB).min(ccols);
                    for i in i0..imax {
                        let row = &src[i * ccols..i * ccols + ccols];
                        for j in j0..jmax {
                            out[j * r + i] = row[j];
                        }
                    }
                }
            }
            return Tensor::new(out_shape, out);
        }
        // Odometer over the output shape; inner loop over the last output
        // dim with its (input) stride.
        let last = out_shape.len() - 1;
        let inner = out_shape[last];
        let inner_stride = perm_strides[last];
        let outer: usize = out_shape[..last].iter().product();
        let mut idx = vec![0usize; last];
        let mut out_pos = 0usize;
        for _ in 0..outer.max(1) {
            let mut base = 0usize;
            for d in 0..last {
                base += idx[d] * perm_strides[d];
            }
            if inner_stride == 1 {
                out[out_pos..out_pos + inner].copy_from_slice(&self.data[base..base + inner]);
            } else {
                for j in 0..inner {
                    out[out_pos + j] = self.data[base + j * inner_stride];
                }
            }
            out_pos += inner;
            for d in (0..last).rev() {
                idx[d] += 1;
                if idx[d] < out_shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        Tensor::new(out_shape, out)
    }

    /// Max absolute difference vs another tensor (testing aid).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "compare shape mismatch: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// Relative-tolerance allclose (testing aid).
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(other.data.iter())
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs().max(a.abs()))
    }

    /// In-place elementwise accumulate with an associative op.
    pub fn accumulate(&mut self, other: &Tensor, op: impl Fn(f32, f32) -> f32) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "accumulate shape mismatch: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let dst = Arc::make_mut(&mut self.data);
        for (a, b) in dst.iter_mut().zip(other.data.iter()) {
            *a = op(*a, *b);
        }
        Ok(())
    }
}

/// Row-major flat offset of `idx` within `shape` (no allocation).
#[inline]
fn flat_offset(shape: &[usize], idx: &[usize]) -> usize {
    debug_assert_eq!(idx.len(), shape.len());
    idx.iter().zip(shape).fold(0usize, |acc, (&i, &d)| {
        debug_assert!(i < d);
        acc * d + i
    })
}

/// Row-major strides of a shape. Empty shape -> empty strides.
pub fn strides_of(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

/// Iterate over all multi-indices of a bound (odometer order).
/// This is `I(b)` in the paper's notation.
pub fn index_space(bound: &[usize]) -> IndexSpace {
    IndexSpace {
        bound: bound.to_vec(),
        cur: vec![0; bound.len()],
        done: bound.iter().any(|&b| b == 0),
        first: true,
    }
}

/// Iterator over `I(b)`.
pub struct IndexSpace {
    bound: Vec<usize>,
    cur: Vec<usize>,
    done: bool,
    first: bool,
}

impl Iterator for IndexSpace {
    type Item = Vec<usize>;
    fn next(&mut self) -> Option<Vec<usize>> {
        if self.done {
            return None;
        }
        if self.first {
            self.first = false;
            return Some(self.cur.clone());
        }
        for d in (0..self.bound.len()).rev() {
            self.cur[d] += 1;
            if self.cur[d] < self.bound[d] {
                return Some(self.cur.clone());
            }
            self.cur[d] = 0;
        }
        self.done = true;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_len() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(strides_of(&[2, 3, 4]), vec![12, 4, 1]);
        assert_eq!(strides_of(&[5]), vec![1]);
        assert_eq!(strides_of(&[]), Vec::<usize>::new());
    }

    #[test]
    fn at_and_set() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.set(&[1, 2], 7.0);
        assert_eq!(t.at(&[1, 2]), 7.0);
        assert_eq!(t.at(&[0, 0]), 0.0);
    }

    #[test]
    fn slice_matches_paper_u_example() {
        // The paper's 4x4 matrix U, partitioned d=[2,2]: tile (1,0) is
        // [[9,10],[11,12]].
        let u = Tensor::new(
            vec![4, 4],
            vec![
                1., 2., 5., 6., 3., 4., 7., 8., 9., 10., 13., 14., 11., 12., 15., 16.,
            ],
        )
        .unwrap();
        let tile = u.slice(&[2, 0], &[2, 2]).unwrap();
        assert_eq!(tile.data(), &[9., 10., 11., 12.]);
        // d=[4,2]: tile (0,1) is the column [2,4]^T
        let tile2 = u.slice(&[0, 2], &[1, 2]).unwrap();
        assert_eq!(tile2.data(), &[5., 6.]);
    }

    #[test]
    fn slice_out_of_bounds() {
        let t = Tensor::zeros(&[4, 4]);
        assert!(t.slice(&[3, 0], &[2, 2]).is_err());
    }

    #[test]
    fn slice_write_roundtrip() {
        let t = Tensor::iota(&[4, 6]);
        let s = t.slice(&[1, 2], &[2, 3]).unwrap();
        let mut z = Tensor::zeros(&[4, 6]);
        z.write_slice(&[1, 2], &s).unwrap();
        assert_eq!(z.at(&[1, 2]), t.at(&[1, 2]));
        assert_eq!(z.at(&[2, 4]), t.at(&[2, 4]));
        assert_eq!(z.at(&[0, 0]), 0.0);
    }

    #[test]
    fn permute_transpose() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let p = t.permute(&[1, 0]).unwrap();
        assert_eq!(p.shape(), &[3, 2]);
        assert_eq!(p.data(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn permute_rank3() {
        let t = Tensor::iota(&[2, 3, 4]);
        let p = t.permute(&[2, 0, 1]).unwrap();
        assert_eq!(p.shape(), &[4, 2, 3]);
        assert_eq!(p.at(&[3, 1, 2]), t.at(&[1, 2, 3]));
    }

    #[test]
    fn permute_identity_fast_path() {
        let t = Tensor::random(&[3, 5], 1);
        assert_eq!(t.permute(&[0, 1]).unwrap(), t);
    }

    #[test]
    fn index_space_iterates_in_odometer_order() {
        let v: Vec<_> = index_space(&[2, 2]).collect();
        assert_eq!(
            v,
            vec![vec![0, 0], vec![0, 1], vec![1, 0], vec![1, 1]]
        );
        assert_eq!(index_space(&[]).count(), 1); // scalar: single empty index
        assert_eq!(index_space(&[3, 0]).count(), 0);
    }

    #[test]
    fn accumulate_sum() {
        let mut a = Tensor::full(&[2, 2], 1.0);
        let b = Tensor::full(&[2, 2], 2.0);
        a.accumulate(&b, |x, y| x + y).unwrap();
        assert_eq!(a.data(), &[3.0; 4]);
    }

    #[test]
    fn allclose_and_diff() {
        let a = Tensor::full(&[2], 1.0);
        let b = Tensor::full(&[2], 1.0 + 1e-7);
        assert!(a.allclose(&b, 1e-5, 1e-6));
        assert!(a.max_abs_diff(&b).unwrap() < 1e-6);
    }

    #[test]
    fn clone_is_shared_and_cow_isolates() {
        let mut a = Tensor::iota(&[2, 3]);
        let b = a.clone();
        // clone shares the buffer...
        assert!(std::ptr::eq(a.data().as_ptr(), b.data().as_ptr()));
        // ...until a write, which copies a's buffer and leaves b intact.
        a.set(&[0, 0], 99.0);
        assert_eq!(a.at(&[0, 0]), 99.0);
        assert_eq!(b.at(&[0, 0]), 0.0);
        assert!(!std::ptr::eq(a.data().as_ptr(), b.data().as_ptr()));
    }

    #[test]
    fn identity_permute_shares_buffer() {
        let t = Tensor::random(&[3, 5], 1);
        let p = t.permute(&[0, 1]).unwrap();
        assert!(std::ptr::eq(t.data().as_ptr(), p.data().as_ptr()));
    }

    #[test]
    fn write_slice_view_matches_write_slice() {
        let t = Tensor::iota(&[4, 6]);
        let owned = t.slice(&[1, 2], &[2, 3]).unwrap();
        let view = t.slice_view(&[1, 2], &[2, 3]).unwrap();
        let mut a = Tensor::zeros(&[4, 6]);
        let mut b = Tensor::zeros(&[4, 6]);
        a.write_slice(&[1, 2], &owned).unwrap();
        b.write_slice_view(&[1, 2], &view).unwrap();
        assert_eq!(a, b);
        // strided source (transposed view) gathers per element
        let tv = view.permute(&[1, 0]).unwrap();
        let mut c = Tensor::zeros(&[3, 2]);
        c.write_slice_view(&[0, 0], &tv).unwrap();
        assert_eq!(c, owned.permute(&[1, 0]).unwrap());
        assert!(b.write_slice_view(&[3, 4], &view).is_err());
    }

    #[test]
    fn into_data_handles_sharing() {
        let t = Tensor::iota(&[2, 2]);
        let keep = t.clone();
        let v = t.into_data(); // shared: falls back to a copy
        assert_eq!(v, keep.data());
        assert_eq!(keep.into_data(), v); // unique: moves out
    }

    #[test]
    fn scalar_tensor() {
        let s = Tensor::scalar(3.5);
        assert_eq!(s.rank(), 0);
        assert_eq!(s.at(&[]), 3.5);
        let sl = s.slice(&[], &[]).unwrap();
        assert_eq!(sl.at(&[]), 3.5);
    }
}

//! Strided, reference-counted tensor views — the zero-copy tile type.
//!
//! A [`TensorView`] is `(shared buffer, offset, shape, strides)`: it
//! describes a hyper-rectangle *inside* a [`Tensor`]'s buffer without
//! owning or copying it. Partitioning a tensor into a relation
//! ([`crate::tra::relation::TensorRelation::partition`]) produces one
//! view per tile in O(1) each; slicing and axis permutation on a view
//! are stride arithmetic, never data movement. Paths that genuinely need
//! a contiguous, row-major buffer (PJRT kernels, network serialization)
//! call [`TensorView::to_tensor`], which is itself O(1) whenever the
//! view already covers a whole contiguous buffer.

use crate::error::{Error, Result};
use crate::tensor::{strides_of, Tensor};
use crate::util::BufferPool;
use std::sync::Arc;

/// A strided window into a shared `f32` buffer.
///
/// The element at multi-index `idx` lives at flat position
/// `offset + Σ idx[d] * strides[d]` of the underlying buffer. Views are
/// cheap to clone (an `Arc` bump plus two small `Vec`s) and immutable:
/// all kernels read through views and write fresh output buffers.
///
/// ```
/// use eindecomp::tensor::Tensor;
/// let t = Tensor::iota(&[4, 4]);
/// // O(1): no floats are copied to make or slice a view.
/// let tile = t.slice_view(&[2, 0], &[2, 2]).unwrap();
/// assert_eq!(tile.shape(), &[2, 2]);
/// assert_eq!(tile.at(&[0, 1]), t.at(&[2, 1]));
/// // Materialize only when contiguity is required.
/// assert_eq!(tile.to_tensor().data(), &[8.0, 9.0, 12.0, 13.0]);
/// ```
#[derive(Clone)]
pub struct TensorView {
    buf: Arc<Vec<f32>>,
    offset: usize,
    shape: Vec<usize>,
    strides: Vec<usize>,
}

impl std::fmt::Debug for TensorView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TensorView")
            .field("offset", &self.offset)
            .field("shape", &self.shape)
            .field("strides", &self.strides)
            .finish()
    }
}

impl TensorView {
    /// Build a view from raw parts. Internal: callers guarantee that
    /// every addressable element lies inside `buf` (checked here).
    pub(crate) fn from_parts(
        buf: Arc<Vec<f32>>,
        offset: usize,
        shape: Vec<usize>,
        strides: Vec<usize>,
    ) -> TensorView {
        debug_assert_eq!(shape.len(), strides.len());
        if !shape.iter().any(|&d| d == 0) {
            let max: usize = offset
                + shape
                    .iter()
                    .zip(&strides)
                    .map(|(&d, &s)| (d - 1) * s)
                    .sum::<usize>();
            debug_assert!(
                max < buf.len().max(1),
                "view out of bounds: max index {max}, buffer {}",
                buf.len()
            );
        }
        TensorView {
            buf,
            offset,
            shape,
            strides,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Strides of this view **in the underlying buffer** (not the
    /// row-major strides of `shape()` unless the view is contiguous).
    pub fn strides(&self) -> &[usize] {
        &self.strides
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Logical element count, `prod(shape)`.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Logical size in bytes (f32 elements).
    pub fn bytes(&self) -> usize {
        self.len() * std::mem::size_of::<f32>()
    }

    /// Read the element at a multi-index.
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let off: usize = idx.iter().zip(&self.strides).map(|(i, s)| i * s).sum();
        self.buf[self.offset + off]
    }

    /// The addressable tail of the underlying buffer, starting at this
    /// view's origin. Kernels index it via [`strides`](Self::strides);
    /// construction guarantees every `(idx < shape) · strides` offset is
    /// in bounds.
    pub(crate) fn raw(&self) -> &[f32] {
        &self.buf[self.offset..]
    }

    /// Whether elements are laid out exactly row-major and adjacent
    /// (strides equal the row-major strides of `shape`).
    pub fn is_contiguous(&self) -> bool {
        self.strides == strides_of(&self.shape)
    }

    /// The view's elements as a single contiguous slice, when the layout
    /// allows it (no copy).
    pub fn as_contiguous(&self) -> Option<&[f32]> {
        if self.is_contiguous() {
            Some(&self.buf[self.offset..self.offset + self.len()])
        } else {
            None
        }
    }

    /// O(1) sub-view: the hyper-rectangle at `offset` with size `size`.
    pub fn slice(&self, offset: &[usize], size: &[usize]) -> Result<TensorView> {
        if offset.len() != self.rank() || size.len() != self.rank() {
            return Err(Error::Shape(format!(
                "view slice rank mismatch: view {:?}, offset {offset:?}, size {size:?}",
                self.shape
            )));
        }
        for d in 0..self.rank() {
            if offset[d] + size[d] > self.shape[d] {
                return Err(Error::Shape(format!(
                    "view slice out of bounds on dim {d}: {}+{} > {}",
                    offset[d], size[d], self.shape[d]
                )));
            }
        }
        let extra: usize = offset.iter().zip(&self.strides).map(|(o, s)| o * s).sum();
        Ok(TensorView::from_parts(
            self.buf.clone(),
            self.offset + extra,
            size.to_vec(),
            self.strides.clone(),
        ))
    }

    /// O(1) axis permutation: output dim `i` is input dim `perm[i]`.
    /// Pure stride shuffling — no data moves, which is what deletes the
    /// "unpack" materialization on the BMM path.
    pub fn permute(&self, perm: &[usize]) -> Result<TensorView> {
        if perm.len() != self.rank() {
            return Err(Error::Shape(format!(
                "view permute rank mismatch: {:?} vs {perm:?}",
                self.shape
            )));
        }
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            if p >= perm.len() || seen[p] {
                return Err(Error::Shape(format!("invalid permutation {perm:?}")));
            }
            seen[p] = true;
        }
        Ok(TensorView::from_parts(
            self.buf.clone(),
            self.offset,
            perm.iter().map(|&p| self.shape[p]).collect(),
            perm.iter().map(|&p| self.strides[p]).collect(),
        ))
    }

    /// Copy the view's elements, row-major, into `dst` (which must hold
    /// exactly `len()` floats). Innermost runs with stride 1 are memcpys.
    pub fn copy_into(&self, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.len(), "copy_into: size mismatch");
        if self.is_empty() {
            return;
        }
        let src = self.raw();
        if self.rank() == 0 {
            dst[0] = src[0];
            return;
        }
        // Rank-2 strided gather (e.g. a transposed view): 32x32 cache
        // tiles, mirroring `Tensor::permute`'s transpose fast path.
        if self.rank() == 2 && self.strides[1] != 1 {
            let (r, c) = (self.shape[0], self.shape[1]);
            let (s0, s1) = (self.strides[0], self.strides[1]);
            const TB: usize = 32;
            for i0 in (0..r).step_by(TB) {
                let imax = (i0 + TB).min(r);
                for j0 in (0..c).step_by(TB) {
                    let jmax = (j0 + TB).min(c);
                    for i in i0..imax {
                        for j in j0..jmax {
                            dst[i * c + j] = src[i * s0 + j * s1];
                        }
                    }
                }
            }
            return;
        }
        let last = self.rank() - 1;
        let inner = self.shape[last];
        let inner_stride = self.strides[last];
        let outer: usize = self.shape[..last].iter().product();
        let mut idx = vec![0usize; last];
        let mut out_pos = 0usize;
        for _ in 0..outer.max(1) {
            let mut base = 0usize;
            for d in 0..last {
                base += idx[d] * self.strides[d];
            }
            if inner_stride == 1 {
                dst[out_pos..out_pos + inner].copy_from_slice(&src[base..base + inner]);
            } else {
                for j in 0..inner {
                    dst[out_pos + j] = src[base + j * inner_stride];
                }
            }
            out_pos += inner;
            for d in (0..last).rev() {
                idx[d] += 1;
                if idx[d] < self.shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
    }

    /// Materialize an owned, contiguous [`Tensor`] with the same
    /// elements. O(1) when the view already covers a whole contiguous
    /// buffer (the common case for kernel outputs wrapped via
    /// [`Tensor::into_view`]); otherwise one strided copy into a pooled
    /// buffer.
    pub fn to_tensor(&self) -> Tensor {
        if self.is_contiguous() && self.offset == 0 && self.len() == self.buf.len() {
            return Tensor::from_shared(self.shape.clone(), self.buf.clone());
        }
        let mut out = BufferPool::take(self.len());
        self.copy_into(&mut out);
        Tensor::from_shared(self.shape.clone(), Arc::new(out))
    }

    /// Row-major copy of the elements (testing / display aid).
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        self.copy_into(&mut out);
        out
    }

    /// Recycle the underlying buffer into the thread's [`BufferPool`] if
    /// this view was its last reference; otherwise just drop the view.
    pub fn recycle(self) {
        if let Ok(v) = Arc::try_unwrap(self.buf) {
            BufferPool::give(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn whole_view_matches_tensor() {
        let t = Tensor::random(&[3, 5], 1);
        let v = t.view();
        assert!(v.is_contiguous());
        assert_eq!(v.as_contiguous().unwrap(), t.data());
        assert_eq!(v.to_tensor(), t);
        assert_eq!(v.strides(), t.strides().as_slice());
    }

    #[test]
    fn slice_view_matches_owned_slice() {
        let t = Tensor::iota(&[4, 6, 3]);
        let (off, sz) = (&[1usize, 2, 0][..], &[2usize, 3, 2][..]);
        let owned = t.slice(off, sz).unwrap();
        let view = t.slice_view(off, sz).unwrap();
        assert_eq!(view.shape(), owned.shape());
        assert_eq!(view.to_vec(), owned.data());
        assert_eq!(view.to_tensor(), owned);
        assert!(!view.is_contiguous());
    }

    #[test]
    fn nested_slicing_composes() {
        let t = Tensor::iota(&[8, 8]);
        let a = t.slice_view(&[2, 2], &[4, 4]).unwrap();
        let b = a.slice(&[1, 1], &[2, 2]).unwrap();
        assert_eq!(b.at(&[0, 0]), t.at(&[3, 3]));
        assert_eq!(b.to_vec(), t.slice(&[3, 3], &[2, 2]).unwrap().data());
    }

    #[test]
    fn permute_is_stride_shuffle() {
        let t = Tensor::iota(&[2, 3, 4]);
        let v = t.view().permute(&[2, 0, 1]).unwrap();
        assert_eq!(v.shape(), &[4, 2, 3]);
        assert_eq!(v.at(&[3, 1, 2]), t.at(&[1, 2, 3]));
        assert_eq!(v.to_tensor(), t.permute(&[2, 0, 1]).unwrap());
    }

    #[test]
    fn permute_of_slice_matches_materialized() {
        let t = Tensor::random(&[5, 7], 9);
        let v = t.slice_view(&[1, 2], &[3, 4]).unwrap();
        let pv = v.permute(&[1, 0]).unwrap();
        let want = t.slice(&[1, 2], &[3, 4]).unwrap().permute(&[1, 0]).unwrap();
        assert_eq!(pv.to_tensor(), want);
    }

    #[test]
    fn rank0_and_empty_views() {
        let s = Tensor::scalar(4.5);
        let v = s.view();
        assert_eq!(v.rank(), 0);
        assert_eq!(v.len(), 1);
        assert_eq!(v.at(&[]), 4.5);
        assert_eq!(v.to_tensor(), s);
        let e = Tensor::zeros(&[0, 3]);
        assert!(e.view().is_empty());
        assert_eq!(e.view().to_vec(), Vec::<f32>::new());
    }

    #[test]
    fn out_of_bounds_slices_rejected() {
        let t = Tensor::zeros(&[4, 4]);
        assert!(t.view().slice(&[3, 0], &[2, 2]).is_err());
        assert!(t.view().slice(&[0], &[1]).is_err());
        assert!(t.view().permute(&[0, 0]).is_err());
    }

    #[test]
    fn to_tensor_is_o1_for_whole_buffers() {
        let t = Tensor::random(&[16, 16], 3);
        let v = t.view();
        let u = v.to_tensor();
        // Shares the allocation: no copy happened.
        assert!(std::ptr::eq(t.data().as_ptr(), u.data().as_ptr()));
    }
}

//! `eindecomp` binary: plan and run EinSum programs and the paper's model
//! workloads on the simulated cluster. See `eindecomp help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = eindecomp::coordinator::cli::main_with_args(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

//! Experiment 1 workload: the matrix chain `(A x B) + (C x (D x E))`.
//!
//! Two variants (paper §9.2): *uniform* — all matrices `s x s`; *skewed* —
//! `A: s x s/10`, `B: s/10 x s`, `C: s x s/10`, `D: s/10 x 10s`,
//! `E: 10s x s`. The skewed chain is where SQRT's shape-blind slicing
//! loses to EinDecomp (Figs. 7–8).

use crate::einsum::expr::{EinSum, JoinOp};
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::labels;
use crate::error::Result;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Handles into the chain graph.
pub struct Chain {
    pub graph: EinGraph,
    pub a: VertexId,
    pub b: VertexId,
    pub c: VertexId,
    pub d: VertexId,
    pub e: VertexId,
    pub z: VertexId,
}

/// Build the chain at scale `s` (`skewed` selects the second variant; `s`
/// should be a multiple of 10 for the skewed shapes).
pub fn chain_graph(s: usize, skewed: bool) -> Result<Chain> {
    let t = (s / 10).max(1); // 0.1 s
    let (da, db, dc, dd, de) = if skewed {
        ([s, t], [t, s], [s, t], [t, 10 * s], [10 * s, s])
    } else {
        ([s, s], [s, s], [s, s], [s, s], [s, s])
    };
    let mut g = EinGraph::new();
    let a = g.input("A", da.to_vec());
    let b = g.input("B", db.to_vec());
    let c = g.input("C", dc.to_vec());
    let d = g.input("D", dd.to_vec());
    let e = g.input("E", de.to_vec());
    let ab = g.add(
        "AB",
        EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
        vec![a, b],
    )?;
    let de = g.add(
        "DE",
        EinSum::contraction(labels("j m"), labels("m k"), labels("j k")),
        vec![d, e],
    )?;
    let cde = g.add(
        "CDE",
        EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
        vec![c, de],
    )?;
    let z = g.add(
        "Z",
        EinSum::elementwise(labels("i k"), labels("i k"), JoinOp::Add),
        vec![ab, cde],
    )?;
    Ok(Chain {
        graph: g,
        a,
        b,
        c,
        d,
        e,
        z,
    })
}

/// Random inputs for a chain, keyed by vertex.
pub fn chain_inputs(chain: &Chain, seed: u64) -> HashMap<VertexId, Tensor> {
    let g = &chain.graph;
    let mut m = HashMap::new();
    for (i, &v) in [chain.a, chain.b, chain.c, chain.d, chain.e].iter().enumerate() {
        m.insert(v, Tensor::random(&g.vertex(v).bound, seed + i as u64));
    }
    m
}

/// Dense reference result for correctness checks.
pub fn chain_reference(chain: &Chain, inputs: &HashMap<VertexId, Tensor>) -> Result<Tensor> {
    use crate::runtime::native::eval_einsum;
    let g = &chain.graph;
    let ab = eval_einsum(
        &g.vertex(g.by_name("AB").unwrap()).op,
        &[&inputs[&chain.a], &inputs[&chain.b]],
    )?;
    let de = eval_einsum(
        &g.vertex(g.by_name("DE").unwrap()).op,
        &[&inputs[&chain.d], &inputs[&chain.e]],
    )?;
    let cde = eval_einsum(
        &g.vertex(g.by_name("CDE").unwrap()).op,
        &[&inputs[&chain.c], &de],
    )?;
    eval_einsum(&g.vertex(chain.z).op, &[&ab, &cde])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{plan_graph, PlannerConfig};
    use crate::runtime::NativeEngine;
    use crate::sim::{Cluster, NetworkProfile};

    #[test]
    fn uniform_chain_shapes() {
        let c = chain_graph(40, false).unwrap();
        c.graph.validate().unwrap();
        assert_eq!(c.graph.vertex(c.z).bound, vec![40, 40]);
        assert!(c.graph.is_tree_like());
    }

    #[test]
    fn skewed_chain_shapes_match_paper() {
        let c = chain_graph(40, true).unwrap();
        assert_eq!(c.graph.vertex(c.a).bound, vec![40, 4]);
        assert_eq!(c.graph.vertex(c.d).bound, vec![4, 400]);
        assert_eq!(c.graph.vertex(c.e).bound, vec![400, 40]);
        assert_eq!(c.graph.vertex(c.z).bound, vec![40, 40]);
    }

    #[test]
    fn executed_chain_matches_reference() {
        let c = chain_graph(40, true).unwrap();
        let inputs = chain_inputs(&c, 9);
        let want = chain_reference(&c, &inputs).unwrap();
        let plan = plan_graph(&c.graph, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let (outs, _) = cluster
            .execute(&c.graph, &plan, &NativeEngine::new(), &inputs)
            .unwrap();
        assert!(outs[&c.z].allclose(&want, 1e-3, 1e-4));
    }
}

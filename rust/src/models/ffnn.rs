//! Experiment 2 workload: training step of a feed-forward classifier,
//! expressed *entirely* as an EinGraph — forward pass, loss, and all
//! gradients are EinSum vertices, so EinDecomp plans the whole step.
//!
//! Network (paper: AmazonCat-14K, 597,540 features, 8,192 hidden units,
//! 14,588 labels):
//!
//! ```text
//!   P1 = X W1            H1 = relu(P1)
//!   Y  = H1 W2                       (logits)
//!   G2 = (Y - T) * (1/batch)         (MSE-style output gradient)
//!   dW2 = H1^T G2
//!   GH = G2 W2^T ; G1 = GH * relu'(P1)
//!   dW1 = X^T G1
//!   loss = sum (Y - T)^2 * (0.5/batch)
//! ```
//!
//! Labels: `b` batch, `f` input features, `h` hidden, `c` classes — so the
//! data-parallel baseline shards `b`, the model-parallel baseline shards
//! `h`/`c`, and EinDecomp mixes per vertex.

use crate::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::Label;
use crate::error::Result;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Network + graph handles for one training step.
pub struct FfnnStep {
    pub graph: EinGraph,
    pub x: VertexId,
    pub t: VertexId,
    pub w1: VertexId,
    pub w2: VertexId,
    pub logits: VertexId,
    pub dw1: VertexId,
    pub dw2: VertexId,
    pub loss: VertexId,
    pub batch: usize,
    pub features: usize,
    pub hidden: usize,
    pub classes: usize,
}

/// Build the training-step graph.
pub fn ffnn_step(batch: usize, features: usize, hidden: usize, classes: usize) -> Result<FfnnStep> {
    let b = Label::new("b");
    let f = Label::new("f");
    let h = Label::new("h");
    let c = Label::new("c");
    let mut g = EinGraph::new();
    let x = g.input("X", vec![batch, features]);
    let t = g.input("T", vec![batch, classes]);
    let w1 = g.input("W1", vec![features, hidden]);
    let w2 = g.input("W2", vec![hidden, classes]);

    // forward
    let p1 = g.add(
        "P1",
        EinSum::contraction(vec![b, f], vec![f, h], vec![b, h]),
        vec![x, w1],
    )?;
    let h1 = g.add("H1", EinSum::map(vec![b, h], UnaryOp::Relu), vec![p1])?;
    let y = g.add(
        "Y",
        EinSum::contraction(vec![b, h], vec![h, c], vec![b, c]),
        vec![h1, w2],
    )?;

    // output gradient (MSE): G2 = (Y - T) / batch
    let diff = g.add(
        "Diff",
        EinSum::elementwise(vec![b, c], vec![b, c], JoinOp::Sub),
        vec![y, t],
    )?;
    let g2 = g.add(
        "G2",
        EinSum::map(vec![b, c], UnaryOp::Scale(1.0 / batch as f32)),
        vec![diff],
    )?;

    // loss = 0.5/batch * sum diff^2
    let sq = g.add("SqErr", EinSum::map(vec![b, c], UnaryOp::Square), vec![diff])?;
    let sse = g.add("SSE", EinSum::reduce(vec![b, c], vec![], AggOp::Sum), vec![sq])?;
    let loss = g.add(
        "Loss",
        EinSum::map(vec![], UnaryOp::Scale(0.5 / batch as f32)),
        vec![sse],
    )?;

    // dW2 = H1^T G2 : dW2_hc <- sum_b H1_bh G2_bc
    let dw2 = g.add(
        "dW2",
        EinSum::contraction(vec![b, h], vec![b, c], vec![h, c]),
        vec![h1, g2],
    )?;

    // GH = G2 W2^T : GH_bh <- sum_c G2_bc W2_hc
    let gh = g.add(
        "GH",
        EinSum::contraction(vec![b, c], vec![h, c], vec![b, h]),
        vec![g2, w2],
    )?;
    // relu'(P1)
    let dr = g.add("dRelu", EinSum::map(vec![b, h], UnaryOp::ReluGrad), vec![p1])?;
    let g1 = g.add(
        "G1",
        EinSum::elementwise(vec![b, h], vec![b, h], JoinOp::Mul),
        vec![gh, dr],
    )?;
    // dW1 = X^T G1 : dW1_fh <- sum_b X_bf G1_bh
    let dw1 = g.add(
        "dW1",
        EinSum::contraction(vec![b, f], vec![b, h], vec![f, h]),
        vec![x, g1],
    )?;

    g.validate()?;
    Ok(FfnnStep {
        graph: g,
        x,
        t,
        w1,
        w2,
        logits: y,
        dw1,
        dw2,
        loss,
        batch,
        features,
        hidden,
        classes,
    })
}

/// Mutable training state (weights live outside the graph; the step graph
/// reads them as inputs and emits gradients).
pub struct FfnnState {
    pub w1: Tensor,
    pub w2: Tensor,
}

impl FfnnState {
    pub fn init(features: usize, hidden: usize, classes: usize, seed: u64) -> Self {
        // small-variance init so relu nets at these widths stay stable
        let scale1 = (2.0 / features as f32).sqrt();
        let scale2 = (2.0 / hidden as f32).sqrt();
        let mut w1 = Tensor::random(&[features, hidden], seed);
        for v in w1.data_mut() {
            *v *= 2.0 * scale1;
        }
        let mut w2 = Tensor::random(&[hidden, classes], seed + 1);
        for v in w2.data_mut() {
            *v *= 2.0 * scale2;
        }
        FfnnState { w1, w2 }
    }

    /// SGD update from the step's gradient outputs.
    pub fn apply(&mut self, dw1: &Tensor, dw2: &Tensor, lr: f32) -> Result<()> {
        self.w1.accumulate(dw1, move |w, g| w - lr * g)?;
        self.w2.accumulate(dw2, move |w, g| w - lr * g)?;
        Ok(())
    }
}

/// Inputs map for one step.
pub fn step_inputs(
    step: &FfnnStep,
    state: &FfnnState,
    x: Tensor,
    t: Tensor,
) -> HashMap<VertexId, Tensor> {
    let mut m = HashMap::new();
    m.insert(step.x, x);
    m.insert(step.t, t);
    m.insert(step.w1, state.w1.clone());
    m.insert(step.w2, state.w2.clone());
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::classifier_batch;
    use crate::decomp::{plan_graph, PlanMode, PlannerConfig};
    use crate::runtime::NativeEngine;
    use crate::sim::{Cluster, NetworkProfile};

    #[test]
    fn graph_builds_and_is_dag() {
        let s = ffnn_step(8, 32, 16, 4).unwrap();
        // X, H1, P1, G2 all multiply consumed -> not tree-like
        assert!(!s.graph.is_tree_like());
        assert_eq!(s.graph.vertex(s.dw1).bound, vec![32, 16]);
        assert_eq!(s.graph.vertex(s.dw2).bound, vec![16, 4]);
        assert_eq!(s.graph.vertex(s.loss).bound, Vec::<usize>::new());
    }

    #[test]
    fn gradients_match_finite_difference() {
        let step = ffnn_step(4, 6, 5, 3).unwrap();
        let mut state = FfnnState::init(6, 5, 3, 7);
        let (x, t) = classifier_batch(4, 6, 3, 0.5, 11);
        let plan = plan_graph(
            &step.graph,
            &PlannerConfig { p: 2, mode: PlanMode::Linearized, ..Default::default() },
        )
        .unwrap();
        let cluster = Cluster::new(2, NetworkProfile::loopback());
        let engine = NativeEngine::new();
        let run = |state: &FfnnState| {
            let inputs = step_inputs(&step, state, x.clone(), t.clone());
            let (outs, _) = cluster.execute(&step.graph, &plan, &engine, &inputs).unwrap();
            (
                outs[&step.loss].at(&[]),
                outs[&step.dw1].clone(),
                outs[&step.dw2].clone(),
            )
        };
        let (_, dw1, dw2) = run(&state);
        // finite differences on a few coordinates
        let eps = 1e-3f32;
        for &(i, j) in &[(0usize, 0usize), (2, 3), (5, 4)] {
            let orig = state.w1.at(&[i, j]);
            state.w1.set(&[i, j], orig + eps);
            let (lp, _, _) = run(&state);
            state.w1.set(&[i, j], orig - eps);
            let (lm, _, _) = run(&state);
            state.w1.set(&[i, j], orig);
            let fd = (lp - lm) / (2.0 * eps);
            let an = dw1.at(&[i, j]);
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                "dW1[{i},{j}]: fd {fd} vs analytic {an}"
            );
        }
        for &(i, j) in &[(0usize, 0usize), (4, 2)] {
            let orig = state.w2.at(&[i, j]);
            state.w2.set(&[i, j], orig + eps);
            let (lp, _, _) = run(&state);
            state.w2.set(&[i, j], orig - eps);
            let (lm, _, _) = run(&state);
            state.w2.set(&[i, j], orig);
            let fd = (lp - lm) / (2.0 * eps);
            let an = dw2.at(&[i, j]);
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                "dW2[{i},{j}]: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn short_training_reduces_loss() {
        let step = ffnn_step(16, 24, 12, 4).unwrap();
        let mut state = FfnnState::init(24, 12, 4, 3);
        let plan = plan_graph(
            &step.graph,
            &PlannerConfig { p: 4, mode: PlanMode::Linearized, ..Default::default() },
        )
        .unwrap();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let engine = NativeEngine::new();
        let (x, t) = classifier_batch(16, 24, 4, 0.5, 5);
        let mut losses = Vec::new();
        for _ in 0..30 {
            let inputs = step_inputs(&step, &state, x.clone(), t.clone());
            let (outs, _) = cluster.execute(&step.graph, &plan, &engine, &inputs).unwrap();
            losses.push(outs[&step.loss].at(&[]));
            state
                .apply(&outs[&step.dw1], &outs[&step.dw2], 0.5)
                .unwrap();
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.5),
            "loss did not drop: {losses:?}"
        );
    }
}

//! Model-graph builders for the paper's evaluation workloads:
//!
//! * [`matchain`] — the matrix-operation chain of Experiment 1
//!   (`(A x B) + (C x (D x E))`, uniform and skewed);
//! * [`ffnn`] — the feed-forward classifier *training step* (forward +
//!   backward, gradients as EinSums) of Experiment 2;
//! * [`llama`] — the LLaMA-style decoder stack (RMSNorm, multi-head
//!   attention, SwiGLU FFN) used for first-token inference in
//!   Experiments 3 and 4.

pub mod ffnn;
pub mod llama;
pub mod matchain;

//! Experiments 3 & 4 workload: a LLaMA-style decoder stack for first-token
//! ("prefill") inference, built entirely from EinSum vertices — RMSNorm,
//! multi-head attention (paper §3's formulation), and the SwiGLU
//! feed-forward block, with residual connections (which make the graph a
//! true DAG, exercising the §8.4 linearized planner).
//!
//! `LlamaConfig::llama7b()` / `llama65b()` carry the real model shapes for
//! paper-scale *dry-run* costing; `scaled(k)` shrinks every dimension by
//! `k` for real execution in this container.

use crate::einsum::expr::{EinSum, JoinOp, UnaryOp};
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::Label;
use crate::einsum::macros::{multihead_attention, rmsnorm};
use crate::error::Result;
use crate::tensor::Tensor;
use std::collections::{HashMap, HashSet};

/// Transformer shape configuration.
#[derive(Clone, Debug)]
pub struct LlamaConfig {
    pub layers: usize,
    pub batch: usize,
    pub seq: usize,
    /// model (attribute) dimension `a`
    pub model_dim: usize,
    /// heads `h`
    pub heads: usize,
    /// per-head dimension `d`
    pub head_dim: usize,
    /// feed-forward hidden dimension `f`
    pub ffn_dim: usize,
}

impl LlamaConfig {
    /// LLaMA-7B shapes (Touvron et al. 2023).
    pub fn llama7b(batch: usize, seq: usize) -> Self {
        LlamaConfig {
            layers: 32,
            batch,
            seq,
            model_dim: 4096,
            heads: 32,
            head_dim: 128,
            ffn_dim: 11008,
        }
    }

    /// LLaMA-65B shapes.
    pub fn llama65b(batch: usize, seq: usize) -> Self {
        LlamaConfig {
            layers: 80,
            batch,
            seq,
            model_dim: 8192,
            heads: 64,
            head_dim: 128,
            ffn_dim: 22016,
        }
    }

    /// Shrink every dimension by `k` (layers by `layer_k`) for real
    /// execution at container scale.
    pub fn scaled(&self, k: usize, layer_k: usize) -> Self {
        LlamaConfig {
            layers: (self.layers / layer_k).max(1),
            batch: self.batch,
            seq: (self.seq / k).max(4),
            model_dim: (self.model_dim / k).max(8),
            heads: (self.heads / k).max(1),
            head_dim: (self.head_dim / k).max(4),
            ffn_dim: (self.ffn_dim / k).max(8),
        }
    }

    /// Total weight parameters of the stack.
    pub fn params(&self) -> usize {
        let attn = 4 * self.model_dim * self.heads * self.head_dim;
        let ffn = 3 * self.model_dim * self.ffn_dim;
        let norms = 2 * self.model_dim;
        self.layers * (attn + ffn + norms)
    }
}

/// The built model graph.
pub struct LlamaModel {
    pub graph: EinGraph,
    pub config: LlamaConfig,
    pub x: VertexId,
    pub out: VertexId,
    /// All weight input vertices (for Fig. 11's offload policies).
    pub weights: Vec<VertexId>,
}

/// Build the decoder stack for first-token inference.
pub fn llama_graph(cfg: &LlamaConfig) -> Result<LlamaModel> {
    let b = Label::new("b");
    let s = Label::new("s");
    let a = Label::new("a");
    let f = Label::new("f");
    let lx = vec![b, s, a];
    let mut g = EinGraph::new();
    let x0 = g.input("X", vec![cfg.batch, cfg.seq, cfg.model_dim]);
    let mut weights = Vec::new();
    let mut x = x0;
    for l in 0..cfg.layers {
        let pre = format!("l{l}");
        // --- attention sub-block ---
        let g1 = g.input(&format!("{pre}.g1"), vec![cfg.model_dim]);
        weights.push(g1);
        let xn = rmsnorm(&mut g, &format!("{pre}.rms1"), x, g1, &lx)?;
        let wq = g.input(
            &format!("{pre}.wq"),
            vec![cfg.model_dim, cfg.heads, cfg.head_dim],
        );
        let wk = g.input(
            &format!("{pre}.wk"),
            vec![cfg.model_dim, cfg.heads, cfg.head_dim],
        );
        let wv = g.input(
            &format!("{pre}.wv"),
            vec![cfg.model_dim, cfg.heads, cfg.head_dim],
        );
        let wo = g.input(
            &format!("{pre}.wo"),
            vec![cfg.model_dim, cfg.heads, cfg.head_dim],
        );
        weights.extend([wq, wk, wv, wo]);
        let attn = multihead_attention(
            &mut g,
            &format!("{pre}.attn"),
            xn,
            xn,
            xn,
            wq,
            wk,
            wv,
            wo,
            true,
        )?;
        let x2 = g.add(
            &format!("{pre}.res1"),
            EinSum::elementwise(lx.clone(), lx.clone(), JoinOp::Add),
            vec![x, attn],
        )?;
        // --- feed-forward sub-block (SwiGLU) ---
        let g2 = g.input(&format!("{pre}.g2"), vec![cfg.model_dim]);
        weights.push(g2);
        let x2n = rmsnorm(&mut g, &format!("{pre}.rms2"), x2, g2, &lx)?;
        let wg = g.input(&format!("{pre}.wg"), vec![cfg.model_dim, cfg.ffn_dim]);
        let wu = g.input(&format!("{pre}.wu"), vec![cfg.model_dim, cfg.ffn_dim]);
        let wd = g.input(&format!("{pre}.wd"), vec![cfg.ffn_dim, cfg.model_dim]);
        weights.extend([wg, wu, wd]);
        let gate_pre = g.add(
            &format!("{pre}.gate"),
            EinSum::contraction(lx.clone(), vec![a, f], vec![b, s, f]),
            vec![x2n, wg],
        )?;
        let gate = g.add(
            &format!("{pre}.silu"),
            EinSum::map(vec![b, s, f], UnaryOp::Silu),
            vec![gate_pre],
        )?;
        let up = g.add(
            &format!("{pre}.up"),
            EinSum::contraction(lx.clone(), vec![a, f], vec![b, s, f]),
            vec![x2n, wu],
        )?;
        let hidden = g.add(
            &format!("{pre}.glu"),
            EinSum::elementwise(vec![b, s, f], vec![b, s, f], JoinOp::Mul),
            vec![gate, up],
        )?;
        let down = g.add(
            &format!("{pre}.down"),
            EinSum::contraction(vec![b, s, f], vec![f, a], lx.clone()),
            vec![hidden, wd],
        )?;
        x = g.add(
            &format!("{pre}.res2"),
            EinSum::elementwise(lx.clone(), lx.clone(), JoinOp::Add),
            vec![x2, down],
        )?;
    }
    g.validate()?;
    Ok(LlamaModel {
        graph: g,
        config: cfg.clone(),
        x: x0,
        out: x,
        weights,
    })
}

/// Random inputs (activations + every weight) for real execution.
pub fn llama_inputs(model: &LlamaModel, seed: u64) -> HashMap<VertexId, Tensor> {
    let g = &model.graph;
    let mut m = HashMap::new();
    let mut i = 0u64;
    for v in g.inputs() {
        let bound = &g.vertex(v).bound;
        let mut t = Tensor::random(bound, seed + i);
        // keep activations/weights small so 32 layers of silu stay finite
        let scale = 1.0 / (*bound.last().unwrap_or(&1) as f32).sqrt();
        for val in t.data_mut() {
            *val *= scale * 2.0;
        }
        // rmsnorm gains: near 1
        if bound.len() == 1 {
            for val in t.data_mut() {
                *val = 1.0 + 0.1 * *val;
            }
        }
        m.insert(v, t);
        i += 1;
    }
    m
}

/// Weight vertex set as a `HashSet` (for the memory policies).
pub fn weight_set(model: &LlamaModel) -> HashSet<VertexId> {
    model.weights.iter().copied().collect()
}

/// Total weight bytes (f32).
pub fn weight_bytes(model: &LlamaModel) -> u64 {
    model
        .weights
        .iter()
        .map(|&v| {
            model.graph.vertex(v).bound.iter().product::<usize>() as u64 * 4
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::baselines::{assign, LabelRoles, Strategy};
    use crate::decomp::{plan_graph, PlanMode, PlannerConfig};
    use crate::runtime::NativeEngine;
    use crate::sim::{Cluster, NetworkProfile};

    fn tiny() -> LlamaConfig {
        LlamaConfig {
            layers: 2,
            batch: 2,
            seq: 8,
            model_dim: 16,
            heads: 2,
            head_dim: 8,
            ffn_dim: 32,
        }
    }

    #[test]
    fn graph_builds_and_validates() {
        let m = llama_graph(&tiny()).unwrap();
        assert_eq!(
            m.graph.vertex(m.out).bound,
            vec![2, 8, 16]
        );
        // residuals make it a DAG
        assert!(!m.graph.is_tree_like());
        // 2 layers x 9 weights (g1, wq, wk, wv, wo, g2, wg, wu, wd)
        assert_eq!(m.weights.len(), 18);
    }

    #[test]
    fn param_count_7b_is_7ish_billion() {
        let cfg = LlamaConfig::llama7b(1, 4096);
        let p = cfg.params();
        assert!(
            (6_000_000_000..8_000_000_000).contains(&p),
            "params {p}"
        );
    }

    #[test]
    fn executes_and_stays_finite() {
        let m = llama_graph(&tiny()).unwrap();
        let plan = plan_graph(
            &m.graph,
            &PlannerConfig { p: 4, mode: PlanMode::Linearized, ..Default::default() },
        )
        .unwrap();
        let cluster = Cluster::new(4, NetworkProfile::loopback());
        let inputs = llama_inputs(&m, 1);
        let (outs, rep) = cluster
            .execute(&m.graph, &plan, &NativeEngine::new(), &inputs)
            .unwrap();
        let out = &outs[&m.out];
        assert!(out.data().iter().all(|v| v.is_finite()));
        assert!(rep.kernel_calls > 0);
    }

    #[test]
    fn all_llm_strategies_plan_the_stack() {
        let m = llama_graph(&tiny()).unwrap();
        let roles = LabelRoles::by_convention();
        for s in [
            Strategy::EinDecomp,
            Strategy::Megatron,
            Strategy::Sequence,
            Strategy::AttentionHead,
        ] {
            let plan = assign(&m.graph, &s, 4, &roles).unwrap();
            assert!(plan.predicted_cost.is_finite(), "{}", s.name());
        }
    }

    #[test]
    fn decomposition_matches_undecomposed_execution() {
        // plan with p=4 vs p=1: results must agree
        let m = llama_graph(&tiny()).unwrap();
        let inputs = llama_inputs(&m, 2);
        let engine = NativeEngine::new();
        let p1 = plan_graph(
            &m.graph,
            &PlannerConfig { p: 1, mode: PlanMode::Linearized, ..Default::default() },
        )
        .unwrap();
        let p4 = plan_graph(
            &m.graph,
            &PlannerConfig { p: 4, mode: PlanMode::Linearized, ..Default::default() },
        )
        .unwrap();
        let c1 = Cluster::new(1, NetworkProfile::loopback());
        let c4 = Cluster::new(4, NetworkProfile::loopback());
        let (o1, _) = c1.execute(&m.graph, &p1, &engine, &inputs).unwrap();
        let (o4, _) = c4.execute(&m.graph, &p4, &engine, &inputs).unwrap();
        assert!(o1[&m.out].allclose(&o4[&m.out], 1e-3, 1e-4));
    }
}

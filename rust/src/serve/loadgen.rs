//! Closed-loop load generator: `clients` threads each keep exactly one
//! request in flight, so queue pressure (and therefore batching
//! opportunity) scales with the client count, not with an open-loop
//! arrival rate that could overrun the admission bound.

use super::server::Server;
use crate::einsum::graph::{EinGraph, VertexId};
use crate::error::Result;
use crate::tensor::Tensor;
use crate::util::{percentile, Json};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Load generator shape: `clients` threads, each submitting
/// `requests_per_client` back-to-back requests.
#[derive(Clone, Copy, Debug)]
pub struct LoadConfig {
    pub clients: usize,
    pub requests_per_client: usize,
}

/// Nearest-rank latency percentiles over one load run, in milliseconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
}

impl LatencySummary {
    /// Summarize request latencies given in seconds.
    pub fn from_seconds(seconds: &[f64]) -> LatencySummary {
        if seconds.is_empty() {
            return LatencySummary::default();
        }
        let ms: Vec<f64> = seconds.iter().map(|s| s * 1e3).collect();
        LatencySummary {
            p50_ms: percentile(&ms, 50.0),
            p95_ms: percentile(&ms, 95.0),
            p99_ms: percentile(&ms, 99.0),
            mean_ms: ms.iter().sum::<f64>() / ms.len() as f64,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("p50_ms".into(), Json::Num(self.p50_ms)),
            ("p95_ms".into(), Json::Num(self.p95_ms)),
            ("p99_ms".into(), Json::Num(self.p99_ms)),
            ("mean_ms".into(), Json::Num(self.mean_ms)),
        ])
    }
}

/// What one load run measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Requests issued (`clients * requests_per_client`).
    pub requests: usize,
    /// Requests that returned successfully.
    pub completed: usize,
    /// Requests rejected or failed.
    pub rejected: usize,
    pub elapsed_s: f64,
    /// Completed requests per wall-clock second.
    pub req_per_s: f64,
    pub latency: LatencySummary,
    /// Largest `batched_with` observed across responses.
    pub max_batched_with: usize,
    /// Mean `batched_with` over completed responses (1.0 = no
    /// coalescing happened).
    pub mean_batched_with: f64,
    /// XOR of every response's [`output_checksum`] — order-independent,
    /// so it can be compared against the same XOR over solo reference
    /// runs to check bitwise parity of an entire load run.
    ///
    /// [`output_checksum`]: super::output_checksum
    pub checksum: u64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("requests".into(), Json::Num(self.requests as f64)),
            ("completed".into(), Json::Num(self.completed as f64)),
            ("rejected".into(), Json::Num(self.rejected as f64)),
            ("elapsed_s".into(), Json::Num(self.elapsed_s)),
            ("req_per_s".into(), Json::Num(self.req_per_s)),
            ("latency".into(), self.latency.to_json()),
            (
                "max_batched_with".into(),
                Json::Num(self.max_batched_with as f64),
            ),
            (
                "mean_batched_with".into(),
                Json::Num(self.mean_batched_with),
            ),
            ("checksum".into(), Json::str(format!("{:016x}", self.checksum))),
        ])
    }
}

/// Drive `server` with a closed-loop fleet. `make(client, i)` supplies
/// each request as `(tenant, graph, inputs)`; requests and graphs may
/// repeat freely (the session's plan cache absorbs recompiles). Errors
/// are counted as rejections, not propagated — a load run measures the
/// server, it does not assume the server is perfect.
pub fn run_load<F>(server: &Server, cfg: &LoadConfig, make: F) -> Result<LoadReport>
where
    F: Fn(usize, usize) -> (String, EinGraph, HashMap<VertexId, Tensor>) + Sync,
{
    let latencies = Mutex::new(Vec::with_capacity(cfg.clients * cfg.requests_per_client));
    let completed = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let checksum = AtomicU64::new(0);
    let batch_sum = AtomicU64::new(0);
    let batch_max = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..cfg.clients {
            let make = &make;
            let latencies = &latencies;
            let completed = &completed;
            let rejected = &rejected;
            let checksum = &checksum;
            let batch_sum = &batch_sum;
            let batch_max = &batch_max;
            scope.spawn(move || {
                for i in 0..cfg.requests_per_client {
                    let (tenant, g, inputs) = make(c, i);
                    let t = Instant::now();
                    match server.run(&tenant, &g, inputs) {
                        Ok(resp) => {
                            let dt = t.elapsed().as_secs_f64();
                            latencies.lock().unwrap().push(dt);
                            completed.fetch_add(1, Ordering::Relaxed);
                            checksum.fetch_xor(
                                super::output_checksum(&resp.outputs),
                                Ordering::Relaxed,
                            );
                            batch_sum
                                .fetch_add(resp.report.batched_with as u64, Ordering::Relaxed);
                            batch_max
                                .fetch_max(resp.report.batched_with as u64, Ordering::Relaxed);
                        }
                        Err(_) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let elapsed_s = t0.elapsed().as_secs_f64();
    let lats = latencies.into_inner().unwrap();
    let done = completed.load(Ordering::Relaxed) as usize;
    Ok(LoadReport {
        requests: cfg.clients * cfg.requests_per_client,
        completed: done,
        rejected: rejected.load(Ordering::Relaxed) as usize,
        elapsed_s,
        req_per_s: if elapsed_s > 0.0 {
            done as f64 / elapsed_s
        } else {
            0.0
        },
        latency: LatencySummary::from_seconds(&lats),
        max_batched_with: batch_max.load(Ordering::Relaxed) as usize,
        mean_batched_with: if done > 0 {
            batch_sum.load(Ordering::Relaxed) as f64 / done as f64
        } else {
            0.0
        },
        checksum: checksum.load(Ordering::Relaxed),
    })
}

#[cfg(test)]
mod tests {
    use super::super::server::ServeConfig;
    use super::*;
    use crate::coordinator::driver::DriverConfig;
    use crate::coordinator::session::Session;
    use crate::models::matchain;

    #[test]
    fn load_run_matches_solo_checksums() {
        let chain = matchain::chain_graph(16, false).unwrap();
        let session = Session::new(DriverConfig {
            workers: 2,
            p: 2,
            ..Default::default()
        })
        .unwrap();
        // solo references: one direct run per distinct seed
        let exe = session.compile(&chain.graph).unwrap();
        let seeds: Vec<u64> = vec![11, 12, 13];
        let mut expected = 0u64;
        for &s in &seeds {
            let (outs, _) = exe.run(&matchain::chain_inputs(&chain, s)).unwrap();
            expected ^= super::super::output_checksum(&outs);
        }
        let server = Server::with_session(
            std::sync::Arc::new(session),
            ServeConfig {
                serve_workers: 2,
                max_batch: 4,
                ..Default::default()
            },
        );
        let cfg = LoadConfig {
            clients: 3,
            requests_per_client: 1,
        };
        let report = run_load(&server, &cfg, |c, _| {
            (
                format!("tenant-{c}"),
                chain.graph.clone(),
                matchain::chain_inputs(&chain, seeds[c]),
            )
        })
        .unwrap();
        assert_eq!(report.completed, 3);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.checksum, expected, "batched serving changed bits");
        assert!(report.latency.p50_ms >= 0.0);
        assert!(report.latency.p99_ms >= report.latency.p50_ms);
        assert!(report.max_batched_with >= 1);
    }
}

//! The serving front end: admission control, per-tenant fair queueing,
//! a fixed worker pool, and the dynamic batcher's gather loop.

use super::batch::{batched_twin, size_class, split_output, stack_inputs};
use crate::coordinator::driver::RunReport;
use crate::coordinator::session::{Executable, Session};
use crate::einsum::graph::{EinGraph, VertexId};
use crate::error::{Error, ExecCause, Result, ServeCause};
use crate::tensor::Tensor;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Configuration of a [`Server`].
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Serving pool threads, i.e. how many executions run concurrently.
    /// Distinct from the session's simulated cluster `workers`, which
    /// each execution spawns internally.
    pub serve_workers: usize,
    /// Largest number of same-signature requests one execution may
    /// coalesce. `1` disables batching entirely.
    pub max_batch: usize,
    /// How long a worker holds an under-full batch open for
    /// co-batchable arrivals, measured from the seed request's dequeue.
    pub batch_window: Duration,
    /// Admission bound: total requests queued across all tenants.
    /// Submissions beyond it are rejected with a typed
    /// [`ServeCause::QueueFull`].
    pub max_queue_depth: usize,
    /// When false, requests enqueue but nothing executes until
    /// [`Server::start`] — lets tests stage a queue and observe
    /// deterministic batch formation.
    pub autostart: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            serve_workers: 2,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            max_queue_depth: 1024,
            autostart: true,
        }
    }
}

/// Monotonic serving counters (see [`Server::serve_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Every `submit` call, admitted or not.
    pub submitted: u64,
    /// Requests whose execution succeeded.
    pub completed: u64,
    /// Requests refused at admission (compile failure, bad inputs,
    /// queue full, shutdown).
    pub rejected: u64,
    /// Coalesced executions, each covering >= 2 requests.
    pub batches: u64,
    /// Requests served through a coalesced execution.
    pub batched_requests: u64,
}

/// One request's result: outputs under the caller's own vertex
/// numbering, the per-request report (batch size, queue wait), and the
/// execution sequence number (`seq`) — executions are numbered in
/// completion order, batch members sharing their execution's number.
pub struct Response {
    pub outputs: HashMap<VertexId, Tensor>,
    pub report: RunReport,
    pub seq: u64,
}

/// Handle to a pending request; redeem with [`Ticket::wait`].
pub struct Ticket {
    tenant: String,
    rx: mpsc::Receiver<Result<Response>>,
}

impl Ticket {
    /// Block until the server replies. A dropped server side surfaces
    /// as a typed [`ServeCause::Disconnected`] rejection.
    pub fn wait(self) -> Result<Response> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(Error::serve_rejected(self.tenant, ServeCause::Disconnected)),
        }
    }
}

/// An admitted request parked in its tenant's subqueue.
struct Pending {
    tenant: String,
    exe: Arc<Executable>,
    inputs: HashMap<VertexId, Tensor>,
    enqueued: Instant,
    tx: mpsc::Sender<Result<Response>>,
}

struct QueueState {
    /// Per-tenant subqueues in first-seen order; `rr` is the
    /// round-robin cursor — the next tenant to serve from.
    tenants: Vec<(String, VecDeque<Pending>)>,
    rr: usize,
    /// Total parked requests across all subqueues.
    depth: usize,
    /// False once shutdown begins: no further admissions.
    open: bool,
    /// Workers only dequeue once started (see [`ServeConfig::autostart`]).
    started: bool,
}

struct Shared {
    session: Arc<Session>,
    cfg: ServeConfig,
    q: Mutex<QueueState>,
    cv: Condvar,
    /// Batched-twin cache, keyed `(solo artifact key, size class)`.
    /// Artifact keys stay valid for the session's lifetime because the
    /// session's plan cache never evicts, so a key cannot be reused by
    /// a different artifact.
    twins: Mutex<HashMap<(usize, usize), Arc<Executable>>>,
    seq: AtomicU64,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
}

/// Multi-tenant serving front end over one shared [`Session`].
///
/// Requests compile through the session's plan cache on the caller's
/// thread (compile errors surface synchronously), then park in their
/// tenant's subqueue. Pool workers pick seeds round-robin across
/// tenants, gather same-signature requests within the batch window,
/// and run either the solo executable or a batched twin. Dropping the
/// server shuts it down: admission closes, the queue drains, and the
/// pool joins.
pub struct Server {
    shared: Arc<Shared>,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Server {
    /// Build a server owning its session.
    pub fn new(session: Session, cfg: ServeConfig) -> Server {
        Server::with_session(Arc::new(session), cfg)
    }

    /// Build a server over a shared session (zero-count config fields
    /// are clamped up to 1).
    pub fn with_session(session: Arc<Session>, cfg: ServeConfig) -> Server {
        let mut cfg = cfg;
        cfg.serve_workers = cfg.serve_workers.max(1);
        cfg.max_batch = cfg.max_batch.max(1);
        cfg.max_queue_depth = cfg.max_queue_depth.max(1);
        let started = cfg.autostart;
        let workers = cfg.serve_workers;
        let shared = Arc::new(Shared {
            session,
            cfg,
            q: Mutex::new(QueueState {
                tenants: Vec::new(),
                rr: 0,
                depth: 0,
                open: true,
                started,
            }),
            cv: Condvar::new(),
            twins: Mutex::new(HashMap::new()),
            seq: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let sh = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("serve-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawn serve worker"),
            );
        }
        Server {
            shared,
            handles: Mutex::new(handles),
        }
    }

    /// The shared session (its `stats()` expose compile-cache behaviour
    /// across tenants).
    pub fn session(&self) -> &Session {
        &self.shared.session
    }

    /// Begin executing queued requests; no-op when `autostart` was set.
    pub fn start(&self) {
        self.shared.q.lock().unwrap().started = true;
        self.shared.cv.notify_all();
    }

    /// Current queue depth (admitted, not-yet-dequeued requests).
    pub fn queue_depth(&self) -> usize {
        self.shared.q.lock().unwrap().depth
    }

    /// How many batched twins have been compiled so far.
    pub fn twin_cache_entries(&self) -> usize {
        self.shared.twins.lock().unwrap().len()
    }

    /// Snapshot of the serving counters.
    pub fn serve_stats(&self) -> ServeStats {
        ServeStats {
            submitted: self.shared.submitted.load(Ordering::Relaxed),
            completed: self.shared.completed.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            batches: self.shared.batches.load(Ordering::Relaxed),
            batched_requests: self.shared.batched_requests.load(Ordering::Relaxed),
        }
    }

    /// Admit one request for `tenant`: compile (or cache-hit) the
    /// graph, validate inputs, and park it. Admission failures are
    /// synchronous typed errors; the returned [`Ticket`] resolves once
    /// a pool worker executes the request.
    pub fn submit(
        &self,
        tenant: &str,
        g: &EinGraph,
        inputs: HashMap<VertexId, Tensor>,
    ) -> Result<Ticket> {
        let sh = &*self.shared;
        sh.submitted.fetch_add(1, Ordering::Relaxed);
        let exe = match sh.session.compile(g) {
            Ok(e) => Arc::new(e),
            Err(e) => {
                sh.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        // Validate up front so a malformed request is rejected at
        // admission instead of poisoning a coalesced batch later.
        for v in g.inputs() {
            let vert = g.vertex(v);
            match inputs.get(&v) {
                None => {
                    sh.rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::exec_failure(
                        None,
                        0,
                        ExecCause::MissingInput {
                            vertex: vert.name.clone(),
                        },
                    ));
                }
                Some(t) if t.shape() != vert.bound.as_slice() => {
                    sh.rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::exec_failure(
                        None,
                        0,
                        ExecCause::ShapeMismatch {
                            vertex: vert.name.clone(),
                            got: t.shape().to_vec(),
                            want: vert.bound.clone(),
                        },
                    ));
                }
                Some(_) => {}
            }
        }
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            tenant: tenant.to_string(),
            exe,
            inputs,
            enqueued: Instant::now(),
            tx,
        };
        {
            let mut q = sh.q.lock().unwrap();
            if !q.open {
                sh.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(Error::serve_rejected(tenant, ServeCause::ShuttingDown));
            }
            if q.depth >= sh.cfg.max_queue_depth {
                sh.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(Error::serve_rejected(
                    tenant,
                    ServeCause::QueueFull {
                        depth: q.depth,
                        limit: sh.cfg.max_queue_depth,
                    },
                ));
            }
            let ti = match q.tenants.iter().position(|(name, _)| name == tenant) {
                Some(i) => i,
                None => {
                    q.tenants.push((tenant.to_string(), VecDeque::new()));
                    q.tenants.len() - 1
                }
            };
            q.tenants[ti].1.push_back(pending);
            q.depth += 1;
        }
        sh.cv.notify_all();
        Ok(Ticket {
            tenant: tenant.to_string(),
            rx,
        })
    }

    /// Convenience: `submit` + [`Ticket::wait`].
    pub fn run(
        &self,
        tenant: &str,
        g: &EinGraph,
        inputs: HashMap<VertexId, Tensor>,
    ) -> Result<Response> {
        self.submit(tenant, g, inputs)?.wait()
    }

    /// Close admission, drain the queue, and join the pool. Idempotent;
    /// also runs on drop.
    pub fn shutdown(&self) {
        {
            let mut q = self.shared.q.lock().unwrap();
            q.open = false;
            // a never-started server must still drain its queue
            q.started = true;
        }
        self.shared.cv.notify_all();
        let handles: Vec<_> = self.handles.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(sh: &Shared) {
    loop {
        let mut q = sh.q.lock().unwrap();
        loop {
            if !q.open && q.depth == 0 {
                return;
            }
            if q.started && q.depth > 0 {
                break;
            }
            q = sh.cv.wait(q).unwrap();
        }
        // Seed: pop the front of the next non-empty tenant subqueue in
        // round-robin order, so a hot tenant cannot starve a cold one.
        let nt = q.tenants.len();
        let mut seed = None;
        for off in 0..nt {
            let ti = (q.rr + off) % nt;
            if let Some(p) = q.tenants[ti].1.pop_front() {
                q.rr = (ti + 1) % nt;
                q.depth -= 1;
                seed = Some(p);
                break;
            }
        }
        let Some(seed) = seed else {
            drop(q);
            continue;
        };
        let mut batch = vec![seed];
        if sh.cfg.max_batch > 1 {
            // Gather co-batchable requests (same plan-cache artifact),
            // holding the window open until full, deadline, or
            // shutdown. The lock is released while waiting.
            let key = batch[0].exe.artifact_key();
            let deadline = Instant::now() + sh.cfg.batch_window;
            loop {
                gather(&mut q, key, &mut batch, sh.cfg.max_batch);
                if batch.len() >= sh.cfg.max_batch || !q.open {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = sh.cv.wait_timeout(q, deadline - now).unwrap();
                q = guard;
            }
        }
        drop(q);
        execute(sh, batch);
    }
}

/// Remove up to `cap - batch.len()` requests whose executable resolved
/// to `key`'s artifact, sweeping tenants in round-robin order and
/// taking at most one request per tenant per sweep — batching is a
/// shared ride, not a hot tenant's express lane. Within a tenant,
/// requests of a given signature leave in FIFO order.
fn gather(q: &mut QueueState, key: usize, batch: &mut Vec<Pending>, cap: usize) {
    loop {
        let mut took = false;
        let nt = q.tenants.len();
        for off in 0..nt {
            if batch.len() >= cap {
                return;
            }
            let ti = (q.rr + off) % nt;
            let dq = &mut q.tenants[ti].1;
            if let Some(pos) = dq.iter().position(|p| p.exe.artifact_key() == key) {
                let p = dq.remove(pos).expect("position just found");
                q.depth -= 1;
                batch.push(p);
                took = true;
            }
        }
        if !took || batch.len() >= cap {
            return;
        }
    }
}

/// Run one dequeued batch and deliver each member's result.
fn execute(sh: &Shared, batch: Vec<Pending>) {
    let start = Instant::now();
    let seq = sh.seq.fetch_add(1, Ordering::Relaxed);
    let k = batch.len();
    if k == 1 {
        let p = batch.into_iter().next().expect("k == 1");
        let wait = start.duration_since(p.enqueued).as_secs_f64();
        let result = p.exe.run(&p.inputs).map(|(outputs, mut report)| {
            report.batched_with = 1;
            report.queue_wait_s = wait;
            Response {
                outputs,
                report,
                seq,
            }
        });
        if result.is_ok() {
            sh.completed.fetch_add(1, Ordering::Relaxed);
        }
        let _ = p.tx.send(result);
        return;
    }
    match run_batched(sh, &batch, start, seq) {
        Ok(responses) => {
            sh.batches.fetch_add(1, Ordering::Relaxed);
            sh.batched_requests.fetch_add(k as u64, Ordering::Relaxed);
            sh.completed.fetch_add(k as u64, Ordering::Relaxed);
            for (p, resp) in batch.into_iter().zip(responses) {
                let _ = p.tx.send(Ok(resp));
            }
        }
        Err(e) => {
            // The coalesced execution failed as a unit: every member
            // gets a typed error naming the batch size and root cause.
            let detail = e.to_string();
            for p in batch {
                let err = Error::serve_rejected(
                    p.tenant,
                    ServeCause::BatchFailed {
                        batched_with: k,
                        detail: detail.clone(),
                    },
                );
                let _ = p.tx.send(Err(err));
            }
        }
    }
}

/// Coalesced execution: translate each member's inputs to the stored
/// numbering, stack, run the cached (or freshly compiled) twin once,
/// split every output back, and translate into each member's own
/// numbering. Members may come from differently-numbered (but
/// canonically equal) graphs — their per-executable remaps bridge the
/// difference.
fn run_batched(
    sh: &Shared,
    batch: &[Pending],
    start: Instant,
    seq: u64,
) -> Result<Vec<Response>> {
    let k = batch.len();
    let solo = &batch[0].exe;
    let class = size_class(k);
    let twin = twin_for(sh, solo, class)?;
    let mapped: Vec<HashMap<VertexId, Tensor>> = batch
        .iter()
        .map(|p| {
            p.inputs
                .iter()
                .map(|(v, t)| (p.exe.to_stored(*v), t.clone()))
                .collect()
        })
        .collect();
    let stacked = stack_inputs(solo, class, &mapped)?;
    let (outs, report) = twin.run(&stacked)?;
    let mut per_member: Vec<HashMap<VertexId, Tensor>> =
        (0..k).map(|_| HashMap::with_capacity(outs.len())).collect();
    for (v, t) in &outs {
        let slices = split_output(t, k)?;
        for (r, s) in slices.into_iter().enumerate() {
            per_member[r].insert(batch[r].exe.to_presented(*v), s);
        }
    }
    Ok(per_member
        .into_iter()
        .zip(batch)
        .map(|(outputs, p)| {
            let mut rep = report.clone();
            rep.batched_with = k;
            rep.queue_wait_s = start.duration_since(p.enqueued).as_secs_f64();
            Response {
                outputs,
                report: rep,
                seq,
            }
        })
        .collect())
}

/// Fetch or compile the batched twin for `(solo, class)`. Compilation
/// happens outside the cache lock; a racing worker's duplicate twin is
/// discarded in favour of the incumbent, mirroring the session plan
/// cache's publish rule.
fn twin_for(sh: &Shared, solo: &Arc<Executable>, class: usize) -> Result<Arc<Executable>> {
    let key = (solo.artifact_key(), class);
    if let Some(t) = sh.twins.lock().unwrap().get(&key) {
        return Ok(Arc::clone(t));
    }
    let twin = Arc::new(batched_twin(&sh.session, solo, class)?);
    let mut twins = sh.twins.lock().unwrap();
    Ok(Arc::clone(twins.entry(key).or_insert(twin)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::driver::DriverConfig;
    use crate::models::matchain;

    fn small_session() -> Session {
        Session::new(DriverConfig {
            workers: 2,
            p: 2,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn solo_serve_matches_direct_run() {
        let chain = matchain::chain_graph(16, false).unwrap();
        let inputs = matchain::chain_inputs(&chain, 7);
        let session = small_session();
        let exe = session.compile(&chain.graph).unwrap();
        let (direct, _) = exe.run(&inputs).unwrap();
        let server = Server::with_session(
            Arc::new(session),
            ServeConfig {
                max_batch: 1,
                ..Default::default()
            },
        );
        let resp = server.run("t0", &chain.graph, inputs).unwrap();
        assert_eq!(resp.report.batched_with, 1);
        assert!(resp.report.queue_wait_s >= 0.0);
        assert_eq!(
            super::super::output_checksum(&resp.outputs),
            super::super::output_checksum(&direct)
        );
        let stats = server.serve_stats();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.batches, 0);
    }

    #[test]
    fn queue_full_and_shutdown_are_typed_rejections() {
        let chain = matchain::chain_graph(8, false).unwrap();
        let server = Server::new(
            small_session(),
            ServeConfig {
                serve_workers: 1,
                max_batch: 1,
                max_queue_depth: 2,
                autostart: false,
                ..Default::default()
            },
        );
        let t1 = server
            .submit("a", &chain.graph, matchain::chain_inputs(&chain, 1))
            .unwrap();
        let t2 = server
            .submit("b", &chain.graph, matchain::chain_inputs(&chain, 2))
            .unwrap();
        let err = server
            .submit("c", &chain.graph, matchain::chain_inputs(&chain, 3))
            .unwrap_err();
        assert!(err.is_queue_full(), "{err}");
        assert!(err.to_string().contains("tenant c"), "{err}");
        assert_eq!(server.queue_depth(), 2);
        server.start();
        t1.wait().unwrap();
        t2.wait().unwrap();
        server.shutdown();
        let err = server
            .submit("d", &chain.graph, matchain::chain_inputs(&chain, 4))
            .unwrap_err();
        assert!(
            err.to_string().contains("shutting down"),
            "expected shutdown rejection: {err}"
        );
        let stats = server.serve_stats();
        assert_eq!(stats.submitted, 4);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.rejected, 2);
    }

    #[test]
    fn bad_inputs_rejected_at_admission() {
        let chain = matchain::chain_graph(8, false).unwrap();
        let server = Server::new(small_session(), ServeConfig::default());
        let err = server
            .submit("t", &chain.graph, HashMap::new())
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing input"), "{err}");
        let mut bad = matchain::chain_inputs(&chain, 0);
        let first = *bad.keys().next().unwrap();
        bad.insert(first, Tensor::zeros(&[3]));
        let err = server.submit("t", &chain.graph, bad).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
        assert_eq!(server.serve_stats().rejected, 2);
    }

    #[test]
    fn staged_queue_coalesces_into_one_batch() {
        let chain = matchain::chain_graph(16, false).unwrap();
        let session = small_session();
        let server = Server::new(
            session,
            ServeConfig {
                serve_workers: 1,
                max_batch: 8,
                batch_window: Duration::from_millis(50),
                autostart: false,
                ..Default::default()
            },
        );
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                server
                    .submit(
                        &format!("tenant-{i}"),
                        &chain.graph,
                        matchain::chain_inputs(&chain, i as u64),
                    )
                    .unwrap()
            })
            .collect();
        assert_eq!(server.queue_depth(), 4);
        server.start();
        let seqs: Vec<u64> = tickets
            .into_iter()
            .map(|t| {
                let resp = t.wait().unwrap();
                assert_eq!(resp.report.batched_with, 4);
                assert!(resp.report.queue_wait_s >= 0.0);
                resp.seq
            })
            .collect();
        // one execution served all four requests
        assert!(seqs.windows(2).all(|w| w[0] == w[1]));
        let stats = server.serve_stats();
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.batched_requests, 4);
        assert_eq!(stats.completed, 4);
        assert_eq!(server.twin_cache_entries(), 1);
    }
}

//! Batched-twin construction: derive the stacked graph and plan for a
//! coalesced execution, and move request tensors in and out of the
//! stacked layout.
//!
//! Bitwise identity with solo runs rests on three facts, each asserted
//! by the differential suite in `tests/serving.rs`:
//!
//! 1. [`EinGraph::batched`] prepends the fresh batch label to every
//!    operand *and* output list, so `bmm_plan`'s label classification
//!    and the unary fast-path condition are preserved — every op keeps
//!    its solo kernel dispatch path.
//! 2. The twin plan leaves the batch dimension unsplit (`[1] ++ parts`),
//!    so repartitioning slices exactly as the solo plan does within each
//!    batch entry; intra-op kernel sharding over batch entries supplies
//!    the extra parallelism instead.
//! 3. Stacking and splitting are plain contiguous `memcpy`s: entry `r`
//!    of a stacked tensor *is* request `r`'s tensor, bit for bit, and
//!    batch entries never mix in any kernel's accumulation order.
//!
//! [`EinGraph::batched`]: crate::einsum::graph::EinGraph::batched

use crate::coordinator::session::{Executable, Session};
use crate::decomp::Plan;
use crate::einsum::graph::VertexId;
use crate::error::{Error, ExecCause, Result};
use crate::tensor::Tensor;
use std::collections::HashMap;

/// Batch size class for `k` coalesced requests: the next power of two.
/// Classing keeps the twin cache at O(log max_batch) entries per
/// signature; short batches pad with zero entries (batch entries are
/// independent, so padding cannot perturb the real slices, and zeros
/// pass the non-finite input screen).
pub fn size_class(k: usize) -> usize {
    k.max(1).next_power_of_two()
}

/// Compile the batched twin of `solo` for `class` stacked requests.
///
/// The twin reuses the solo artifact's plan — extended with an unsplit
/// batch dimension — via [`Session::compile_with_plan`], so the planner
/// never reruns for a batch and the partitioning seen by every kernel
/// is exactly the solo partitioning per entry. The twin is compiled
/// against the *stored* (possibly canon-remapped) solo graph, so its
/// vertex ids line up with [`Executable::to_stored`] translations.
pub fn batched_twin(session: &Session, solo: &Executable, class: usize) -> Result<Executable> {
    let bg = solo.graph().batched(class)?;
    let sp = solo.plan();
    let mut parts = HashMap::with_capacity(sp.parts.len());
    for (v, d) in &sp.parts {
        let mut bd = Vec::with_capacity(d.len() + 1);
        bd.push(1); // batch dim stays unsplit; kernels shard over entries
        bd.extend_from_slice(d);
        parts.insert(*v, bd);
    }
    let mut plan = Plan {
        parts,
        // finalize_inputs derives these from first consumers; it must
        // start empty or stale solo entries (wrong rank) would win.
        input_parts: HashMap::new(),
        predicted_cost: 0.0,
        strategy: format!("{}+batch{}", sp.strategy, class),
    };
    plan.finalize_inputs(&bg);
    plan.predicted_cost = plan.total_cost(&bg).unwrap_or(sp.predicted_cost * class as f64);
    session.compile_with_plan(&bg, plan)
}

/// Stack per-request input maps (already translated to the stored
/// numbering of `solo`'s graph) into the twin's `[class, ..]` inputs.
/// Slots beyond `members.len()` stay zero — padding for short batches.
pub(crate) fn stack_inputs(
    solo: &Executable,
    class: usize,
    members: &[HashMap<VertexId, Tensor>],
) -> Result<HashMap<VertexId, Tensor>> {
    let g = solo.graph();
    let mut out = HashMap::new();
    for v in g.inputs() {
        let vert = g.vertex(v);
        let len: usize = vert.bound.iter().product();
        let mut shape = Vec::with_capacity(vert.bound.len() + 1);
        shape.push(class);
        shape.extend_from_slice(&vert.bound);
        let mut stacked = Tensor::zeros(&shape);
        let data = stacked.data_mut();
        for (r, m) in members.iter().enumerate() {
            let t = m.get(&v).ok_or_else(|| {
                Error::exec_failure(
                    None,
                    0,
                    ExecCause::MissingInput {
                        vertex: vert.name.clone(),
                    },
                )
            })?;
            if t.len() != len {
                return Err(Error::exec_failure(
                    None,
                    0,
                    ExecCause::ShapeMismatch {
                        vertex: vert.name.clone(),
                        got: t.shape().to_vec(),
                        want: vert.bound.clone(),
                    },
                ));
            }
            // The whole bitwise story: entry r of the stacked input IS
            // request r's tensor.
            data[r * len..(r + 1) * len].copy_from_slice(t.data());
        }
        out.insert(v, stacked);
    }
    Ok(out)
}

/// Split a stacked `[class, ..]` output back into the first `k`
/// per-request tensors; padding entries are dropped.
pub(crate) fn split_output(stacked: &Tensor, k: usize) -> Result<Vec<Tensor>> {
    let inner: Vec<usize> = stacked.shape()[1..].to_vec();
    let len: usize = inner.iter().product();
    let data = stacked.data();
    (0..k)
        .map(|r| Tensor::new(inner.clone(), data[r * len..(r + 1) * len].to_vec()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_classes_are_powers_of_two() {
        assert_eq!(size_class(0), 1);
        assert_eq!(size_class(1), 1);
        assert_eq!(size_class(2), 2);
        assert_eq!(size_class(3), 4);
        assert_eq!(size_class(7), 8);
        assert_eq!(size_class(8), 8);
    }

    #[test]
    fn stack_then_split_roundtrips_bitwise() {
        use crate::coordinator::driver::DriverConfig;
        use crate::coordinator::session::Session;
        use crate::models::matchain;

        let chain = matchain::chain_graph(12, false).unwrap();
        let session = Session::new(DriverConfig {
            workers: 2,
            p: 2,
            ..Default::default()
        })
        .unwrap();
        let exe = session.compile(&chain.graph).unwrap();
        let members: Vec<HashMap<VertexId, Tensor>> = (0..3)
            .map(|seed| {
                matchain::chain_inputs(&chain, seed as u64)
                    .into_iter()
                    .map(|(v, t)| (exe.to_stored(v), t))
                    .collect()
            })
            .collect();
        let stacked = stack_inputs(&exe, 4, &members).unwrap();
        for (v, t) in &stacked {
            let bound = &exe.graph().vertex(*v).bound;
            assert_eq!(t.shape()[0], 4);
            assert_eq!(&t.shape()[1..], bound.as_slice());
            let per = split_output(t, 3).unwrap();
            let len: usize = bound.iter().product();
            for (r, s) in per.iter().enumerate() {
                assert_eq!(s.data(), members[r][v].data(), "entry {r} mismatch");
                assert_eq!(s.len(), len);
            }
            // padding slot stays zero
            assert!(t.data()[3 * len..].iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn stack_reports_missing_and_misshapen_inputs() {
        use crate::coordinator::driver::DriverConfig;
        use crate::coordinator::session::Session;
        use crate::models::matchain;

        let chain = matchain::chain_graph(8, false).unwrap();
        let session = Session::new(DriverConfig {
            workers: 1,
            p: 1,
            ..Default::default()
        })
        .unwrap();
        let exe = session.compile(&chain.graph).unwrap();
        let empty = vec![HashMap::new()];
        let err = stack_inputs(&exe, 1, &empty).unwrap_err().to_string();
        assert!(err.contains("missing input"), "{err}");

        let bad: Vec<HashMap<VertexId, Tensor>> = vec![exe
            .graph()
            .inputs()
            .into_iter()
            .map(|v| (v, Tensor::zeros(&[1])))
            .collect()];
        let err = stack_inputs(&exe, 1, &bad).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
    }
}

//! Multi-tenant serving: shared compile cache, concurrent executors, and
//! signature-keyed dynamic batching.
//!
//! A [`Server`] owns one shared [`Session`](crate::coordinator::Session)
//! and a fixed pool of serving threads draining a bounded, per-tenant
//! request queue:
//!
//! ```text
//!   tenant A ──┐
//!   tenant B ──┼──> admission ──> per-tenant subqueues ──> round-robin
//!   tenant C ──┘    (depth cap)                              seed pick
//!                                                               │
//!                             batch window: gather same-signature
//!                             requests across tenants (≤ max_batch)
//!                                                               │
//!                      k == 1 ──> solo Executable::run           │
//!                      k >= 2 ──> batched twin (stack along a     │
//!                                 fresh batch label, run once,    │
//!                                 split outputs per request) <────┘
//! ```
//!
//! Coalescing is keyed by [`Executable::artifact_key`]: two requests
//! batch together iff they resolved to the *same* plan-cache entry,
//! which already folds in canonical signature equality and the
//! label-sensitive strategies' named-signature rule. The batched twin
//! is the solo graph run through [`EinGraph::batched`]
//! (a fresh batch label prepended to every operand and output list) and
//! compiled with the solo plan extended by an unsplit batch dimension —
//! so every kernel takes the same dispatch path as the solo run and the
//! split-back outputs are bitwise-identical to running each request
//! alone. Twins are cached per `(artifact key, batch size class)` where
//! the class is the next power of two; short batches pad with zero
//! entries that are discarded on split.
//!
//! Worked example: tenants A and B each submit `chain_graph(64)` inside
//! one batch window. Both compiles hit the same cache entry, so the
//! worker seeds A's request, gathers B's, and (class 2) runs the twin
//! `__batch` graph once on inputs of shape `[2, 64, 64]`. Entry 0 of
//! every output goes back to A, entry 1 to B, each with
//! `report.batched_with == 2` and its own `queue_wait_s`.
//!
//! [`EinGraph::batched`]: crate::einsum::graph::EinGraph::batched
//! [`Executable::artifact_key`]: crate::coordinator::Executable::artifact_key

mod batch;
mod loadgen;
mod server;

pub use batch::{batched_twin, size_class};
pub use loadgen::{run_load, LatencySummary, LoadConfig, LoadReport};
pub use server::{Response, ServeConfig, ServeStats, Server, Ticket};

use crate::einsum::graph::VertexId;
use crate::tensor::Tensor;
use std::collections::HashMap;

/// FNV-1a over the outputs in vertex-id order: shape dims, then the raw
/// f32 bit patterns. Equal iff the outputs are bitwise-identical — the
/// serving differential suites and `scripts/chaos_smoke.sh` both diff
/// this fingerprint.
pub fn output_checksum(outs: &HashMap<VertexId, Tensor>) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut ids: Vec<_> = outs.keys().copied().collect();
    ids.sort_by_key(|v| v.0);
    let mut h: u64 = 0xcbf29ce484222325;
    for vid in ids {
        h = (h ^ vid.0 as u64).wrapping_mul(PRIME);
        let t = &outs[&vid];
        for &d in t.shape() {
            h = (h ^ d as u64).wrapping_mul(PRIME);
        }
        for &v in t.data() {
            h = (h ^ u64::from(v.to_bits())).wrapping_mul(PRIME);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_detects_single_bit_flip() {
        let mut outs = HashMap::new();
        outs.insert(
            VertexId(3),
            Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap(),
        );
        let base = output_checksum(&outs);
        let mut flipped = outs.clone();
        let t = flipped.get_mut(&VertexId(3)).unwrap();
        let bits = t.data()[2].to_bits() ^ 1;
        t.data_mut()[2] = f32::from_bits(bits);
        assert_ne!(base, output_checksum(&flipped));
        assert_eq!(base, output_checksum(&outs.clone()));
    }
}

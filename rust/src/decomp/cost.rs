//! The communication cost model (paper §7): an upper bound on the number
//! of floating point numbers transferred to execute a decomposed vertex —
//! join input movement, aggregation movement, and repartition movement
//! between producer/consumer vertices.
//!
//! All quantities are element counts (f32s), computed in `f64`. Tile sizes
//! use `ceil(b/d)` so the bound stays an upper bound under the balanced
//! (uneven) tiling the runtime uses when `d` does not divide `b`; when it
//! divides, this is exactly the paper's `b/d`.

use crate::einsum::expr::EinSum;
use crate::einsum::label::project;
use crate::error::{Error, Result};
use crate::sim::network::Topology;

#[inline]
fn ceil_div(b: usize, d: usize) -> f64 {
    b.div_ceil(d) as f64
}

/// Product of per-dimension tile sizes `ceil(b/d)`.
fn tile_elems(bound: &[usize], part: &[usize]) -> f64 {
    bound
        .iter()
        .zip(part)
        .map(|(&b, &d)| ceil_div(b, d))
        .product()
}

/// Number of join result tuples `N(l_X, l_Y, d) = prod d[l_X (.) l_Y]`
/// (paper §6). Repeated labels count once — they carry the join's equality
/// predicate. `d` is parallel to `op.unique_labels()`.
pub fn join_tuples(_op: &EinSum, d: &[usize]) -> f64 {
    // unique_labels == concat_dedup of the operand lists
    d.iter().map(|&x| x as f64).product()
}

/// §7 "Transferring into the join": every kernel call receives one
/// sub-tensor from each side, so the bound is `N * (n_X + n_Y)` (the paper
/// writes `p`, which equals `N` under the exactly-`p` viability
/// constraint; using `N` generalizes to baseline plans that do not hold
/// the constraint).
pub fn cost_join(op: &EinSum, in_bounds: &[&[usize]], d: &[usize]) -> Result<f64> {
    let uniq = op.unique_labels();
    if d.len() != uniq.len() {
        return Err(Error::InvalidPartitioning(format!(
            "d {d:?} not parallel to {uniq:?}"
        )));
    }
    let n = join_tuples(op, d);
    let mut per_call = 0.0;
    for (o, lo) in op.operand_labels().iter().enumerate() {
        let bo = in_bounds[o];
        let do_ = project(d, lo, &uniq);
        per_call += tile_elems(bo, &do_);
    }
    Ok(n * per_call)
}

/// §7 "Transferring into the aggregation": `(N / n_agg) * (n_agg - 1) *
/// n_Z`, where `n_agg = prod d[l_agg]` sub-tensors reduce to one and
/// `n_Z` is the size of each kernel-call output tile.
pub fn cost_agg(op: &EinSum, in_bounds: &[&[usize]], d: &[usize]) -> Result<f64> {
    let uniq = op.unique_labels();
    if d.len() != uniq.len() {
        return Err(Error::InvalidPartitioning(format!(
            "d {d:?} not parallel to {uniq:?}"
        )));
    }
    let lagg = op.lagg();
    if lagg.is_empty() {
        return Ok(0.0);
    }
    let n_agg: f64 = project(d, &lagg, &uniq).iter().map(|&x| x as f64).product();
    if n_agg <= 1.0 {
        return Ok(0.0);
    }
    let lz = op.lz().expect("not input");
    let bxy = op.bxy(in_bounds);
    let lxy = op.lxy();
    let bz = project(&bxy, lz, &lxy);
    let dz = project(d, lz, &uniq);
    let n_z = tile_elems(&bz, &dz);
    let n = join_tuples(op, d);
    Ok((n / n_agg) * (n_agg - 1.0) * n_z)
}

/// §7 "Re-partitioning across operations": producer emits a tensor of
/// bound `b` partitioned `d_z`; the consumer needs it partitioned `d_x`.
/// The paper's formula (verified against its worked 320-float example):
///
/// ```text
///   n      = prod b                      (total floats)
///   n_p    = prod ceil(b / d_z)          (producer tile)
///   n_c    = prod ceil(b / d_x)          (consumer tile)
///   n_int  = prod min(b/d_z, b/d_x)      (overlap region)
///   cost   = (n_c/n_int - 1) * (n/n_c) * (n_c + n_p)
///          + [n_p != n_int] * n_p * (n/n_c)
/// ```
pub fn cost_repart(d_x: &[usize], d_z: &[usize], bound: &[usize]) -> f64 {
    if d_x == d_z {
        return 0.0;
    }
    let n: f64 = bound.iter().map(|&b| b as f64).product();
    let n_p = tile_elems(bound, d_z);
    let n_c = tile_elems(bound, d_x);
    let n_int: f64 = bound
        .iter()
        .zip(d_z.iter().zip(d_x))
        .map(|(&b, (&dz, &dx))| ceil_div(b, dz).min(ceil_div(b, dx)))
        .product();
    let mut cost = (n_c / n_int - 1.0) * (n / n_c) * (n_c + n_p);
    if (n_p - n_int).abs() > f64::EPSILON {
        cost += n_p * (n / n_c);
    }
    cost
}

/// Topology-aware repartition cost: the §7 closed form, scaled by the
/// fraction of moved elements that traverse each link class, weighted by
/// that class's bandwidth relative to the outermost (flat) class.
///
/// `None` and single-level topologies return [`cost_repart`] verbatim,
/// so the seed model — and every optimality result proved against it —
/// is untouched. A hierarchical topology discounts the closed form by
/// `sum_class(frac_class * class_weight)` where the fractions come from
/// enumerating producer x consumer tile overlaps under the canonical
/// worker mapping `w(tile) = linear_key mod workers` (the same mapping
/// round-robin placement uses), with same-worker overlaps free. Since
/// every fraction sums to <= 1 and the preset weights are <= 1, the
/// hierarchical cost never exceeds the flat one for the same plan.
pub fn cost_repart_on(
    topo: Option<&Topology>,
    d_x: &[usize],
    d_z: &[usize],
    bound: &[usize],
) -> f64 {
    let base = cost_repart(d_x, d_z, bound);
    match topo {
        Some(t) if !t.is_flat() && base > 0.0 => {
            base * repart_link_discount(t, d_x, d_z, bound)
        }
        _ => base,
    }
}

/// Weighted fraction of repartition traffic, by link class, under the
/// canonical worker mapping. In `[0, 1]` for the builtin presets.
fn repart_link_discount(topo: &Topology, d_x: &[usize], d_z: &[usize], bound: &[usize]) -> f64 {
    use crate::tensor::index_space;
    use crate::tra::relation::{linearize, overlapping_tiles, tile_offset, tile_size};
    let workers = topo.workers().max(1);
    let mut total = 0.0f64;
    let mut weighted = 0.0f64;
    for pkey in index_space(d_z) {
        let wp = linearize(&pkey, d_z) % workers;
        // per-dim extent of this producer tile, then the consumer tiles
        // it overlaps
        let ranges: Vec<(usize, usize)> = bound
            .iter()
            .zip(d_z.iter().zip(&pkey))
            .map(|(&b, (&dz, &k))| {
                let off = tile_offset(b, dz, k);
                let len = tile_size(b, dz, k);
                (off, len)
            })
            .collect();
        let windows: Vec<(usize, usize)> = bound
            .iter()
            .zip(d_x.iter().zip(&ranges))
            .map(|(&b, (&dx, &(off, len)))| overlapping_tiles(b, dx, off, len))
            .collect();
        let win_dims: Vec<usize> = windows.iter().map(|&(lo, hi)| hi - lo + 1).collect();
        for rel in index_space(&win_dims) {
            let ckey: Vec<usize> = rel.iter().zip(&windows).map(|(&r, &(lo, _))| lo + r).collect();
            let wc = linearize(&ckey, d_x) % workers;
            let mut overlap = 1.0f64;
            for (dim, &ck) in ckey.iter().enumerate() {
                let (poff, plen) = ranges[dim];
                let coff = tile_offset(bound[dim], d_x[dim], ck);
                let clen = tile_size(bound[dim], d_x[dim], ck);
                let lo = poff.max(coff);
                let hi = (poff + plen).min(coff + clen);
                overlap *= hi.saturating_sub(lo) as f64;
            }
            total += overlap;
            if let Some(cls) = topo.link_class(wp, wc) {
                weighted += overlap * topo.class_weight(cls);
            }
        }
    }
    if total <= 0.0 {
        return 1.0;
    }
    weighted / total
}

/// Floats a ring all-gather (or ring reduce-scatter) of an `n`-float
/// tensor moves over `p` members: `(p-1)/p * n` per the textbook
/// bandwidth-optimal schedule.
pub fn cost_ring_collective(n: f64, p: usize) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    (p as f64 - 1.0) / p as f64 * n
}

/// Floats a ring all-reduce moves: a reduce-scatter followed by an
/// all-gather, `2 * (p-1)/p * n`.
pub fn cost_ring_allreduce(n: f64, p: usize) -> f64 {
    2.0 * cost_ring_collective(n, p)
}

/// Serialized steps in a ring schedule over `p` members: `p - 1`.
pub fn ring_steps(p: usize) -> usize {
    p.saturating_sub(1)
}

/// Depth of an `arity`-ary tree schedule over `p` members:
/// `ceil(log_arity(p))`.
pub fn tree_depth(p: usize, arity: usize) -> usize {
    let arity = arity.max(2);
    let mut depth = 0usize;
    let mut n = p.max(1);
    while n > 1 {
        n = n.div_ceil(arity);
        depth += 1;
    }
    depth
}

/// Join + aggregation cost of executing one vertex under `d`.
pub fn vertex_cost(op: &EinSum, in_bounds: &[&[usize]], d: &[usize]) -> Result<f64> {
    Ok(cost_join(op, in_bounds, d)? + cost_agg(op, in_bounds, d)?)
}

/// A cost model carrying the processor count (for reports; the formulas
/// themselves derive everything from `d`).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub p: usize,
}

impl CostModel {
    pub fn new(p: usize) -> Self {
        CostModel { p }
    }

    /// Convert a float count to bytes (f32).
    pub fn bytes(floats: f64) -> f64 {
        floats * 4.0
    }

    /// Estimated wire time in seconds for `floats` under `bw` bytes/sec.
    pub fn wire_seconds(floats: f64, bw: f64) -> f64 {
        Self::bytes(floats) / bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::expr::{AggOp, JoinOp};
    use crate::einsum::label::labels;

    fn matmul() -> EinSum {
        EinSum::contraction(labels("i j"), labels("j k"), labels("i k"))
    }

    #[test]
    fn join_tuple_counts_match_paper() {
        // §6: d = [16,2,4] over (i,j,k) -> 16*2*4 = 128 join tuples
        // (the repeated j counts once).
        let op = matmul();
        assert_eq!(join_tuples(&op, &[16, 2, 4]), 128.0);
        // Figure 1/2: all four example vectors produce 16 tuples.
        for d in [[4usize, 1, 4], [2, 1, 8], [2, 4, 2], [2, 2, 4]] {
            assert_eq!(join_tuples(&op, &d), 16.0);
        }
    }

    #[test]
    fn cost_join_matches_paper_example() {
        // §7 top-left Figure 2 case: b_XY=[8,8,8,8], d=[4,1,1,4] (over
        // unique labels: [4,1,4]); n_X = 2*8 = 16, n_Y = 8*2 = 16.
        // The paper writes the total as p*(n_X+n_Y); with N = 16 kernel
        // calls the bound is 16*(16+16) = 512. (The paper's printed
        // "8x(16+16)" appears to use 8 from an inconsistent p; we follow
        // the formula as defined, N*(n_X+n_Y).)
        let op = matmul();
        let b: &[usize] = &[8, 8];
        let c = cost_join(&op, &[b, b], &[4, 1, 4]).unwrap();
        assert_eq!(c, 16.0 * 32.0);
    }

    #[test]
    fn cost_agg_matches_paper_example() {
        // §7 bottom-right case: d=[2,2,4] over (i,j,k): n_agg = 2,
        // n_Z = (8/2)*(8/4) = 8, N = 16 -> (16/2)*(2-1)*8 = 64.
        let op = matmul();
        let b: &[usize] = &[8, 8];
        let c = cost_agg(&op, &[b, b], &[2, 2, 4]).unwrap();
        assert_eq!(c, 64.0);
        // top-left case: d_j = 1 -> no aggregation cost.
        let c0 = cost_agg(&op, &[b, b], &[4, 1, 4]).unwrap();
        assert_eq!(c0, 0.0);
    }

    #[test]
    fn cost_repart_matches_paper_320_example() {
        // §7: producer d_Z = [2,4] (from d=[2,2,2,4] on Z_ik), consumer
        // needs d_X = [4,1]; bound [8,8]. Paper: 128 + 192 = 320.
        let c = cost_repart(&[4, 1], &[2, 4], &[8, 8]);
        assert_eq!(c, 320.0);
    }

    #[test]
    fn cost_repart_identity_is_free() {
        assert_eq!(cost_repart(&[2, 4], &[2, 4], &[8, 8]), 0.0);
    }

    #[test]
    fn cost_repart_no_extraction_term_when_producer_tile_nested() {
        // producer [4,4] tiles (2x2 floats), consumer [2,2] tiles (4x4):
        // every producer tile is wholly contained in one consumer tile
        // (n_p == n_int), so no extraction transfer.
        let c_nested = cost_repart(&[2, 2], &[4, 4], &[8, 8]);
        // n=64, n_p=4, n_c=16, n_int=4: (16/4-1)*(64/16)*(16+4) = 240
        assert_eq!(c_nested, 240.0);
    }

    #[test]
    fn elementwise_has_no_agg_cost() {
        let op = EinSum::elementwise(labels("i j"), labels("i j"), JoinOp::Add);
        let b: &[usize] = &[8, 8];
        assert_eq!(cost_agg(&op, &[b, b], &[4, 4]).unwrap(), 0.0);
    }

    #[test]
    fn unary_vertex_cost() {
        let op = EinSum::reduce(labels("i j"), labels("i"), AggOp::Sum);
        let b: &[usize] = &[8, 8];
        // d=[2,2]: N=4 tiles of 4*4=16 -> join side 64; agg: n_agg=2,
        // n_Z = 8/2 = 4, (4/2)*(2-1)*4 = 8.
        let c = vertex_cost(&op, &[b], &[2, 2]).unwrap();
        assert_eq!(c, 64.0 + 8.0);
    }

    #[test]
    fn uneven_bounds_use_ceiling() {
        // 7 split 2 ways -> tile size ceil(7/2)=4
        let op = matmul();
        let c = cost_join(&op, &[&[7, 4], &[4, 6]], &[2, 1, 1]).unwrap();
        // N=2; n_X = 4*4; n_Y = 4*6 -> 2*(16+24) = 80
        assert_eq!(c, 80.0);
    }

    #[test]
    fn cost_repart_on_none_and_flat_are_the_seed_model() {
        use crate::sim::network::NetworkProfile;
        let net = NetworkProfile::cpu_cluster();
        let flat = Topology::flat_of(&net, 8);
        for (dx, dz, b) in [
            (vec![4, 1], vec![2, 4], vec![8, 8]),
            (vec![2, 2], vec![4, 4], vec![8, 8]),
            (vec![3, 2], vec![2, 3], vec![7, 5]),
        ] {
            let seed = cost_repart(&dx, &dz, &b);
            assert_eq!(cost_repart_on(None, &dx, &dz, &b), seed);
            assert_eq!(cost_repart_on(Some(&flat), &dx, &dz, &b), seed);
        }
    }

    #[test]
    fn hierarchical_repart_cost_never_exceeds_flat() {
        use crate::sim::network::NetworkProfile;
        let net = NetworkProfile::cpu_cluster();
        for workers in [2usize, 4, 8] {
            for t in [
                Topology::two_level_of(&net, workers),
                Topology::three_level_of(&net, workers),
            ] {
                for (dx, dz, b) in [
                    (vec![4, 1], vec![2, 4], vec![8, 8]),
                    (vec![1, 8], vec![8, 1], vec![16, 16]),
                    (vec![2, 2], vec![4, 4], vec![8, 8]),
                ] {
                    let flat = cost_repart(&dx, &dz, &b);
                    let hier = cost_repart_on(Some(&t), &dx, &dz, &b);
                    assert!(
                        hier <= flat + 1e-9,
                        "{}: {hier} > {flat} for {dx:?}<-{dz:?}",
                        t.name()
                    );
                    assert!(hier >= 0.0);
                }
            }
        }
    }

    #[test]
    fn collective_formulas_match_textbook_counts() {
        // ring all-gather / reduce-scatter of n floats over p: (p-1)/p * n
        assert_eq!(cost_ring_collective(1024.0, 8), 896.0);
        assert_eq!(cost_ring_collective(1024.0, 2), 512.0);
        assert_eq!(cost_ring_collective(1024.0, 1), 0.0);
        // ring all-reduce: reduce-scatter + all-gather
        assert_eq!(cost_ring_allreduce(1024.0, 8), 1792.0);
        // step counts
        assert_eq!(ring_steps(8), 7);
        assert_eq!(ring_steps(1), 0);
        assert_eq!(tree_depth(8, 2), 3);
        assert_eq!(tree_depth(16, 4), 2);
        assert_eq!(tree_depth(1, 2), 0);
        assert_eq!(tree_depth(9, 2), 4);
    }

    #[test]
    fn more_parallelism_more_join_cost() {
        // Sanity: for fixed work, higher N raises the join bound.
        let op = matmul();
        let b: &[usize] = &[64, 64];
        let c4 = cost_join(&op, &[b, b], &[2, 1, 2]).unwrap();
        let c16 = cost_join(&op, &[b, b], &[4, 1, 4]).unwrap();
        assert!(c16 > c4);
    }
}

//! Baseline decomposition strategies (paper §9): the bespoke schemes
//! EinDecomp is compared against. Each produces a full [`Plan`] over the
//! same EinGraph, so every comparison isolates the *decomposition* — the
//! paper's own methodology for its Experiment 3 ("all three of these
//! methods were implemented on top of Einsummable").
//!
//! Strategies assign partitionings by label *role* (batch / sequence /
//! head / hidden / feature); model builders supply a [`LabelRoles`]
//! describing their graphs.

use super::{plan_graph, Plan, PlanMode, PlannerConfig};
use crate::einsum::expr::EinSum;
use crate::einsum::graph::EinGraph;
use crate::einsum::label::Label;
use crate::error::Result;
use crate::sim::network::Topology;

/// Semantic roles of labels in a model graph, used by role-driven
/// baselines (data parallel = split batch, Megatron = split heads/hidden,
/// sequence = split sequence, ...).
#[derive(Clone, Debug, Default)]
pub struct LabelRoles {
    pub batch: Vec<Label>,
    pub seq: Vec<Label>,
    pub head: Vec<Label>,
    pub hidden: Vec<Label>,
    pub feature: Vec<Label>,
}

impl LabelRoles {
    /// Default name-based roles: `b`→batch, `s`/`s'`→seq, `h`→head,
    /// `f`→hidden, `a`→feature.
    pub fn by_convention() -> Self {
        LabelRoles {
            batch: vec![Label::new("b")],
            seq: vec![Label::new("s"), Label::new("s'")],
            head: vec![Label::new("h")],
            hidden: vec![Label::new("f")],
            feature: vec![Label::new("a")],
        }
    }
}

/// A decomposition strategy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// The paper's algorithm (exact DP on trees, linearized on DAGs).
    EinDecomp,
    /// EinDecomp restricted to the linearized DP (ablation).
    EinDecompLinearized,
    /// Per-vertex local greedy (ablation).
    Greedy,
    /// "SQRT": slice every tensor sqrt(p) x sqrt(p) (paper Experiment 1).
    /// For square matmuls this induces the 3D-algorithm-style co-partition.
    Sqrt,
    /// Classic data parallelism: shard batch labels, replicate weights.
    DataParallel,
    /// Megatron-style tensor/model parallelism: shard heads in attention
    /// and the hidden dimension in feed-forward blocks.
    Megatron,
    /// Shard the sequence dimension (paper's "sequence" baseline).
    Sequence,
    /// Shard attention heads only; sequence elsewhere (paper's
    /// "attention" baseline).
    AttentionHead,
    /// Dask-like fixed chunking: split every dimension into tiles of at
    /// most `chunk` elements, regardless of `p`.
    DaskLike { chunk: usize },
}

impl Strategy {
    pub fn name(&self) -> String {
        match self {
            Strategy::EinDecomp => "eindecomp".into(),
            Strategy::EinDecompLinearized => "eindecomp-lin".into(),
            Strategy::Greedy => "greedy".into(),
            Strategy::Sqrt => "sqrt".into(),
            Strategy::DataParallel => "data-parallel".into(),
            Strategy::Megatron => "megatron".into(),
            Strategy::Sequence => "sequence".into(),
            Strategy::AttentionHead => "attention".into(),
            Strategy::DaskLike { chunk } => format!("dask-chunk{chunk}"),
        }
    }
}

/// Assign a plan for `g` under `strategy` with `p` processors.
pub fn assign(g: &EinGraph, strategy: &Strategy, p: usize, roles: &LabelRoles) -> Result<Plan> {
    assign_on(g, strategy, p, roles, None)
}

/// [`assign`] under a worker [`Topology`]: the EinDecomp-family planners
/// cost repartition edges per link class (discounting moves that stay
/// inside fast groups), and `predicted_cost` is scored on the same
/// topology. Role-driven baselines assign by label role regardless —
/// only their reported cost changes. `None` is exactly [`assign`].
pub fn assign_on(
    g: &EinGraph,
    strategy: &Strategy,
    p: usize,
    roles: &LabelRoles,
    topology: Option<&Topology>,
) -> Result<Plan> {
    match strategy {
        // EinDecomp default: exact DP on trees; on DAGs, a small portfolio
        // — the linearized DP *with* cross-path cost awareness
        // (off_path_cost, strictly better-informed than the paper's §8.4
        // which ignores the black edges of its Fig. 6) AND the local
        // greedy, keeping whichever the full cost model scores lower
        // (greedy's complete producer visibility wins on wide DAGs, the
        // path DP on deep stacks; see the ablation_planner bench). The
        // paper-faithful variant is `EinDecompLinearized`.
        Strategy::EinDecomp => {
            let a = plan_graph(
                g,
                &PlannerConfig {
                    p,
                    mode: PlanMode::Auto,
                    off_path_cost: true,
                    topology: topology.cloned(),
                    ..Default::default()
                },
            )?;
            if g.is_tree_like() {
                Ok(a)
            } else {
                let b = plan_graph(
                    g,
                    &PlannerConfig {
                        p,
                        mode: PlanMode::Greedy,
                        off_path_cost: false,
                        topology: topology.cloned(),
                        ..Default::default()
                    },
                )?;
                let mut best = if b.predicted_cost < a.predicted_cost { b } else { a };
                best.strategy = "eindecomp".into();
                Ok(best)
            }
        }
        Strategy::EinDecompLinearized => plan_graph(
            g,
            &PlannerConfig {
                p,
                mode: PlanMode::Linearized,
                off_path_cost: false,
                topology: topology.cloned(),
                ..Default::default()
            },
        ),
        Strategy::Greedy => plan_graph(
            g,
            &PlannerConfig {
                p,
                mode: PlanMode::Greedy,
                off_path_cost: false,
                topology: topology.cloned(),
                ..Default::default()
            },
        ),
        Strategy::Sqrt => role_plan(g, p, strategy.name(), |_, _| RolePrefs::sqrt()),
        Strategy::DataParallel => role_plan(g, p, strategy.name(), |roles_, _| RolePrefs {
            tiers: vec![roles_.batch.clone(), roles_.seq.clone()],
            fill: Fill::None,
        })
        .map(with_roles(roles)),
        Strategy::Megatron => role_plan(g, p, strategy.name(), |roles_, _| RolePrefs {
            tiers: vec![
                [roles_.head.clone(), roles_.hidden.clone()].concat(),
                [roles_.batch.clone(), roles_.seq.clone()].concat(),
            ],
            fill: Fill::None,
        })
        .map(with_roles(roles)),
        Strategy::Sequence => role_plan(g, p, strategy.name(), |roles_, _| RolePrefs {
            tiers: vec![roles_.seq.clone(), roles_.batch.clone()],
            fill: Fill::None,
        })
        .map(with_roles(roles)),
        Strategy::AttentionHead => role_plan(g, p, strategy.name(), |roles_, _| RolePrefs {
            tiers: vec![
                roles_.head.clone(),
                roles_.seq.clone(),
                roles_.batch.clone(),
            ],
            fill: Fill::None,
        })
        .map(with_roles(roles)),
        Strategy::DaskLike { chunk } => dask_plan(g, *chunk),
    }
    .map(|mut plan| {
        plan.finalize_inputs(g);
        plan.predicted_cost = plan.total_cost_on(g, topology).unwrap_or(f64::NAN);
        plan
    })
}

// role_plan's closure receives roles captured separately; this adapter is
// a no-op that keeps the closure signatures simple.
fn with_roles(_roles: &LabelRoles) -> impl Fn(Plan) -> Plan + '_ {
    |p| p
}

/// How a role strategy picks labels to split.
struct RolePrefs {
    /// Priority tiers of labels: split tier 0's labels as far as possible,
    /// then tier 1's, etc.
    tiers: Vec<Vec<Label>>,
    fill: Fill,
}

/// What to do if the preferred labels cannot absorb all of `p`.
enum Fill {
    /// Leave the vertex under-parallelized (classic data parallel with a
    /// small batch really does idle processors).
    None,
    /// Split remaining output labels, largest remaining tile first (SQRT).
    OutputLabels,
}

impl RolePrefs {
    fn sqrt() -> Self {
        RolePrefs {
            tiers: vec![],
            fill: Fill::OutputLabels,
        }
    }
}

/// Build a plan by assigning each vertex independently according to label
/// preferences. The co-partitioning constraint is automatic because `d`
/// is stored over unique labels.
fn role_plan(
    g: &EinGraph,
    p: usize,
    name: String,
    prefs_for: impl Fn(&LabelRoles, &EinSum) -> RolePrefs,
) -> Result<Plan> {
    let roles = LabelRoles::by_convention();
    let mut plan = Plan {
        strategy: name,
        ..Default::default()
    };
    for vert in g.vertices() {
        if matches!(vert.op, EinSum::Input) {
            continue;
        }
        let op = &vert.op;
        let in_bounds: Vec<&[usize]> = vert
            .inputs
            .iter()
            .map(|&i| g.vertex(i).bound.as_slice())
            .collect();
        let ubounds = super::viable::unique_label_bounds(op, &in_bounds);
        let uniq = op.unique_labels();
        let prefs = prefs_for(&roles, op);
        let mut d = vec![1usize; uniq.len()];
        let mut remaining = p.next_power_of_two();

        // split preference tiers in order
        for tier in &prefs.tiers {
            for (i, l) in uniq.iter().enumerate() {
                if !tier.contains(l) {
                    continue;
                }
                while remaining > 1 && d[i] * 2 <= ubounds[i] {
                    d[i] *= 2;
                    remaining /= 2;
                }
            }
            if remaining == 1 {
                break;
            }
        }
        // fill policy
        if remaining > 1 {
            match prefs.fill {
                Fill::None => {}
                Fill::OutputLabels => {
                    // SQRT semantics: slice the *output* sqrt(p) x sqrt(p)
                    // (and co-partition whatever that implies on inputs).
                    // Repeatedly halve the output label with the largest
                    // current tile.
                    let lz = op.lz().unwrap().clone();
                    while remaining > 1 {
                        let mut best: Option<(usize, f64)> = None;
                        for (i, l) in uniq.iter().enumerate() {
                            if !lz.contains(l) || d[i] * 2 > ubounds[i] {
                                continue;
                            }
                            let tile = ubounds[i] as f64 / d[i] as f64;
                            if best.map_or(true, |(_, t)| tile > t) {
                                best = Some((i, tile));
                            }
                        }
                        match best {
                            Some((i, _)) => {
                                d[i] *= 2;
                                remaining /= 2;
                            }
                            None => break,
                        }
                    }
                }
            }
        }
        plan.parts.insert(vert.id, d);
    }
    Ok(plan)
}

/// Dask-like chunking: split every unique label so tiles are at most
/// `chunk` long per dimension (power-of-two splits).
fn dask_plan(g: &EinGraph, chunk: usize) -> Result<Plan> {
    let mut plan = Plan {
        strategy: format!("dask-chunk{chunk}"),
        ..Default::default()
    };
    for vert in g.vertices() {
        if matches!(vert.op, EinSum::Input) {
            continue;
        }
        let op = &vert.op;
        let in_bounds: Vec<&[usize]> = vert
            .inputs
            .iter()
            .map(|&i| g.vertex(i).bound.as_slice())
            .collect();
        let ubounds = super::viable::unique_label_bounds(op, &in_bounds);
        let d: Vec<usize> = ubounds
            .iter()
            .map(|&b| {
                let mut parts = 1usize;
                while b.div_ceil(parts) > chunk && parts * 2 <= b {
                    parts *= 2;
                }
                parts
            })
            .collect();
        plan.parts.insert(vert.id, d);
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::expr::JoinOp;
    use crate::einsum::label::labels;

    fn matmul_graph(m: usize, k: usize, n: usize) -> EinGraph {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![m, k]);
        let b = g.input("B", vec![k, n]);
        g.add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
        g
    }

    #[test]
    fn sqrt_splits_output_square() {
        let g = matmul_graph(64, 64, 64);
        let plan = assign(&g, &Strategy::Sqrt, 16, &LabelRoles::by_convention()).unwrap();
        let z = g.by_name("Z").unwrap();
        let d = &plan.parts[&z];
        // output labels i, k split 4x4; join label j untouched
        assert_eq!(d, &vec![4, 1, 4]);
    }

    #[test]
    fn sqrt_does_not_adapt_to_skew() {
        // skewed matmul: the paper's point is SQRT still slices square.
        let g = matmul_graph(1024, 8, 1024);
        let sqrt = assign(&g, &Strategy::Sqrt, 16, &LabelRoles::by_convention()).unwrap();
        let ein = assign(&g, &Strategy::EinDecomp, 16, &LabelRoles::by_convention()).unwrap();
        assert!(
            ein.predicted_cost <= sqrt.predicted_cost + 1e-6,
            "eindecomp {} vs sqrt {}",
            ein.predicted_cost,
            sqrt.predicted_cost
        );
    }

    #[test]
    fn data_parallel_splits_batch_only() {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![64, 32]); // [b, f_in]
        let w = g.input("W", vec![32, 16]);
        let b_lab = Label::new("b");
        let f = Label::new("j");
        let n = Label::new("k");
        g.add(
            "H",
            EinSum::contraction(vec![b_lab, f], vec![f, n], vec![b_lab, n]),
            vec![x, w],
        )
        .unwrap();
        let plan = assign(&g, &Strategy::DataParallel, 8, &LabelRoles::by_convention()).unwrap();
        let h = g.by_name("H").unwrap();
        let d = &plan.parts[&h];
        // unique labels [b, j, k]: batch split 8, weights untouched
        assert_eq!(d, &vec![8, 1, 1]);
    }

    #[test]
    fn all_strategies_produce_valid_plans() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![32, 32]);
        let b = g.input("B", vec![32, 32]);
        let ab = g
            .add(
                "AB",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let c = g.input("C", vec![32, 32]);
        g.add(
            "Z",
            EinSum::elementwise(labels("i k"), labels("i k"), JoinOp::Add),
            vec![ab, c],
        )
        .unwrap();
        let roles = LabelRoles::by_convention();
        for s in [
            Strategy::EinDecomp,
            Strategy::EinDecompLinearized,
            Strategy::Greedy,
            Strategy::Sqrt,
            Strategy::DataParallel,
            Strategy::Sequence,
            Strategy::Megatron,
            Strategy::AttentionHead,
            Strategy::DaskLike { chunk: 8 },
        ] {
            let plan = assign(&g, &s, 4, &roles).unwrap();
            assert_eq!(plan.parts.len(), 2, "{}", s.name());
            assert!(plan.predicted_cost.is_finite(), "{}", s.name());
        }
    }

    #[test]
    fn dask_chunking_ignores_p() {
        let g = matmul_graph(64, 64, 64);
        let plan = assign(
            &g,
            &Strategy::DaskLike { chunk: 16 },
            4,
            &LabelRoles::by_convention(),
        )
        .unwrap();
        let z = g.by_name("Z").unwrap();
        // every label split 64/16 = 4 ways regardless of p=4
        assert_eq!(plan.parts[&z], vec![4, 4, 4]);
    }
}

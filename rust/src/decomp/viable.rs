//! Enumerating viable partitioning vectors (paper §6 and §8.1).
//!
//! A partitioning vector `d` (stored parallel to the EinSum's unique
//! labels, which bakes in the co-partitioning of repeated labels) is
//! *viable* for processor count `p` iff every entry is a power of two and
//! the number of join result tuples
//! `N(l_X, l_Y, d) = prod d[l_X (.) l_Y]` equals exactly `p` — ensuring
//! `p` independent kernel calls, no more (movement) and no fewer
//! (idle processors).
//!
//! Because every entry is a power of two, enumeration is stars-and-bars:
//! place `log2(p)` balls into `D` buckets (§8.1: `(N+D-1)! / (N!(D-1)!)`
//! possibilities). Entries are additionally capped by the dimension bound
//! so no tile is empty — a practical constraint the paper leaves implicit.

use crate::einsum::expr::EinSum;
use crate::einsum::label::project;
use crate::error::{Error, Result};

/// Number of unconstrained partitionings: `C(n_balls + buckets - 1,
/// buckets - 1)` — the paper's counting formula (§8.1).
pub fn count_partitionings(n_balls: u32, buckets: u32) -> u128 {
    if buckets == 0 {
        return u128::from(n_balls == 0);
    }
    // C(n + b - 1, b - 1)
    binomial(u128::from(n_balls + buckets - 1), u128::from(buckets - 1))
}

fn binomial(n: u128, k: u128) -> u128 {
    let k = k.min(n - k);
    let mut acc: u128 = 1;
    for i in 0..k {
        acc = acc * (n - i) / (i + 1);
    }
    acc
}

/// Round `p` up to the next power of two (§8.1: "If the actual number of
/// processors is not a power of two, p can be chosen to be larger").
pub fn pow2_at_least(p: usize) -> usize {
    p.next_power_of_two()
}

/// Enumerate all viable partitioning vectors for an EinSum expression.
///
/// * `op` — the expression; `d` is parallel to `op.unique_labels()`.
/// * `bounds` — the bound of each unique label (callers derive it from the
///   operand bounds).
/// * `p` — target kernel calls; must be a power of two (use
///   [`pow2_at_least`]).
///
/// Returns vectors `d` with `prod(d) == p` and `d[i] <= bounds[i]`.
pub fn viable(op: &EinSum, bounds: &[usize], p: usize) -> Result<Vec<Vec<usize>>> {
    let uniq = op.unique_labels();
    if bounds.len() != uniq.len() {
        return Err(Error::InvalidPartitioning(format!(
            "bounds {bounds:?} not parallel to unique labels {uniq:?}"
        )));
    }
    if !p.is_power_of_two() {
        return Err(Error::InvalidPartitioning(format!(
            "p={p} must be a power of two (see pow2_at_least)"
        )));
    }
    let n_balls = p.trailing_zeros();
    let mut out = Vec::new();
    let mut cur = vec![1usize; uniq.len()];
    distribute(n_balls, 0, bounds, &mut cur, &mut out);
    if out.is_empty() {
        return Err(Error::NoViablePlan(format!(
            "no power-of-two partitioning of {bounds:?} yields {p} kernel calls"
        )));
    }
    Ok(out)
}

/// Recursively place `balls` doublings into buckets `from..`.
fn distribute(
    balls: u32,
    from: usize,
    bounds: &[usize],
    cur: &mut Vec<usize>,
    out: &mut Vec<Vec<usize>>,
) {
    if balls == 0 {
        out.push(cur.clone());
        return;
    }
    if from >= cur.len() {
        return;
    }
    // number of balls this bucket can absorb without exceeding its bound
    let mut max_here = 0u32;
    while (cur[from] << (max_here + 1)) <= bounds[from] && max_here + 1 <= balls {
        max_here += 1;
    }
    for b in 0..=max_here {
        cur[from] <<= b;
        distribute(balls - b, from + 1, bounds, cur, out);
        cur[from] >>= b;
    }
}

/// Bounds of the unique labels of `op`, derived from the operand bounds.
pub fn unique_label_bounds(op: &EinSum, in_bounds: &[&[usize]]) -> Vec<usize> {
    let uniq = op.unique_labels();
    let lxy = op.lxy();
    let bxy = op.bxy(in_bounds);
    project(&bxy, &uniq, &lxy)
}

/// The set of distinct output partitionings `d_Z` reachable from a list of
/// viable `d` vectors (used to size the DP table).
pub fn output_partitionings(op: &EinSum, ds: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let uniq = op.unique_labels();
    let lz = op.lz().expect("not an input");
    let mut out: Vec<Vec<usize>> = Vec::new();
    for d in ds {
        let dz = project(d, lz, &uniq);
        if !out.contains(&dz) {
            out.push(dz);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;

    #[test]
    fn counting_matches_paper() {
        // §8.1: N=10 balls, D=6 buckets -> 3003 partitionings.
        assert_eq!(count_partitionings(10, 6), 3003);
        assert_eq!(count_partitionings(0, 4), 1);
        assert_eq!(count_partitionings(3, 1), 1);
    }

    #[test]
    fn pow2_rounding() {
        assert_eq!(pow2_at_least(8), 8);
        assert_eq!(pow2_at_least(12), 16);
        assert_eq!(pow2_at_least(1), 1);
    }

    #[test]
    fn matmul_p8_matches_paper_enumeration() {
        // §8.2 lists 8 partitioning vectors for the 8x8 matmul at p=8, but
        // the complete stars-and-bars enumeration (3 balls, 3 buckets) has
        // C(5,2) = 10 — the paper's own §8.1 formula. The two the paper's
        // list omits are [2,4,1] and [1,4,2] (d_j = 4). We enumerate all 10.
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        let ds = viable(&op, &[8, 8, 8], 8).unwrap();
        assert_eq!(ds.len(), 10);
        assert!(ds.contains(&vec![2, 4, 1]));
        for d in &ds {
            assert_eq!(d.iter().product::<usize>(), 8);
        }
        assert!(ds.contains(&vec![2, 2, 2]));
        assert!(ds.contains(&vec![1, 8, 1]));
        assert!(ds.contains(&vec![8, 1, 1]));
    }

    #[test]
    fn paper_output_partitionings_for_p8() {
        // §8.2 lists the d_Z values [2,4];[4,2];[8,1];[1,8];[2,2];[4,1];
        // [1,4];[1,1] — all of which must be reachable. The complete
        // enumeration also reaches [2,1] and [1,2] (via the two d vectors
        // the paper's list omits; see matmul_p8_matches_paper_enumeration).
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        let ds = viable(&op, &[8, 8, 8], 8).unwrap();
        let dzs = output_partitionings(&op, &ds);
        let want: Vec<Vec<usize>> = vec![
            vec![2, 4],
            vec![4, 2],
            vec![8, 1],
            vec![1, 8],
            vec![2, 2],
            vec![4, 1],
            vec![1, 4],
            vec![1, 1],
        ];
        for w in want {
            assert!(dzs.contains(&w), "missing {w:?}");
        }
        assert_eq!(dzs.len(), 10);
    }

    #[test]
    fn bounds_cap_enumeration() {
        // a 4x4 matmul cannot split any dim more than 4 ways
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        let ds = viable(&op, &[4, 4, 4], 16).unwrap();
        for d in &ds {
            assert!(d.iter().all(|&x| x <= 4));
            assert_eq!(d.iter().product::<usize>(), 16);
        }
        // p=256 impossible on 4x4x4 (max 4*4*4=64)
        assert!(viable(&op, &[4, 4, 4], 256).is_err());
    }

    #[test]
    fn stars_and_bars_count_without_bounds() {
        // With generous bounds the enumeration size equals the formula.
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        let ds = viable(&op, &[1 << 20, 1 << 20, 1 << 20], 1 << 10).unwrap();
        assert_eq!(ds.len() as u128, count_partitionings(10, 3));
    }

    #[test]
    fn unary_viable() {
        let op = EinSum::reduce(labels("i j"), labels("i"), crate::einsum::expr::AggOp::Sum);
        let ds = viable(&op, &[16, 16], 4).unwrap();
        // [1,4],[2,2],[4,1]
        assert_eq!(ds.len(), 3);
    }

    #[test]
    fn unique_bounds_derivation() {
        let op = EinSum::contraction(labels("i j b"), labels("j b k"), labels("i k"));
        let b = unique_label_bounds(&op, &[&[10, 100, 20], &[100, 20, 2000]]);
        // unique labels [i, j, b, k]
        assert_eq!(b, vec![10, 100, 20, 2000]);
    }

    #[test]
    fn p_must_be_pow2() {
        let op = EinSum::contraction(labels("i j"), labels("j k"), labels("i k"));
        assert!(viable(&op, &[8, 8, 8], 6).is_err());
    }
}

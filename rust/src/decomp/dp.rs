//! The EinDecomp dynamic program (paper §8.2–8.3) for tree-like graphs,
//! plus a per-vertex greedy planner used as an ablation baseline.
//!
//! The DP maintains `M[v, d_Z]` — the optimal cost of computing the
//! subgraph up to `v` with output partitioning `d_Z` — filling the table
//! in topological order and backtracking from the cheapest entry of the
//! output vertex.

use super::cost::{cost_repart_on, vertex_cost};
use super::viable::{pow2_at_least, unique_label_bounds, viable};
use super::{Plan, PlannerConfig};
use crate::einsum::expr::EinSum;
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::project;
use crate::error::{Error, Result};
use crate::sim::network::Topology;
use std::collections::HashMap;

/// One DP table row: output partitioning -> (cost, chosen d, chosen child
/// output partitionings).
type Row = HashMap<Vec<usize>, (f64, Vec<usize>, Vec<Vec<usize>>)>;

/// Enumerate viable partitionings, halving `p` until the bounds admit at
/// least one (small tensors cannot always feed `p` kernels; the paper
/// assumes they can).
pub fn viable_or_relaxed(
    op: &EinSum,
    bounds: &[usize],
    p: usize,
) -> Result<(usize, Vec<Vec<usize>>)> {
    let mut q = pow2_at_least(p);
    loop {
        match viable(op, bounds, q) {
            Ok(ds) => return Ok((q, ds)),
            Err(_) if q > 1 => q /= 2,
            Err(e) => return Err(e),
        }
    }
}

/// Cheapest way to obtain child `c`'s output in partitioning `need`:
/// `min_dc M[c][dc] + cost_repart(need, dc, bound_c)`. Inputs are free.
fn child_cost(
    g: &EinGraph,
    tables: &HashMap<VertexId, Row>,
    c: VertexId,
    need: &[usize],
    topo: Option<&Topology>,
) -> Result<(f64, Vec<usize>)> {
    let cv = g.vertex(c);
    if matches!(cv.op, EinSum::Input) {
        // pre-partitioned offline at no cost, in exactly the needed layout
        return Ok((0.0, need.to_vec()));
    }
    let row = tables
        .get(&c)
        .ok_or_else(|| Error::NoViablePlan(format!("child {} has no DP row", cv.name)))?;
    let mut best: Option<(f64, Vec<usize>)> = None;
    for (dc, (mc, _, _)) in row {
        let total = mc + cost_repart_on(topo, need, dc, &cv.bound);
        if best.as_ref().map_or(true, |(b, _)| total < *b) {
            best = Some((total, dc.clone()));
        }
    }
    best.ok_or_else(|| Error::NoViablePlan(format!("empty DP row for {}", cv.name)))
}

/// Fill the DP row for one vertex given completed child rows.
fn fill_row(
    g: &EinGraph,
    tables: &HashMap<VertexId, Row>,
    v: VertexId,
    p: usize,
    topo: Option<&Topology>,
) -> Result<Row> {
    let vert = g.vertex(v);
    let op = &vert.op;
    let in_bounds: Vec<&[usize]> = vert
        .inputs
        .iter()
        .map(|&i| g.vertex(i).bound.as_slice())
        .collect();
    let ubounds = unique_label_bounds(op, &in_bounds);
    let (_, ds) = viable_or_relaxed(op, &ubounds, p)?;
    let uniq = op.unique_labels();
    let lz = op.lz().unwrap();
    let mut row: Row = HashMap::new();
    for d in ds {
        let mut total = vertex_cost(op, &in_bounds, &d)?;
        let mut chosen_children = Vec::with_capacity(vert.inputs.len());
        let mut feasible = true;
        for (o, &c) in vert.inputs.iter().enumerate() {
            let need = project(&d, op.operand_labels()[o], &uniq);
            match child_cost(g, tables, c, &need, topo) {
                Ok((cc, dc)) => {
                    total += cc;
                    chosen_children.push(dc);
                }
                Err(_) => {
                    feasible = false;
                    break;
                }
            }
        }
        if !feasible {
            continue;
        }
        let dz = project(&d, lz, &uniq);
        let entry = row.entry(dz).or_insert((f64::INFINITY, vec![], vec![]));
        if total < entry.0 {
            *entry = (total, d, chosen_children);
        }
    }
    if row.is_empty() {
        return Err(Error::NoViablePlan(format!(
            "no feasible partitioning for vertex {}",
            vert.name
        )));
    }
    Ok(row)
}

/// Exact DP over a tree-like EinGraph (§8.2). Errors if some non-input
/// vertex output has multiple consumers.
pub fn plan_exact_tree(g: &EinGraph, cfg: &PlannerConfig) -> Result<Plan> {
    if !g.is_tree_like() {
        return Err(Error::InvalidGraph(
            "graph is not tree-like; use Linearized mode (§8.4)".into(),
        ));
    }
    let p = pow2_at_least(cfg.p);
    let mut tables: HashMap<VertexId, Row> = HashMap::new();
    for v in g.topo_order() {
        if matches!(g.vertex(v).op, EinSum::Input) {
            continue;
        }
        let row = fill_row(g, &tables, v, p, cfg.topology.as_ref())?;
        tables.insert(v, row);
    }
    // Backtrack from each output's cheapest entry.
    let mut plan = Plan {
        strategy: "eindecomp-exact".into(),
        ..Default::default()
    };
    let mut stack: Vec<(VertexId, Vec<usize>)> = Vec::new();
    for out in g.outputs() {
        if matches!(g.vertex(out).op, EinSum::Input) {
            continue;
        }
        let row = &tables[&out];
        let (dz, _) = row
            .iter()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .ok_or_else(|| Error::NoViablePlan("empty output row".into()))?;
        stack.push((out, dz.clone()));
    }
    while let Some((v, dz)) = stack.pop() {
        let (_, d, children) = tables[&v][&dz].clone();
        plan.parts.insert(v, d);
        let vert = g.vertex(v);
        for (o, &c) in vert.inputs.iter().enumerate() {
            if !matches!(g.vertex(c).op, EinSum::Input) {
                stack.push((c, children[o].clone()));
            }
        }
    }
    Ok(plan)
}

/// Greedy ablation: visit vertices in topological order, choosing for each
/// the `d` minimizing its local join+agg cost plus repartition from the
/// already-fixed producers. No lookahead — quantifies the value of the DP.
pub fn plan_greedy(g: &EinGraph, cfg: &PlannerConfig) -> Result<Plan> {
    let p = pow2_at_least(cfg.p);
    let mut plan = Plan {
        strategy: "greedy".into(),
        ..Default::default()
    };
    // fixed output partitioning per vertex
    let mut fixed: HashMap<VertexId, Vec<usize>> = HashMap::new();
    for v in g.topo_order() {
        let vert = g.vertex(v);
        if matches!(vert.op, EinSum::Input) {
            continue;
        }
        let op = &vert.op;
        let in_bounds: Vec<&[usize]> = vert
            .inputs
            .iter()
            .map(|&i| g.vertex(i).bound.as_slice())
            .collect();
        let ubounds = unique_label_bounds(op, &in_bounds);
        let (_, ds) = viable_or_relaxed(op, &ubounds, p)?;
        let uniq = op.unique_labels();
        let lz = op.lz().unwrap();
        let mut best: Option<(f64, Vec<usize>)> = None;
        for d in ds {
            let mut total = vertex_cost(op, &in_bounds, &d)?;
            for (o, &c) in vert.inputs.iter().enumerate() {
                let need = project(&d, op.operand_labels()[o], &uniq);
                if let Some(have) = fixed.get(&c) {
                    total += cost_repart_on(cfg.topology.as_ref(), &need, have, &g.vertex(c).bound);
                }
                // inputs: free
            }
            if best.as_ref().map_or(true, |(b, _)| total < *b) {
                best = Some((total, d));
            }
        }
        let (_, d) = best
            .ok_or_else(|| Error::NoViablePlan(format!("greedy: no d for {}", vert.name)))?;
        fixed.insert(v, project(&d, lz, &uniq));
        plan.parts.insert(v, d);
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::expr::JoinOp;
    use crate::einsum::label::labels;

    fn matmul_graph(s: usize) -> EinGraph {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        g.add(
            "Z",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![a, b],
        )
        .unwrap();
        g
    }

    fn chain_graph(s: usize) -> EinGraph {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![s, s]);
        let b = g.input("B", vec![s, s]);
        let c = g.input("C", vec![s, s]);
        let d = g.input("D", vec![s, s]);
        let e = g.input("E", vec![s, s]);
        let ab = g
            .add(
                "AB",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let de = g
            .add(
                "DE",
                EinSum::contraction(labels("j k"), labels("k m"), labels("j m")),
                vec![d, e],
            )
            .unwrap();
        let cde = g
            .add(
                "CDE",
                EinSum::contraction(labels("i j"), labels("j m"), labels("i m")),
                vec![c, de],
            )
            .unwrap();
        g.add(
            "Z",
            EinSum::elementwise(labels("i k"), labels("i k"), JoinOp::Add),
            vec![ab, cde],
        )
        .unwrap();
        g
    }

    #[test]
    fn single_matmul_plans() {
        let g = matmul_graph(64);
        let cfg = PlannerConfig {
            p: 16,
            ..Default::default()
        };
        let mut plan = plan_exact_tree(&g, &cfg).unwrap();
        plan.finalize_inputs(&g);
        let z = g.by_name("Z").unwrap();
        let d = &plan.parts[&z];
        assert_eq!(d.iter().product::<usize>(), 16);
        // DP is optimal by construction: verify against brute force over
        // all viable vectors. (Interestingly the optimum here *does* split
        // j — a 2.5D-style [4,2,2] beats the aggregation-free [4,1,4]
        // under the paper's cost model.)
        let dp_cost = plan.total_cost(&g).unwrap();
        let op = &g.vertex(z).op;
        let mut best = f64::INFINITY;
        for cand in viable(op, &[64, 64, 64], 16).unwrap() {
            let mut p2 = Plan::default();
            p2.parts.insert(z, cand);
            p2.finalize_inputs(&g);
            best = best.min(p2.total_cost(&g).unwrap());
        }
        assert!((dp_cost - best).abs() < 1e-9, "dp {dp_cost} vs brute {best}");
    }

    #[test]
    fn chain_plans_and_costs() {
        let g = chain_graph(64);
        let cfg = PlannerConfig {
            p: 8,
            ..Default::default()
        };
        let mut plan = plan_exact_tree(&g, &cfg).unwrap();
        plan.finalize_inputs(&g);
        let cost_dp = plan.total_cost(&g).unwrap();
        let mut greedy = plan_greedy(&g, &cfg).unwrap();
        greedy.finalize_inputs(&g);
        let cost_greedy = greedy.total_cost(&g).unwrap();
        assert!(
            cost_dp <= cost_greedy + 1e-6,
            "DP ({cost_dp}) must not lose to greedy ({cost_greedy})"
        );
        // all four compute vertices assigned
        assert_eq!(plan.parts.len(), 4);
    }

    #[test]
    fn dp_optimal_vs_bruteforce_small() {
        // Exhaustively verify optimality on a 2-op chain at p=4.
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let c = g.input("C", vec![16, 16]);
        let ab = g
            .add(
                "AB",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        g.add(
            "ABC",
            EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
            vec![ab, c],
        )
        .unwrap();
        let cfg = PlannerConfig {
            p: 4,
            ..Default::default()
        };
        let plan = super::plan_exact_tree(&g, &cfg).unwrap();
        let mut plan = plan;
        plan.finalize_inputs(&g);
        let dp_cost = plan.total_cost(&g).unwrap();

        // brute force over all (d1, d2) pairs
        let v1 = g.by_name("AB").unwrap();
        let v2 = g.by_name("ABC").unwrap();
        let op1 = &g.vertex(v1).op;
        let op2 = &g.vertex(v2).op;
        let ds1 = viable(op1, &[16, 16, 16], 4).unwrap();
        let ds2 = viable(op2, &[16, 16, 16], 4).unwrap();
        let mut best = f64::INFINITY;
        for d1 in &ds1 {
            for d2 in &ds2 {
                let mut p = Plan::default();
                p.parts.insert(v1, d1.clone());
                p.parts.insert(v2, d2.clone());
                p.finalize_inputs(&g);
                let c = p.total_cost(&g).unwrap();
                if c < best {
                    best = c;
                }
            }
        }
        assert!(
            (dp_cost - best).abs() < 1e-6,
            "DP {dp_cost} != brute force {best}"
        );
    }

    #[test]
    fn non_tree_rejected_by_exact() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![8, 8]);
        let sq = g
            .add(
                "sq",
                EinSum::map(labels("i j"), crate::einsum::expr::UnaryOp::Square),
                vec![a],
            )
            .unwrap();
        g.add(
            "z1",
            EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
            vec![sq, sq],
        )
        .unwrap();
        // sq consumed twice
        let cfg = PlannerConfig::default();
        assert!(plan_exact_tree(&g, &cfg).is_err());
    }

    #[test]
    fn small_bounds_relax_p() {
        // 2x2 matmul cannot produce 64 kernel calls; planner relaxes.
        let g = matmul_graph(2);
        let cfg = PlannerConfig {
            p: 64,
            ..Default::default()
        };
        let plan = plan_exact_tree(&g, &cfg).unwrap();
        let z = g.by_name("Z").unwrap();
        assert!(plan.parts[&z].iter().product::<usize>() <= 8);
    }
}

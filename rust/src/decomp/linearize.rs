//! Linearized DP for general DAGs (paper §8.4, Figure 6).
//!
//! The exact DP of §8.2 breaks when a vertex output has multiple
//! consumers. EinDecomp therefore decomposes the DAG into node-disjoint
//! paths (longest first) and runs the chain DP along each path,
//! ignoring the cost of inputs that do not come from the path.
//! Already-fixed off-path inputs can optionally be charged their
//! repartition cost (`PlannerConfig::off_path_cost`) — a strictly better
//! approximation than the paper's, evaluated as an ablation.

use super::cost::{cost_repart_on, vertex_cost};
use super::dp::viable_or_relaxed;
use super::viable::{pow2_at_least, unique_label_bounds};
use super::{Plan, PlannerConfig};
use crate::einsum::expr::EinSum;
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::project;
use crate::error::{Error, Result};
use std::collections::HashMap;

/// Per-path DP row: output partitioning -> (cost, d, prev-vertex dz).
type Row = HashMap<Vec<usize>, (f64, Vec<usize>, Option<Vec<usize>>)>;

pub fn plan_linearized(g: &EinGraph, cfg: &PlannerConfig) -> Result<Plan> {
    let p = pow2_at_least(cfg.p);
    let topo = cfg.topology.as_ref();
    let mut plan = Plan {
        strategy: if cfg.off_path_cost {
            "eindecomp-linearized+offpath".into()
        } else {
            "eindecomp-linearized".into()
        },
        ..Default::default()
    };
    // fixed (already labeled) vertices: output partitioning + full d
    let mut fixed_dz: HashMap<VertexId, Vec<usize>> = HashMap::new();
    let mut fixed_d: HashMap<VertexId, Vec<usize>> = HashMap::new();
    let consumers = g.consumers();

    for path in g.linear_paths() {
        // rows[i]: DP table for path[i]
        let mut rows: Vec<Row> = Vec::with_capacity(path.len());
        for (pi, &v) in path.iter().enumerate() {
            let vert = g.vertex(v);
            let op = &vert.op;
            let in_bounds: Vec<&[usize]> = vert
                .inputs
                .iter()
                .map(|&i| g.vertex(i).bound.as_slice())
                .collect();
            let ubounds = unique_label_bounds(op, &in_bounds);
            let (_, ds) = viable_or_relaxed(op, &ubounds, p)?;
            let uniq = op.unique_labels();
            let lz = op.lz().unwrap();
            let prev = if pi > 0 { Some(path[pi - 1]) } else { None };
            let mut row: Row = HashMap::new();
            for d in ds {
                let mut total = vertex_cost(op, &in_bounds, &d)?;
                let mut prev_choice: Option<Vec<usize>> = None;
                let mut feasible = true;
                for (o, &c) in vert.inputs.iter().enumerate() {
                    let need = project(&d, op.operand_labels()[o], &uniq);
                    if Some(c) == prev {
                        // on-path input: consult previous row
                        let prow = rows.last().unwrap();
                        let mut best: Option<(f64, Vec<usize>)> = None;
                        for (dzc, (mc, _, _)) in prow {
                            let t = mc + cost_repart_on(topo, &need, dzc, &g.vertex(c).bound);
                            if best.as_ref().map_or(true, |(b, _)| t < *b) {
                                best = Some((t, dzc.clone()));
                            }
                        }
                        match best {
                            Some((t, dzc)) => {
                                total += t;
                                prev_choice = Some(dzc);
                            }
                            None => {
                                feasible = false;
                                break;
                            }
                        }
                    } else if matches!(g.vertex(c).op, EinSum::Input) {
                        // free, pre-partitioned
                    } else if cfg.off_path_cost {
                        if let Some(have) = fixed_dz.get(&c) {
                            total += cost_repart_on(topo, &need, have, &g.vertex(c).bound);
                        }
                        // not yet fixed: paper ignores (0)
                    }
                }
                if !feasible {
                    continue;
                }
                let dz = project(&d, lz, &uniq);
                // Consumer-aware refinement (beyond the paper, gated on
                // the same flag): if a consumer of v was fixed by an
                // earlier path, our dz choice determines a repartition on
                // that cross-path ("black", Fig. 6) edge — charge it.
                if cfg.off_path_cost {
                    for &cons in &consumers[v.0] {
                        if let Some(dc) = fixed_d.get(&cons) {
                            let cvert = g.vertex(cons);
                            let cuniq = cvert.op.unique_labels();
                            for (o, &inp) in cvert.inputs.iter().enumerate() {
                                if inp == v {
                                    let need = project(
                                        dc,
                                        cvert.op.operand_labels()[o],
                                        &cuniq,
                                    );
                                    total += cost_repart_on(topo, &need, &dz, &vert.bound);
                                }
                            }
                        }
                    }
                }
                let entry = row.entry(dz).or_insert((f64::INFINITY, vec![], None));
                if total < entry.0 {
                    *entry = (total, d, prev_choice);
                }
            }
            if row.is_empty() {
                return Err(Error::NoViablePlan(format!(
                    "linearized: no feasible d for {}",
                    vert.name
                )));
            }
            rows.push(row);
        }
        // backtrack along the path
        let last = rows.len() - 1;
        let (mut dz, _) = rows[last]
            .iter()
            .min_by(|a, b| a.1 .0.partial_cmp(&b.1 .0).unwrap())
            .map(|(k, v)| (k.clone(), v.0))
            .ok_or_else(|| Error::NoViablePlan("empty path row".into()))?;
        for pi in (0..path.len()).rev() {
            let (_, d, prev_choice) = rows[pi][&dz].clone();
            plan.parts.insert(path[pi], d.clone());
            fixed_dz.insert(path[pi], dz.clone());
            fixed_d.insert(path[pi], d);
            match prev_choice {
                Some(pc) => dz = pc,
                None => break,
            }
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::dp::plan_exact_tree;
    use crate::einsum::expr::{EinSum, JoinOp, UnaryOp};
    use crate::einsum::label::labels;

    /// Diamond DAG: X consumed by two branches that later merge.
    fn diamond() -> EinGraph {
        let mut g = EinGraph::new();
        let x = g.input("X", vec![32, 32]);
        let w1 = g.input("W1", vec![32, 32]);
        let w2 = g.input("W2", vec![32, 32]);
        let h = g
            .add(
                "H",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![x, w1],
            )
            .unwrap();
        let a = g
            .add("A", EinSum::map(labels("i k"), UnaryOp::Relu), vec![h])
            .unwrap();
        let b = g
            .add(
                "B",
                EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
                vec![h, w2],
            )
            .unwrap();
        g.add(
            "Z",
            EinSum::elementwise(labels("i k"), labels("i k"), JoinOp::Add),
            vec![a, b],
        )
        .unwrap();
        g
    }

    #[test]
    fn linearized_handles_multi_consumer() {
        let g = diamond();
        assert!(!g.is_tree_like());
        let cfg = PlannerConfig {
            p: 8,
            ..Default::default()
        };
        let mut plan = plan_linearized(&g, &cfg).unwrap();
        plan.finalize_inputs(&g);
        // all four compute vertices labeled
        assert_eq!(plan.parts.len(), 4);
        let cost = plan.total_cost(&g).unwrap();
        assert!(cost.is_finite() && cost >= 0.0);
    }

    #[test]
    fn linearized_matches_exact_on_trees() {
        // On a tree-like chain the linearization is one path == exact DP.
        let mut g = EinGraph::new();
        let a = g.input("A", vec![64, 64]);
        let b = g.input("B", vec![64, 64]);
        let c = g.input("C", vec![64, 64]);
        let ab = g
            .add(
                "AB",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        g.add(
            "ABC",
            EinSum::contraction(labels("i k"), labels("k m"), labels("i m")),
            vec![ab, c],
        )
        .unwrap();
        let cfg = PlannerConfig {
            p: 8,
            ..Default::default()
        };
        let mut lin = plan_linearized(&g, &cfg).unwrap();
        lin.finalize_inputs(&g);
        let mut exact = plan_exact_tree(&g, &cfg).unwrap();
        exact.finalize_inputs(&g);
        assert!((lin.total_cost(&g).unwrap() - exact.total_cost(&g).unwrap()).abs() < 1e-6);
    }

    #[test]
    fn off_path_cost_never_worse() {
        let g = diamond();
        let base_cfg = PlannerConfig {
            p: 8,
            off_path_cost: false,
            ..Default::default()
        };
        let imp_cfg = PlannerConfig {
            p: 8,
            off_path_cost: true,
            ..Default::default()
        };
        let mut base = plan_linearized(&g, &base_cfg).unwrap();
        base.finalize_inputs(&g);
        let mut imp = plan_linearized(&g, &imp_cfg).unwrap();
        imp.finalize_inputs(&g);
        // The off-path-aware variant optimizes the true objective more
        // closely; it should not be (meaningfully) worse on this graph.
        assert!(imp.total_cost(&g).unwrap() <= base.total_cost(&g).unwrap() * 1.5 + 1e-6);
    }
}

//! The EinDecomp planner (paper Sections 5–8): choose a partitioning
//! vector for every vertex of an EinGraph so as to minimize an upper bound
//! on communication, subject to producing (about) `p` independent kernel
//! calls per vertex.
//!
//! * [`viable`] — enumerate candidate partitioning vectors (§6, §8.1);
//! * [`cost`] — the three transfer-cost components (§7);
//! * [`dp`] — the exact dynamic program for tree-like graphs (§8.2–8.3);
//! * [`linearize`] — path-decomposition DP for general DAGs (§8.4);
//! * [`baselines`] — the bespoke decomposition strategies the paper
//!   compares against (SQRT, data/model parallel, sequence, attention,
//!   ScaLAPACK-like, Dask-like, ZeRO-like, FlexGen-like).

pub mod baselines;
pub mod cost;
pub mod dp;
pub mod linearize;
pub mod viable;

use crate::einsum::expr::EinSum;
use crate::einsum::graph::{EinGraph, VertexId};
use crate::einsum::label::project;
use crate::error::{Error, Result};
use std::collections::HashMap;

/// How the planner explores the assignment space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    /// Exact DP — valid when no non-input vertex has more than one
    /// consumer (§8.2). Errors otherwise.
    ExactTree,
    /// Linearize into longest paths and DP along each (§8.4).
    Linearized,
    /// Per-vertex local greedy (ablation baseline).
    Greedy,
    /// ExactTree when the graph allows it, Linearized otherwise.
    Auto,
}

/// Planner configuration.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Number of processors: the planner targets exactly `p` kernel calls
    /// per vertex. Rounded up to a power of two (§8.1).
    pub p: usize,
    pub mode: PlanMode,
    /// §8.4: when optimizing along a path, also charge repartition cost
    /// for off-path inputs whose partitioning is already fixed. The paper
    /// ignores these edges; including them is a strictly better
    /// approximation that we evaluate as an ablation.
    pub off_path_cost: bool,
    /// TRA-IR pass selector carried for toolchains that plan *and*
    /// lower from one config (the lowering bench, sweep scripts):
    /// `cfg.passes.manager().run(&mut prog)` after
    /// [`crate::tra::program::from_plan`]. **The planner itself never
    /// reads this** — the cost model scores the raw Eq.-5 rewrite — and
    /// the library's lowering path (`Cluster::lower`) is driven by
    /// `Cluster::passes` / `DriverConfig::passes`, not this field.
    pub passes: crate::tra::passes::PassSelector,
    /// Hierarchical worker topology for the cost model. `None` (the
    /// default) and flat topologies score repartitions with the seed §7
    /// closed form, byte-for-byte; a multi-level topology discounts
    /// transfers that stay on faster inner links
    /// ([`cost::cost_repart_on`]), never exceeding the flat bound.
    pub topology: Option<crate::sim::network::Topology>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            p: 16,
            mode: PlanMode::Auto,
            off_path_cost: false,
            passes: crate::tra::passes::PassSelector::default(),
            topology: None,
        }
    }
}

/// A complete decomposition: one partitioning vector (parallel to
/// `op.unique_labels()`) per non-input vertex, plus the partitioning each
/// *input* tensor should be pre-sharded with (the paper treats input
/// placement as free and offline).
#[derive(Clone, Debug, Default)]
pub struct Plan {
    /// vertex -> d over the vertex's unique labels.
    pub parts: HashMap<VertexId, Vec<usize>>,
    /// input vertex -> pre-partitioning (derived from its first consumer).
    pub input_parts: HashMap<VertexId, Vec<usize>>,
    /// The planner's predicted communication upper bound (floats moved).
    pub predicted_cost: f64,
    /// Human-readable strategy tag for reports.
    pub strategy: String,
}

impl Plan {
    /// Output partitioning `d_Z` of a vertex under this plan (inputs use
    /// their assigned pre-partitioning; unassigned inputs default to
    /// unpartitioned).
    pub fn out_part(&self, g: &EinGraph, v: VertexId) -> Vec<usize> {
        let vert = g.vertex(v);
        match &vert.op {
            EinSum::Input => self
                .input_parts
                .get(&v)
                .cloned()
                .unwrap_or_else(|| vec![1; vert.bound.len()]),
            op => {
                let d = &self.parts[&v];
                let uniq = op.unique_labels();
                project(d, op.lz().unwrap(), &uniq)
            }
        }
    }

    /// Partitioning this plan requires for operand `o` of vertex `v`.
    pub fn required_in_part(&self, g: &EinGraph, v: VertexId, o: usize) -> Vec<usize> {
        let vert = g.vertex(v);
        let op = &vert.op;
        let d = &self.parts[&v];
        let uniq = op.unique_labels();
        project(d, op.operand_labels()[o], &uniq)
    }

    /// Derive `input_parts` from the consumers: each input is pre-sharded
    /// the way its first consumer wants it (free, per the paper).
    pub fn finalize_inputs(&mut self, g: &EinGraph) {
        for vert in g.vertices() {
            if matches!(vert.op, EinSum::Input) {
                continue;
            }
            if !self.parts.contains_key(&vert.id) {
                continue;
            }
            for (o, &c) in vert.inputs.iter().enumerate() {
                if matches!(g.vertex(c).op, EinSum::Input) {
                    let req = self.required_in_part(g, vert.id, o);
                    self.input_parts.entry(c).or_insert(req);
                }
            }
        }
        // inputs nobody consumes (degenerate): unpartitioned
        for vert in g.vertices() {
            if matches!(vert.op, EinSum::Input) {
                self.input_parts
                    .entry(vert.id)
                    .or_insert_with(|| vec![1; vert.bound.len()]);
            }
        }
    }

    /// Signature-stable JSON serialization: vertices emitted in ascending
    /// id order (never `HashMap` iteration order), so the same plan always
    /// renders to the same bytes — the property the plan cache and the
    /// bench artifacts rely on when diffing plans across runs.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let part_obj = |m: &HashMap<VertexId, Vec<usize>>| -> Json {
            let mut entries: Vec<(VertexId, &Vec<usize>)> =
                m.iter().map(|(&v, d)| (v, d)).collect();
            entries.sort_by_key(|(v, _)| *v);
            Json::Obj(
                entries
                    .into_iter()
                    .map(|(v, d)| {
                        let arr = d.iter().map(|&x| Json::num(x as f64)).collect();
                        (v.to_string(), Json::Arr(arr))
                    })
                    .collect(),
            )
        };
        Json::Obj(vec![
            ("strategy".into(), Json::str(self.strategy.clone())),
            ("predicted_cost_floats".into(), Json::num(self.predicted_cost)),
            ("parts".into(), part_obj(&self.parts)),
            ("input_parts".into(), part_obj(&self.input_parts)),
        ])
    }

    /// Evaluate the full communication upper bound of this plan under the
    /// paper's cost model: per-vertex join + aggregation costs, plus
    /// repartition costs on every producer->consumer edge (and on input
    /// edges whose pre-partitioning differs from what the consumer needs —
    /// free only for the *first* consumer).
    pub fn total_cost(&self, g: &EinGraph) -> Result<f64> {
        self.total_cost_on(g, None)
    }

    /// [`Plan::total_cost`] under a worker topology: repartition edges
    /// are charged via [`cost::cost_repart_on`]. `None` and flat
    /// topologies reproduce `total_cost` exactly.
    pub fn total_cost_on(
        &self,
        g: &EinGraph,
        topo: Option<&crate::sim::network::Topology>,
    ) -> Result<f64> {
        let mut total = 0.0;
        for vert in g.vertices() {
            if matches!(vert.op, EinSum::Input) {
                continue;
            }
            let d = self.parts.get(&vert.id).ok_or_else(|| {
                Error::NoViablePlan(format!("vertex {} unassigned", vert.name))
            })?;
            let in_bounds: Vec<&[usize]> = vert
                .inputs
                .iter()
                .map(|&i| g.vertex(i.0.into()).bound.as_slice())
                .collect();
            total += cost::vertex_cost(&vert.op, &in_bounds, d)?;
            for (o, &c) in vert.inputs.iter().enumerate() {
                let have = self.out_part(g, c);
                let need = self.required_in_part(g, vert.id, o);
                total += cost::cost_repart_on(topo, &need, &have, &g.vertex(c).bound);
            }
        }
        Ok(total)
    }
}

// VertexId helper for total_cost's indexing
impl From<usize> for VertexId {
    fn from(v: usize) -> Self {
        VertexId(v)
    }
}

/// Top-level entry: plan an EinGraph with the EinDecomp algorithm.
///
/// Picks one partitioning vector per non-input vertex (parallel to the
/// vertex's unique labels, product exactly `p` after rounding `p` up to a
/// power of two) minimizing the §7 communication upper bound, then
/// derives input pre-partitionings and the plan's predicted cost.
///
/// ```
/// use eindecomp::decomp::{plan_graph, PlannerConfig};
/// use eindecomp::einsum::expr::EinSum;
/// use eindecomp::einsum::graph::EinGraph;
/// use eindecomp::einsum::label::labels;
///
/// // Z[i,k] = sum_j A[i,j] * B[j,k], planned for p = 4 kernel calls.
/// let mut g = EinGraph::new();
/// let a = g.input("A", vec![64, 64]);
/// let b = g.input("B", vec![64, 64]);
/// let z = g.add(
///     "Z",
///     EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
///     vec![a, b],
/// )?;
/// let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() })?;
///
/// // d runs over Z's unique labels (i, j, k) and yields exactly p tiles.
/// let d = &plan.parts[&z];
/// assert_eq!(d.len(), 3);
/// assert_eq!(d.iter().product::<usize>(), 4);
/// assert!(plan.predicted_cost > 0.0);
/// # Ok::<(), eindecomp::Error>(())
/// ```
pub fn plan_graph(g: &EinGraph, cfg: &PlannerConfig) -> Result<Plan> {
    let mode = match cfg.mode {
        PlanMode::Auto => {
            if g.is_tree_like() {
                PlanMode::ExactTree
            } else {
                PlanMode::Linearized
            }
        }
        m => m,
    };
    let mut plan = match mode {
        PlanMode::ExactTree => dp::plan_exact_tree(g, cfg)?,
        PlanMode::Linearized => linearize::plan_linearized(g, cfg)?,
        PlanMode::Greedy => dp::plan_greedy(g, cfg)?,
        PlanMode::Auto => unreachable!(),
    };
    plan.finalize_inputs(g);
    plan.predicted_cost = plan.total_cost_on(g, cfg.topology.as_ref())?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;

    #[test]
    fn plan_to_json_is_deterministic_and_ordered() {
        let mut g = EinGraph::new();
        let a = g.input("A", vec![16, 16]);
        let b = g.input("B", vec![16, 16]);
        let z = g
            .add(
                "Z",
                EinSum::contraction(labels("i j"), labels("j k"), labels("i k")),
                vec![a, b],
            )
            .unwrap();
        let plan = plan_graph(&g, &PlannerConfig { p: 4, ..Default::default() }).unwrap();
        let r1 = plan.to_json().render();
        let r2 = plan.clone().to_json().render();
        assert_eq!(r1, r2);
        // non-input vertex is under "parts"; inputs under "input_parts"
        // in ascending id order
        assert!(r1.contains(&format!("\"{z}\"")));
        let pos_a = r1.find(&format!("\"{a}\"")).unwrap();
        let pos_b = r1.find(&format!("\"{b}\"")).unwrap();
        assert!(pos_a < pos_b);
        assert!(r1.contains("\"strategy\""));
    }
}

//! Small in-tree utilities replacing unavailable external crates: a
//! deterministic RNG (no `rand`), a scoped thread-pool helper (no
//! `rayon`), and a minimal JSON *writer* for reports (no `serde_json`).

/// Deterministic SplitMix64 RNG — reproducible across runs and platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [-0.5, 0.5).
    #[inline]
    pub fn next_centered(&mut self) -> f32 {
        self.next_f32() - 0.5
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Approximate standard normal via the sum of 4 uniforms (Irwin–Hall,
    /// variance-corrected) — plenty for weight init.
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.next_f32()).sum::<f32>() - 2.0;
        s * (12.0f32 / 4.0).sqrt()
    }
}

/// Run `f(chunk_index)` for `n` chunks on up to `threads` OS threads.
/// A minimal data-parallel scatter used by the executor and benches.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Minimal JSON value writer for structured reports (we only ever *write*
/// JSON; the artifact manifest uses a line format both sides parse).
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_f32_in_range() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn rng_mean_reasonable() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let mean: f32 = (0..n).map(|_| r.next_centered()).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn parallel_for_covers_all() {
        let hits: Vec<std::sync::atomic::AtomicUsize> =
            (0..100).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect();
        parallel_for(100, 8, |i| {
            hits[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(std::sync::atomic::Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn json_rendering() {
        let j = Json::Obj(vec![
            ("a".into(), Json::num(1.5)),
            ("b".into(), Json::Arr(vec![Json::str("x\"y"), Json::Bool(true)])),
        ]);
        assert_eq!(j.render(), r#"{"a":1.5,"b":["x\"y",true]}"#);
    }
}

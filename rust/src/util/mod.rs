//! Small in-tree utilities replacing unavailable external crates: a
//! deterministic RNG (no `rand`), a scoped thread-pool helper, a
//! work-stealing DAG scheduler with nested intra-op work stealing (no
//! `rayon`/`crossbeam`), a per-thread [`BufferPool`] recycling kernel
//! output and scratch buffers, and a minimal JSON *writer* for reports
//! (no `serde_json`).
//!
//! The intra-op layer ([`ShardRegistry`] / [`ShardScope`]) lets a running
//! task publish independent *shards* of itself (e.g. row blocks of a
//! GEMM) that idle scheduler workers pick up — so a plan with fewer ready
//! tasks than cores still saturates the machine. See
//! [`execute_dag_scoped`] for how the two levels compose.

/// Deterministic SplitMix64 RNG — reproducible across runs and platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [-0.5, 0.5).
    #[inline]
    pub fn next_centered(&mut self) -> f32 {
        self.next_f32() - 0.5
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Approximate standard normal via the sum of 4 uniforms (Irwin–Hall,
    /// variance-corrected) — plenty for weight init.
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.next_f32()).sum::<f32>() - 2.0;
        s * (12.0f32 / 4.0).sqrt()
    }
}

/// Capped exponential backoff: the delay before retry `attempt`
/// (0-based) is `base << attempt`, saturating at `cap`. A pure function
/// so the same schedule drives both wall-clock sleeps in the recovery
/// executor and virtual-time charges in the modeled ledger (see
/// `sim::faults::RunOptions`).
pub fn backoff_delay(
    base: std::time::Duration,
    cap: std::time::Duration,
    attempt: u32,
) -> std::time::Duration {
    // shifting past 63 bits would overflow; anything that large is
    // beyond any cap we would ever configure
    let factor = 1u64.checked_shl(attempt).unwrap_or(u64::MAX);
    base.checked_mul(factor.min(u32::MAX as u64) as u32)
        .unwrap_or(cap)
        .min(cap)
}

/// Nearest-rank percentile of a sample set, deterministic for any input
/// order: the `ceil(pct/100 * n)`-th smallest sample (1-indexed), with
/// `pct` clamped to `[0, 100]` and rank clamped to `[1, n]` so `pct = 0`
/// yields the minimum and `pct = 100` the maximum. Ordering uses
/// `f32`/`f64` total order, so NaN samples sort last instead of
/// poisoning the comparison. Returns NaN for an empty sample set.
///
/// Used by the serving load generator's p50/p95/p99 latency summary and
/// the CLI `run --repeat` timing summary.
pub fn percentile(samples: &[f64], pct: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let n = sorted.len();
    let pct = pct.clamp(0.0, 100.0);
    let rank = ((pct / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Run `f(chunk_index)` for `n` chunks on up to `threads` OS threads.
/// A minimal data-parallel scatter used by the executor and benches.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Shared pointer to an `f32` buffer that several shards write through.
///
/// # Safety contract (callers)
///
/// Every user must guarantee that concurrently-executing shards write
/// **disjoint** index sets of the buffer, and that the buffer outlives
/// the `fork_join` call that spawns the writers. The intra-op kernels
/// (`runtime::gemm::sgemm_scoped`, the sharded paths in
/// `runtime::native`, the chunked aggregation fold in `sim::cluster`)
/// all split by fixed, deterministically-computed output regions, which
/// is what makes their results bitwise-identical to the serial kernels.
pub(crate) struct SyncPtr(*mut f32);

// SAFETY: `SyncPtr` is only a capability to *derive* disjoint sub-slices;
// disjointness is the caller's obligation (see the type docs).
unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

impl SyncPtr {
    pub(crate) fn new(ptr: *mut f32) -> Self {
        SyncPtr(ptr)
    }

    /// The raw pointer. A *method* rather than a public field so that
    /// closures capture `&SyncPtr` (which is `Sync`) instead of the bare
    /// `*mut f32` (which is not) under edition-2021 disjoint capture.
    #[inline]
    pub(crate) fn get(&self) -> *mut f32 {
        self.0
    }
}

/// Minimum output-element (or flop-proxy) count before a sharded kernel
/// path is worth the fork-join hand-off; shared by every intra-op path
/// (`runtime::gemm`, `runtime::native`, the aggregation fold in
/// `sim::cluster`).
pub(crate) const SHARD_MIN: usize = 4096;

/// `[lo, hi)` bounds of chunk `i` when `len` items split into `parts`
/// contiguous chunks. Chunks are pairwise disjoint and cover `[0, len)` —
/// the single audited implementation every [`SyncPtr`]-based sharded
/// writer's disjointness argument rests on. Deterministic in
/// `(len, parts, i)` alone, which keeps chunked kernels bitwise-stable.
#[inline]
pub(crate) fn chunk_bounds(len: usize, parts: usize, i: usize) -> (usize, usize) {
    (len * i / parts, len * (i + 1) / parts)
}

/// Largest size class the pool retains: `2^26` floats (256 MiB). Larger
/// buffers bypass the pool entirely.
const POOL_MAX_CLASS: usize = 26;
/// Free-list depth per size class — bounds pool residency per thread.
const POOL_CLASS_CAP: usize = 32;
/// Per-class retained-capacity cap as a multiple of the class size:
/// class `c` parks at most `8 << c` floats (≈ 8 buffers). Without it the
/// count cap alone lets one class pin `32 * 2^26` floats after a burst of
/// large retirements; the capacity cap trims the excess at `give` time so
/// steady-state residency is bounded by geometry, not burst history.
const POOL_CLASS_RETAIN_X: usize = 8;

/// Point-in-time counters of the calling thread's [`BufferPool`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers handed out ([`BufferPool::take`] / `take_filled`).
    pub takes: u64,
    /// Takes served from a free list (no allocation).
    pub hits: u64,
    /// Takes that had to allocate (`takes - hits`).
    pub misses: u64,
    /// Buffers returned via [`BufferPool::give`] (kept or dropped).
    pub gives: u64,
    /// Gives dropped by the per-class residency caps (free-list depth
    /// [`POOL_CLASS_CAP`] or retained capacity `8 << c` floats).
    pub trimmed: u64,
    /// Floats currently parked on this thread's free lists.
    pub resident: usize,
}

/// Per-thread, size-classed free lists of `f32` buffers — the runtime's
/// allocation recycler for kernel outputs, GEMM pack scratch, and tile
/// buffers.
///
/// Buffers are classed by the power of two at or above their length;
/// each worker thread owns its own lists (thread-local state, so every
/// operation is lock-free by construction). A buffer allocated on one
/// thread and recycled on another simply joins the recycler thread's
/// lists — ownership is wherever the `give` happened.
///
/// **Contents are stale, not zeroed.** [`BufferPool::take`] returns a
/// buffer whose prefix holds values from its previous life; callers must
/// overwrite every element (GEMM outputs with `beta = 0` and fully-tiled
/// repartition targets do so by construction) or use
/// [`BufferPool::take_filled`].
///
/// ```
/// use eindecomp::util::BufferPool;
/// BufferPool::reset();
/// let v = BufferPool::take_filled(1000, 0.0);
/// BufferPool::give(v);
/// // Same size class: the allocation is reused, not reallocated.
/// let w = BufferPool::take(1000);
/// assert_eq!(w.len(), 1000);
/// let s = BufferPool::stats();
/// assert_eq!((s.takes, s.hits, s.misses), (2, 1, 1));
/// ```
pub struct BufferPool {
    /// `classes[c]` holds buffers with capacity at least `2^c`.
    classes: Vec<Vec<Vec<f32>>>,
    takes: u64,
    hits: u64,
    gives: u64,
    resident: usize,
}

thread_local! {
    static POOL: std::cell::RefCell<BufferPool> = std::cell::RefCell::new(BufferPool {
        classes: (0..=POOL_MAX_CLASS).map(|_| Vec::new()).collect(),
        takes: 0,
        hits: 0,
        gives: 0,
        trimmed: 0,
        resident: 0,
    });
}

/// Size class of a requested length: index of the power of two at or
/// above it. `None` when the length is 0 or beyond [`POOL_MAX_CLASS`].
fn pool_class_for_len(len: usize) -> Option<usize> {
    if len == 0 {
        return None;
    }
    let c = len.next_power_of_two().trailing_zeros() as usize;
    (c <= POOL_MAX_CLASS).then_some(c)
}

/// Size class a buffer can *serve*: the largest power of two at or below
/// its capacity (every request routed to that class fits).
fn pool_class_for_cap(cap: usize) -> Option<usize> {
    if cap == 0 {
        return None;
    }
    let c = (usize::BITS - 1 - cap.leading_zeros()) as usize;
    (c <= POOL_MAX_CLASS).then_some(c)
}

impl BufferPool {
    /// Take a buffer of exactly `len` elements with **stale contents**
    /// (see the type docs); the caller must overwrite every element.
    ///
    /// Debug builds poison reused buffers with NaN before handing them
    /// out, so a caller that *reads* before overwriting (a broken
    /// `beta = 0` kernel, a partially-written repartition target, an
    /// aggregation folding into uninitialized memory) propagates NaN into
    /// its output and fails the dense-reference comparisons instead of
    /// silently returning whatever the buffer held last. Release builds
    /// skip the fill — the contract is unchanged, only unenforced.
    pub fn take(len: usize) -> Vec<f32> {
        POOL.with(|p| {
            let mut pool = p.borrow_mut();
            pool.takes += 1;
            if let Some(c) = pool_class_for_len(len) {
                if let Some(mut v) = pool.classes[c].pop() {
                    pool.hits += 1;
                    pool.resident -= v.capacity();
                    if v.len() >= len {
                        v.truncate(len);
                    } else {
                        v.resize(len, 0.0);
                    }
                    #[cfg(debug_assertions)]
                    v.fill(f32::NAN);
                    return v;
                }
                let mut v = Vec::with_capacity(1usize << c);
                v.resize(len, 0.0);
                return v;
            }
            vec![0.0; len]
        })
    }

    /// Take a buffer of `len` elements, every element set to `fill`.
    pub fn take_filled(len: usize, fill: f32) -> Vec<f32> {
        let mut v = Self::take(len);
        v.fill(fill);
        v
    }

    /// Return a buffer to the calling thread's free lists. Dropped — and
    /// counted in [`PoolStats::trimmed`] — when its class is already full
    /// by buffer count ([`POOL_CLASS_CAP`]) or would exceed the class's
    /// retained-capacity cap (`8 << c` floats), so a burst of retirements
    /// cannot pin memory past the steady-state working set.
    pub fn give(v: Vec<f32>) {
        POOL.with(|p| {
            let mut pool = p.borrow_mut();
            pool.gives += 1;
            if let Some(c) = pool_class_for_cap(v.capacity()) {
                let parked: usize = pool.classes[c].iter().map(|b| b.capacity()).sum();
                if pool.classes[c].len() < POOL_CLASS_CAP
                    && parked + v.capacity() <= (POOL_CLASS_RETAIN_X << c)
                {
                    pool.resident += v.capacity();
                    pool.classes[c].push(v);
                } else {
                    pool.trimmed += 1;
                }
            }
        });
    }

    /// Counters for the calling thread's pool.
    pub fn stats() -> PoolStats {
        POOL.with(|p| {
            let pool = p.borrow();
            PoolStats {
                takes: pool.takes,
                hits: pool.hits,
                misses: pool.takes - pool.hits,
                gives: pool.gives,
                trimmed: pool.trimmed,
                resident: pool.resident,
            }
        })
    }

    /// Drop all parked buffers and zero the counters (testing aid).
    pub fn reset() {
        POOL.with(|p| {
            let mut pool = p.borrow_mut();
            for c in pool.classes.iter_mut() {
                c.clear();
            }
            pool.takes = 0;
            pool.hits = 0;
            pool.gives = 0;
            pool.trimmed = 0;
            pool.resident = 0;
        });
    }
}

/// RAII handle on a pooled buffer: derefs to `[f32]`, returns the buffer
/// to the pool on drop. Used for function-local scratch (GEMM pack
/// panels); buffers that escape into [`crate::tensor::Tensor`]s are
/// recycled explicitly instead (`Tensor::recycle`).
pub struct PooledVec {
    v: Vec<f32>,
}

impl PooledVec {
    /// Pooled scratch with **stale contents** (every element must be
    /// overwritten before being read).
    pub fn take(len: usize) -> PooledVec {
        PooledVec {
            v: BufferPool::take(len),
        }
    }
}

impl Drop for PooledVec {
    fn drop(&mut self) {
        BufferPool::give(std::mem::take(&mut self.v));
    }
}

impl std::ops::Deref for PooledVec {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.v
    }
}

impl std::ops::DerefMut for PooledVec {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.v
    }
}

/// One published fork-join group: `total` shards, claimed by atomically
/// incrementing `next`, completion tracked in `done`.
struct ShardGroup {
    /// Type-erased shard body, stored as a raw pointer (not a reference)
    /// because helpers can briefly hold the `Arc` past the publisher's
    /// return, and a live Rust *reference* to the then-dead closure frame
    /// would violate validity rules even if never called. SAFETY: the
    /// publisher removes the group from the registry and waits for
    /// `done == total` before returning from `fork_join`, and once
    /// `next >= total` no thread dereferences the pointer again.
    f: *const (dyn Fn(usize) + Sync),
    total: usize,
    next: std::sync::atomic::AtomicUsize,
    done: std::sync::atomic::AtomicUsize,
}

// SAFETY: the raw closure pointer is only dereferenced under the
// claim protocol above, and the erased closure itself is `Sync` (the
// `fork_join` bound), so sharing the group across worker threads is sound.
unsafe impl Send for ShardGroup {}
unsafe impl Sync for ShardGroup {}

/// Converts a panic in a shard body into a process abort. Unwinding out
/// of the fork-join protocol is unsound either way: a publisher panic
/// would free the erased closure while helpers can still claim shards
/// (use-after-free), and a helper panic would leave `done < total`
/// forever, hanging the publisher. Fail fast instead.
struct AbortOnUnwind;

impl Drop for AbortOnUnwind {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!("fatal: intra-op shard body panicked; aborting (see message above)");
            std::process::abort();
        }
    }
}

/// Registry of in-flight intra-op shard groups, shared by all workers of
/// one scheduler (or one standalone pool).
///
/// `intra_op` is the *configured* shard fan-out: kernels ask
/// [`ShardScope::parallelism`] how many shards to split into, and the
/// answer never depends on runtime idleness — shard boundaries must be a
/// deterministic function of the problem shape so that results are
/// reproducible run to run (see `tests/gemm_parallel.rs`).
pub struct ShardRegistry {
    groups: std::sync::Mutex<Vec<std::sync::Arc<ShardGroup>>>,
    intra_op: usize,
    /// Parking lot shared with the owning scheduler: helpers park here,
    /// publishers and task-completions notify it.
    park: std::sync::Mutex<()>,
    wake: std::sync::Condvar,
}

impl ShardRegistry {
    pub fn new(intra_op: usize) -> Self {
        ShardRegistry {
            groups: std::sync::Mutex::new(Vec::new()),
            intra_op: intra_op.max(1),
            park: std::sync::Mutex::new(()),
            wake: std::sync::Condvar::new(),
        }
    }

    /// Handle that task bodies use to publish shards.
    pub fn scope(&self) -> ShardScope<'_> {
        ShardScope { reg: self }
    }

    /// Execute pending shards of other tasks, if any. Returns whether any
    /// shard body actually ran. Called by idle workers before parking.
    pub fn help(&self) -> bool {
        use std::sync::atomic::Ordering;
        let mut did = false;
        loop {
            let group = {
                let groups = self.groups.lock().unwrap();
                groups
                    .iter()
                    .find(|g| g.next.load(Ordering::Relaxed) < g.total)
                    .cloned()
            };
            let Some(g) = group else { return did };
            let mut claimed = false;
            loop {
                let i = g.next.fetch_add(1, Ordering::SeqCst);
                if i >= g.total {
                    break;
                }
                claimed = true;
                did = true;
                let guard = AbortOnUnwind;
                // SAFETY: i < total, so the publisher is still inside
                // fork_join and the erased closure is alive (see
                // ShardGroup::f); the reference is transient.
                let body: &(dyn Fn(usize) + Sync) = unsafe { &*g.f };
                body(i);
                drop(guard);
                if g.done.fetch_add(1, Ordering::SeqCst) + 1 == g.total {
                    self.wake.notify_all();
                }
            }
            if !claimed {
                // Lost the race for the last shard: `next` is now past
                // `total`, so the find above cannot return this group
                // again — no livelock.
                return did;
            }
        }
    }

    /// Park until notified or `timeout` elapses (guards the push-vs-sleep
    /// race the same way `execute_dag`'s workers do).
    fn park_timeout(&self, timeout: std::time::Duration) {
        let guard = self.park.lock().unwrap();
        let _ = self.wake.wait_timeout(guard, timeout).unwrap();
    }
}

/// Capability handed to task bodies for publishing intra-op shards.
#[derive(Clone, Copy)]
pub struct ShardScope<'a> {
    reg: &'a ShardRegistry,
}

impl ShardScope<'_> {
    /// Configured intra-op fan-out (>= 1). Kernels use this to pick a
    /// *deterministic* shard count; it intentionally does not reflect how
    /// many workers happen to be idle right now.
    pub fn parallelism(&self) -> usize {
        self.reg.intra_op
    }

    /// Run `f(0..shards)` with the calling thread plus any idle scheduler
    /// workers, returning only after every shard has finished.
    ///
    /// Shard bodies must be independent (no shard may wait on another)
    /// and — when they write a shared buffer — must write disjoint
    /// regions. A panicking shard body **aborts the process** (unwinding
    /// out of the claim protocol would dangle the erased closure or hang
    /// the publisher — see `AbortOnUnwind`). Serial fallback
    /// (`shards <= 1` or a registry configured with `intra_op = 1`) runs
    /// the shards inline in index order, which every sharded kernel in
    /// this crate is bitwise equivalent to by construction.
    pub fn fork_join<F>(&self, shards: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        use std::sync::atomic::{AtomicUsize, Ordering};
        if shards <= 1 || self.reg.intra_op <= 1 {
            for i in 0..shards {
                f(i);
            }
            return;
        }
        let local: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY of the lifetime erasure: the group is removed from the
        // registry below, and this function only returns once
        // `done == total`; after that point `next >= total` forever, so
        // no helper dereferences the pointer again (same fat-pointer
        // layout on both sides of the transmute).
        let erased: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(local) };
        let group = std::sync::Arc::new(ShardGroup {
            f: erased,
            total: shards,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
        });
        self.reg.groups.lock().unwrap().push(group.clone());
        self.reg.wake.notify_all();
        // The publisher works its own group first (helpers join in from
        // the registry side).
        loop {
            let i = group.next.fetch_add(1, Ordering::SeqCst);
            if i >= group.total {
                break;
            }
            let guard = AbortOnUnwind;
            f(i);
            drop(guard);
            if group.done.fetch_add(1, Ordering::SeqCst) + 1 == group.total {
                self.reg.wake.notify_all();
            }
        }
        self.reg.groups.lock().unwrap().retain(|g| !std::sync::Arc::ptr_eq(g, &group));
        // Wait for helper-claimed shards still in flight.
        while group.done.load(Ordering::SeqCst) < group.total {
            let guard = self.reg.park.lock().unwrap();
            if group.done.load(Ordering::SeqCst) >= group.total {
                break;
            }
            let _ = self
                .reg
                .wake
                .wait_timeout(guard, std::time::Duration::from_micros(100))
                .unwrap();
        }
    }
}

/// A [`ShardScope`] that always runs shards inline (intra-op = 1). Used
/// by serial entry points and the level-barrier reference executor.
pub fn serial_scope() -> ShardScope<'static> {
    static SERIAL: std::sync::OnceLock<ShardRegistry> = std::sync::OnceLock::new();
    SERIAL.get_or_init(|| ShardRegistry::new(1)).scope()
}

/// Run `f` with a [`ShardScope`] backed by a standalone pool of
/// `threads` helper threads (the calling thread participates at
/// `fork_join` time, so `threads = n` means an `n`-way `parallelism()`).
/// Used by tests and benches to exercise sharded kernels without a task
/// DAG.
pub fn with_intra_op_pool<R>(threads: usize, f: impl FnOnce(&ShardScope) -> R) -> R {
    use std::sync::atomic::{AtomicBool, Ordering};
    let reg = ShardRegistry::new(threads);
    if threads <= 1 {
        return f(&reg.scope());
    }
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        for _ in 0..threads - 1 {
            scope.spawn(|| {
                while !stop.load(Ordering::SeqCst) {
                    if !reg.help() {
                        reg.park_timeout(std::time::Duration::from_micros(200));
                    }
                }
            });
        }
        let r = f(&reg.scope());
        stop.store(true, Ordering::SeqCst);
        reg.wake.notify_all();
        r
    })
}

/// Execute a dependency-counted task DAG on `threads` OS threads with
/// per-worker deques, a shared injector, and work stealing.
///
/// * `consumers[p]` lists every task that depends on `p`, **once per dep
///   occurrence** (a task reading the same producer tile through two
///   operands appears twice);
/// * `indegree[t]` is the matching occurrence count of `t`'s deps — a task
///   becomes ready exactly when its counter hits zero;
/// * `home[t]` is the preferred worker (tasks seed onto
///   `deques[home[t]]` when `home[t] < threads`, the injector otherwise);
/// * `f(t)` runs each task exactly once, after all of its deps.
///
/// Scheduling protocol (the executor's readiness/stealing invariants live
/// here; `sim::cluster` documents how they map onto task graphs):
///
/// 1. initially-ready tasks (indegree 0) are seeded to their home deque
///    or the shared injector;
/// 2. a worker pops from the **back** of its own deque (freshest first —
///    its own recent outputs are cache-hot), then from the front of the
///    injector, then steals from the **front** of other workers' deques
///    (oldest first, the classic Chase–Lev discipline);
/// 3. completing a task decrements each consumer's counter once per dep
///    edge; the worker that performs the final decrement pushes that
///    consumer onto its *own* deque (the consumer's first input is the
///    tile just produced — locality);
/// 4. at most one deque lock is ever held at a time, so stealing cannot
///    deadlock;
/// 5. workers that find nothing to pop park on a condvar with a short
///    timeout (no busy-spin); every push/completion/abort notifies;
/// 6. an `Err` from `f` aborts the run: in-flight tasks finish, nothing
///    new starts, and the first error is returned.
///
/// Any error type `E: Send` is supported. Panics if the scheduler
/// deadlocks — no task queued, none running, yet not all completed —
/// which indicates a cyclic or miscounted dependency structure (the
/// `outstanding` counter makes this state detectable: it counts tasks
/// that are queued or running, and only the completion of a running
/// task can queue new ones).
pub fn execute_dag<E, F>(
    consumers: &[Vec<usize>],
    indegree: &[usize],
    home: &[usize],
    threads: usize,
    f: F,
) -> std::result::Result<(), E>
where
    F: Fn(usize) -> std::result::Result<(), E> + Sync,
    E: Send,
{
    execute_dag_scoped(consumers, indegree, home, threads, 1, |t, _| f(t))
}

/// [`execute_dag`] with **nested** work stealing: each task body receives
/// a [`ShardScope`] through which it can `fork_join` independent shards
/// of itself (row blocks of a GEMM, batch entries of a BMM, chunks of an
/// elementwise map), and workers with no ready *task* execute pending
/// *shards* of running tasks before parking.
///
/// `intra_op` configures [`ShardScope::parallelism`] — the shard fan-out
/// kernels split into. It bounds shard-queue pressure, not concurrency:
/// however many workers are idle may help, but the shard *boundaries*
/// depend only on `intra_op` and the problem shape, which keeps sharded
/// kernels bitwise-deterministic (two idle workers vs. seven executing
/// the same 8 shards produce identical bytes).
///
/// Scheduling protocol additions over [`execute_dag`]:
///
/// * a worker that finds no ready task first drains the shard registry
///   ([`ShardRegistry::help`]) and only parks when both levels are empty;
/// * `fork_join` publishers and final shard completions notify the same
///   condvar the DAG uses, so a shard hand-off wakes parked workers just
///   like a task hand-off does;
/// * deadlock detection is unchanged: a task blocked in `fork_join`
///   still holds its `outstanding` +1, and shard bodies cannot wait on
///   tasks, so the two levels cannot cycle.
pub fn execute_dag_scoped<E, F>(
    consumers: &[Vec<usize>],
    indegree: &[usize],
    home: &[usize],
    threads: usize,
    intra_op: usize,
    f: F,
) -> std::result::Result<(), E>
where
    F: Fn(usize, &ShardScope) -> std::result::Result<(), E> + Sync,
    E: Send,
{
    use std::collections::VecDeque;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Mutex;

    let n = consumers.len();
    debug_assert_eq!(indegree.len(), n);
    debug_assert_eq!(home.len(), n);
    if n == 0 {
        return Ok(());
    }
    let threads = threads.max(1);
    let registry = ShardRegistry::new(intra_op);
    let pending: Vec<AtomicUsize> = indegree.iter().map(|&d| AtomicUsize::new(d)).collect();
    let deques: Vec<Mutex<VecDeque<usize>>> =
        (0..threads).map(|_| Mutex::new(VecDeque::new())).collect();
    let injector: Mutex<VecDeque<usize>> = Mutex::new(VecDeque::new());
    // Tasks that are queued or currently running. A running task keeps its
    // +1 until after it has queued its newly-ready consumers, so
    // `outstanding == 0` with `completed < n` can only mean deadlock.
    let outstanding = AtomicUsize::new(0);
    let mut seeded = 0usize;
    for (i, &d) in indegree.iter().enumerate() {
        if d == 0 {
            seeded += 1;
            if home[i] < threads {
                deques[home[i]].lock().unwrap().push_back(i);
            } else {
                injector.lock().unwrap().push_back(i);
            }
        }
    }
    outstanding.store(seeded, Ordering::SeqCst);
    let completed = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let error: Mutex<Option<E>> = Mutex::new(None);
    // Idle parking: workers with nothing to pop (tasks or shards) wait on
    // the registry's condvar (with a timeout guarding the push-vs-sleep
    // race) instead of busy-spinning. The registry shares it so shard
    // publications wake parked workers too.
    let wake = &registry.wake;

    let worker = |w: usize| {
        loop {
            if abort.load(Ordering::SeqCst) || completed.load(Ordering::SeqCst) >= n {
                break;
            }
            // Each pop is a separate statement so at most one deque lock
            // is held at a time (invariant 4).
            let mut task = deques[w].lock().unwrap().pop_back();
            if task.is_none() {
                task = injector.lock().unwrap().pop_front();
            }
            if task.is_none() {
                for off in 1..threads {
                    let v = (w + off) % threads;
                    task = deques[v].lock().unwrap().pop_front();
                    if task.is_some() {
                        break;
                    }
                }
            }
            let Some(t) = task else {
                // No ready task: execute pending intra-op shards of tasks
                // other workers are running (nested work stealing).
                if registry.help() {
                    continue;
                }
                if outstanding.load(Ordering::SeqCst) == 0
                    && completed.load(Ordering::SeqCst) < n
                    && !abort.load(Ordering::SeqCst)
                {
                    // Nothing queued, nothing running, work remains:
                    // no task can ever become ready again.
                    panic!(
                        "execute_dag: deadlock ({} of {n} tasks completed) — \
                         cyclic or miscounted dependency structure",
                        completed.load(Ordering::SeqCst)
                    );
                }
                registry.park_timeout(std::time::Duration::from_micros(200));
                continue;
            };
            match f(t, &registry.scope()) {
                Ok(()) => {
                    for &c in &consumers[t] {
                        if pending[c].fetch_sub(1, Ordering::AcqRel) == 1 {
                            outstanding.fetch_add(1, Ordering::SeqCst);
                            deques[w].lock().unwrap().push_back(c);
                            wake.notify_all();
                        }
                    }
                    completed.fetch_add(1, Ordering::SeqCst);
                    // Release this task's running +1 only after its
                    // consumers are queued (see `outstanding` above).
                    outstanding.fetch_sub(1, Ordering::SeqCst);
                    if completed.load(Ordering::SeqCst) >= n {
                        wake.notify_all();
                    }
                }
                Err(e) => {
                    let mut slot = error.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                    drop(slot);
                    abort.store(true, Ordering::SeqCst);
                    wake.notify_all();
                    break;
                }
            }
        }
    };

    if threads == 1 {
        worker(0);
    } else {
        std::thread::scope(|scope| {
            for w in 0..threads {
                let worker = &worker;
                scope.spawn(move || worker(w));
            }
        });
    }
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }
    debug_assert_eq!(
        completed.load(Ordering::SeqCst),
        n,
        "execute_dag: workers exited with unexecuted tasks"
    );
    Ok(())
}

/// Minimal JSON value writer for structured reports (we only ever *write*
/// JSON; the artifact manifest uses a line format both sides parse).
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_f32_in_range() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn backoff_delay_doubles_then_caps() {
        use std::time::Duration;
        let base = Duration::from_millis(1);
        let cap = Duration::from_millis(16);
        assert_eq!(backoff_delay(base, cap, 0), Duration::from_millis(1));
        assert_eq!(backoff_delay(base, cap, 2), Duration::from_millis(4));
        assert_eq!(backoff_delay(base, cap, 4), Duration::from_millis(16));
        assert_eq!(backoff_delay(base, cap, 40), cap, "saturates");
        assert_eq!(backoff_delay(base, cap, 200), cap, "no shift overflow");
    }

    #[test]
    fn rng_mean_reasonable() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let mean: f32 = (0..n).map(|_| r.next_centered()).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [15.0, 20.0, 35.0, 40.0, 50.0];
        // classic nearest-rank worked example: ranks ceil(p/100 * 5)
        assert_eq!(percentile(&v, 30.0), 20.0);
        assert_eq!(percentile(&v, 40.0), 20.0);
        assert_eq!(percentile(&v, 50.0), 35.0);
        assert_eq!(percentile(&v, 100.0), 50.0);
        assert_eq!(percentile(&v, 0.0), 15.0, "p0 is the minimum");
        // single sample: every percentile is that sample
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_order_invariant_and_total() {
        let sorted = [1.0, 2.0, 3.0, 4.0];
        let shuffled = [3.0, 1.0, 4.0, 2.0];
        for p in [0.0, 25.0, 50.0, 75.0, 95.0, 99.0, 100.0] {
            assert_eq!(percentile(&sorted, p), percentile(&shuffled, p));
        }
        // out-of-range percentiles clamp instead of indexing out of bounds
        assert_eq!(percentile(&sorted, -10.0), 1.0);
        assert_eq!(percentile(&sorted, 250.0), 4.0);
        // NaN samples sort last (total order) and empty input returns NaN
        assert_eq!(percentile(&[f64::NAN, 2.0, 1.0], 50.0), 2.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn parallel_for_covers_all() {
        let hits: Vec<std::sync::atomic::AtomicUsize> =
            (0..100).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect();
        parallel_for(100, 8, |i| {
            hits[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(std::sync::atomic::Ordering::Relaxed), 1);
        }
    }

    /// Build (consumers, indegree) from a dep list, occurrence-counted.
    fn dag(deps: &[Vec<usize>]) -> (Vec<Vec<usize>>, Vec<usize>) {
        let mut consumers = vec![vec![]; deps.len()];
        for (t, ds) in deps.iter().enumerate() {
            for &d in ds {
                consumers[d].push(t);
            }
        }
        let indegree: Vec<usize> = deps.iter().map(|d| d.len()).collect();
        (consumers, indegree)
    }

    #[test]
    fn execute_dag_respects_dependencies() {
        use std::sync::atomic::{AtomicBool, Ordering};
        // 0 -> 1 -> 3, 0 -> 2 -> 3, plus a duplicate edge 2 -> 3
        let deps = vec![vec![], vec![0], vec![0], vec![1, 2, 2]];
        let (consumers, indegree) = dag(&deps);
        let done: Vec<AtomicBool> = (0..4).map(|_| AtomicBool::new(false)).collect();
        for threads in [1usize, 2, 8] {
            for d in &done {
                d.store(false, Ordering::SeqCst);
            }
            execute_dag::<(), _>(&consumers, &indegree, &[0, 0, 1, 1], threads, |t| {
                for &d in &deps[t] {
                    assert!(done[d].load(Ordering::SeqCst), "task {t} ran before dep {d}");
                }
                done[t].store(true, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
            assert!(done.iter().all(|d| d.load(Ordering::SeqCst)));
        }
    }

    #[test]
    fn execute_dag_runs_each_task_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // random-ish wide/deep DAG: task t depends on some earlier tasks
        let mut rng = Rng::seed_from_u64(42);
        let n = 400;
        let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
        for t in 0..n {
            let k = if t == 0 { 0 } else { rng.next_below(3.min(t) + 1) };
            let mut ds = Vec::new();
            for _ in 0..k {
                ds.push(rng.next_below(t));
            }
            deps.push(ds);
        }
        let (consumers, indegree) = dag(&deps);
        let home: Vec<usize> = (0..n).map(|t| t % 5).collect();
        let runs: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        execute_dag::<(), _>(&consumers, &indegree, &home, 6, |t| {
            runs[t].fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        for (t, r) in runs.iter().enumerate() {
            assert_eq!(r.load(Ordering::SeqCst), 1, "task {t}");
        }
    }

    #[test]
    fn execute_dag_propagates_errors() {
        let deps = vec![vec![], vec![0], vec![1], vec![2]];
        let (consumers, indegree) = dag(&deps);
        let r = execute_dag::<String, _>(&consumers, &indegree, &[0; 4], 4, |t| {
            if t == 1 {
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
        assert_eq!(r.unwrap_err(), "boom");
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn execute_dag_detects_miscounted_deps() {
        // indegree claims one dep, but no producer ever decrements it
        let consumers = vec![vec![]];
        let indegree = vec![1usize];
        let _ = execute_dag::<(), _>(&consumers, &indegree, &[0], 1, |_| Ok(()));
    }

    #[test]
    fn execute_dag_empty_and_single() {
        execute_dag::<(), _>(&[], &[], &[], 4, |_| Ok(())).unwrap();
        let (consumers, indegree) = dag(&[vec![]]);
        execute_dag::<(), _>(&consumers, &indegree, &[99], 4, |_| Ok(())).unwrap();
    }

    #[test]
    fn fork_join_runs_every_shard_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for threads in [1usize, 2, 8] {
            let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
            with_intra_op_pool(threads, |scope| {
                assert_eq!(scope.parallelism(), threads.max(1));
                scope.fork_join(100, |i| {
                    hits[i].fetch_add(1, Ordering::SeqCst);
                });
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "shard {i} threads {threads}");
            }
        }
    }

    #[test]
    fn serial_scope_runs_shards_inline_in_order() {
        let seen = std::sync::Mutex::new(Vec::new());
        let scope = serial_scope();
        assert_eq!(scope.parallelism(), 1);
        scope.fork_join(5, |i| seen.lock().unwrap().push(i));
        assert_eq!(*seen.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn nested_fork_join_inside_dag_tasks() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // 12 independent tasks on 4 workers, each publishing 8 shards:
        // idle workers must help without double-running any shard.
        let n = 12;
        let shards = 8;
        let consumers = vec![vec![]; n];
        let indegree = vec![0usize; n];
        let home: Vec<usize> = (0..n).map(|t| t % 4).collect();
        let hits: Vec<AtomicUsize> = (0..n * shards).map(|_| AtomicUsize::new(0)).collect();
        execute_dag_scoped::<(), _>(&consumers, &indegree, &home, 4, shards, |t, scope| {
            assert_eq!(scope.parallelism(), shards);
            scope.fork_join(shards, |s| {
                hits[t * shards + s].fetch_add(1, Ordering::SeqCst);
            });
            Ok(())
        })
        .unwrap();
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task shard {i}");
        }
    }

    #[test]
    fn fork_join_shards_fill_disjoint_ranges() {
        // The SyncPtr pattern every sharded kernel uses: each shard owns a
        // fixed chunk of one output buffer.
        let len = 10_000;
        let chunks = 16;
        let mut buf = vec![0.0f32; len];
        with_intra_op_pool(4, |scope| {
            let ptr = SyncPtr::new(buf.as_mut_ptr());
            scope.fork_join(chunks, |ci| {
                let (lo, hi) = chunk_bounds(len, chunks, ci);
                // SAFETY: [lo, hi) ranges are pairwise disjoint.
                let s = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(lo), hi - lo) };
                for (off, v) in s.iter_mut().enumerate() {
                    *v = (lo + off) as f32;
                }
            });
        });
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn pool_reuses_allocations_by_class() {
        BufferPool::reset();
        let a = BufferPool::take_filled(1000, 1.0);
        let cap = a.capacity();
        assert!(cap >= 1024); // rounded up to the class size
        BufferPool::give(a);
        assert_eq!(BufferPool::stats().resident, cap);
        // Any length in (512, 1024] lands in the same class and reuses it.
        let b = BufferPool::take(700);
        assert_eq!(b.len(), 700);
        assert_eq!(b.capacity(), cap);
        let s = BufferPool::stats();
        assert_eq!((s.takes, s.hits, s.misses), (2, 1, 1));
        assert_eq!(s.resident, 0);
        BufferPool::reset();
    }

    #[test]
    fn pool_take_filled_overwrites_stale_contents() {
        BufferPool::reset();
        BufferPool::give(vec![7.0f32; 64]);
        let v = BufferPool::take_filled(64, 0.0);
        assert!(v.iter().all(|&x| x == 0.0));
        BufferPool::reset();
    }

    #[test]
    fn pool_zero_len_and_oversize_bypass() {
        BufferPool::reset();
        let v = BufferPool::take(0);
        assert!(v.is_empty());
        BufferPool::give(v); // capacity 0: dropped, not parked
        assert_eq!(BufferPool::stats().resident, 0);
        BufferPool::reset();
    }

    #[test]
    fn pooled_vec_returns_on_drop() {
        BufferPool::reset();
        {
            let mut s = PooledVec::take(128);
            s[0] = 3.0;
            assert_eq!(s.len(), 128);
        }
        let st = BufferPool::stats();
        assert_eq!(st.gives, 1);
        assert!(st.resident >= 128);
        BufferPool::reset();
    }

    #[test]
    fn pool_class_cap_bounds_residency() {
        BufferPool::reset();
        for _ in 0..(POOL_CLASS_CAP + 5) {
            BufferPool::give(vec![0.0f32; 16]);
        }
        let st = BufferPool::stats();
        assert!(st.resident <= POOL_CLASS_CAP * 16);
        BufferPool::reset();
    }

    #[test]
    fn pool_capacity_cap_trims_burst_and_keeps_steady_state() {
        BufferPool::reset();
        // Burst: retire far more class-12 (4096-float) buffers than the
        // retained-capacity cap (8 << 12 floats = 8 buffers) admits.
        for _ in 0..20 {
            BufferPool::give(Vec::with_capacity(4096));
        }
        let st = BufferPool::stats();
        assert_eq!(st.gives, 20);
        assert_eq!(st.trimmed, 12, "8 parked, 12 trimmed");
        assert!(st.resident <= POOL_CLASS_RETAIN_X << 12, "{}", st.resident);
        // Steady state: a take/give loop inside the cap reuses buffers and
        // never trims again — residency and trim count are both flat.
        let parked = BufferPool::stats().resident;
        let trimmed = BufferPool::stats().trimmed;
        for _ in 0..50 {
            let v = BufferPool::take(4096);
            BufferPool::give(v);
        }
        let st = BufferPool::stats();
        assert_eq!(st.trimmed, trimmed, "steady state must not trim");
        assert_eq!(st.resident, parked, "steady state residency is flat");
        assert_eq!(st.misses, 0, "every steady-state take is a pool hit");
        BufferPool::reset();
    }

    #[test]
    fn json_rendering() {
        let j = Json::Obj(vec![
            ("a".into(), Json::num(1.5)),
            ("b".into(), Json::Arr(vec![Json::str("x\"y"), Json::Bool(true)])),
        ]);
        assert_eq!(j.render(), r#"{"a":1.5,"b":["x\"y",true]}"#);
    }
}

//! Small in-tree utilities replacing unavailable external crates: a
//! deterministic RNG (no `rand`), a scoped thread-pool helper and a
//! work-stealing DAG scheduler (no `rayon`/`crossbeam`), and a minimal
//! JSON *writer* for reports (no `serde_json`).

/// Deterministic SplitMix64 RNG — reproducible across runs and platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [-0.5, 0.5).
    #[inline]
    pub fn next_centered(&mut self) -> f32 {
        self.next_f32() - 0.5
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Approximate standard normal via the sum of 4 uniforms (Irwin–Hall,
    /// variance-corrected) — plenty for weight init.
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.next_f32()).sum::<f32>() - 2.0;
        s * (12.0f32 / 4.0).sqrt()
    }
}

/// Run `f(chunk_index)` for `n` chunks on up to `threads` OS threads.
/// A minimal data-parallel scatter used by the executor and benches.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Execute a dependency-counted task DAG on `threads` OS threads with
/// per-worker deques, a shared injector, and work stealing.
///
/// * `consumers[p]` lists every task that depends on `p`, **once per dep
///   occurrence** (a task reading the same producer tile through two
///   operands appears twice);
/// * `indegree[t]` is the matching occurrence count of `t`'s deps — a task
///   becomes ready exactly when its counter hits zero;
/// * `home[t]` is the preferred worker (tasks seed onto
///   `deques[home[t]]` when `home[t] < threads`, the injector otherwise);
/// * `f(t)` runs each task exactly once, after all of its deps.
///
/// Scheduling protocol (the executor's readiness/stealing invariants live
/// here; `sim::cluster` documents how they map onto task graphs):
///
/// 1. initially-ready tasks (indegree 0) are seeded to their home deque
///    or the shared injector;
/// 2. a worker pops from the **back** of its own deque (freshest first —
///    its own recent outputs are cache-hot), then from the front of the
///    injector, then steals from the **front** of other workers' deques
///    (oldest first, the classic Chase–Lev discipline);
/// 3. completing a task decrements each consumer's counter once per dep
///    edge; the worker that performs the final decrement pushes that
///    consumer onto its *own* deque (the consumer's first input is the
///    tile just produced — locality);
/// 4. at most one deque lock is ever held at a time, so stealing cannot
///    deadlock;
/// 5. workers that find nothing to pop park on a condvar with a short
///    timeout (no busy-spin); every push/completion/abort notifies;
/// 6. an `Err` from `f` aborts the run: in-flight tasks finish, nothing
///    new starts, and the first error is returned.
///
/// Any error type `E: Send` is supported. Panics if the scheduler
/// deadlocks — no task queued, none running, yet not all completed —
/// which indicates a cyclic or miscounted dependency structure (the
/// `outstanding` counter makes this state detectable: it counts tasks
/// that are queued or running, and only the completion of a running
/// task can queue new ones).
pub fn execute_dag<E, F>(
    consumers: &[Vec<usize>],
    indegree: &[usize],
    home: &[usize],
    threads: usize,
    f: F,
) -> std::result::Result<(), E>
where
    F: Fn(usize) -> std::result::Result<(), E> + Sync,
    E: Send,
{
    use std::collections::VecDeque;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Mutex;

    let n = consumers.len();
    debug_assert_eq!(indegree.len(), n);
    debug_assert_eq!(home.len(), n);
    if n == 0 {
        return Ok(());
    }
    let threads = threads.max(1);
    let pending: Vec<AtomicUsize> = indegree.iter().map(|&d| AtomicUsize::new(d)).collect();
    let deques: Vec<Mutex<VecDeque<usize>>> =
        (0..threads).map(|_| Mutex::new(VecDeque::new())).collect();
    let injector: Mutex<VecDeque<usize>> = Mutex::new(VecDeque::new());
    // Tasks that are queued or currently running. A running task keeps its
    // +1 until after it has queued its newly-ready consumers, so
    // `outstanding == 0` with `completed < n` can only mean deadlock.
    let outstanding = AtomicUsize::new(0);
    let mut seeded = 0usize;
    for (i, &d) in indegree.iter().enumerate() {
        if d == 0 {
            seeded += 1;
            if home[i] < threads {
                deques[home[i]].lock().unwrap().push_back(i);
            } else {
                injector.lock().unwrap().push_back(i);
            }
        }
    }
    outstanding.store(seeded, Ordering::SeqCst);
    let completed = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let error: Mutex<Option<E>> = Mutex::new(None);
    // Idle parking: workers with nothing to pop wait here (with a timeout
    // guarding the push-vs-sleep race) instead of busy-spinning.
    let park = Mutex::new(());
    let wake = std::sync::Condvar::new();

    let worker = |w: usize| {
        loop {
            if abort.load(Ordering::SeqCst) || completed.load(Ordering::SeqCst) >= n {
                break;
            }
            // Each pop is a separate statement so at most one deque lock
            // is held at a time (invariant 4).
            let mut task = deques[w].lock().unwrap().pop_back();
            if task.is_none() {
                task = injector.lock().unwrap().pop_front();
            }
            if task.is_none() {
                for off in 1..threads {
                    let v = (w + off) % threads;
                    task = deques[v].lock().unwrap().pop_front();
                    if task.is_some() {
                        break;
                    }
                }
            }
            let Some(t) = task else {
                if outstanding.load(Ordering::SeqCst) == 0
                    && completed.load(Ordering::SeqCst) < n
                    && !abort.load(Ordering::SeqCst)
                {
                    // Nothing queued, nothing running, work remains:
                    // no task can ever become ready again.
                    panic!(
                        "execute_dag: deadlock ({} of {n} tasks completed) — \
                         cyclic or miscounted dependency structure",
                        completed.load(Ordering::SeqCst)
                    );
                }
                let guard = park.lock().unwrap();
                let _ = wake
                    .wait_timeout(guard, std::time::Duration::from_micros(200))
                    .unwrap();
                continue;
            };
            match f(t) {
                Ok(()) => {
                    for &c in &consumers[t] {
                        if pending[c].fetch_sub(1, Ordering::AcqRel) == 1 {
                            outstanding.fetch_add(1, Ordering::SeqCst);
                            deques[w].lock().unwrap().push_back(c);
                            wake.notify_all();
                        }
                    }
                    completed.fetch_add(1, Ordering::SeqCst);
                    // Release this task's running +1 only after its
                    // consumers are queued (see `outstanding` above).
                    outstanding.fetch_sub(1, Ordering::SeqCst);
                    if completed.load(Ordering::SeqCst) >= n {
                        wake.notify_all();
                    }
                }
                Err(e) => {
                    let mut slot = error.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                    drop(slot);
                    abort.store(true, Ordering::SeqCst);
                    wake.notify_all();
                    break;
                }
            }
        }
    };

    if threads == 1 {
        worker(0);
    } else {
        std::thread::scope(|scope| {
            for w in 0..threads {
                let worker = &worker;
                scope.spawn(move || worker(w));
            }
        });
    }
    if let Some(e) = error.into_inner().unwrap() {
        return Err(e);
    }
    debug_assert_eq!(
        completed.load(Ordering::SeqCst),
        n,
        "execute_dag: workers exited with unexecuted tasks"
    );
    Ok(())
}

/// Minimal JSON value writer for structured reports (we only ever *write*
/// JSON; the artifact manifest uses a line format both sides parse).
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_f32_in_range() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let v = r.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn rng_mean_reasonable() {
        let mut r = Rng::seed_from_u64(2);
        let n = 100_000;
        let mean: f32 = (0..n).map(|_| r.next_centered()).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn parallel_for_covers_all() {
        let hits: Vec<std::sync::atomic::AtomicUsize> =
            (0..100).map(|_| std::sync::atomic::AtomicUsize::new(0)).collect();
        parallel_for(100, 8, |i| {
            hits[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(std::sync::atomic::Ordering::Relaxed), 1);
        }
    }

    /// Build (consumers, indegree) from a dep list, occurrence-counted.
    fn dag(deps: &[Vec<usize>]) -> (Vec<Vec<usize>>, Vec<usize>) {
        let mut consumers = vec![vec![]; deps.len()];
        for (t, ds) in deps.iter().enumerate() {
            for &d in ds {
                consumers[d].push(t);
            }
        }
        let indegree: Vec<usize> = deps.iter().map(|d| d.len()).collect();
        (consumers, indegree)
    }

    #[test]
    fn execute_dag_respects_dependencies() {
        use std::sync::atomic::{AtomicBool, Ordering};
        // 0 -> 1 -> 3, 0 -> 2 -> 3, plus a duplicate edge 2 -> 3
        let deps = vec![vec![], vec![0], vec![0], vec![1, 2, 2]];
        let (consumers, indegree) = dag(&deps);
        let done: Vec<AtomicBool> = (0..4).map(|_| AtomicBool::new(false)).collect();
        for threads in [1usize, 2, 8] {
            for d in &done {
                d.store(false, Ordering::SeqCst);
            }
            execute_dag::<(), _>(&consumers, &indegree, &[0, 0, 1, 1], threads, |t| {
                for &d in &deps[t] {
                    assert!(done[d].load(Ordering::SeqCst), "task {t} ran before dep {d}");
                }
                done[t].store(true, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
            assert!(done.iter().all(|d| d.load(Ordering::SeqCst)));
        }
    }

    #[test]
    fn execute_dag_runs_each_task_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // random-ish wide/deep DAG: task t depends on some earlier tasks
        let mut rng = Rng::seed_from_u64(42);
        let n = 400;
        let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
        for t in 0..n {
            let k = if t == 0 { 0 } else { rng.next_below(3.min(t) + 1) };
            let mut ds = Vec::new();
            for _ in 0..k {
                ds.push(rng.next_below(t));
            }
            deps.push(ds);
        }
        let (consumers, indegree) = dag(&deps);
        let home: Vec<usize> = (0..n).map(|t| t % 5).collect();
        let runs: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        execute_dag::<(), _>(&consumers, &indegree, &home, 6, |t| {
            runs[t].fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        for (t, r) in runs.iter().enumerate() {
            assert_eq!(r.load(Ordering::SeqCst), 1, "task {t}");
        }
    }

    #[test]
    fn execute_dag_propagates_errors() {
        let deps = vec![vec![], vec![0], vec![1], vec![2]];
        let (consumers, indegree) = dag(&deps);
        let r = execute_dag::<String, _>(&consumers, &indegree, &[0; 4], 4, |t| {
            if t == 1 {
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
        assert_eq!(r.unwrap_err(), "boom");
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn execute_dag_detects_miscounted_deps() {
        // indegree claims one dep, but no producer ever decrements it
        let consumers = vec![vec![]];
        let indegree = vec![1usize];
        let _ = execute_dag::<(), _>(&consumers, &indegree, &[0], 1, |_| Ok(()));
    }

    #[test]
    fn execute_dag_empty_and_single() {
        execute_dag::<(), _>(&[], &[], &[], 4, |_| Ok(())).unwrap();
        let (consumers, indegree) = dag(&[vec![]]);
        execute_dag::<(), _>(&consumers, &indegree, &[99], 4, |_| Ok(())).unwrap();
    }

    #[test]
    fn json_rendering() {
        let j = Json::Obj(vec![
            ("a".into(), Json::num(1.5)),
            ("b".into(), Json::Arr(vec![Json::str("x\"y"), Json::Bool(true)])),
        ]);
        assert_eq!(j.render(), r#"{"a":1.5,"b":["x\"y",true]}"#);
    }
}

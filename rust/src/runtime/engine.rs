//! Dispatching kernel engine: PJRT artifacts when available, native
//! fallback otherwise — plus per-kind hit counters so benches can report
//! how much of the hot path ran on AOT-compiled XLA kernels.

use super::native::NativeEngine;
use super::pjrt::PjrtEngine;
use super::{Backend, KernelEngine};
use crate::einsum::expr::EinSum;
use crate::error::{Error, Result};
use crate::tensor::{Tensor, TensorView};
use crate::util::ShardScope;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Composite engine implementing the [`Backend`] policy.
pub struct DispatchEngine {
    backend: Backend,
    native: NativeEngine,
    pjrt: Option<Arc<PjrtEngine>>,
    pjrt_hits: AtomicU64,
    native_hits: AtomicU64,
}

impl DispatchEngine {
    /// Build an engine for the chosen backend. `artifact_dir` is consulted
    /// only for `Auto`/`PjrtStrict`. `Auto` silently degrades to native if
    /// the artifacts are missing (e.g. `make artifacts` not yet run) or if
    /// this build lacks an executing PJRT runtime (see
    /// [`PjrtEngine::runtime_available`]).
    pub fn new(backend: Backend, artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let pjrt = match backend {
            Backend::Native => None,
            Backend::Auto => {
                if PjrtEngine::runtime_available() {
                    PjrtEngine::load(&artifact_dir).ok().map(Arc::new)
                } else {
                    None
                }
            }
            Backend::PjrtStrict => {
                if !PjrtEngine::runtime_available() {
                    return Err(Error::Runtime(
                        "PjrtStrict requested but this build has no executing PJRT \
                         runtime (xla FFI absent); use Backend::Native or Auto"
                            .into(),
                    ));
                }
                Some(Arc::new(PjrtEngine::load(&artifact_dir)?))
            }
        };
        Ok(DispatchEngine {
            backend,
            native: NativeEngine::new(),
            pjrt,
            pjrt_hits: AtomicU64::new(0),
            native_hits: AtomicU64::new(0),
        })
    }

    /// Native-only engine (no artifact directory needed).
    pub fn native() -> Self {
        DispatchEngine {
            backend: Backend::Native,
            native: NativeEngine::new(),
            pjrt: None,
            pjrt_hits: AtomicU64::new(0),
            native_hits: AtomicU64::new(0),
        }
    }

    /// (pjrt, native) kernel-invocation counters.
    pub fn hit_counts(&self) -> (u64, u64) {
        (
            self.pjrt_hits.load(Ordering::Relaxed),
            self.native_hits.load(Ordering::Relaxed),
        )
    }

    /// Whether a PJRT engine is attached.
    pub fn has_pjrt(&self) -> bool {
        self.pjrt.is_some()
    }
}

impl KernelEngine for DispatchEngine {
    fn eval(&self, op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor> {
        self.eval_scoped(op, inputs, &crate::util::serial_scope())
    }

    /// PJRT kernels are opaque AOT binaries and run as one shard; only
    /// the native fallback forwards the scope for intra-op sharding.
    fn eval_scoped(&self, op: &EinSum, inputs: &[&Tensor], scope: &ShardScope) -> Result<Tensor> {
        if let Some(pjrt) = &self.pjrt {
            match pjrt.try_eval(op, inputs)? {
                Some(t) => {
                    self.pjrt_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(t);
                }
                None => {
                    if self.backend == Backend::PjrtStrict {
                        return Err(Error::Artifact(format!(
                            "PjrtStrict: no artifact for {op} on {:?}",
                            inputs.iter().map(|t| t.shape()).collect::<Vec<_>>()
                        )));
                    }
                }
            }
        }
        self.native_hits.fetch_add(1, Ordering::Relaxed);
        self.native.eval_scoped(op, inputs, scope)
    }

    fn eval_view(&self, op: &EinSum, inputs: &[&TensorView]) -> Result<Tensor> {
        self.eval_view_scoped(op, inputs, &crate::util::serial_scope())
    }

    /// View tiles stay strided on the native path; only a PJRT artifact
    /// hit forces materialization (AOT kernels take contiguous buffers).
    fn eval_view_scoped(
        &self,
        op: &EinSum,
        inputs: &[&TensorView],
        scope: &ShardScope,
    ) -> Result<Tensor> {
        if let Some(pjrt) = &self.pjrt {
            let owned: Vec<Tensor> = inputs.iter().map(|v| v.to_tensor()).collect();
            let refs: Vec<&Tensor> = owned.iter().collect();
            match pjrt.try_eval(op, &refs)? {
                Some(t) => {
                    self.pjrt_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(t);
                }
                None => {
                    if self.backend == Backend::PjrtStrict {
                        return Err(Error::Artifact(format!(
                            "PjrtStrict: no artifact for {op} on {:?}",
                            inputs.iter().map(|t| t.shape()).collect::<Vec<_>>()
                        )));
                    }
                }
            }
        }
        self.native_hits.fetch_add(1, Ordering::Relaxed);
        self.native.eval_view_scoped(op, inputs, scope)
    }

    /// Same dispatch as [`eval_view_scoped`](Self::eval_view_scoped): a
    /// PJRT artifact hit evaluates the bare kernel and applies the fused
    /// epilogue on the host (artifacts are compiled without it); misses
    /// fall through to the native engine's in-place epilogue path.
    fn eval_view_epilogue_scoped(
        &self,
        op: &EinSum,
        inputs: &[&TensorView],
        epilogue: &[crate::einsum::expr::UnaryOp],
        scope: &ShardScope,
    ) -> Result<Tensor> {
        if let Some(pjrt) = &self.pjrt {
            let owned: Vec<Tensor> = inputs.iter().map(|v| v.to_tensor()).collect();
            let refs: Vec<&Tensor> = owned.iter().collect();
            match pjrt.try_eval(op, &refs)? {
                Some(mut t) => {
                    self.pjrt_hits.fetch_add(1, Ordering::Relaxed);
                    crate::runtime::gemm::apply_epilogue(t.data_mut(), epilogue);
                    return Ok(t);
                }
                None => {
                    if self.backend == Backend::PjrtStrict {
                        return Err(Error::Artifact(format!(
                            "PjrtStrict: no artifact for {op} on {:?}",
                            inputs.iter().map(|t| t.shape()).collect::<Vec<_>>()
                        )));
                    }
                }
            }
        }
        self.native_hits.fetch_add(1, Ordering::Relaxed);
        self.native
            .eval_view_epilogue_scoped(op, inputs, epilogue, scope)
    }

    fn name(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native",
            Backend::Auto => "auto(pjrt+native)",
            Backend::PjrtStrict => "pjrt-strict",
        }
    }
}

//! Out-of-core tile storage: a per-worker memory-budgeted [`TileStore`]
//! with a disk spill tier and graph-driven prefetch.
//!
//! The paper's decomposition story assumes the decomposed tensors fit on
//! the `p` workers. This module extends the real executor to the case
//! where they do not (ROADMAP item 5, the regime `sim/memory.rs` could
//! previously only *model*): every intermediate tile lives in the store,
//! and when a worker's resident bytes would exceed its
//! [`MemoryBudget`], cold tiles are **evicted** — intermediates to a disk
//! tier (plain `std::fs` files of little-endian `f32` bytes, staged
//! through the [`crate::util::BufferPool`]), input tiles by dropping
//! their zero-copy view (the dense input lives in driver memory, so
//! "spilling" one models releasing its device copy). A consumer that
//! needs an evicted tile **faults** it back in: disk tiles are read into
//! a pooled buffer, input tiles are re-sliced — both restore the exact
//! logical bytes, so budgeted runs are bitwise-identical to unbudgeted
//! ones (spill/fault is pure data movement; kernels are
//! stride-independent by the [`crate::runtime::KernelEngine`] contract).
//!
//! # State machine
//!
//! Each tile is in one of three states:
//!
//! ```text
//!            publish                 evict (budget pressure)
//!   Empty ──────────────▶ Resident ─────────────────────────▶ Spilled
//!     ▲                      │  ▲                                │
//!     │   reclaim / purge    │  │          fault-in / prefetch   │
//!     └──────────────────────┘  └────────────────────────────────┘
//! ```
//!
//! `Spilled` is `Disk` for owned intermediates and `Input` for
//! pre-sliced input views. `reclaim` (last-consumer buffer recycling)
//! and `purge` (worker death) return a tile to `Empty` from either
//! state.
//!
//! # Invariants
//!
//! * **peak ≤ budget**: bytes are *reserved* under a per-worker lock
//!   before any tile becomes resident, evicting until the reservation
//!   fits (or failing with a typed
//!   [`ExecCause::BudgetExceeded`](crate::error::ExecCause) when even
//!   evicting everything unpinned cannot make room — the single-task
//!   working set does not fit). Concurrent releases only shrink
//!   residency, so the tracked per-worker peak can never exceed the
//!   budget.
//! * **pinned tiles are never evicted**: the executor pins a task's
//!   dependencies (faulting them in as needed) before running it and
//!   unpins after, so kernel reads always see resident views.
//! * **determinism**: eviction picks the unpinned resident tile with the
//!   *farthest next use* (the smallest not-yet-completed consumer id,
//!   larger = colder; ties broken toward the larger task id). The
//!   victim choice affects only data movement, never values.
//! * **zero unbudgeted overhead**: with no budget, publish is a slot
//!   write plus residency/peak accounting (the per-worker
//!   `peak_resident_bytes` ledger is tracked even when unbudgeted);
//!   nothing is ever evicted, pinned, or staged, and every spill counter
//!   stays zero, so a fault-free unbudgeted ledger is byte-identical to
//!   the pre-spill executor's.
//!
//! # Prefetch
//!
//! The task graph is frozen at placement time, so the next-k tasks of
//! each worker are known while the current one runs. The executor asks
//! the store to prefetch their spilled dependencies into free headroom
//! (never evicting for a prefetch), overlapping read-back with compute.

use crate::error::{Error, ExecCause, Result};
use crate::tensor::{Tensor, TensorView};
use crate::util::BufferPool;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// A task's result slot: the produced tile as a zero-copy view. Slots
/// are `Option` so the executor can *take* a tile back once every
/// consumer has read it and recycle its buffer — and so the [`TileStore`]
/// can evict a cold tile to the spill tier (or worker death can drop
/// every tile homed on the dead worker).
pub(crate) type ResultSlot = Mutex<Option<TensorView>>;

/// Lock a result slot, converting mutex poisoning (a panicking sibling
/// thread) into a typed, recoverable
/// [`ExecCause::LockPoisoned`](crate::error::ExecCause) instead of
/// propagating the panic into an unrelated task.
pub(crate) fn lock_slot(
    results: &[ResultSlot],
    i: usize,
) -> Result<MutexGuard<'_, Option<TensorView>>> {
    results[i].lock().map_err(|_| {
        Error::exec_failure(Some(i), 0, ExecCause::LockPoisoned { what: "result slot" })
    })
}

/// Per-worker device-memory budget for real execution, threaded through
/// `Cluster` / `DriverConfig` / `Session` / the CLI's `--mem-budget-mb`.
///
/// The budget bounds the bytes of tile data resident on any one worker
/// at any instant; tiles beyond it spill to disk and fault back on
/// demand (see the module docs). Budgeted runs return bitwise-identical
/// outputs to unbudgeted ones.
///
/// ```
/// use eindecomp::runtime::spill::MemoryBudget;
/// let b = MemoryBudget::per_worker_mb(64);
/// assert_eq!(b.bytes_per_worker(), 64 << 20);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoryBudget {
    bytes: u64,
}

impl MemoryBudget {
    /// A budget of `bytes` per worker. Zero means "unlimited" at the
    /// configuration layer and is normalized away before reaching the
    /// store (see `Cluster::with_mem_budget`).
    pub fn per_worker_bytes(bytes: u64) -> Self {
        MemoryBudget { bytes }
    }

    /// A budget of `mb` MiB per worker (the CLI's `--mem-budget-mb`).
    pub fn per_worker_mb(mb: u64) -> Self {
        MemoryBudget { bytes: mb << 20 }
    }

    /// The per-worker cap in bytes.
    pub fn bytes_per_worker(&self) -> u64 {
        self.bytes
    }

    /// True when the cap is zero, i.e. the "unlimited" sentinel.
    pub fn is_unlimited(&self) -> bool {
        self.bytes == 0
    }
}

/// Where an evicted tile's contents live.
enum SpillState {
    /// Not spilled (resident, or never produced / reclaimed).
    None,
    /// Owned intermediate written to the disk tier as LE `f32` bytes.
    Disk { path: PathBuf, shape: Vec<usize>, len: usize },
    /// Pre-sliced input view dropped; fault-in re-slices the dense
    /// input (O(1), no disk involved).
    Input,
}

/// Uniquifies spill directories across stores within one process.
static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

/// How many upcoming same-worker tasks the executor prefetches spilled
/// dependencies for (see the module docs).
pub(crate) const PREFETCH_WINDOW: usize = 2;

/// The per-run tile store: owns residency accounting, the spill tier,
/// pinning, and the eviction policy for one execution's result slots.
/// Created by `Cluster::run_lowered_modeled_opts` next to the slots and
/// dropped with them (removing its spill directory).
pub(crate) struct TileStore {
    /// Per-worker byte cap; `None` = unlimited (accounting only).
    budget: Option<u64>,
    /// Bytes currently resident per worker.
    resident: Vec<AtomicU64>,
    /// High-water mark per worker (tracked even when unbudgeted).
    peak: Vec<AtomicU64>,
    /// Which worker each tile's bytes are charged to, as `worker + 1`
    /// (`0` = not charged, i.e. not resident).
    charged: Vec<AtomicUsize>,
    /// Pin counts: a pinned tile is never chosen for eviction.
    pins: Vec<AtomicUsize>,
    /// Per-tile spill state. Lock order: a tile's meta before its slot;
    /// eviction acquires *other* tiles' metas only via `try_lock`, so
    /// holding one meta while reserving can never deadlock.
    meta: Vec<Mutex<SpillState>>,
    /// Consumer task ids per tile, ascending — the eviction policy's
    /// next-use oracle.
    consumers: Vec<Vec<usize>>,
    /// Which tasks are input tiles (spill = drop the view, no disk).
    input_tile: Vec<bool>,
    /// Serializes reservations per worker so check-then-charge is atomic
    /// (the peak ≤ budget invariant).
    reserve_locks: Vec<Mutex<()>>,
    /// Lazily-created spill directory (unique per store).
    dir: Mutex<Option<PathBuf>>,
    seq: u64,
    /// Bytes evicted off workers (disk writes + dropped input views).
    spill_bytes: AtomicU64,
    /// Tiles faulted back in (demand + prefetch; disk reads + input
    /// re-slices).
    spill_faults: AtomicU64,
    /// Wall time spent writing and demand-reading spill files
    /// (prefetch reads overlap compute and are not charged).
    stall_ns: AtomicU64,
}

impl TileStore {
    pub(crate) fn new(
        workers: usize,
        budget: Option<MemoryBudget>,
        consumers: Vec<Vec<usize>>,
        input_tile: Vec<bool>,
    ) -> Self {
        let n = consumers.len();
        let workers = workers.max(1);
        let budget = budget
            .filter(|b| !b.is_unlimited())
            .map(|b| b.bytes_per_worker());
        TileStore {
            budget,
            resident: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            peak: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            charged: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            pins: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            meta: (0..n).map(|_| Mutex::new(SpillState::None)).collect(),
            consumers,
            input_tile,
            reserve_locks: (0..workers).map(|_| Mutex::new(())).collect(),
            dir: Mutex::new(None),
            seq: STORE_SEQ.fetch_add(1, Ordering::Relaxed),
            spill_bytes: AtomicU64::new(0),
            spill_faults: AtomicU64::new(0),
            stall_ns: AtomicU64::new(0),
        }
    }

    /// True when a finite per-worker budget is armed.
    pub(crate) fn budgeted(&self) -> bool {
        self.budget.is_some()
    }

    // ---- counters -------------------------------------------------------

    pub(crate) fn spill_bytes(&self) -> u64 {
        self.spill_bytes.load(Ordering::Relaxed)
    }

    pub(crate) fn spill_faults(&self) -> u64 {
        self.spill_faults.load(Ordering::Relaxed)
    }

    pub(crate) fn spill_stall_s(&self) -> f64 {
        self.stall_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Per-worker resident high-water marks (bytes).
    pub(crate) fn peak_resident(&self) -> Vec<u64> {
        self.peak.iter().map(|p| p.load(Ordering::Relaxed)).collect()
    }

    // ---- accounting -----------------------------------------------------

    fn bump_peak(&self, w: usize, now: u64) {
        let p = &self.peak[w];
        let mut cur = p.load(Ordering::Relaxed);
        while now > cur {
            match p.compare_exchange_weak(cur, now, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    /// Charge `need` bytes to worker `w` without a budget check — the
    /// unbudgeted fast path (nothing is ever evicted, so residency only
    /// needs tracking, not enforcement).
    fn charge_unbudgeted(&self, w: usize, need: u64) {
        let now = self.resident[w].fetch_add(need, Ordering::AcqRel) + need;
        self.bump_peak(w, now);
    }

    fn uncharge(&self, w: usize, bytes: u64) {
        self.resident[w].fetch_sub(bytes, Ordering::AcqRel);
    }

    /// Reserve `need` bytes on worker `w`, evicting cold tiles until the
    /// reservation fits. The check-then-charge runs under the worker's
    /// reserve lock; concurrent releases only shrink residency, so once
    /// this returns `Ok` the worker's residency (and therefore its peak)
    /// is `<= budget`. Fails typed when even a fully-evicted worker
    /// cannot host `need` more bytes.
    fn reserve(&self, results: &[ResultSlot], w: usize, need: u64, completed: &[AtomicBool]) -> Result<()> {
        let Some(budget) = self.budget else {
            self.charge_unbudgeted(w, need);
            return Ok(());
        };
        let _guard = self.reserve_locks[w].lock().map_err(|_| {
            Error::exec_failure(None, 0, ExecCause::LockPoisoned { what: "reserve lock" })
        })?;
        loop {
            let r = self.resident[w].load(Ordering::Acquire);
            if r.saturating_add(need) <= budget {
                let now = self.resident[w].fetch_add(need, Ordering::AcqRel) + need;
                self.bump_peak(w, now);
                return Ok(());
            }
            if !self.evict_one(results, w, completed)? {
                return Err(Error::exec_failure(
                    None,
                    0,
                    ExecCause::BudgetExceeded {
                        worker: w,
                        needed_bytes: need,
                        budget_bytes: budget,
                    },
                ));
            }
        }
    }

    /// Reserve `need` bytes on `w` only if they fit in free headroom —
    /// the prefetch path, which must never evict (and never block on a
    /// busy reserve lock). Returns whether the reservation was taken.
    fn try_reserve_headroom(&self, w: usize, need: u64) -> bool {
        let Some(budget) = self.budget else { return false };
        let Ok(_guard) = self.reserve_locks[w].try_lock() else {
            return false;
        };
        let r = self.resident[w].load(Ordering::Acquire);
        if r.saturating_add(need) > budget {
            return false;
        }
        let now = self.resident[w].fetch_add(need, Ordering::AcqRel) + need;
        self.bump_peak(w, now);
        true
    }

    // ---- eviction -------------------------------------------------------

    /// Evict one unpinned tile charged to worker `w`, chosen
    /// deterministically by farthest next use. Returns `false` only when
    /// no candidate exists (every resident tile is pinned or mid-flight);
    /// `true` means "progress was made or the race should be retried".
    fn evict_one(&self, results: &[ResultSlot], w: usize, completed: &[AtomicBool]) -> Result<bool> {
        // Deterministic victim: the tile whose earliest pending consumer
        // is farthest away (usize::MAX = no pending consumer, coldest of
        // all — e.g. a kept output tile waiting for assembly).
        let mut best: Option<(usize, usize)> = None;
        for i in 0..self.meta.len() {
            if self.charged[i].load(Ordering::Acquire) != w + 1
                || self.pins[i].load(Ordering::Acquire) != 0
            {
                continue;
            }
            let next = self.consumers[i]
                .iter()
                .copied()
                .find(|&c| !completed[c].load(Ordering::Acquire))
                .unwrap_or(usize::MAX);
            if best.map_or(true, |b| (next, i) > b) {
                best = Some((next, i));
            }
        }
        let Some((_, i)) = best else { return Ok(false) };
        // try_lock, not lock: a demand fault holds this meta while
        // waiting on our reserve lock (pinned tiles are filtered above,
        // but the pin may have landed after the scan) — blocking here
        // would deadlock. A failed try means the tile is busy; report
        // progress so the caller rescans.
        let Ok(mut meta) = self.meta[i].try_lock() else {
            std::thread::yield_now();
            return Ok(true);
        };
        if self.pins[i].load(Ordering::Acquire) != 0
            || self.charged[i].load(Ordering::Acquire) != w + 1
        {
            std::thread::yield_now();
            return Ok(true); // pinned or migrated since the scan; rescan
        }
        let mut slot = lock_slot(results, i)?;
        let Some(view) = slot.take() else {
            // charged but slot still empty: a publish is mid-flight;
            // treat as a race and rescan
            drop(meta);
            drop(slot);
            std::thread::yield_now();
            return Ok(true);
        };
        drop(slot); // readers re-check state under `meta`, held below
        self.charged[i].store(0, Ordering::Release);
        let bytes = view.bytes() as u64;
        self.uncharge(w, bytes);
        if self.input_tile[i] {
            // Input views alias the caller's dense tensor; dropping the
            // view releases the modeled device copy. Fault-in re-slices.
            *meta = SpillState::Input;
            view.recycle();
        } else {
            let t0 = Instant::now();
            let path = self.spill_path(i)?;
            write_tile(&path, &view)?;
            self.stall_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            *meta = SpillState::Disk {
                path,
                shape: view.shape().to_vec(),
                len: view.len(),
            };
            view.recycle();
        }
        self.spill_bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(true)
    }

    fn spill_path(&self, i: usize) -> Result<PathBuf> {
        let mut dir = self.dir.lock().map_err(|_| {
            Error::exec_failure(None, 0, ExecCause::LockPoisoned { what: "spill dir" })
        })?;
        if dir.is_none() {
            let p = std::env::temp_dir().join(format!(
                "eindecomp-spill-{}-{}",
                std::process::id(),
                self.seq
            ));
            std::fs::create_dir_all(&p)?;
            *dir = Some(p);
        }
        Ok(dir.as_ref().expect("just created").join(format!("tile-{i}.bin")))
    }

    // ---- publish / reclaim ----------------------------------------------

    /// Install task `i`'s freshly-computed tile, reserving its bytes on
    /// worker `w` first. Returns whether this call won the slot (a
    /// concurrent recovery walk may have published bitwise-identical
    /// bytes already; the loser's buffer is recycled and its reservation
    /// released).
    pub(crate) fn publish(
        &self,
        results: &[ResultSlot],
        i: usize,
        w: usize,
        view: TensorView,
        completed: &[AtomicBool],
    ) -> Result<bool> {
        let need = view.bytes() as u64;
        self.reserve(results, w, need, completed)?;
        let mut slot = lock_slot(results, i)?;
        if slot.is_none() {
            self.charged[i].store(w + 1, Ordering::Release);
            *slot = Some(view);
            Ok(true)
        } else {
            drop(slot);
            self.uncharge(w, need);
            view.recycle();
            Ok(false)
        }
    }

    /// Release tile `i` entirely: take and recycle its resident view (if
    /// any), delete its spill file (if any), and return it to `Empty`.
    /// Used by last-consumer reclamation and the end-of-run drain;
    /// idempotent.
    pub(crate) fn reclaim(&self, results: &[ResultSlot], i: usize) -> Result<()> {
        self.purge(results, i).map(|_| ())
    }

    /// [`Self::reclaim`], reporting whether the tile held any state
    /// (resident *or* spilled) — worker death uses this to know whether
    /// a completed flag needs rolling back.
    pub(crate) fn purge(&self, results: &[ResultSlot], i: usize) -> Result<bool> {
        let mut meta = self.meta[i].lock().map_err(|_| {
            Error::exec_failure(Some(i), 0, ExecCause::LockPoisoned { what: "tile meta" })
        })?;
        let mut present = false;
        if let Some(v) = lock_slot(results, i)?.take() {
            let c = self.charged[i].swap(0, Ordering::AcqRel);
            if c > 0 {
                self.uncharge(c - 1, v.bytes() as u64);
            }
            v.recycle();
            present = true;
        }
        match std::mem::replace(&mut *meta, SpillState::None) {
            SpillState::None => {}
            SpillState::Disk { path, .. } => {
                let _ = std::fs::remove_file(path);
                present = true;
            }
            SpillState::Input => present = true,
        }
        Ok(present)
    }

    // ---- fault-in / pinning ---------------------------------------------

    /// True when tile `i` currently lives in the spill tier. A spilled
    /// tile *was produced* — recovery must fault it back, not recompute
    /// it.
    pub(crate) fn is_spilled(&self, i: usize) -> bool {
        self.meta[i]
            .lock()
            .map(|m| !matches!(*m, SpillState::None))
            .unwrap_or(false)
    }

    /// If tile `i` is spilled, fault it back onto worker `w` (reserving
    /// room, evicting colder tiles as needed). `restore_input` re-slices
    /// input tiles. Returns whether the tile is now known resident
    /// (faulted here or already back); `false` means it was not spilled.
    pub(crate) fn fault_if_spilled(
        &self,
        results: &[ResultSlot],
        i: usize,
        w: usize,
        completed: &[AtomicBool],
        restore_input: &dyn Fn() -> Result<TensorView>,
    ) -> Result<bool> {
        let mut meta = self.meta[i].lock().map_err(|_| {
            Error::exec_failure(Some(i), 0, ExecCause::LockPoisoned { what: "tile meta" })
        })?;
        match &*meta {
            SpillState::None => Ok(lock_slot(results, i)?.is_some()),
            SpillState::Disk { path, shape, len } => {
                self.reserve(results, w, (*len * 4) as u64, completed)?;
                let t0 = Instant::now();
                let data = read_tile(path, *len)?;
                self.stall_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                let _ = std::fs::remove_file(path);
                let tile = Tensor::new(shape.clone(), data)?.into_view();
                self.charged[i].store(w + 1, Ordering::Release);
                *lock_slot(results, i)? = Some(tile);
                *meta = SpillState::None;
                self.spill_faults.fetch_add(1, Ordering::Relaxed);
                Ok(true)
            }
            SpillState::Input => {
                let view = restore_input()?;
                self.reserve(results, w, view.bytes() as u64, completed)?;
                self.charged[i].store(w + 1, Ordering::Release);
                *lock_slot(results, i)? = Some(view);
                *meta = SpillState::None;
                self.spill_faults.fetch_add(1, Ordering::Relaxed);
                Ok(true)
            }
        }
    }

    /// Pin tile `i` resident on behalf of a consumer running on worker
    /// `w`, faulting it in first if it was evicted. While pinned the
    /// tile cannot be evicted; callers must [`Self::unpin`]. Only
    /// meaningful under a budget (the executor skips pinning entirely
    /// when unbudgeted). Fails with a typed `MissingDep` when the tile
    /// is neither resident nor spilled (a racing worker death purged it
    /// — the caller's retry loop recomputes lineage).
    pub(crate) fn pin(
        &self,
        results: &[ResultSlot],
        i: usize,
        w: usize,
        completed: &[AtomicBool],
        restore_input: &dyn Fn() -> Result<TensorView>,
    ) -> Result<()> {
        self.pins[i].fetch_add(1, Ordering::SeqCst);
        loop {
            // An evictor that takes the slot lock after this point sees
            // the pin and skips; one that won the race leaves the tile
            // spilled, which the fault below undoes.
            if lock_slot(results, i)?.is_some() {
                return Ok(());
            }
            match self.fault_if_spilled(results, i, w, completed, restore_input) {
                Ok(true) => continue, // re-check the slot (it may already be gone again)
                Ok(false) => {
                    self.pins[i].fetch_sub(1, Ordering::SeqCst);
                    return Err(Error::exec_failure(
                        None,
                        0,
                        ExecCause::MissingDep { dep: i },
                    ));
                }
                Err(e) => {
                    self.pins[i].fetch_sub(1, Ordering::SeqCst);
                    return Err(e);
                }
            }
        }
    }

    pub(crate) fn unpin(&self, i: usize) {
        self.pins[i].fetch_sub(1, Ordering::SeqCst);
    }

    /// Best-effort prefetch: if tile `i` is spilled and worker `w` has
    /// free headroom for it, fault it back now so the consumer finds it
    /// resident. Never evicts, never blocks on contended locks, and
    /// swallows nothing: I/O errors still surface (a broken spill tier
    /// should fail the run, not silently degrade).
    pub(crate) fn prefetch(
        &self,
        results: &[ResultSlot],
        i: usize,
        w: usize,
        restore_input: &dyn Fn() -> Result<TensorView>,
    ) -> Result<()> {
        if !self.budgeted() {
            return Ok(());
        }
        // try_lock: if the tile is mid-fault or mid-evict, skip it.
        let Ok(mut meta) = self.meta[i].try_lock() else {
            return Ok(());
        };
        match &*meta {
            SpillState::None => Ok(()),
            SpillState::Disk { path, shape, len } => {
                if !self.try_reserve_headroom(w, (*len * 4) as u64) {
                    return Ok(());
                }
                // Prefetch reads overlap compute; not charged to stall.
                let data = read_tile(path, *len)?;
                let _ = std::fs::remove_file(path);
                let tile = Tensor::new(shape.clone(), data)?.into_view();
                self.charged[i].store(w + 1, Ordering::Release);
                *lock_slot(results, i)? = Some(tile);
                *meta = SpillState::None;
                self.spill_faults.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            SpillState::Input => {
                let view = restore_input()?;
                if !self.try_reserve_headroom(w, view.bytes() as u64) {
                    return Ok(());
                }
                self.charged[i].store(w + 1, Ordering::Release);
                *lock_slot(results, i)? = Some(view);
                *meta = SpillState::None;
                self.spill_faults.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
        }
    }
}

impl Drop for TileStore {
    fn drop(&mut self) {
        if let Ok(dir) = self.dir.lock() {
            if let Some(p) = dir.as_ref() {
                let _ = std::fs::remove_dir_all(p);
            }
        }
    }
}

/// Serialize a tile's logical contents as little-endian `f32` bytes.
/// Strides never reach the disk format, so a restored tile is a
/// contiguous tensor with the exact same logical values — bitwise-safe
/// because every kernel path is stride-independent.
fn write_tile(path: &std::path::Path, view: &TensorView) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    match view.as_contiguous() {
        Some(s) => write_floats(&mut w, s)?,
        None => {
            // Strided view: stage a contiguous copy through the pool.
            let t = view.to_tensor();
            write_floats(&mut w, t.data())?;
            t.recycle();
        }
    }
    w.flush()?;
    Ok(())
}

fn write_floats<W: Write>(w: &mut W, s: &[f32]) -> Result<()> {
    for v in s {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Read `len` little-endian `f32`s back into a pooled buffer — the exact
/// bytes `write_tile` wrote (f32 → LE bytes → f32 round-trips
/// losslessly, NaN payloads included).
fn read_tile(path: &std::path::Path, len: usize) -> Result<Vec<f32>> {
    let f = std::fs::File::open(path)?;
    let mut r = BufReader::new(f);
    let mut data = BufferPool::take(len);
    let mut b = [0u8; 4];
    for v in data.iter_mut() {
        r.read_exact(&mut b)?;
        *v = f32::from_le_bytes(b);
    }
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slots(n: usize) -> Vec<ResultSlot> {
        (0..n).map(|_| Mutex::new(None)).collect()
    }

    fn flags(n: usize) -> Vec<AtomicBool> {
        (0..n).map(|_| AtomicBool::new(false)).collect()
    }

    fn tile(vals: &[f32]) -> TensorView {
        Tensor::new(vec![vals.len()], vals.to_vec()).unwrap().into_view()
    }

    #[test]
    fn budget_zero_is_unlimited() {
        assert!(MemoryBudget::per_worker_mb(0).is_unlimited());
        assert!(!MemoryBudget::per_worker_mb(1).is_unlimited());
        assert_eq!(MemoryBudget::per_worker_mb(2).bytes_per_worker(), 2 << 20);
        // the store normalizes the sentinel away
        let s = TileStore::new(1, Some(MemoryBudget::per_worker_bytes(0)), vec![vec![]], vec![false]);
        assert!(!s.budgeted());
    }

    #[test]
    fn unbudgeted_publish_tracks_peak_without_spilling() {
        let results = slots(2);
        let done = flags(2);
        let store = TileStore::new(1, None, vec![vec![], vec![]], vec![false, false]);
        assert!(store.publish(&results, 0, 0, tile(&[1.0; 8]), &done).unwrap());
        assert!(store.publish(&results, 1, 0, tile(&[2.0; 8]), &done).unwrap());
        assert_eq!(store.peak_resident(), vec![64]);
        assert_eq!(store.spill_bytes(), 0);
        store.reclaim(&results, 0).unwrap();
        store.reclaim(&results, 1).unwrap();
        assert_eq!(store.peak_resident(), vec![64]); // high-water sticks
        assert_eq!(store.spill_faults(), 0);
    }

    #[test]
    fn eviction_spills_cold_tile_and_fault_restores_bytes() {
        // budget fits exactly one 8-float tile
        let budget = MemoryBudget::per_worker_bytes(32);
        let results = slots(3);
        let done = flags(3);
        // tile 0 consumed by task 2 (pending), tile 1 by task 2 as well
        let store = TileStore::new(
            1,
            Some(budget),
            vec![vec![2], vec![2], vec![]],
            vec![false, false, false],
        );
        let vals0: Vec<f32> = (0..8).map(|i| i as f32 + 0.5).collect();
        assert!(store.publish(&results, 0, 0, tile(&vals0), &done).unwrap());
        // publishing tile 1 forces tile 0 to disk
        assert!(store.publish(&results, 1, 0, tile(&[9.0; 8]), &done).unwrap());
        assert!(store.is_spilled(0));
        assert_eq!(store.spill_bytes(), 32);
        assert!(lock_slot(&results, 0).unwrap().is_none());
        // every tracked peak respects the budget
        assert!(store.peak_resident().iter().all(|&p| p <= 32));
        // fault tile 0 back (evicting tile 1 in turn) and check bytes
        let restore = || -> Result<TensorView> { unreachable!("not an input tile") };
        store
            .pin(&results, 0, 0, &done, &restore)
            .unwrap();
        let got = lock_slot(&results, 0).unwrap().clone().unwrap();
        assert_eq!(got.to_vec(), vals0);
        assert!(store.is_spilled(1));
        assert_eq!(store.spill_faults(), 1);
        assert!(store.spill_stall_s() >= 0.0);
        store.unpin(0);
        assert!(store.peak_resident().iter().all(|&p| p <= 32));
    }

    #[test]
    fn pinned_tiles_are_not_evicted_and_overflow_is_typed() {
        let budget = MemoryBudget::per_worker_bytes(32);
        let results = slots(2);
        let done = flags(2);
        let store = TileStore::new(1, Some(budget), vec![vec![1], vec![]], vec![false, false]);
        let restore = || -> Result<TensorView> { unreachable!() };
        assert!(store.publish(&results, 0, 0, tile(&[1.0; 8]), &done).unwrap());
        store.pin(&results, 0, 0, &done, &restore).unwrap();
        // the only resident tile is pinned: a second 32-byte tile cannot fit
        let err = store
            .publish(&results, 1, 0, tile(&[2.0; 8]), &done)
            .unwrap_err();
        let cause = &err.as_exec().expect("typed").cause;
        assert!(
            matches!(cause, ExecCause::BudgetExceeded { worker: 0, needed_bytes: 32, budget_bytes: 32 }),
            "{cause:?}"
        );
        store.unpin(0);
        // unpinned, the same publish now succeeds by evicting tile 0
        assert!(store.publish(&results, 1, 0, tile(&[2.0; 8]), &done).unwrap());
        assert!(store.is_spilled(0));
    }

    #[test]
    fn input_tiles_spill_by_dropping_and_restore_by_reslicing() {
        let budget = MemoryBudget::per_worker_bytes(16);
        let results = slots(2);
        let done = flags(2);
        let store = TileStore::new(1, Some(budget), vec![vec![1], vec![]], vec![true, false]);
        let src = Tensor::new(vec![4], vec![3.0, 4.0, 5.0, 6.0]).unwrap();
        assert!(store
            .publish(&results, 0, 0, src.slice_view(&[0], &[4]).unwrap(), &done)
            .unwrap());
        // a 4-float intermediate displaces the input view — no disk file
        assert!(store.publish(&results, 1, 0, tile(&[7.0; 4]), &done).unwrap());
        assert!(store.is_spilled(0));
        assert_eq!(store.spill_bytes(), 16);
        let restore = || src.slice_view(&[0], &[4]);
        store.pin(&results, 0, 0, &done, &restore).unwrap();
        let got = lock_slot(&results, 0).unwrap().clone().unwrap();
        assert_eq!(got.to_vec(), vec![3.0, 4.0, 5.0, 6.0]);
        store.unpin(0);
    }

    #[test]
    fn eviction_prefers_farthest_next_use() {
        let budget = MemoryBudget::per_worker_bytes(64);
        let results = slots(4);
        let done = flags(4);
        // tile 0's next pending consumer is task 2; tile 1's is task 3
        // (farther) — tile 1 is the colder one and must go first.
        let store = TileStore::new(
            1,
            Some(budget),
            vec![vec![2], vec![3], vec![], vec![]],
            vec![false; 4],
        );
        assert!(store.publish(&results, 0, 0, tile(&[1.0; 8]), &done).unwrap());
        assert!(store.publish(&results, 1, 0, tile(&[2.0; 8]), &done).unwrap());
        assert!(store.publish(&results, 2, 0, tile(&[3.0; 8]), &done).unwrap());
        assert!(store.is_spilled(1));
        assert!(!store.is_spilled(0));
    }

    #[test]
    fn purge_reports_presence_and_clears_both_tiers() {
        let results = slots(2);
        let done = flags(2);
        let store = TileStore::new(
            1,
            Some(MemoryBudget::per_worker_bytes(32)),
            vec![vec![1], vec![]],
            vec![false, false],
        );
        assert!(store.publish(&results, 0, 0, tile(&[1.0; 8]), &done).unwrap());
        assert!(store.publish(&results, 1, 0, tile(&[2.0; 8]), &done).unwrap());
        assert!(store.is_spilled(0)); // evicted to disk by tile 1
        assert!(store.purge(&results, 0).unwrap()); // spilled counts as present
        assert!(!store.is_spilled(0));
        assert!(store.purge(&results, 1).unwrap()); // resident counts as present
        assert!(!store.purge(&results, 1).unwrap()); // idempotent: now empty
    }

    #[test]
    fn prefetch_fills_headroom_only() {
        let budget = MemoryBudget::per_worker_bytes(64);
        let results = slots(3);
        let done = flags(3);
        let store = TileStore::new(
            1,
            Some(budget),
            vec![vec![2], vec![2], vec![]],
            vec![false; 3],
        );
        let vals: Vec<f32> = (0..8).map(|i| 2.0 * i as f32).collect();
        assert!(store.publish(&results, 0, 0, tile(&vals), &done).unwrap());
        assert!(store.publish(&results, 1, 0, tile(&[1.0; 8]), &done).unwrap());
        // force tile 0 out by filling the second half of the budget
        assert!(store.publish(&results, 2, 0, tile(&[4.0; 8]), &done).unwrap());
        let spilled = if store.is_spilled(0) { 0 } else { 1 };
        let restore = || -> Result<TensorView> { unreachable!() };
        // no headroom: prefetch is a no-op
        store.prefetch(&results, spilled, 0, &restore).unwrap();
        assert!(store.is_spilled(spilled));
        // free a tile, then prefetch succeeds into the fresh headroom
        store.reclaim(&results, 2).unwrap();
        store.prefetch(&results, spilled, 0, &restore).unwrap();
        assert!(!store.is_spilled(spilled));
        assert_eq!(
            lock_slot(&results, spilled).unwrap().as_ref().unwrap().len(),
            8
        );
        assert!(store.peak_resident().iter().all(|&p| p <= 64));
    }
}

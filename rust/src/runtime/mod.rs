//! Kernel execution engines.
//!
//! The TRA join invokes a *kernel function* `K` on pairs of sub-tensors
//! (paper §4.2). A [`KernelEngine`] evaluates an arbitrary EinSum
//! expression on concrete tile tensors. Two engines are provided:
//!
//! * [`native::NativeEngine`] — pure-rust evaluator with a batched-GEMM
//!   fast path (the in-tree packed kernel in [`gemm`]) for Mul/Sum
//!   contractions and a generic loop-nest fallback for the extended
//!   `(+)`/`(x)` operator space. Used as the always-available fallback,
//!   as a second correctness oracle, and — through
//!   [`KernelEngine::eval_scoped`] — as the intra-op-parallel hot path.
//! * [`pjrt::PjrtEngine`] — loads AOT-compiled HLO artifacts produced by
//!   the python/jax/Pallas compile path (`make artifacts`) and executes
//!   them on the PJRT CPU client. Python never runs on this path.
//!
//! [`engine::DispatchEngine`] composes the two: PJRT when an artifact with
//! a matching (kind, shape) exists, native otherwise.

pub mod engine;
pub mod gemm;
pub mod native;
pub mod pjrt;
pub mod spill;

use crate::einsum::expr::EinSum;
use crate::error::Result;
use crate::tensor::{Tensor, TensorView};
use crate::util::ShardScope;

/// Which kernel backend to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust kernels only.
    Native,
    /// AOT PJRT kernels where artifacts exist, native fallback otherwise.
    Auto,
    /// PJRT only — error if no artifact matches (used by artifact tests).
    PjrtStrict,
}

/// A kernel engine evaluates one EinSum expression on concrete tensors.
/// This is the paper's kernel function `K` generalized to all vertex kinds.
pub trait KernelEngine: Send + Sync {
    fn eval(&self, op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor>;

    /// Evaluate with an intra-op [`ShardScope`]: engines that can split a
    /// kernel into independent shards (row blocks of a GEMM, batch
    /// entries of a BMM, chunks of an elementwise map) publish them
    /// through `scope` so idle executor workers help. Results must be
    /// bitwise-identical to [`KernelEngine::eval`] — sharding is a
    /// scheduling choice, never a numerics choice. The default ignores
    /// the scope and evaluates serially.
    fn eval_scoped(&self, op: &EinSum, inputs: &[&Tensor], scope: &ShardScope) -> Result<Tensor> {
        let _ = scope;
        self.eval(op, inputs)
    }

    /// Evaluate on strided [`TensorView`] tiles — the zero-copy hot path
    /// the TRA join and the executor use. Engines that can read through
    /// strides (the native engine) override this; the default
    /// materializes each view and calls [`eval`](Self::eval). Results
    /// must be bitwise-identical to evaluating the materialized tiles.
    fn eval_view(&self, op: &EinSum, inputs: &[&TensorView]) -> Result<Tensor> {
        let owned: Vec<Tensor> = inputs.iter().map(|v| v.to_tensor()).collect();
        let refs: Vec<&Tensor> = owned.iter().collect();
        self.eval(op, &refs)
    }

    /// [`eval_view`](Self::eval_view) with an intra-op [`ShardScope`].
    fn eval_view_scoped(
        &self,
        op: &EinSum,
        inputs: &[&TensorView],
        scope: &ShardScope,
    ) -> Result<Tensor> {
        let owned: Vec<Tensor> = inputs.iter().map(|v| v.to_tensor()).collect();
        let refs: Vec<&Tensor> = owned.iter().collect();
        self.eval_scoped(op, &refs, scope)
    }

    /// [`eval_view_scoped`](Self::eval_view_scoped) followed by a fused
    /// pointwise epilogue (the `fuse-epilogue` IR pass's kernel hook —
    /// see `runtime/gemm.rs`'s `alpha`/`beta` contract for where the
    /// epilogue sits). Ops apply in order to every output element and
    /// must be bitwise-identical to running each retired map kernel
    /// separately. The default evaluates then rewrites the freshly-owned
    /// output in place; engines with a cheaper path (the native engine
    /// reuses its GEMM epilogue loop) override.
    fn eval_view_epilogue_scoped(
        &self,
        op: &EinSum,
        inputs: &[&TensorView],
        epilogue: &[crate::einsum::expr::UnaryOp],
        scope: &ShardScope,
    ) -> Result<Tensor> {
        let mut t = self.eval_view_scoped(op, inputs, scope)?;
        for e in epilogue {
            for v in t.data_mut().iter_mut() {
                *v = e.apply(*v);
            }
        }
        Ok(t)
    }

    /// Human-readable identifier for reports.
    fn name(&self) -> &'static str;
}

pub use engine::DispatchEngine;
pub use native::NativeEngine;
pub use pjrt::PjrtEngine;
pub use spill::MemoryBudget;

//! Kernel execution engines.
//!
//! The TRA join invokes a *kernel function* `K` on pairs of sub-tensors
//! (paper §4.2). A [`KernelEngine`] evaluates an arbitrary EinSum
//! expression on concrete tile tensors. Two engines are provided:
//!
//! * [`native::NativeEngine`] — pure-rust evaluator with a batched-GEMM
//!   fast path (`matrixmultiply`) for Mul/Sum contractions and a generic
//!   loop-nest fallback for the extended `(+)`/`(x)` operator space. Used
//!   as the always-available fallback and as a second correctness oracle.
//! * [`pjrt::PjrtEngine`] — loads AOT-compiled HLO artifacts produced by
//!   the python/jax/Pallas compile path (`make artifacts`) and executes
//!   them on the PJRT CPU client. Python never runs on this path.
//!
//! [`engine::DispatchEngine`] composes the two: PJRT when an artifact with
//! a matching (kind, shape) exists, native otherwise.

pub mod engine;
pub mod gemm;
pub mod native;
pub mod pjrt;

use crate::einsum::expr::EinSum;
use crate::error::Result;
use crate::tensor::Tensor;

/// Which kernel backend to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-rust kernels only.
    Native,
    /// AOT PJRT kernels where artifacts exist, native fallback otherwise.
    Auto,
    /// PJRT only — error if no artifact matches (used by artifact tests).
    PjrtStrict,
}

/// A kernel engine evaluates one EinSum expression on concrete tensors.
/// This is the paper's kernel function `K` generalized to all vertex kinds.
pub trait KernelEngine: Send + Sync {
    fn eval(&self, op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor>;

    /// Human-readable identifier for reports.
    fn name(&self) -> &'static str;
}

pub use engine::DispatchEngine;
pub use native::NativeEngine;
pub use pjrt::PjrtEngine;

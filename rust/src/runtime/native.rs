//! Native (pure-rust) kernel engine.
//!
//! Mirrors the paper's CPU kernel structure (§9.1): *(1) unpack the input
//! tensors, (2) call a batch matrix multiply, (3) re-pack the result* — here
//! "unpack" is an axis permutation onto the canonical `[batch, m, k]` /
//! `[batch, k, n]` layout and the BMM is the in-tree [`super::gemm`]. EinSums
//! that do not fit the BMM pattern (non-Mul joins, non-Sum aggregations,
//! labels private to one operand) fall back to a generic loop nest over the
//! full iteration space, which implements the extended EinSum semantics
//! exactly.
//!
//! # Intra-op sharding
//!
//! Every evaluation path accepts a [`ShardScope`] (via
//! [`eval_einsum_scoped`]) and splits itself into independent shards that
//! idle executor workers steal: the BMM path shards across the batch
//! dimension or (for small batches) across GEMM row blocks, the generic
//! loop nest and the unary reduction shard over the leading index-space
//! dimension when it maps to an output label, and pure elementwise maps
//! chunk their buffer. All shard splits are chosen deterministically from
//! the problem shape and write disjoint output regions in the serial
//! kernel's per-cell order, so sharded results are **bitwise-identical**
//! to serial ones for every intra-op degree (`tests/gemm_parallel.rs`).

use super::KernelEngine;
use crate::einsum::expr::{AggOp, EinSum, JoinOp, UnaryOp};
use crate::einsum::label::{project, Label, LabelList};
use crate::error::{Error, Result};
use crate::tensor::{index_space, strides_of, Tensor};
use crate::util::{chunk_bounds, serial_scope, ShardScope, SyncPtr, SHARD_MIN};

/// Pure-rust kernel engine. Stateless and cheap to clone.
#[derive(Clone, Debug, Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        NativeEngine
    }
}

impl KernelEngine for NativeEngine {
    fn eval(&self, op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor> {
        eval_einsum(op, inputs)
    }

    fn eval_scoped(&self, op: &EinSum, inputs: &[&Tensor], scope: &ShardScope) -> Result<Tensor> {
        eval_einsum_scoped(op, inputs, scope)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Evaluate an EinSum on dense tensors (serial).
pub fn eval_einsum(op: &EinSum, inputs: &[&Tensor]) -> Result<Tensor> {
    eval_einsum_scoped(op, inputs, &serial_scope())
}

/// Evaluate an EinSum on dense tensors, sharding the hot loops through
/// `scope` (see the module docs for which paths shard and why the result
/// is bitwise-identical to [`eval_einsum`]).
pub fn eval_einsum_scoped(op: &EinSum, inputs: &[&Tensor], scope: &ShardScope) -> Result<Tensor> {
    match op {
        EinSum::Input => Err(Error::InvalidEinsum(
            "Input vertices are not evaluated".into(),
        )),
        EinSum::Unary { lx, lz, op: u, agg } => {
            if inputs.len() != 1 {
                return Err(Error::InvalidEinsum("unary op needs 1 input".into()));
            }
            eval_unary(lx, lz, *u, *agg, inputs[0], scope)
        }
        EinSum::Binary {
            lx,
            ly,
            lz,
            join,
            agg,
        } => {
            if inputs.len() != 2 {
                return Err(Error::InvalidEinsum("binary op needs 2 inputs".into()));
            }
            eval_binary(lx, ly, lz, *join, *agg, inputs[0], inputs[1], scope)
        }
    }
}

/// Unary: map + optional reduction.
fn eval_unary(
    lx: &LabelList,
    lz: &LabelList,
    u: UnaryOp,
    agg: AggOp,
    x: &Tensor,
    scope: &ShardScope,
) -> Result<Tensor> {
    if x.rank() != lx.len() {
        return Err(Error::Shape(format!(
            "unary: tensor rank {} vs labels {lx:?}",
            x.rank()
        )));
    }
    let bz = project(x.shape(), lz, lx);
    // Fast path: pure map / transpose (no reduction).
    if lz.len() == lx.len() {
        let perm: Vec<usize> = lz
            .iter()
            .map(|l| lx.iter().position(|m| m == l).unwrap())
            .collect();
        let mut t = x.permute(&perm)?;
        if !matches!(u, UnaryOp::Identity) {
            let data = t.data_mut();
            let p = scope.parallelism();
            if p > 1 && data.len() >= SHARD_MIN {
                // Elementwise map: any chunking is bitwise-identical;
                // chunk bounds are still fixed by (len, p) for clarity.
                let len = data.len();
                let ptr = SyncPtr::new(data.as_mut_ptr());
                scope.fork_join(p, |ci| {
                    let (lo, hi) = chunk_bounds(len, p, ci);
                    // SAFETY: [lo, hi) chunks are pairwise disjoint.
                    let s = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(lo), hi - lo) };
                    for v in s {
                        *v = u.apply(*v);
                    }
                });
            } else {
                for v in data {
                    *v = u.apply(*v);
                }
            }
        }
        return Ok(t);
    }
    // Reduction path: iterate I(b_X), accumulate into output.
    let mut out = Tensor::full(&bz, agg.identity());
    let out_strides = strides_of(&bz);
    // position of each lz label within lx
    let zpos: Vec<usize> = lz
        .iter()
        .map(|l| lx.iter().position(|m| m == l).unwrap())
        .collect();
    let xdata = x.data();
    let p = scope.parallelism();
    // Shard over the leading input dimension when it survives into the
    // output: distinct leading coordinates then touch distinct output
    // cells (disjoint writes), and each cell's accumulation order stays
    // exactly the serial row-major order (bitwise-identical).
    let dim0_in_out = !lx.is_empty() && lz.contains(&lx[0]);
    if p > 1 && dim0_in_out && x.shape()[0] >= 2 && x.len() >= SHARD_MIN {
        let d0 = x.shape()[0];
        let rest: Vec<usize> = x.shape()[1..].to_vec();
        let rest_len: usize = rest.iter().product();
        let shards = p.min(d0);
        let optr = SyncPtr::new(out.data_mut().as_mut_ptr());
        scope.fork_join(shards, |s| {
            let (lo, hi) = chunk_bounds(d0, shards, s);
            for i0 in lo..hi {
                for (r, ridx) in index_space(&rest).enumerate() {
                    let flat = i0 * rest_len + r;
                    let mut o = 0usize;
                    for (st, &pz) in out_strides.iter().zip(&zpos) {
                        o += st * if pz == 0 { i0 } else { ridx[pz - 1] };
                    }
                    // SAFETY: o depends injectively on i0 for fixed ridx
                    // (lx[0] is an output coordinate), so shards write
                    // disjoint cells.
                    unsafe {
                        let cell = optr.get().add(o);
                        *cell = agg.combine(*cell, u.apply(xdata[flat]));
                    }
                }
            }
        });
        return Ok(out);
    }
    let out_data = out.data_mut();
    for (flat, idx) in index_space(x.shape()).enumerate() {
        let mut o = 0usize;
        for (s, &p) in out_strides.iter().zip(&zpos) {
            o += s * idx[p];
        }
        out_data[o] = agg.combine(out_data[o], u.apply(xdata[flat]));
    }
    Ok(out)
}

/// Label classification for the BMM fast path.
struct BmmPlan {
    batch: LabelList,
    m: LabelList,
    n: LabelList,
    k: LabelList,
}

/// Classify labels as batch (X,Y,Z), m (X,Z), n (Y,Z), k (X,Y). Returns
/// `None` if any label falls outside those classes (e.g. appears in only
/// one operand), which the generic path handles.
fn bmm_plan(lx: &LabelList, ly: &LabelList, lz: &LabelList) -> Option<BmmPlan> {
    let mut plan = BmmPlan {
        batch: vec![],
        m: vec![],
        n: vec![],
        k: vec![],
    };
    let in_x = |l: &Label| lx.contains(l);
    let in_y = |l: &Label| ly.contains(l);
    let in_z = |l: &Label| lz.contains(l);
    let mut seen: Vec<Label> = vec![];
    for l in lx.iter().chain(ly.iter()) {
        if seen.contains(l) {
            continue;
        }
        seen.push(*l);
        match (in_x(l), in_y(l), in_z(l)) {
            (true, true, true) => plan.batch.push(*l),
            (true, false, true) => plan.m.push(*l),
            (false, true, true) => plan.n.push(*l),
            (true, true, false) => plan.k.push(*l),
            _ => return None,
        }
    }
    Some(plan)
}

/// Binary EinSum evaluation.
#[allow(clippy::too_many_arguments)]
fn eval_binary(
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    join: JoinOp,
    agg: AggOp,
    x: &Tensor,
    y: &Tensor,
    scope: &ShardScope,
) -> Result<Tensor> {
    if x.rank() != lx.len() || y.rank() != ly.len() {
        return Err(Error::Shape(format!(
            "binary: ranks {}/{} vs labels {lx:?}/{ly:?}",
            x.rank(),
            y.rank()
        )));
    }
    // shared labels must agree on size
    for (i, l) in lx.iter().enumerate() {
        if let Some(j) = ly.iter().position(|m| m == l) {
            if x.shape()[i] != y.shape()[j] {
                return Err(Error::Shape(format!(
                    "label {l}: {} vs {}",
                    x.shape()[i],
                    y.shape()[j]
                )));
            }
        }
    }
    // GEMM fast path: Mul/Sum with a clean batch/m/n/k split.
    if join == JoinOp::Mul && agg == AggOp::Sum {
        if let Some(plan) = bmm_plan(lx, ly, lz) {
            return eval_bmm(&plan, lx, ly, lz, x, y, scope);
        }
    }
    eval_binary_generic_scoped(lx, ly, lz, join, agg, x, y, scope)
}

/// Permute-to-BMM path: X -> [B, M, K], Y -> [B, K, N], sgemm per batch,
/// result [B, M, N] -> permute to l_Z order.
///
/// Intra-op sharding: a batch dimension at least as wide as the scope's
/// fan-out shards across batch entries (disjoint `[b, m, n]` slabs,
/// serial kernel per slab); smaller batches run
/// [`super::gemm::sgemm_scoped`] per entry, sharding GEMM row blocks
/// instead. Both splits are bitwise-
/// identical to the serial loop because the per-entry kernel is.
fn eval_bmm(
    plan: &BmmPlan,
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    x: &Tensor,
    y: &Tensor,
    scope: &ShardScope,
) -> Result<Tensor> {
    let dim_of_x = |l: &Label| x.shape()[lx.iter().position(|m| m == l).unwrap()];
    let dim_of_y = |l: &Label| y.shape()[ly.iter().position(|m| m == l).unwrap()];
    let b: usize = plan.batch.iter().map(dim_of_x).product();
    let m: usize = plan.m.iter().map(dim_of_x).product();
    let k: usize = plan.k.iter().map(dim_of_x).product();
    let n: usize = plan.n.iter().map(dim_of_y).product();

    // canonical label orders
    let x_order: LabelList = plan
        .batch
        .iter()
        .chain(plan.m.iter())
        .chain(plan.k.iter())
        .copied()
        .collect();
    let y_order: LabelList = plan
        .batch
        .iter()
        .chain(plan.k.iter())
        .chain(plan.n.iter())
        .copied()
        .collect();
    let perm_x: Vec<usize> = x_order
        .iter()
        .map(|l| lx.iter().position(|m2| m2 == l).unwrap())
        .collect();
    let perm_y: Vec<usize> = y_order
        .iter()
        .map(|l| ly.iter().position(|m2| m2 == l).unwrap())
        .collect();
    let xc = x.permute(&perm_x)?; // [B.., M.., K..] row-major == [b, m, k]
    let yc = y.permute(&perm_y)?; // [b, k, n]

    let mut out = vec![0.0f32; b * m * n];
    let xd = xc.data();
    let yd = yc.data();
    let p = scope.parallelism();
    if p > 1 && b >= p && b * m * k * n >= SHARD_MIN {
        // Wide batch: at most p shards, each a contiguous batch range
        // running the serial GEMM per entry (bounded fork-join overhead,
        // matching every other sharded path's p-way split).
        let optr = SyncPtr::new(out.as_mut_ptr());
        scope.fork_join(p, |s| {
            let (blo, bhi) = chunk_bounds(b, p, s);
            let base = optr.get();
            for bi in blo..bhi {
                let xo = &xd[bi * m * k..(bi + 1) * m * k];
                let yo = &yd[bi * k * n..(bi + 1) * k * n];
                // SAFETY: batch slabs [bi*m*n, (bi+1)*m*n) are disjoint
                // across the disjoint batch ranges.
                let oo = unsafe { std::slice::from_raw_parts_mut(base.add(bi * m * n), m * n) };
                super::gemm::sgemm(m, k, n, 1.0, xo, yo, 0.0, oo);
            }
        });
    } else {
        // Narrow batch (typically b == 1 after decomposition): shard the
        // GEMM's M row blocks instead.
        for bi in 0..b {
            let xo = &xd[bi * m * k..(bi + 1) * m * k];
            let yo = &yd[bi * k * n..(bi + 1) * k * n];
            let oo = &mut out[bi * m * n..(bi + 1) * m * n];
            super::gemm::sgemm_scoped(m, k, n, 1.0, xo, yo, 0.0, oo, scope);
        }
    }
    // canonical output label order: [batch, m, n]
    let z_canon: LabelList = plan
        .batch
        .iter()
        .chain(plan.m.iter())
        .chain(plan.n.iter())
        .copied()
        .collect();
    let z_shape_canon: Vec<usize> = plan
        .batch
        .iter()
        .map(dim_of_x)
        .chain(plan.m.iter().map(dim_of_x))
        .chain(plan.n.iter().map(dim_of_y))
        .collect();
    let t = Tensor::new(z_shape_canon, out)?;
    // permute canonical -> requested lz order
    let perm_z: Vec<usize> = lz
        .iter()
        .map(|l| z_canon.iter().position(|m2| m2 == l).unwrap())
        .collect();
    t.permute(&perm_z)
}

/// Generic loop nest: iterate the joint index space of all unique labels,
/// apply the join scalar function, aggregate into the output cell. Exact
/// for every `(+)`/`(x)` pair, including broadcast joins where one operand
/// indexes a subset of the labels. Serial oracle for the BMM fast path —
/// production callers go through the scoped form below.
#[cfg(test)]
fn eval_binary_generic(
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    join: JoinOp,
    agg: AggOp,
    x: &Tensor,
    y: &Tensor,
) -> Result<Tensor> {
    eval_binary_generic_scoped(lx, ly, lz, join, agg, x, y, &serial_scope())
}

/// [`eval_binary_generic`] with intra-op sharding: when the *leading*
/// unique label maps to an output coordinate, the iteration splits over
/// that label's range. Each shard then writes a disjoint set of output
/// cells, and every cell still receives its contributions in the serial
/// row-major order (its leading coordinate is fixed), so the result is
/// bitwise-identical to the serial nest. A leading label that is reduced
/// away (no disjoint split exists along it) falls back to serial.
#[allow(clippy::too_many_arguments)]
fn eval_binary_generic_scoped(
    lx: &LabelList,
    ly: &LabelList,
    lz: &LabelList,
    join: JoinOp,
    agg: AggOp,
    x: &Tensor,
    y: &Tensor,
    scope: &ShardScope,
) -> Result<Tensor> {
    let uniq = crate::einsum::label::concat_dedup(lx, ly);
    // bound of each unique label
    let ubound: Vec<usize> = uniq
        .iter()
        .map(|l| {
            lx.iter()
                .position(|m| m == l)
                .map(|i| x.shape()[i])
                .unwrap_or_else(|| y.shape()[ly.iter().position(|m| m == l).unwrap()])
        })
        .collect();
    let bz = project(&ubound, lz, &uniq);
    let mut out = Tensor::full(&bz, agg.identity());

    // Strides of x/y/out with respect to the joint index (per unique label).
    let xs = strides_of(x.shape());
    let ys = strides_of(y.shape());
    let zs = strides_of(&bz);
    let stride_for = |labels_of: &LabelList, strides: &[usize], l: &Label| -> usize {
        labels_of
            .iter()
            .position(|m| m == l)
            .map(|i| strides[i])
            .unwrap_or(0)
    };
    let jx: Vec<usize> = uniq.iter().map(|l| stride_for(lx, &xs, l)).collect();
    let jy: Vec<usize> = uniq.iter().map(|l| stride_for(ly, &ys, l)).collect();
    let jz: Vec<usize> = uniq.iter().map(|l| stride_for(lz, &zs, l)).collect();

    let xd = x.data();
    let yd = y.data();
    let rank = uniq.len();
    if ubound.iter().any(|&b| b == 0) {
        return Ok(out);
    }
    if rank == 0 {
        let od = out.data_mut();
        od[0] = agg.combine(od[0], join.apply(xd[0], yd[0]));
        return Ok(out);
    }
    let total: usize = ubound.iter().product();
    let p = scope.parallelism();
    // Output strides are never 0, so jz[0] != 0 iff uniq[0] is in l_Z.
    let od = SyncPtr::new(out.data_mut().as_mut_ptr());
    if p > 1 && jz[0] != 0 && ubound[0] >= 2 && total >= SHARD_MIN {
        let shards = p.min(ubound[0]);
        scope.fork_join(shards, |s| {
            let (lo, hi) = chunk_bounds(ubound[0], shards, s);
            // SAFETY: uniq[0] is an output coordinate, so disjoint
            // leading ranges write disjoint output cells.
            unsafe { generic_nest(lo, hi, &ubound, &jx, &jy, &jz, xd, yd, od.get(), join, agg) };
        });
    } else {
        let hi = ubound[0];
        // SAFETY: single caller, exclusive access to the output buffer.
        unsafe { generic_nest(0, hi, &ubound, &jx, &jy, &jz, xd, yd, od.get(), join, agg) };
    }
    Ok(out)
}

/// Odometer over the joint index space with the leading dimension
/// restricted to `[lo, hi)`, maintaining the three flat offsets
/// incrementally.
///
/// # Safety
///
/// `od` must be valid for the whole output buffer, and concurrent callers
/// must use disjoint `[lo, hi)` ranges whose cells do not overlap (which
/// holds exactly when `jz[0] != 0`, i.e. the leading unique label is an
/// output coordinate).
#[allow(clippy::too_many_arguments)]
unsafe fn generic_nest(
    lo: usize,
    hi: usize,
    ubound: &[usize],
    jx: &[usize],
    jy: &[usize],
    jz: &[usize],
    xd: &[f32],
    yd: &[f32],
    od: *mut f32,
    join: JoinOp,
    agg: AggOp,
) {
    if lo >= hi {
        return;
    }
    let rank = ubound.len();
    let mut idx = vec![0usize; rank];
    idx[0] = lo;
    let (mut ox, mut oy, mut oz) = (lo * jx[0], lo * jy[0], lo * jz[0]);
    loop {
        *od.add(oz) = agg.combine(*od.add(oz), join.apply(xd[ox], yd[oy]));
        // increment
        let mut d = rank;
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            idx[d] += 1;
            ox += jx[d];
            oy += jy[d];
            oz += jz[d];
            let bound = if d == 0 { hi } else { ubound[d] };
            if idx[d] < bound {
                break;
            }
            if d == 0 {
                return;
            }
            // reset dimension d
            ox -= jx[d] * ubound[d];
            oy -= jy[d] * ubound[d];
            oz -= jz[d] * ubound[d];
            idx[d] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::label::labels;

    fn l(s: &str) -> LabelList {
        labels(s)
    }

    #[test]
    fn matmul_matches_manual() {
        let x = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let y = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]).unwrap();
        let op = EinSum::contraction(l("i j"), l("j k"), l("i k"));
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        assert_eq!(z.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_transposed_output() {
        let x = Tensor::random(&[3, 4], 1);
        let y = Tensor::random(&[4, 5], 2);
        let zik = eval_einsum(
            &EinSum::contraction(l("i j"), l("j k"), l("i k")),
            &[&x, &y],
        )
        .unwrap();
        let zki = eval_einsum(
            &EinSum::contraction(l("i j"), l("j k"), l("k i")),
            &[&x, &y],
        )
        .unwrap();
        assert_eq!(zki.shape(), &[5, 3]);
        assert!(zki.permute(&[1, 0]).unwrap().allclose(&zik, 1e-5, 1e-6));
    }

    #[test]
    fn batch_matmul_sum_out_batch() {
        // Paper example: Z_ik <- sum_{b,j} X_ijb Y_jbk
        let x = Tensor::random(&[3, 4, 2], 1);
        let y = Tensor::random(&[4, 2, 5], 2);
        let op = EinSum::contraction(l("i j b"), l("j b k"), l("i k"));
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        assert_eq!(z.shape(), &[3, 5]);
        // manual check at one cell
        let mut want = 0.0;
        for j in 0..4 {
            for b in 0..2 {
                want += x.at(&[1, j, b]) * y.at(&[j, b, 3]);
            }
        }
        assert!((z.at(&[1, 3]) - want).abs() < 1e-4);
    }

    #[test]
    fn generic_vs_bmm_agree() {
        // Force the generic path by wrapping Mul/Sum in a contraction the
        // planner *can* BMM, then compare against the generic evaluator
        // called directly.
        let x = Tensor::random(&[4, 6], 3);
        let y = Tensor::random(&[6, 3], 4);
        let generic =
            eval_binary_generic(&l("i j"), &l("j k"), &l("i k"), JoinOp::Mul, AggOp::Sum, &x, &y)
                .unwrap();
        let fast = eval_einsum(
            &EinSum::contraction(l("i j"), l("j k"), l("i k")),
            &[&x, &y],
        )
        .unwrap();
        assert!(generic.allclose(&fast, 1e-5, 1e-6));
    }

    #[test]
    fn l2_distance_einsum() {
        // Z_ik <- sum_j (X_ij - Y_jk)^2 — paper's squared-L2 example.
        let x = Tensor::random(&[3, 4], 5);
        let y = Tensor::random(&[4, 2], 6);
        let op = EinSum::Binary {
            lx: l("i j"),
            ly: l("j k"),
            lz: l("i k"),
            join: JoinOp::SquaredDiff,
            agg: AggOp::Sum,
        };
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        let mut want = 0.0;
        for j in 0..4 {
            let d = x.at(&[2, j]) - y.at(&[j, 1]);
            want += d * d;
        }
        assert!((z.at(&[2, 1]) - want).abs() < 1e-4);
    }

    #[test]
    fn linf_distance_einsum() {
        // Z_ik <- max_j |X_ij - Y_jk| — paper's L-inf example.
        let x = Tensor::random(&[3, 4], 7);
        let y = Tensor::random(&[4, 2], 8);
        let op = EinSum::Binary {
            lx: l("i j"),
            ly: l("j k"),
            lz: l("i k"),
            join: JoinOp::AbsDiff,
            agg: AggOp::Max,
        };
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        let want = (0..4)
            .map(|j| (x.at(&[0, j]) - y.at(&[j, 0])).abs())
            .fold(f32::NEG_INFINITY, f32::max);
        assert!((z.at(&[0, 0]) - want).abs() < 1e-5);
    }

    #[test]
    fn broadcast_join_divide() {
        // Y_ij <- E_ij / S_i
        let e = Tensor::random(&[3, 4], 9);
        let s = Tensor::full(&[3], 2.0);
        let op = EinSum::Binary {
            lx: l("i j"),
            ly: l("i"),
            lz: l("i j"),
            join: JoinOp::Div,
            agg: AggOp::Sum,
        };
        let z = eval_einsum(&op, &[&e, &s]).unwrap();
        assert!((z.at(&[1, 2]) - e.at(&[1, 2]) / 2.0).abs() < 1e-6);
    }

    #[test]
    fn unary_map_and_reduce() {
        let x = Tensor::new(vec![2, 3], vec![1., -2., 3., -4., 5., -6.]).unwrap();
        let relu = eval_einsum(&EinSum::map(l("i j"), UnaryOp::Relu), &[&x]).unwrap();
        assert_eq!(relu.data(), &[1., 0., 3., 0., 5., 0.]);
        let rowmax = eval_einsum(&EinSum::reduce(l("i j"), l("i"), AggOp::Max), &[&x]).unwrap();
        assert_eq!(rowmax.data(), &[3., 5.]);
        let colsum = eval_einsum(&EinSum::reduce(l("i j"), l("j"), AggOp::Sum), &[&x]).unwrap();
        assert_eq!(colsum.data(), &[-3., 3., -3.]);
    }

    #[test]
    fn unary_transpose_with_map() {
        let x = Tensor::random(&[2, 3, 4], 10);
        let op = EinSum::Unary {
            lx: l("a b c"),
            lz: l("c a b"),
            op: UnaryOp::Scale(2.0),
            agg: AggOp::Sum,
        };
        let z = eval_einsum(&op, &[&x]).unwrap();
        assert_eq!(z.shape(), &[4, 2, 3]);
        assert!((z.at(&[3, 1, 0]) - 2.0 * x.at(&[1, 0, 3])).abs() < 1e-6);
    }

    #[test]
    fn x_only_label_reduced() {
        // Z_k <- sum_{i,j} X_ij * Y_jk — i appears only in X, not in Z:
        // falls off the BMM plan, exercised via the generic path.
        let x = Tensor::random(&[3, 4], 11);
        let y = Tensor::random(&[4, 2], 12);
        let op = EinSum::contraction(l("i j"), l("j k"), l("k"));
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        let mut want = 0.0;
        for i in 0..3 {
            for j in 0..4 {
                want += x.at(&[i, j]) * y.at(&[j, 1]);
            }
        }
        assert!((z.at(&[1]) - want).abs() < 1e-4);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let x = Tensor::zeros(&[3, 4]);
        let y = Tensor::zeros(&[5, 2]);
        let op = EinSum::contraction(l("i j"), l("j k"), l("i k"));
        assert!(eval_einsum(&op, &[&x, &y]).is_err());
    }

    #[test]
    fn rank1_dot_product() {
        let x = Tensor::new(vec![3], vec![1., 2., 3.]).unwrap();
        let y = Tensor::new(vec![3], vec![4., 5., 6.]).unwrap();
        let op = EinSum::contraction(l("i"), l("i"), vec![]);
        let z = eval_einsum(&op, &[&x, &y]).unwrap();
        assert_eq!(z.rank(), 0);
        assert_eq!(z.at(&[]), 32.0);
    }
}
